"""AdamW (Loshchilov & Hutter, 2019) over the flat parameter list.

The paper trains every model with AdamW at a fixed learning rate of 0.002
(§7).  Decoupled weight decay is applied only to parameters whose manifest
entry sets ``decay`` (matrices / embeddings — not biases, LayerNorm gains or
the (a, b) taps), matching standard GPT-2 practice.

State is two moment lists ``m``/``v`` shaped like the parameters plus the
integer step counter, which the rust coordinator owns and feeds back each
step (it is also the dropout seed source, so a resumed run is bit-exact).
"""

from __future__ import annotations

from typing import List, Tuple

import jax.numpy as jnp

from .configs import Preset
from .model import ParamSpec


def adamw_update(
    specs: List[ParamSpec],
    params: List[jnp.ndarray],
    grads: List[jnp.ndarray],
    m: List[jnp.ndarray],
    v: List[jnp.ndarray],
    step: jnp.ndarray,  # int32 scalar, 0-based; bias correction uses step+1
    hp: Preset,
) -> Tuple[List[jnp.ndarray], List[jnp.ndarray], List[jnp.ndarray]]:
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - hp.beta1 ** t
    bc2 = 1.0 - hp.beta2 ** t
    new_p, new_m, new_v = [], [], []
    for spec, p, g, mi, vi in zip(specs, params, grads, m, v):
        mi = hp.beta1 * mi + (1.0 - hp.beta1) * g
        vi = hp.beta2 * vi + (1.0 - hp.beta2) * (g * g)
        m_hat = mi / bc1
        v_hat = vi / bc2
        update = m_hat / (jnp.sqrt(v_hat) + hp.eps)
        if spec.decay:
            update = update + hp.weight_decay * p
        new_p.append(p - hp.lr * update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v
