"""Jittable entry points — the four functions that become HLO artifacts.

Every function takes/returns *flat* structures (lists of arrays and scalars)
so the lowered HLO's parameter order is exactly the manifest order; the rust
runtime marshals buffers positionally.  See DESIGN.md §2 for the signatures.
"""

from __future__ import annotations

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, Preset
from .model import init_params, loss_and_accuracy, forward, param_specs
from .optimizer import adamw_update


def make_init_fn(cfg: ModelConfig):
    """``(seed: u32) -> (param_0, …, param_{P-1})``"""

    def init_fn(seed):
        key = jax.random.PRNGKey(seed)
        return tuple(init_params(cfg, key))

    return init_fn


def make_train_step(cfg: ModelConfig, hp: Preset, use_pallas: bool = True):
    """``(params, m, v, step, x, y) -> (params', m', v', loss, acc)``

    ``step`` doubles as the dropout seed (folded into a PRNG key), so the
    rust loop needs no separate RNG plumbing and runs are reproducible.
    """
    specs = param_specs(cfg)

    def train_step(params, m, v, step, x, y):
        rng = jax.random.PRNGKey(step)

        def loss_fn(ps):
            loss, acc = loss_and_accuracy(
                cfg, list(ps), x, y, training=True, rng=rng, use_pallas=use_pallas
            )
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(tuple(params))
        new_p, new_m, new_v = adamw_update(specs, params, list(grads), m, v, step, hp)
        return tuple(new_p), tuple(new_m), tuple(new_v), loss, acc

    return train_step


def make_eval_step(cfg: ModelConfig, use_pallas: bool = True):
    """``(params, x, y) -> (loss, acc)`` — no dropout, no state mutation."""

    def eval_step(params, x, y):
        return loss_and_accuracy(cfg, list(params), x, y, training=False, use_pallas=use_pallas)

    return eval_step


def make_decode_fn(cfg: ModelConfig, use_pallas: bool = True):
    """``(params, tokens[B, T]) -> logits[B, T, vocab]``

    Full-context forward; the rust sampler reads the row at the current
    position.  (HSM admits an O(1)-state incremental decoder — kept as an
    extension; at ctx = 128 the full forward is already sub-millisecond.)
    """

    def decode_fn(params, tokens):
        return forward(cfg, list(params), tokens, training=False, use_pallas=use_pallas)

    return decode_fn


def example_args(cfg: ModelConfig, hp: Preset, kind: str):
    """ShapeDtypeStructs matching each artifact's signature, for lowering."""
    P = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in param_specs(cfg)]
    i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
    x = i32((hp.batch, cfg.ctx))
    if kind == "init":
        return (jax.ShapeDtypeStruct((), jnp.uint32),)
    if kind == "train_step":
        return (P, P, P, jax.ShapeDtypeStruct((), jnp.int32), x, x)
    if kind == "eval_step":
        return (P, x, x)
    if kind == "decode":
        return (P, i32((1, cfg.ctx)))
    raise ValueError(kind)
