"""The L2 JAX model: a GPT-2-style decoder with pluggable token mixers.

Implements every mixer of Forchheimer (2026): scalar/vector/matrix (a, b)
weighting, single- and double-input gating, fusion, multihead (a, b) with
per-head shifts, and the causal softmax attention of the GPT reference —
plus arbitrary per-layer combinations, which is how the hybrid stacks are
expressed (the paper's §5 observation that HSM layers are drop-in
replacements for attention layers because input/output formats coincide).

Architecture follows the paper's GPT-2-derived reference (§6.1):
pre-layer-norm blocks, learned positional embeddings, tied input/output
embedding, a final LayerNorm before the logit projection, dropout 0.1 on the
embedding and on each residual branch.

Parameters live in a *flat list* whose order is fixed by
:func:`param_specs`; that order is the AOT artifact's HLO parameter order
and is serialised to ``manifest.json`` for the rust runtime.  No pytree
nesting — the rust side indexes buffers positionally.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import configs
from .configs import AB, ATTN, FUSION, GATE1, GATE2, MAT, VEC, LayerSpec, ModelConfig
from .kernels.attention import causal_attention
from .kernels.gated import gated_combine
from .kernels.shift_mix import shift_mix, shift_tokens
from .kernels import ref as kref


# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """One trainable tensor: name, shape, init scheme, weight-decay flag."""

    name: str
    shape: Tuple[int, ...]
    init: str  # "normal" | "zeros" | "ones" | "half" | "residual"
    decay: bool


def _mixer_param_specs(l: int, spec: LayerSpec, dim: int) -> List[ParamSpec]:
    pre = f"layer{l}."
    hd = dim // spec.heads
    k = spec.kind
    if k == AB:
        # Scalar taps, one pair per head (single-head => the §3.1 scheme).
        return [
            ParamSpec(pre + "mix_a", (spec.heads,), "half", False),
            ParamSpec(pre + "mix_b", (spec.heads,), "half", False),
        ]
    if k == VEC:
        return [
            ParamSpec(pre + "mix_a", (dim,), "half", False),
            ParamSpec(pre + "mix_b", (dim,), "half", False),
        ]
    if k == MAT:
        return [
            ParamSpec(pre + "mix_A", (dim, dim), "normal", True),
            ParamSpec(pre + "mix_B", (dim, dim), "normal", True),
            ParamSpec(pre + "mix_bias", (dim,), "zeros", False),
        ]
    if k == GATE1:
        return [
            ParamSpec(pre + "gate_w1", (dim, dim), "normal", True),
            ParamSpec(pre + "gate_b1", (dim,), "zeros", False),
            ParamSpec(pre + "gate_w2", (dim, dim), "normal", True),
            ParamSpec(pre + "gate_b2", (dim,), "zeros", False),
        ]
    if k == GATE2:
        return [
            ParamSpec(pre + "gate_w", (spec.heads, 2 * hd, hd), "normal", True),
            ParamSpec(pre + "gate_b", (spec.heads, hd), "zeros", False),
        ]
    if k == FUSION:
        return [
            ParamSpec(pre + "fuse_w1", (spec.heads, 2 * hd, hd), "normal", True),
            ParamSpec(pre + "fuse_b1", (spec.heads, hd), "zeros", False),
            ParamSpec(pre + "fuse_w2", (spec.heads, hd, hd), "normal", True),
            ParamSpec(pre + "fuse_b2", (spec.heads, hd), "zeros", False),
        ]
    if k == ATTN:
        return [
            ParamSpec(pre + "attn_wq", (dim, dim), "normal", True),
            ParamSpec(pre + "attn_bq", (dim,), "zeros", False),
            ParamSpec(pre + "attn_wk", (dim, dim), "normal", True),
            ParamSpec(pre + "attn_bk", (dim,), "zeros", False),
            ParamSpec(pre + "attn_wv", (dim, dim), "normal", True),
            ParamSpec(pre + "attn_bv", (dim,), "zeros", False),
            ParamSpec(pre + "attn_wo", (dim, dim), "residual", True),
            ParamSpec(pre + "attn_bo", (dim,), "zeros", False),
        ]
    raise ValueError(k)


def param_specs(cfg: ModelConfig) -> List[ParamSpec]:
    """The flat, ordered parameter list — this order IS the HLO order."""
    out: List[ParamSpec] = [
        ParamSpec("tok_emb", (cfg.vocab, cfg.dim), "normal", True),
        ParamSpec("pos_emb", (cfg.ctx, cfg.dim), "normal", False),
    ]
    for l, spec in enumerate(cfg.layers):
        pre = f"layer{l}."
        out += [
            ParamSpec(pre + "ln1_g", (cfg.dim,), "ones", False),
            ParamSpec(pre + "ln1_b", (cfg.dim,), "zeros", False),
        ]
        out += _mixer_param_specs(l, spec, cfg.dim)
        out += [
            ParamSpec(pre + "ln2_g", (cfg.dim,), "ones", False),
            ParamSpec(pre + "ln2_b", (cfg.dim,), "zeros", False),
            ParamSpec(pre + "ffn_w1", (cfg.dim, spec.ffn), "normal", True),
            ParamSpec(pre + "ffn_b1", (spec.ffn,), "zeros", False),
            ParamSpec(pre + "ffn_w2", (spec.ffn, cfg.dim), "residual", True),
            ParamSpec(pre + "ffn_b2", (cfg.dim,), "zeros", False),
        ]
    out += [
        ParamSpec("lnf_g", (cfg.dim,), "ones", False),
        ParamSpec("lnf_b", (cfg.dim,), "zeros", False),
    ]
    return out


def param_index(cfg: ModelConfig) -> Dict[str, int]:
    return {s.name: i for i, s in enumerate(param_specs(cfg))}


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jnp.ndarray]:
    """GPT-2-style init: N(0, 0.02²) weights, residual projections scaled by
    1/√(2·n_layers), zero biases, unit LN gains, (a, b) taps at 0.5/0.5."""
    specs = param_specs(cfg)
    keys = jax.random.split(key, len(specs))
    resid_scale = 1.0 / (2 * cfg.n_layers) ** 0.5
    params = []
    for spec, k in zip(specs, keys):
        if spec.init == "normal":
            p = 0.02 * jax.random.normal(k, spec.shape, jnp.float32)
        elif spec.init == "residual":
            p = 0.02 * resid_scale * jax.random.normal(k, spec.shape, jnp.float32)
        elif spec.init == "zeros":
            p = jnp.zeros(spec.shape, jnp.float32)
        elif spec.init == "ones":
            p = jnp.ones(spec.shape, jnp.float32)
        elif spec.init == "half":
            p = jnp.full(spec.shape, 0.5, jnp.float32)
        else:
            raise ValueError(spec.init)
        params.append(p)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _dropout(x, rate, key, training):
    if not training or rate <= 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


class _P:
    """Positional parameter accessor for one layer's slice of the flat list."""

    def __init__(self, params: List[jnp.ndarray], index: Dict[str, int], prefix: str):
        self._params = params
        self._index = index
        self._prefix = prefix

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self._params[self._index[self._prefix + name]]


def apply_mixer(
    spec: LayerSpec,
    p: _P,
    x: jnp.ndarray,
    *,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Dispatch one token-mixing function on post-LN activations ``x``."""
    B, T, D = x.shape
    H = spec.heads
    hd = D // H
    k = spec.kind

    smix = shift_mix if use_pallas else kref.shift_mix_ref
    attn = (lambda q, kk, v: causal_attention(q, kk, v, T)) if use_pallas else kref.causal_attention_ref
    gcomb = gated_combine if use_pallas else kref.gated_combine_ref

    if k in (AB, VEC):
        if k == VEC:
            return smix(x, p["mix_a"], p["mix_b"], spec.shifts[0])
        if H == 1:
            a = jnp.broadcast_to(p["mix_a"], (D,))
            b = jnp.broadcast_to(p["mix_b"], (D,))
            return smix(x, a, b, spec.shifts[0])
        # Multihead (a, b): contiguous channel groups, one static shift each.
        outs = []
        for h in range(H):
            grp = x[:, :, h * hd : (h + 1) * hd]
            a = jnp.broadcast_to(p["mix_a"][h], (hd,))
            b = jnp.broadcast_to(p["mix_b"][h], (hd,))
            outs.append(smix(grp, a, b, spec.shifts[h]))
        return jnp.concatenate(outs, axis=-1)

    s = spec.shifts[0]
    if k == MAT:
        xs = shift_tokens(x, s)
        return x @ p["mix_A"] + xs @ p["mix_B"] + p["mix_bias"]

    if k == GATE1:
        h1 = jax.nn.relu(x @ p["gate_w1"] + p["gate_b1"])
        gate = jnp.tanh(h1 @ p["gate_w2"] + p["gate_b2"])
        return gcomb(gate, x, shift_tokens(x, s))

    if k == GATE2:
        xs = shift_tokens(x, s)
        xh = x.reshape(B, T, H, hd)
        xsh = xs.reshape(B, T, H, hd)
        cat = jnp.concatenate([xh, xsh], axis=-1)  # [B, T, H, 2hd]
        gate = jnp.tanh(jnp.einsum("bthi,hij->bthj", cat, p["gate_w"]) + p["gate_b"])
        return gcomb(gate.reshape(B, T, D), x, xs)

    if k == FUSION:
        xs = shift_tokens(x, s)
        xh = x.reshape(B, T, H, hd)
        xsh = xs.reshape(B, T, H, hd)
        cat = jnp.concatenate([xh, xsh], axis=-1)
        h1 = jax.nn.relu(jnp.einsum("bthi,hij->bthj", cat, p["fuse_w1"]) + p["fuse_b1"])
        y = jnp.einsum("bthi,hij->bthj", h1, p["fuse_w2"]) + p["fuse_b2"]
        return y.reshape(B, T, D)

    if k == ATTN:
        q = (x @ p["attn_wq"] + p["attn_bq"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        kk = (x @ p["attn_wk"] + p["attn_bk"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        v = (x @ p["attn_wv"] + p["attn_bv"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
        o = attn(q, kk, v)  # [B, H, T, hd]
        o = o.transpose(0, 2, 1, 3).reshape(B, T, D)
        return o @ p["attn_wo"] + p["attn_bo"]

    raise ValueError(k)


def forward(
    cfg: ModelConfig,
    params: List[jnp.ndarray],
    tokens: jnp.ndarray,  # int32 [B, T]
    *,
    training: bool = False,
    rng: Optional[jax.Array] = None,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Full decoder forward pass → logits ``[B, T, vocab]``."""
    index = param_index(cfg)
    B, T = tokens.shape
    if training:
        keys = jax.random.split(rng, 2 * cfg.n_layers + 1)
    x = params[index["tok_emb"]][tokens] + params[index["pos_emb"]][None, :T, :]
    if training:
        x = _dropout(x, cfg.dropout, keys[0], training)
    for l, spec in enumerate(cfg.layers):
        p = _P(params, index, f"layer{l}.")
        h = _layer_norm(x, p["ln1_g"], p["ln1_b"])
        h = apply_mixer(spec, p, h, use_pallas=use_pallas)
        if training:
            h = _dropout(h, cfg.dropout, keys[2 * l + 1], training)
        x = x + h
        f = _layer_norm(x, p["ln2_g"], p["ln2_b"])
        f = jax.nn.relu(f @ p["ffn_w1"] + p["ffn_b1"]) @ p["ffn_w2"] + p["ffn_b2"]
        if training:
            f = _dropout(f, cfg.dropout, keys[2 * l + 2], training)
        x = x + f
    x = _layer_norm(x, params[index["lnf_g"]], params[index["lnf_b"]])
    # Tied embedding: logits via the transposed input table (paper Fig. 1).
    return x @ params[index["tok_emb"]].T


def loss_and_accuracy(
    cfg: ModelConfig,
    params: List[jnp.ndarray],
    x: jnp.ndarray,  # int32 [B, T] inputs
    y: jnp.ndarray,  # int32 [B, T] next-token targets
    *,
    training: bool = False,
    rng: Optional[jax.Array] = None,
    use_pallas: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy loss (paper eq. 7 reduced form) + next-token accuracy."""
    logits = forward(cfg, params, x, training=training, rng=rng, use_pallas=use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, acc
