"""Pallas kernels for the HSM shift-mix operator (paper §3.1–§3.2, §4).

The core HSM primitive combines each token with one earlier token at a fixed
temporal shift ``s``::

    y[b, t, :] = a ⊙ x[b, t, :] + b ⊙ x[b, t - s, :]        (x[t<0] = 0)

``a`` and ``b`` are per-channel coefficient vectors; the scalar (a, b) scheme
of §3.1 is the broadcast special case (the broadcast happens at the JAX level
in :mod:`compile.model`, so its gradient reduction is handled by autodiff).
Per-head shifts (multihead HSM, §4) are expressed by calling this kernel once
per contiguous head-channel group with that head's static shift.

TPU mapping (see DESIGN.md §Hardware-Adaptation): this is a 2-tap depthwise
causal convolution with dilation ``s`` — bandwidth-bound, VPU-only.  The grid
iterates over the batch; each step holds one ``[T, D]`` tile (≤ 128 KiB for
the paper configuration) plus its shifted companion in VMEM, so the pipeline
double-buffers batch rows while combining in-register.  ``interpret=True``
everywhere: the CPU PJRT plugin cannot run Mosaic custom-calls.

A custom VJP supplies the backward pass as a second Pallas kernel: the
adjoint of a causal 2-tap filter is the *anti-causal* 2-tap filter

    dx[b, t, :] = a ⊙ dy[b, t, :] + b ⊙ dy[b, t + s, :]     (dy[t≥T] = 0)

plus two channel-wise reductions ``da = Σ dy ⊙ x`` and ``db = Σ dy ⊙ x_s``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, a_ref, b_ref, y_ref, *, shift: int):
    """One batch row: y = a*x + b*shift(x).  Block = full [T, D] tile."""
    x = x_ref[0]  # [T, D] (leading block dim of size 1)
    xs = shifted(x, shift)
    y_ref[0] = a_ref[...] * x + b_ref[...] * xs


def _bwd_kernel(x_ref, dy_ref, a_ref, b_ref, dx_ref, da_ref, db_ref, *, shift: int):
    """Adjoint for one batch row; da/db accumulate across the batch grid."""
    i = pl.program_id(0)
    x = x_ref[0]
    dy = dy_ref[0]
    # dx: anti-causal 2-tap filter (future dy rows flow back through tap b).
    T = dy.shape[0]
    if shift >= T:
        dy_fwd = jnp.zeros_like(dy)
    else:
        dy_fwd = jnp.pad(dy[shift:, :], ((0, shift), (0, 0)))
    dx_ref[0] = a_ref[...] * dy + b_ref[...] * dy_fwd
    # Coefficient gradients: per-channel reductions, accumulated over grid.
    da_row = jnp.sum(dy * x, axis=0)
    db_row = jnp.sum(dy * shifted(x, shift), axis=0)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    da_ref[...] += da_row
    db_ref[...] += db_row


def shifted(x: jnp.ndarray, s: int) -> jnp.ndarray:
    """Causal shift along axis 0 with zero fill: out[t] = x[t-s], out[t<s]=0."""
    T = x.shape[0]
    if s == 0:
        return x
    if s >= T:
        return jnp.zeros_like(x)
    return jnp.pad(x[:-s, :], ((s, 0), (0, 0)))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def shift_mix(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, shift: int):
    """HSM shift-mix: ``a ⊙ x + b ⊙ x_shifted`` over ``x: [B, T, D]``.

    Args:
      x: activations ``[B, T, D]`` (``D`` may be a head-channel slice).
      a, b: per-channel coefficient vectors ``[D]``.
      shift: static temporal shift ``s ≥ 1``; ``s ≥ T`` zeroes the second tap
        (the paper's head-7 / shift-128 case).
    """
    return _shift_mix_fwd_impl(x, a, b, shift)


def _shift_mix_fwd_impl(x, a, b, shift):
    B, T, D = x.shape
    return pl.pallas_call(
        functools.partial(_fwd_kernel, shift=shift),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, T, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
        interpret=True,
    )(x, a, b)


def _shift_mix_fwd(x, a, b, shift):
    return _shift_mix_fwd_impl(x, a, b, shift), (x, a, b)


def _shift_mix_bwd(shift, res, dy):
    x, a, b = res
    B, T, D = x.shape
    dx, da, db = pl.pallas_call(
        functools.partial(_bwd_kernel, shift=shift),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, T, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, T, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),  # revisited every step
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, D), x.dtype),
            jax.ShapeDtypeStruct((D,), a.dtype),
            jax.ShapeDtypeStruct((D,), b.dtype),
        ],
        interpret=True,
    )(x, dy, a, b)
    return dx, da, db


shift_mix.defvjp(_shift_mix_fwd, _shift_mix_bwd)


def shift_tokens(x: jnp.ndarray, s: int) -> jnp.ndarray:
    """JAX-level causal shift for ``[B, T, D]`` (feeds gate/fusion mixers)."""
    B, T, D = x.shape
    if s == 0:
        return x
    if s >= T:
        return jnp.zeros_like(x)
    return jnp.pad(x[:, :-s, :], ((0, 0), (s, 0), (0, 0)))
