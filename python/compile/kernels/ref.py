"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references: the pytest/hypothesis suites sweep the
Pallas kernels against these implementations (values *and* gradients) over
shapes, shifts and head counts.  They are also selectable as a drop-in
kernel backend (``aot.py --kernels jnp``) for the ablation benches that
compare lowered-HLO size and step latency against the Pallas path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def shift_tokens_ref(x: jnp.ndarray, s: int) -> jnp.ndarray:
    """Causal temporal shift with zero fill over ``[B, T, D]``."""
    T = x.shape[1]
    if s == 0:
        return x
    if s >= T:
        return jnp.zeros_like(x)
    return jnp.pad(x[:, :-s, :], ((0, 0), (s, 0), (0, 0)))


def shift_mix_ref(x, a, b, shift: int):
    """Oracle for :func:`compile.kernels.shift_mix.shift_mix`."""
    return a[None, None, :] * x + b[None, None, :] * shift_tokens_ref(x, shift)


def gated_combine_ref(gate, x, xs):
    """Oracle for :func:`compile.kernels.gated.gated_combine`."""
    return gate * x + (1.0 - gate) * xs


def causal_attention_ref(q, k, v):
    """Oracle for :func:`compile.kernels.attention.causal_attention`.

    Plain masked softmax attention over ``[B, H, T, hd]``.
    """
    B, H, T, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
