"""Fused gated-combine kernel for the gated HSM mixers (paper §3.5–§3.6).

Computes the convex-ish blend

    y = gate ⊙ x + (1 − gate) ⊙ x_shifted

in one VMEM pass.  The gate itself (an MLP or a per-head linear map followed
by tanh) stays at the JAX level where XLA fuses it with the surrounding
matmuls; this kernel fuses the three-operand elementwise combine, which
would otherwise cost two extra HBM round-trips on TPU.

The VJP is closed-form and cheap:

    dgate = dy ⊙ (x − x_shifted),   dx = dy ⊙ gate,   dxs = dy ⊙ (1 − gate)

and is implemented as a second Pallas kernel so the backward pass stays a
single fused pass as well.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(g_ref, x_ref, xs_ref, y_ref):
    g = g_ref[0]
    y_ref[0] = g * x_ref[0] + (1.0 - g) * xs_ref[0]


def _bwd_kernel(g_ref, x_ref, xs_ref, dy_ref, dg_ref, dx_ref, dxs_ref):
    g = g_ref[0]
    dy = dy_ref[0]
    dg_ref[0] = dy * (x_ref[0] - xs_ref[0])
    dx_ref[0] = dy * g
    dxs_ref[0] = dy * (1.0 - g)


def _row_spec(T, D):
    return pl.BlockSpec((1, T, D), lambda i: (i, 0, 0))


def _gated_fwd_impl(gate, x, xs):
    B, T, D = x.shape
    spec = _row_spec(T, D)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(B,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), x.dtype),
        interpret=True,
    )(gate, x, xs)


@jax.custom_vjp
def gated_combine(gate, x, xs):
    """``gate ⊙ x + (1 − gate) ⊙ xs`` over ``[B, T, D]`` operands."""
    return _gated_fwd_impl(gate, x, xs)


def _gated_fwd(gate, x, xs):
    return _gated_fwd_impl(gate, x, xs), (gate, x, xs)


def _gated_bwd(res, dy):
    gate, x, xs = res
    B, T, D = x.shape
    spec = _row_spec(T, D)
    out_shape = jax.ShapeDtypeStruct((B, T, D), x.dtype)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(B,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=True,
    )(gate, x, xs, dy)


gated_combine.defvjp(_gated_fwd, _gated_bwd)
