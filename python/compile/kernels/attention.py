"""Pallas causal multi-head attention for the GPT reference / hybrid layers.

Forward is a flash-attention-style kernel: one ``(batch, head)`` pair per
grid step, key/value blocks streamed through VMEM with an online softmax so
the ``[T, T]`` score matrix never materialises in HBM.  For the paper's
configuration (T = 128, head_dim = 32) a single KV block covers the whole
sequence, so the online loop degenerates to one iteration — but the tiling
is written (and tested) for the general multi-block case, which is what a
real-TPU deployment with long contexts would use (see DESIGN.md §Perf).

Backward uses the standard recomputation strategy: the VJP recomputes the
(masked, softmaxed) attention matrix from the saved ``q, k, v`` and applies
the well-known closed-form gradients in plain ``jnp``.  For T = 128 the
recompute is cheaper than saving the probabilities; a Pallas flash-backward
is a documented extension point.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_k: int, scale: float):
    """Causal attention for one (batch, head): online softmax over KV blocks."""
    q = q_ref[0, 0] * scale  # [T, hd]
    T, hd = q.shape
    n_blocks = T // blk_k
    q_pos = jax.lax.broadcasted_iota(jnp.int32, (T, blk_k), 0)

    def body(j, carry):
        acc, m_prev, l_prev = carry
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[0, 0], j * blk_k, blk_k, 0)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[0, 0], j * blk_k, blk_k, 0)
        s = q @ k_blk.T  # [T, blk_k]
        k_pos = j * blk_k + jax.lax.broadcasted_iota(jnp.int32, (T, blk_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        correction = jnp.exp(m_prev - m_new)
        l_new = l_prev * correction + jnp.sum(p, axis=1)
        acc = acc * correction[:, None] + p @ v_blk
        return acc, m_new, l_new

    acc0 = jnp.zeros((T, hd), jnp.float32)
    m0 = jnp.full((T,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((T,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)


def _attention_fwd_impl(q, k, v, blk_k):
    B, H, T, hd = q.shape
    blk_k = min(blk_k, T)
    assert T % blk_k == 0, (T, blk_k)
    scale = 1.0 / (hd ** 0.5)
    spec = pl.BlockSpec((1, 1, T, hd), lambda b, h: (b, h, 0, 0))
    return pl.pallas_call(
        functools.partial(_attn_kernel, blk_k=blk_k, scale=scale),
        grid=(B, H),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def causal_attention(q, k, v, blk_k: int = 128):
    """Causal MHA: softmax(mask(q kᵀ / √hd)) v over ``[B, H, T, hd]``."""
    return _attention_fwd_impl(q, k, v, blk_k)


def _attention_fwd(q, k, v, blk_k):
    return _attention_fwd_impl(q, k, v, blk_k), (q, k, v)


def _attention_bwd(blk_k, res, do):
    q, k, v = res
    B, H, T, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    # Recompute probabilities (flash backward's strategy, expressed in jnp).
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do, v)
    # softmax backward: ds = p * (dp - rowsum(p * dp))
    ds = p * (dp - jnp.sum(p * dp, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q) * scale
    return dq, dk, dv


causal_attention.defvjp(_attention_fwd, _attention_bwd)
