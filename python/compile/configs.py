"""Model / training configuration registry for the HSM reproduction.

This module is the single source of truth for the twelve model variants of
Forchheimer (2026), Table 1 (plus the Figure-7 extra hybrid), and for the
size presets used by this reproduction:

* ``paper``   — the exact 5.1 M-parameter configuration of the paper
                (dim 256, ctx 128, vocab 5000, 7 layers).
* ``desktop`` — paper architecture, smaller vocab/batch; the end-to-end
                training preset used on this single-core sandbox.
* ``ci``      — a miniature configuration for tests and the Table-1 sweep.

The rust coordinator never imports this file; it reads the ``manifest.json``
emitted by :mod:`compile.aot`, which serialises everything defined here.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

# ---------------------------------------------------------------------------
# Mixer kinds
# ---------------------------------------------------------------------------

#: scalar (a, b) weighting  —  y = a x + b x_shift                (paper §3.1)
AB = "ab"
#: per-channel (a, b) weighting — y = a ⊙ x + b ⊙ x_shift         (paper §3.2)
VEC = "vec"
#: matrix (A, B) weighting  —  y = A x + B x_shift + bias          (paper §3.3)
MAT = "mat"
#: single-input gate        —  g = tanh(mlp(x))                    (paper §3.5)
GATE1 = "gate1"
#: double-input gate        —  g = tanh(L [x; x_shift])            (paper §3.6)
GATE2 = "gate2"
#: fusion                   —  y = mlp([x; x_shift])               (paper §3.7)
FUSION = "fusion"
#: causal softmax multi-head attention (the GPT reference mixer)   (paper §2.1)
ATTN = "attn"

MIXER_KINDS = (AB, VEC, MAT, GATE1, GATE2, FUSION, ATTN)

# FFN width as a multiple of `dim`, from Table 1 (paper dim = 256):
#   HSM(a,b)/vec/multihead: 1024/256 = 4.0      HSM(A,B): 640/256 = 2.5
#   single gate: 768/256 = 3.0                  double gate / fusion: 960/256 = 3.75
#   GPT: 512/256 = 2.0
FFN_RATIO = {
    AB: 4.0,
    VEC: 4.0,
    MAT: 2.5,
    GATE1: 3.0,
    GATE2: 3.75,
    FUSION: 3.75,
    ATTN: 2.0,
}


def layer_shift(layer: int, ctx: int) -> int:
    """Shift distance for single-shift layers: 2**layer, clipped to ctx//2.

    The paper's 7-layer / ctx-128 model uses shifts 1, 2, 4, ..., 64 — i.e.
    the deepest layer reaches half the context window.  For smaller presets
    we clip at ctx//2 so the schedule keeps that property.
    """
    return min(2 ** layer, ctx // 2)


def head_shifts(n_heads: int, ctx: int) -> List[int]:
    """Per-head shifts for the multihead (a, b) scheme: 2**h, clipped to ctx.

    The paper's 8-head schedule is [1, 2, 4, ..., 128] with ctx = 128 —
    head 7's shift *equals* the window, so its shifted input is all zeros.
    We reproduce that deliberately (clip at ctx, not ctx//2): the pathology
    is part of what Table 1 measures for "HSM (a, b) Multihead".
    """
    return [min(2 ** h, ctx) for h in range(n_heads)]


def rotate(xs: List[int], k: int) -> List[int]:
    """Rotating permutation for Multihead-ext: [1,2,4..] -> [2,4,..,1] -> ..."""
    k %= len(xs)
    return xs[k:] + xs[:k]


# ---------------------------------------------------------------------------
# Layer / model specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One transformer block: mixer kind, head count, shifts, FFN width."""

    kind: str
    heads: int
    shifts: List[int]  # one entry per head for ab-multihead; else length 1
    ffn: int

    def validate(self, dim: int, ctx: int) -> None:
        assert self.kind in MIXER_KINDS, self.kind
        assert dim % self.heads == 0, (dim, self.heads)
        if self.kind != ATTN:
            assert len(self.shifts) in (1, self.heads)
            assert all(1 <= s <= ctx for s in self.shifts), self.shifts
        assert self.ffn % 8 == 0, self.ffn


@dataclass(frozen=True)
class ModelConfig:
    """Full decoder configuration (one Table-1 row at one size preset)."""

    name: str  # variant id, e.g. "hsm_ab"
    preset: str  # "paper" | "desktop" | "ci"
    dim: int
    ctx: int
    vocab: int
    layers: List[LayerSpec]
    dropout: float = 0.1

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    def validate(self) -> None:
        for spec in self.layers:
            spec.validate(self.dim, self.ctx)

    def param_count(self) -> int:
        """Trainable parameter count (used by the parity tests)."""
        total = self.vocab * self.dim + self.ctx * self.dim  # tok + pos emb
        total += 2 * self.dim  # final LN
        d = self.dim
        for spec in self.layers:
            total += 4 * d  # two LayerNorms
            hd = d // spec.heads
            if spec.kind == AB:
                total += 2 * spec.heads
            elif spec.kind == VEC:
                total += 2 * d
            elif spec.kind == MAT:
                total += 2 * d * d + d
            elif spec.kind == GATE1:
                total += 2 * d * d + 2 * d
            elif spec.kind == GATE2:
                total += spec.heads * (2 * hd * hd + hd)
            elif spec.kind == FUSION:
                total += spec.heads * (2 * hd * hd + hd + hd * hd + hd)
            elif spec.kind == ATTN:
                total += 4 * d * d + 4 * d
            total += d * spec.ffn + spec.ffn + spec.ffn * d + d  # FFN
        return total


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Preset:
    name: str
    dim: int
    ctx: int
    vocab: int
    n_layers: int
    batch: int
    lr: float = 2e-3
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    dropout: float = 0.1
    epochs: int = 20


PRESETS = {
    # The paper's configuration (§6.1): 5.1 M parameters, batch 256, lr 2e-3.
    "paper": Preset("paper", dim=256, ctx=128, vocab=5000, n_layers=7, batch=256),
    # Same architecture, sized so one training step fits a single-core CPU
    # budget; used by examples/train_tinystories.rs.
    "desktop": Preset("desktop", dim=256, ctx=128, vocab=2048, n_layers=7, batch=32),
    # Miniature: tests + the full 12-variant Table-1 sweep.
    "ci": Preset("ci", dim=64, ctx=64, vocab=512, n_layers=7, batch=8, dropout=0.1),
}


def _ffn(kind: str, dim: int) -> int:
    return int(FFN_RATIO[kind] * dim)


def _hsm_layer(kind: str, heads: int, layer: int, p: Preset) -> LayerSpec:
    if kind in (GATE2, FUSION, AB, VEC, MAT, GATE1):
        shifts = [layer_shift(layer, p.ctx)]
    else:
        raise ValueError(kind)
    return LayerSpec(kind=kind, heads=heads, shifts=shifts, ffn=_ffn(kind, p.dim))


def _attn_layer(p: Preset, heads: int = 8) -> LayerSpec:
    return LayerSpec(kind=ATTN, heads=heads, shifts=[1], ffn=_ffn(ATTN, p.dim))


def _ab_mh_layer(layer: int, p: Preset, heads: int = 8, ext: bool = False) -> LayerSpec:
    base = head_shifts(heads, p.ctx)
    shifts = rotate(base, layer) if ext else base
    return LayerSpec(kind=AB, heads=heads, shifts=shifts, ffn=_ffn(AB, p.dim))


def build_variant(variant: str, preset: str) -> ModelConfig:
    """Construct one of the twelve Table-1 / Figure-7 model variants."""
    p = PRESETS[preset]
    L = p.n_layers

    def uniform(fn) -> List[LayerSpec]:
        return [fn(l) for l in range(L)]

    if variant == "hsm_ab":
        layers = uniform(lambda l: _hsm_layer(AB, 1, l, p))
    elif variant == "hsm_vec":
        layers = uniform(lambda l: _hsm_layer(VEC, 1, l, p))
    elif variant == "hsm_mat":
        layers = uniform(lambda l: _hsm_layer(MAT, 1, l, p))
    elif variant == "hsm_gate1":
        layers = uniform(lambda l: _hsm_layer(GATE1, 1, l, p))
    elif variant == "hsm_gate2":
        layers = uniform(lambda l: _hsm_layer(GATE2, 4, l, p))
    elif variant == "hsm_fusion":
        layers = uniform(lambda l: _hsm_layer(FUSION, 4, l, p))
    elif variant == "hsm_ab_mh":
        layers = uniform(lambda l: _ab_mh_layer(l, p))
    elif variant == "hsm_ab_mhext":
        layers = uniform(lambda l: _ab_mh_layer(l, p, ext=True))
    elif variant == "gpt":
        layers = uniform(lambda l: _attn_layer(p))
    elif variant == "hybrid_06":
        # GPT with the first and last layers replaced by HSM (a, b).
        layers = [
            _hsm_layer(AB, 1, l, p) if l in (0, L - 1) else _attn_layer(p)
            for l in range(L)
        ]
    elif variant == "hybrid_mh_06":
        layers = [
            _ab_mh_layer(l, p) if l in (0, L - 1) else _attn_layer(p)
            for l in range(L)
        ]
    elif variant == "hybrid_l3gpt":
        # Figure 7's "HSM:[0,1,2,4,5,6]": HSM (a,b) everywhere except a
        # softmax-attention layer in the middle (layer 3 of 7).
        mid = L // 2
        layers = [
            _attn_layer(p) if l == mid else _hsm_layer(AB, 1, l, p)
            for l in range(L)
        ]
    else:
        raise ValueError(f"unknown variant {variant!r}")

    cfg = ModelConfig(
        name=variant,
        preset=preset,
        dim=p.dim,
        ctx=p.ctx,
        vocab=p.vocab,
        layers=layers,
        dropout=p.dropout,
    )
    cfg.validate()
    return cfg


#: Table-1 row order (GPT last, as in the paper) plus the Figure-7 extra.
VARIANTS = [
    "hsm_ab",
    "hsm_vec",
    "hsm_mat",
    "hsm_gate1",
    "hsm_gate2",
    "hsm_fusion",
    "hsm_ab_mh",
    "hsm_ab_mhext",
    "hybrid_06",
    "hybrid_mh_06",
    "gpt",
    "hybrid_l3gpt",
]

#: Paper display names, used by the rust report drivers via the manifest.
DISPLAY_NAMES = {
    "hsm_ab": "HSM (a,b)",
    "hsm_vec": "HSM (a,b) vector",
    "hsm_mat": "HSM (A,B)",
    "hsm_gate1": "HSM Single input gate",
    "hsm_gate2": "HSM Double input gate",
    "hsm_fusion": "HSM Fusion",
    "hsm_ab_mh": "HSM (a,b) Multihead",
    "hsm_ab_mhext": "HSM (a,b) Multihead-ext",
    "hybrid_06": "Hybrid [0,6]",
    "hybrid_mh_06": "Hybrid Multihead [0,6]",
    "gpt": "GPT",
    "hybrid_l3gpt": "HSM:[0,1,2,4,5,6]",
}


def config_to_dict(cfg: ModelConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d["display_name"] = DISPLAY_NAMES[cfg.name]
    d["param_count"] = cfg.param_count()
    return d
