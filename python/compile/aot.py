"""AOT lowering driver: JAX → HLO text artifacts for the rust runtime.

For each (preset, variant) pair this emits::

    artifacts/<preset>/<variant>/init.hlo.txt
    artifacts/<preset>/<variant>/train_step.hlo.txt
    artifacts/<preset>/<variant>/eval_step.hlo.txt
    artifacts/<preset>/<variant>/decode.hlo.txt
    artifacts/<preset>/<variant>/manifest.json

**HLO text, not serialized protos**: jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate links) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

This is the ONLY place Python runs: ``make artifacts`` invokes it once and
the rust binary is self-contained afterwards.

Usage::

    python -m compile.aot --preset ci --variants all --out ../artifacts
    python -m compile.aot --preset desktop --variants hsm_ab,gpt,hybrid_mh_06
    python -m compile.aot --preset ci --variants gpt --kernels jnp   # ablation
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import configs, model, steps
from .configs import PRESETS, VARIANTS, build_variant, config_to_dict


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACT_KINDS = ("init", "train_step", "eval_step", "decode")


def build_manifest(cfg, hp, kernels: str, files: dict) -> dict:
    specs = model.param_specs(cfg)
    return {
        "schema_version": 1,
        "preset": hp.name,
        "variant": cfg.name,
        "display_name": configs.DISPLAY_NAMES[cfg.name],
        "kernels": kernels,
        "config": config_to_dict(cfg),
        "train": {
            "batch": hp.batch,
            "lr": hp.lr,
            "weight_decay": hp.weight_decay,
            "beta1": hp.beta1,
            "beta2": hp.beta2,
            "eps": hp.eps,
            "dropout": hp.dropout,
            "epochs": hp.epochs,
        },
        "params": [
            {"name": s.name, "shape": list(s.shape), "decay": s.decay}
            for s in specs
        ],
        "artifacts": files,
        # Flat-signature documentation for the rust marshaller.
        "signatures": {
            "init": {"inputs": ["seed:u32"], "outputs": ["params*P"]},
            "train_step": {
                "inputs": ["params*P", "m*P", "v*P", "step:i32", "x:i32[B,T]", "y:i32[B,T]"],
                "outputs": ["params*P", "m*P", "v*P", "loss:f32", "acc:f32"],
            },
            "eval_step": {
                "inputs": ["params*P", "x:i32[B,T]", "y:i32[B,T]"],
                "outputs": ["loss:f32", "acc:f32"],
            },
            "decode": {
                "inputs": ["params*P", "tokens:i32[1,T]"],
                "outputs": ["logits:f32[1,T,V]"],
            },
        },
    }


def lower_variant(variant: str, preset: str, out_root: str, kernels: str, kinds=ARTIFACT_KINDS) -> None:
    hp = PRESETS[preset]
    cfg = build_variant(variant, preset)
    use_pallas = kernels == "pallas"
    outdir = os.path.join(out_root, preset, variant)
    os.makedirs(outdir, exist_ok=True)

    fns = {
        "init": steps.make_init_fn(cfg),
        "train_step": steps.make_train_step(cfg, hp, use_pallas),
        "eval_step": steps.make_eval_step(cfg, use_pallas),
        "decode": steps.make_decode_fn(cfg, use_pallas),
    }

    files = {}
    for kind in kinds:
        t0 = time.time()
        args = steps.example_args(cfg, hp, kind)
        lowered = jax.jit(fns[kind]).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{kind}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        files[kind] = fname
        print(
            f"  {preset}/{variant}/{kind}: {len(text) / 1e6:.2f} MB "
            f"({time.time() - t0:.1f}s)"
        )

    manifest = build_manifest(cfg, hp, kernels, files)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="ci", choices=sorted(PRESETS))
    ap.add_argument("--variants", default="all", help='"all" or comma-separated ids')
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--kernels", default="pallas", choices=("pallas", "jnp"))
    ap.add_argument(
        "--kinds", default=",".join(ARTIFACT_KINDS), help="subset of artifact kinds"
    )
    args = ap.parse_args()

    variants = VARIANTS if args.variants == "all" else args.variants.split(",")
    kinds = tuple(args.kinds.split(","))
    for v in variants:
        if v not in VARIANTS:
            raise SystemExit(f"unknown variant {v!r}; known: {VARIANTS}")
    t0 = time.time()
    for v in variants:
        lower_variant(v, args.preset, args.out, args.kernels, kinds)
    print(f"lowered {len(variants)} variants in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
