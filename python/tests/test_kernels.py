"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Values AND gradients (through the custom VJPs), swept over shapes, shifts
and head-group widths with hypothesis.  This is the core correctness signal
for the whole stack: the same kernels lower into every HLO artifact the
rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import causal_attention
from compile.kernels.gated import gated_combine
from compile.kernels.shift_mix import shift_mix, shift_tokens

TOL = dict(rtol=2e-4, atol=2e-5)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# shift_mix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shift", [1, 2, 4, 7, 15, 16, 64])
def test_shift_mix_matches_ref(shift):
    x = rand(0, (3, 16, 8))
    a, b = rand(1, (8,)), rand(2, (8,))
    got = shift_mix(x, a, b, shift)
    want = ref.shift_mix_ref(x, a, b, shift)
    np.testing.assert_allclose(got, want, **TOL)


def test_shift_mix_shift_equal_to_seq_zeroes_second_tap():
    # The paper's multihead head-7 case: shift == ctx → x_shifted ≡ 0.
    x = rand(0, (2, 8, 4))
    a, b = rand(1, (4,)), rand(2, (4,))
    got = shift_mix(x, a, b, 8)
    np.testing.assert_allclose(got, a[None, None] * x, **TOL)


def test_shift_mix_causality():
    # Output at position t must not depend on inputs at positions > t.
    x = rand(0, (1, 12, 4))
    a, b = rand(1, (4,)), rand(2, (4,))
    base = shift_mix(x, a, b, 3)
    x2 = x.at[:, 9:, :].set(999.0)
    pert = shift_mix(x2, a, b, 3)
    np.testing.assert_allclose(base[:, :9], pert[:, :9], **TOL)


@pytest.mark.parametrize("shift", [1, 3, 16])
def test_shift_mix_grads_match_ref(shift):
    x = rand(3, (2, 16, 8))
    a, b = rand(4, (8,)), rand(5, (8,))

    def loss_k(x, a, b):
        return jnp.sum(shift_mix(x, a, b, shift) ** 2)

    def loss_r(x, a, b):
        return jnp.sum(ref.shift_mix_ref(x, a, b, shift) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, a, b)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(gk, gr):
        np.testing.assert_allclose(u, v, **TOL)


def test_shift_mix_scalar_broadcast_grad_reduces():
    # The (a, b) scalar scheme broadcasts at the JAX level; its gradient
    # must reduce back to a scalar (chain rule through broadcast_to).
    x = rand(6, (2, 8, 4))

    def loss(a_scalar):
        a = jnp.broadcast_to(a_scalar, (4,))
        b = jnp.broadcast_to(1.0 - a_scalar, (4,))
        return jnp.sum(shift_mix(x, a, b, 2) ** 2)

    g = jax.grad(loss)(0.3)
    assert g.shape == ()
    gr = jax.grad(
        lambda s: jnp.sum(
            ref.shift_mix_ref(x, jnp.broadcast_to(s, (4,)), jnp.broadcast_to(1 - s, (4,)), 2) ** 2
        )
    )(0.3)
    np.testing.assert_allclose(g, gr, **TOL)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.integers(2, 24),
    d=st.integers(1, 16),
    data=st.data(),
)
def test_shift_mix_hypothesis_sweep(b, t, d, data):
    shift = data.draw(st.integers(1, t + 2))
    x = rand(b * 131 + t, (b, t, d))
    a, bb = rand(d, (d,)), rand(d + 1, (d,))
    got = shift_mix(x, a, bb, shift)
    want = ref.shift_mix_ref(x, a, bb, shift)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# gated_combine
# ---------------------------------------------------------------------------


def test_gated_combine_matches_ref():
    x = rand(0, (2, 12, 8))
    xs = ref.shift_tokens_ref(x, 2)
    g = jax.nn.sigmoid(rand(1, (2, 12, 8)))
    np.testing.assert_allclose(
        gated_combine(g, x, xs), ref.gated_combine_ref(g, x, xs), **TOL
    )


def test_gated_combine_extremes():
    x = rand(2, (1, 4, 4))
    xs = rand(3, (1, 4, 4))
    ones = jnp.ones_like(x)
    np.testing.assert_allclose(gated_combine(ones, x, xs), x, **TOL)
    np.testing.assert_allclose(gated_combine(0 * ones, x, xs), xs, **TOL)


def test_gated_combine_grads():
    g0, x0, xs0 = jax.nn.sigmoid(rand(4, (2, 6, 4))), rand(5, (2, 6, 4)), rand(6, (2, 6, 4))

    def lk(g, x, xs):
        return jnp.sum(jnp.sin(gated_combine(g, x, xs)))

    def lr(g, x, xs):
        return jnp.sum(jnp.sin(ref.gated_combine_ref(g, x, xs)))

    gk = jax.grad(lk, argnums=(0, 1, 2))(g0, x0, xs0)
    gr = jax.grad(lr, argnums=(0, 1, 2))(g0, x0, xs0)
    for u, v in zip(gk, gr):
        np.testing.assert_allclose(u, v, **TOL)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 3), t=st.integers(1, 16), d=st.integers(1, 12))
def test_gated_combine_hypothesis(b, t, d):
    g = jax.nn.sigmoid(rand(7 + b, (b, t, d)))
    x, xs = rand(8 + t, (b, t, d)), rand(9 + d, (b, t, d))
    np.testing.assert_allclose(
        gated_combine(g, x, xs), ref.gated_combine_ref(g, x, xs), **TOL
    )


# ---------------------------------------------------------------------------
# causal_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("blk_k", [4, 8, 16])
def test_attention_matches_ref_across_block_sizes(blk_k):
    q, k, v = rand(0, (2, 2, 16, 8)), rand(1, (2, 2, 16, 8)), rand(2, (2, 2, 16, 8))
    got = causal_attention(q, k, v, blk_k)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_attention_causality():
    q, k, v = rand(3, (1, 1, 12, 4)), rand(4, (1, 1, 12, 4)), rand(5, (1, 1, 12, 4))
    base = causal_attention(q, k, v, 12)
    k2 = k.at[:, :, 8:, :].set(99.0)
    v2 = v.at[:, :, 8:, :].set(99.0)
    pert = causal_attention(q, k2, v2, 12)
    np.testing.assert_allclose(base[:, :, :8], pert[:, :, :8], rtol=1e-3, atol=1e-4)


def test_attention_first_position_is_v0():
    # Position 0 can only attend to itself.
    q, k, v = rand(6, (1, 2, 8, 4)), rand(7, (1, 2, 8, 4)), rand(8, (1, 2, 8, 4))
    out = causal_attention(q, k, v, 8)
    np.testing.assert_allclose(out[:, :, 0], v[:, :, 0], rtol=1e-3, atol=1e-4)


def test_attention_grads_match_ref():
    q, k, v = rand(9, (1, 2, 8, 4)), rand(10, (1, 2, 8, 4)), rand(11, (1, 2, 8, 4))

    def lk(q, k, v):
        return jnp.sum(causal_attention(q, k, v, 8) ** 2)

    def lr(q, k, v):
        return jnp.sum(ref.causal_attention_ref(q, k, v) ** 2)

    gk = jax.grad(lk, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for u, v2 in zip(gk, gr):
        np.testing.assert_allclose(u, v2, rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    hd=st.sampled_from([2, 4, 8]),
    nblk=st.integers(1, 3),
)
def test_attention_hypothesis_sweep(b, h, hd, nblk):
    t = 4 * nblk
    q, k, v = rand(b, (b, h, t, hd)), rand(h + 20, (b, h, t, hd)), rand(hd + 40, (b, h, t, hd))
    got = causal_attention(q, k, v, 4)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# shift_tokens helper
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [0, 1, 5, 16, 30])
def test_shift_tokens_matches_ref(s):
    x = rand(12, (2, 16, 4))
    np.testing.assert_allclose(shift_tokens(x, s), ref.shift_tokens_ref(x, s), **TOL)
