"""L2 correctness: model shapes, parameter budgets, mixer dispatch,
training dynamics and the pallas-vs-jnp backend equivalence.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, steps
from compile.configs import PRESETS, VARIANTS, build_variant


CI = PRESETS["ci"]


def make(variant):
    return build_variant(variant, "ci")


def toks(cfg, key, batch=2):
    return jax.random.randint(jax.random.PRNGKey(key), (batch, cfg.ctx), 0, cfg.vocab)


# ---------------------------------------------------------------------------
# Registry / config invariants
# ---------------------------------------------------------------------------


def test_all_variants_build_and_validate():
    for preset in PRESETS:
        for v in VARIANTS:
            cfg = build_variant(v, preset)
            assert cfg.n_layers == PRESETS[preset].n_layers


def test_param_budget_parity_paper():
    """Table 1's premise: every variant ≈ the same parameter budget."""
    counts = {v: build_variant(v, "paper").param_count() for v in VARIANTS}
    gpt = counts["gpt"]
    for v, c in counts.items():
        assert abs(c - gpt) / gpt < 0.10, f"{v}: {c} vs gpt {gpt}"


def test_shift_schedule_doubles_per_layer():
    cfg = make("hsm_ab")
    shifts = [l.shifts[0] for l in cfg.layers]
    for i in range(1, len(shifts)):
        assert shifts[i] == min(2 * shifts[i - 1], cfg.ctx // 2) or shifts[i] == cfg.ctx // 2


def test_multihead_ext_rotates_shifts():
    cfg = make("hsm_ab_mhext")
    base = cfg.layers[0].shifts
    for l, spec in enumerate(cfg.layers):
        assert spec.shifts == configs.rotate(base, l) or l == 0


def test_hybrid_layer_placement():
    cfg = make("hybrid_06")
    kinds = [l.kind for l in cfg.layers]
    assert kinds[0] == "ab" and kinds[-1] == "ab"
    assert all(k == "attn" for k in kinds[1:-1])
    cfg2 = make("hybrid_l3gpt")
    kinds2 = [l.kind for l in cfg2.layers]
    assert kinds2[len(kinds2) // 2] == "attn"
    assert sum(k == "attn" for k in kinds2) == 1


def test_unknown_variant_rejected():
    with pytest.raises(ValueError):
        build_variant("nope", "ci")


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("variant", VARIANTS)
def test_forward_shapes_and_finiteness(variant):
    cfg = make(variant)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    logits = model.forward(cfg, params, toks(cfg, 1))
    assert logits.shape == (2, cfg.ctx, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_init_matches_specs():
    cfg = make("gpt")
    specs = model.param_specs(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    assert len(specs) == len(params)
    for s, p in zip(specs, params):
        assert tuple(p.shape) == s.shape, s.name


def test_initial_loss_near_uniform():
    for variant in ["hsm_ab", "gpt"]:
        cfg = make(variant)
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        loss, acc = model.loss_and_accuracy(cfg, params, toks(cfg, 1), toks(cfg, 2))
        assert abs(float(loss) - math.log(cfg.vocab)) < 0.5
        assert float(acc) < 0.05


@pytest.mark.parametrize("variant", ["hsm_ab", "hsm_vec", "hsm_gate2", "hsm_fusion", "gpt"])
def test_pallas_and_jnp_backends_agree(variant):
    cfg = make(variant)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg, 3)
    lp = model.forward(cfg, params, t, use_pallas=True)
    lr = model.forward(cfg, params, t, use_pallas=False)
    np.testing.assert_allclose(lp, lr, rtol=2e-3, atol=1e-4)


@pytest.mark.parametrize("variant", ["hsm_ab", "hsm_ab_mh", "hsm_gate1", "gpt"])
def test_model_causality(variant):
    """Changing future tokens must not affect past logits (any mixer)."""
    cfg = make(variant)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg, 4, batch=1)
    base = model.forward(cfg, params, t)
    t2 = t.at[:, cfg.ctx // 2 :].set((t[:, cfg.ctx // 2 :] + 7) % cfg.vocab)
    pert = model.forward(cfg, params, t2)
    np.testing.assert_allclose(
        base[:, : cfg.ctx // 2], pert[:, : cfg.ctx // 2], rtol=1e-4, atol=1e-5
    )


def test_dropout_only_in_training():
    cfg = make("hsm_ab")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg, 5)
    e1 = model.forward(cfg, params, t, training=False)
    e2 = model.forward(cfg, params, t, training=False)
    np.testing.assert_allclose(e1, e2)
    d1 = model.forward(cfg, params, t, training=True, rng=jax.random.PRNGKey(1))
    d2 = model.forward(cfg, params, t, training=True, rng=jax.random.PRNGKey(2))
    assert not np.allclose(d1, d2), "dropout should vary with the rng"


# ---------------------------------------------------------------------------
# Train / eval / decode steps
# ---------------------------------------------------------------------------


def run_steps(variant, n=8):
    cfg = make(variant)
    hp = CI
    ts = jax.jit(steps.make_train_step(cfg, hp))
    params = list(steps.make_init_fn(cfg)(jnp.uint32(0)))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    x = jax.random.randint(jax.random.PRNGKey(1), (hp.batch, cfg.ctx), 0, cfg.vocab)
    # Learnable target: y = x shifted (structure the model can latch onto).
    y = jnp.roll(x, -1, axis=1)
    losses = []
    for i in range(n):
        params, m, v, loss, acc = ts(params, m, v, jnp.int32(i), x, y)
        losses.append(float(loss))
    return cfg, params, losses


@pytest.mark.parametrize("variant", ["hsm_ab", "gpt", "hybrid_mh_06"])
def test_loss_decreases_over_steps(variant):
    _, _, losses = run_steps(variant)
    assert losses[-1] < losses[0] - 0.3, losses


def test_eval_step_matches_loss_fn():
    cfg = make("hsm_ab")
    params = list(steps.make_init_fn(cfg)(jnp.uint32(0)))
    es = jax.jit(steps.make_eval_step(cfg))
    x, y = toks(cfg, 1, CI.batch), toks(cfg, 2, CI.batch)
    loss, acc = es(params, x, y)
    loss2, acc2 = model.loss_and_accuracy(cfg, params, x, y)
    np.testing.assert_allclose(loss, loss2, rtol=1e-5)
    np.testing.assert_allclose(acc, acc2, rtol=1e-5)


def test_decode_matches_forward():
    cfg = make("hsm_ab")
    params = list(steps.make_init_fn(cfg)(jnp.uint32(0)))
    df = jax.jit(steps.make_decode_fn(cfg))
    t = toks(cfg, 3, batch=1)
    np.testing.assert_allclose(
        df(params, t), model.forward(cfg, params, t), rtol=1e-4, atol=1e-5
    )


def test_init_fn_deterministic_per_seed():
    cfg = make("hsm_ab")
    f = steps.make_init_fn(cfg)
    a = f(jnp.uint32(7))
    b = f(jnp.uint32(7))
    c = f(jnp.uint32(8))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, z) for x, z in zip(a, c))


def test_adamw_decays_only_flagged_params():
    from compile.optimizer import adamw_update

    cfg = make("hsm_ab")
    specs = model.param_specs(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    zeros = [jnp.zeros_like(p) for p in params]
    # Zero gradients: any movement must come from weight decay alone.
    new_p, _, _ = adamw_update(specs, params, zeros, zeros, zeros, jnp.int32(0), CI)
    for s, p, np_ in zip(specs, params, new_p):
        moved = bool(jnp.any(jnp.abs(p - np_) > 0))
        if s.decay:
            assert moved == bool(jnp.any(jnp.abs(p) > 0)), s.name
        else:
            assert not moved, f"{s.name} moved without decay flag"
