//! Quickstart: the whole public API in ~60 lines.
//!
//! Synthesises a corpus, trains a BPE tokenizer, builds the dataset,
//! trains the paper's best pure-HSM variant (`hsm_ab`, ci preset) for a
//! few steps through the PJRT runtime, evaluates, and generates text.
//!
//! ```bash
//! make artifacts            # once
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use hsm::config::Manifest;
use hsm::coordinator::{Trainer, TrainerOptions};
use hsm::corpus;
use hsm::data::Dataset;
use hsm::generation::{generate_windowed, SampleCfg};
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::tokenizer::trainer as bpe;

fn main() -> Result<()> {
    // 1. Load the AOT-compiled artifact set (python ran once, at build time).
    let manifest = Manifest::load_variant("artifacts".as_ref(), "ci", "hsm_ab")?;
    println!(
        "model: {} — dim {}, ctx {}, vocab {}, {} params",
        manifest.display_name, manifest.dim, manifest.ctx, manifest.vocab, manifest.param_count
    );

    // 2. Data: synthetic TinyStories → BPE tokenizer → windows.
    let text = corpus::generate(1234, 1500);
    let tok = bpe::train(&text, manifest.vocab)?;
    let (train, val, stats) = Dataset::build(&text, &tok, manifest.ctx, 0.9, 42)?;
    println!(
        "data: {} stories → {} windows ({} train / {} val)",
        stats.stories_total, stats.windows, train.len(), val.len()
    );

    // 3. Train for a handful of steps (first step pays the XLA compile).
    let mut engine = PjrtEngine::new(manifest)?;
    let mut trainer = Trainer::new(
        &mut engine,
        TrainerOptions {
            epochs: 1,
            max_steps: Some(30),
            eval_batches: Some(4),
            log_every: 10,
            ..Default::default()
        },
    );
    let outcome = trainer.run(&train, &val)?;
    println!(
        "trained {} steps: val loss {:.4} (uniform would be {:.4})",
        outcome.total_steps,
        outcome.final_val_loss(),
        (engine.manifest().vocab as f32).ln()
    );

    // 4. Generate.
    let cfg = SampleCfg { temperature: 0.8, top_k: 40, max_new_tokens: 32, seed: 7, ..Default::default() };
    let g = generate_windowed(&mut engine, &tok, "Once upon a time", &cfg)?;
    println!("\nprompt:     {}", g.prompt);
    println!("completion: {}", g.completion.trim());
    Ok(())
}
