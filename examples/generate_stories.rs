//! Qualitative evaluation driver: the paper's Table-3 prompt suite.
//!
//! Loads a trained checkpoint (from `train_tinystories` or `hsm train
//! --checkpoint-out`), runs the 11 prompts, and prints prompt →
//! completion pairs at several temperatures, demonstrating the
//! user-controllable determinism the paper discusses in §2.
//!
//! ```bash
//! cargo run --release --example generate_stories -- --checkpoint runs/e2e.ckpt
//! ```

use anyhow::{anyhow, bail, Result};
use hsm::checkpoint::Checkpoint;
use hsm::config::Manifest;
use hsm::corpus;
use hsm::generation::{generate_windowed, SampleCfg, TABLE3_PROMPTS};
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::tokenizer::trainer as bpe;
use hsm::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::new("generate_stories")
        .flag("preset", "ci", "artifact preset")
        .flag("variant", "hsm_ab", "model variant (must match checkpoint)")
        .optional("checkpoint", "trained checkpoint (default: fresh init)")
        .flag("corpus-bytes", "2000000", "corpus size (tokenizer must match training)")
        .flag("max-new-tokens", "24", "completion length")
        .flag("temperature", "0", "0 = greedy (the Table-3 setting)")
        .parse(&argv)
        .map_err(|e| anyhow!(e))?;

    let manifest = Manifest::load_variant("artifacts".as_ref(), &a.str("preset"), &a.str("variant"))?;
    let mut engine = PjrtEngine::new(manifest.clone())?;
    match a.get("checkpoint") {
        Some(p) => {
            let ck = Checkpoint::load(p.as_ref())?;
            if ck.meta_value("variant") != Some(&a.str("variant")) {
                bail!("checkpoint variant mismatch: {:?}", ck.meta_value("variant"));
            }
            engine.set_params(ck.group("param"))?;
            println!("loaded checkpoint at step {}", ck.step());
        }
        None => {
            engine.init(42)?;
            println!("(no checkpoint — sampling from a FRESH INIT; expect noise)");
        }
    }

    // The tokenizer is reconstructed deterministically from the same corpus
    // seed used in training (it is a pure function of corpus + vocab).
    let text = corpus::generate(1234, a.usize("corpus-bytes").map_err(|e| anyhow!(e))? / 500);
    let tok = bpe::train(&text, manifest.vocab)?;

    let temp: f32 = a.f64("temperature").map_err(|e| anyhow!(e))? as f32;
    println!("\n=== Table 3 prompt suite ({}, T={temp}) ===\n", manifest.display_name);
    for (i, prompt) in TABLE3_PROMPTS.iter().enumerate() {
        let cfg = SampleCfg {
            temperature: temp,
            top_k: 40,
            max_new_tokens: a.usize("max-new-tokens").map_err(|e| anyhow!(e))?,
            seed: i as u64,
            stop_at_eot: true,
        };
        match generate_windowed(&mut engine, &tok, prompt, &cfg) {
            Ok(g) => println!("{:>2}. {} ▸{}\n", i + 1, g.prompt, g.completion),
            Err(e) => println!("{:>2}. (prompt too long for ctx: {e})\n", i + 1),
        }
    }
    Ok(())
}
