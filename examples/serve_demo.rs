//! Continuous-batching serving demo — the multi-user story, end to end,
//! with no artifacts and no PJRT.
//!
//! Builds a synthetic HSM (a,b) model (one `Arc`-shared weight set),
//! trains a byte-BPE tokenizer on the synthetic corpus, then pushes a
//! queue of requests through [`hsm::serve::Scheduler`]: at most
//! `--max-active` concurrent decode sessions, `--threads` workers
//! stepping disjoint sessions in parallel, and admission the moment a
//! session frees up (no barrier at batch end).
//!
//! Because every request samples from its own RNG stream
//! (`seed ^ request_id`), the output text is byte-identical whatever
//! `--threads`/`--max-active` you pick — the demo verifies that against
//! a sequential single-session reference before printing throughput.
//!
//! ```bash
//! cargo run --release --example serve_demo -- --requests 24 --threads 4
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};
use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{self, SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{Request, Scheduler, ServeCfg};
use hsm::util::cli::Args;

fn synthetic_model(ctx: usize, vocab: usize) -> Result<Arc<Model>> {
    let layers: Vec<LayerInfo> = (0..4)
        .map(|l| LayerInfo {
            kind: "ab".to_string(),
            heads: 4,
            shifts: vec![(1usize << l).min(ctx / 2)],
            ffn: 128,
        })
        .collect();
    let m = Manifest::synthetic("hsm_ab", layers, 64, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 23);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat)?)
}

fn main() -> Result<()> {
    let a = Args::new("serve_demo")
        .flag("requests", "24", "number of requests (prompts cycle the Table-3 suite)")
        .flag("max-active", "6", "admission cap: concurrent decode sessions")
        .flag("threads", "4", "worker threads")
        .flag("max-new-tokens", "48", "tokens per request")
        .parse(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|e| anyhow!(e))?;
    let n = a.usize("requests").map_err(|e| anyhow!(e))?;

    let text = hsm::corpus::generate(1234, 400);
    let tok = hsm::tokenizer::trainer::train(&text, 400)?;
    let model = synthetic_model(192, tok.vocab_size())?;
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: a.usize("max-new-tokens").map_err(|e| anyhow!(e))?,
        seed: 7,
        stop_at_eot: true,
    };

    let requests: Vec<Request> = (0..n)
        .map(|i| Request::new(i as u64, TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]))
        .collect();

    // Sequential single-session reference for the determinism check.
    let reference: Vec<String> = requests
        .iter()
        .map(|r| {
            let solo = SampleCfg { seed: sample.seed ^ r.id, ..sample.clone() };
            Ok(generation::generate(&mut model.session(), &tok, &r.prompt, &solo)?.completion)
        })
        .collect::<Result<_>>()?;

    let cfg = ServeCfg {
        max_active: a.usize("max-active").map_err(|e| anyhow!(e))?,
        threads: a.usize("threads").map_err(|e| anyhow!(e))?,
        quantum: 16,
        sample,
        ..Default::default()
    };
    let (max_active, threads) = (cfg.max_active, cfg.threads);
    let sched = Scheduler::new(Arc::clone(&model), cfg)?;

    let t0 = Instant::now();
    let completions = sched.serve(&tok, requests)?;
    let secs = t0.elapsed().as_secs_f64();

    let mut tokens = 0usize;
    for (c, want) in completions.iter().zip(&reference) {
        assert_eq!(
            &c.completion, want,
            "scheduling must never change sampled text (request {})",
            c.request_id
        );
        tokens += c.tokens_generated;
        let head: String = c.completion.replace('\n', " ").chars().take(48).collect();
        println!("#{:<3} {:>3} tok  {head}", c.request_id, c.tokens_generated);
    }
    println!(
        "\n{} requests / {tokens} tokens in {secs:.2}s — {:.1} tok/s \
         (max_active {max_active}, threads {threads}; output byte-identical to sequential)",
        completions.len(),
        tokens as f64 / secs.max(1e-9),
    );
    Ok(())
}
