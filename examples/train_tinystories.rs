//! End-to-end training driver — the repo's headline validation run.
//!
//! Trains one variant on the TinyStories-like corpus through the full
//! three-layer stack (rust coordinator → PJRT → AOT HLO containing the
//! Pallas kernels), logging the loss curve, and finishes with sampled
//! stories.  The run recorded in EXPERIMENTS.md §E2E used:
//!
//! ```bash
//! cargo run --release --example train_tinystories -- \
//!     --preset ci --variant hsm_ab --steps 300 --corpus-bytes 2000000
//! ```
//!
//! (`--preset desktop` runs the paper-scale architecture: dim 256,
//! ctx 128 — about 100× more FLOPs per step; same code path.)

use anyhow::{anyhow, Result};
use hsm::checkpoint::Checkpoint;
use hsm::config::Manifest;
use hsm::coordinator::{Trainer, TrainerOptions};
use hsm::corpus;
use hsm::data::Dataset;
use hsm::generation::{generate_windowed, SampleCfg};
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::tokenizer::trainer as bpe;
use hsm::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::new("train_tinystories")
        .flag("preset", "ci", "artifact preset")
        .flag("variant", "hsm_ab", "model variant")
        .flag("steps", "300", "optimizer steps")
        .flag("epochs", "100", "epoch cap (steps usually bind first)")
        .flag("corpus-bytes", "2000000", "synthetic corpus size")
        .flag("seed", "42", "init seed")
        .flag("out", "runs/e2e.ckpt", "checkpoint output")
        .parse(&argv)
        .map_err(|e| anyhow!(e))?;

    let manifest = Manifest::load_variant("artifacts".as_ref(), &a.str("preset"), &a.str("variant"))?;
    println!(
        "=== E2E: {} ({} preset, {} params) ===",
        manifest.display_name, manifest.preset, manifest.param_count
    );

    let text = corpus::generate(1234, a.usize("corpus-bytes").map_err(|e| anyhow!(e))? / 500);
    println!("corpus: {} bytes", text.len());
    let tok = bpe::train(&text, manifest.vocab)?;
    let (train, val, stats) = Dataset::build(&text, &tok, manifest.ctx, 0.9, 42)?;
    println!(
        "dataset: {} windows ({} stories, {} filtered), {} train / {} val",
        stats.windows, stats.stories_total, stats.stories_filtered, train.len(), val.len()
    );

    let mut engine = PjrtEngine::new(manifest.clone())?;
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(
        &mut engine,
        TrainerOptions {
            epochs: a.usize("epochs").map_err(|e| anyhow!(e))?,
            max_steps: Some(a.usize("steps").map_err(|e| anyhow!(e))?),
            seed: a.u64("seed").map_err(|e| anyhow!(e))?,
            eval_batches: Some(8),
            log_every: 20,
            record_steps: true,
        },
    );
    let outcome = trainer.run(&train, &val)?;
    println!("\n=== loss curve (per-epoch) ===");
    for e in &outcome.epochs {
        println!(
            "epoch {:>2}: train {:.4}  val {:.4}  acc {:.4}  ({:.1}s, {} steps)",
            e.epoch, e.train_loss, e.val_loss, e.val_acc, e.secs, e.steps
        );
    }
    println!(
        "total: {} steps in {:.1}s ({:.0} ms/step steady-state)",
        outcome.total_steps,
        outcome.total_secs,
        1e3 * outcome.total_secs / outcome.total_steps as f64
    );
    let _ = t0;

    // Checkpoint (embeds a manifest snapshot, so `hsm generate/serve
    // --engine native` can run from it with no artifact directory).
    let (m, v) = engine.get_state()?;
    Checkpoint::from_training(&manifest, outcome.total_steps, engine.get_params()?, m, v)
        .save(a.str("out").as_ref())?;
    println!("checkpoint → {}", a.str("out"));

    // Sample a few stories.
    println!("\n=== samples ===");
    for (i, prompt) in ["Once upon a time", "One day, Lily went to", "There once was a"]
        .iter()
        .enumerate()
    {
        let cfg = SampleCfg {
            temperature: 0.8,
            top_k: 40,
            max_new_tokens: 48,
            seed: 100 + i as u64,
            ..Default::default()
        };
        let g = generate_windowed(&mut engine, &tok, prompt, &cfg)?;
        println!("[{i}] {}{}\n", g.prompt, g.completion);
    }
    Ok(())
}
