//! Ablation: hybrid HSM/GPT layer placement (the paper's §5 + §7 claim
//! that replacing the FIRST and LAST attention layers with HSM (a,b)
//! layers matches or beats pure GPT while training faster).
//!
//! Trains `gpt`, `hsm_ab`, `hybrid_06`, `hybrid_mh_06` and the Fig-7
//! hybrid `hybrid_l3gpt` under identical data/steps and prints a
//! comparison table: final val loss, time/epoch, and speed vs GPT.
//!
//! ```bash
//! cargo run --release --example hybrid_sweep -- --steps 150
//! ```

use anyhow::{anyhow, Result};
use hsm::report::{self, ExperimentCtx, PjrtFactory};
use hsm::util::cli::Args;

const SWEEP: &[&str] = &["gpt", "hsm_ab", "hybrid_06", "hybrid_mh_06", "hybrid_l3gpt"];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::new("hybrid_sweep")
        .flag("preset", "ci", "artifact preset")
        .flag("steps", "150", "optimizer steps per variant")
        .flag("epochs", "50", "epoch cap")
        .flag("corpus-bytes", "1000000", "corpus size")
        .parse(&argv)
        .map_err(|e| anyhow!(e))?;

    let mut ctx = ExperimentCtx::new(&a.str("preset"));
    ctx.reports_dir = "reports/hybrid_sweep".into();
    ctx.epochs = a.usize("epochs").map_err(|e| anyhow!(e))?;
    ctx.max_steps = Some(a.usize("steps").map_err(|e| anyhow!(e))?);
    ctx.corpus_bytes = a.usize("corpus-bytes").map_err(|e| anyhow!(e))?;
    ctx.eval_batches = Some(8);
    ctx.log_every = 50;

    let factory = PjrtFactory::new(&ctx.preset);
    let outcomes = report::sweep(&factory, &ctx, SWEEP)?;

    let gpt = outcomes.iter().find(|o| o.variant == "gpt").unwrap();
    println!("\n=== hybrid placement ablation ({} steps each) ===", a.str("steps"));
    println!("{:<16} {:>10} {:>12} {:>12}", "variant", "val loss", "s/epoch", "vs GPT");
    for o in &outcomes {
        println!(
            "{:<16} {:>10.4} {:>12.1} {:>11.2}×",
            o.variant,
            o.final_val_loss(),
            o.secs_per_epoch(),
            o.secs_per_epoch() / gpt.secs_per_epoch()
        );
    }
    println!(
        "\npaper's shape: hybrids ≈ or < GPT loss at < GPT time; pure HSM fastest.\n\
         (absolute values differ from Table 1 — scaled preset, fewer steps.)"
    );
    // keep the factory alive until the end (one engine per variant)
    Ok(())
}
