//! HTTP streaming serving demo — the cross-process story, end to end,
//! with no artifacts and no PJRT.
//!
//! Builds a synthetic HSM (a,b) model, starts the resident
//! [`hsm::serve::StreamScheduler`] and the [`hsm::server::HttpServer`]
//! front-end on a loopback port, then plays both roles: streaming
//! clients hit `POST /v1/stream` concurrently and print per-token
//! time-to-first-token, and the demo verifies every streamed byte
//! against a sequential single-session reference before shutting the
//! server down gracefully.
//!
//! ```bash
//! cargo run --release --example http_serve_demo -- --requests 8 --clients 4
//! ```
//!
//! While it runs you can also hit the printed address yourself:
//!
//! ```bash
//! curl -sN http://ADDR/v1/stream -d '{"prompt": "Once upon a time"}'
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};
use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{self, SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{ServeCfg, StreamScheduler};
use hsm::server::api::GenerateRequest;
use hsm::server::{client, HttpServer};
use hsm::util::cli::Args;

fn synthetic_model(ctx: usize, vocab: usize) -> Result<Arc<Model>> {
    let layers: Vec<LayerInfo> = (0..4)
        .map(|l| LayerInfo {
            kind: "ab".to_string(),
            heads: 4,
            shifts: vec![(1usize << l).min(ctx / 2)],
            ffn: 128,
        })
        .collect();
    let m = Manifest::synthetic("hsm_ab", layers, 64, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 23);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat)?)
}

fn main() -> Result<()> {
    let a = Args::new("http_serve_demo")
        .flag("requests", "8", "number of streaming requests (prompts cycle the Table-3 suite)")
        .flag("clients", "4", "concurrent client threads")
        .flag("max-active", "4", "admission cap: concurrent decode sessions")
        .flag("threads", "4", "scheduler worker threads")
        .flag("max-new-tokens", "32", "tokens per request")
        .parse(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(|e| anyhow!(e))?;
    let n = a.usize("requests").map_err(|e| anyhow!(e))?;
    let clients = a.usize("clients").map_err(|e| anyhow!(e))?.max(1);

    let text = hsm::corpus::generate(1234, 400);
    let tok = hsm::tokenizer::trainer::train(&text, 400)?;
    let model = synthetic_model(192, tok.vocab_size())?;
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: a.usize("max-new-tokens").map_err(|e| anyhow!(e))?,
        seed: 7,
        stop_at_eot: true,
    };

    // Sequential single-session reference for the determinism check.
    let reference: Vec<String> = (0..n)
        .map(|i| {
            let prompt = TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()];
            let solo = SampleCfg { seed: sample.seed ^ i as u64, ..sample.clone() };
            Ok(generation::generate(&mut model.session(), &tok, prompt, &solo)?.completion)
        })
        .collect::<Result<_>>()?;

    let cfg = ServeCfg {
        max_active: a.usize("max-active").map_err(|e| anyhow!(e))?,
        threads: a.usize("threads").map_err(|e| anyhow!(e))?,
        quantum: 8,
        sample,
        ..Default::default()
    };
    let sched = Arc::new(StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg)?);
    let server = HttpServer::bind("127.0.0.1:0", sched)?;
    let addr = server.local_addr().to_string();
    println!("serving on http://{addr}  (also try: curl -sN http://{addr}/v1/stream -d '{{\"prompt\": \"Once upon a time\"}}')\n");

    let t0 = Instant::now();
    let results = std::thread::scope(|s| -> Result<Vec<(usize, String, f64, usize)>> {
        let handles: Vec<_> = (0..clients)
            .map(|w| {
                let addr = addr.clone();
                s.spawn(move || -> Result<Vec<(usize, String, f64, usize)>> {
                    let mut out = Vec::new();
                    for i in (w..n).step_by(clients) {
                        let mut req =
                            GenerateRequest::new(TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]);
                        req.id = Some(i as u64);
                        let sent = Instant::now();
                        let mut ttft_ms = f64::NAN;
                        let mut streamed = String::new();
                        let completion = client::stream(&addr, &req, |_, delta| {
                            if ttft_ms.is_nan() {
                                ttft_ms = sent.elapsed().as_secs_f64() * 1e3;
                            }
                            streamed.push_str(delta);
                        })?;
                        out.push((i, streamed, ttft_ms, completion.tokens_generated));
                    }
                    Ok(out)
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().expect("client thread panicked")?);
        }
        Ok(all)
    })?;
    let secs = t0.elapsed().as_secs_f64();

    let mut results = results;
    results.sort_by_key(|(i, ..)| *i);
    let mut tokens = 0usize;
    for (i, streamed, ttft_ms, toks) in &results {
        assert_eq!(
            streamed, &reference[*i],
            "streamed text must match the sequential reference (request {i})"
        );
        tokens += toks;
        let head: String = streamed.replace('\n', " ").chars().take(40).collect();
        println!("#{i:<3} ttft {ttft_ms:>6.1}ms  {toks:>3} tok  {head}");
    }
    println!(
        "\n{} streamed requests / {tokens} tokens in {secs:.2}s — {:.1} tok/s over HTTP \
         ({clients} clients; every byte identical to sequential decoding)",
        results.len(),
        tokens as f64 / secs.max(1e-9),
    );

    server.shutdown();
    println!("server shut down gracefully");
    Ok(())
}
