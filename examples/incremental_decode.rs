//! The linear-time inference story: native incremental decoding vs the
//! full-context windowed decode.
//!
//! The paper's complexity argument (§3) says HSM needs O(1) work per layer
//! per generated token, while attention needs O(t).  The windowed path
//! (what the PJRT `decode` artifact forces) recomputes the whole window
//! every token, so this example decodes the same greedy continuation
//! through both [`hsm::infer::Decoder`] implementations and reports
//! per-token cost:
//!
//! 1. [`WindowDecoder`] over a full-context forward — the artifact-shaped
//!    baseline (PJRT artifacts when present, else the native
//!    [`WindowEngine`] reference forward),
//! 2. [`hsm::infer::NativeDecoder`] — ring buffers / KV cache, O(1) per
//!    HSM layer,
//!
//! and verifies 1 ≡ 2 on the greedy token sequence along the way.  With
//! no artifacts on disk it runs entirely from deterministic synthetic
//! weights, so it works on a fresh checkout:
//!
//! ```bash
//! cargo run --release --example incremental_decode -- --tokens 96
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};
use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{argmax, WindowDecoder};
use hsm::infer::{weights, Decoder, Model, ModelWeights, WindowEngine};
use hsm::util::cli::Args;

/// Greedy-decode `n` tokens from the fixed start token; returns the
/// sequence and seconds/token.
fn greedy<D: Decoder>(dec: &mut D, n: usize) -> Result<(Vec<u32>, f64)> {
    dec.reset();
    let mut toks = vec![1u32];
    let t0 = Instant::now();
    for _ in 0..n {
        let logits = dec.step(*toks.last().unwrap())?;
        toks.push(argmax(logits));
    }
    Ok((toks, t0.elapsed().as_secs_f64() / n as f64))
}

fn synthetic(variant: &str, kind: &str, ctx: usize) -> Result<Arc<Model>> {
    let layers: Vec<LayerInfo> = (0..4)
        .map(|l| LayerInfo {
            kind: kind.to_string(),
            heads: 4,
            shifts: if kind == "attn" { vec![] } else { vec![(1usize << l).min(ctx / 2)] },
            ffn: 128,
        })
        .collect();
    let m = Manifest::synthetic(variant, layers, 64, ctx, 512, 1);
    let flat = weights::seeded_flat(&m, 23);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat)?)
}

#[cfg(feature = "pjrt")]
mod pjrt_path {
    use super::*;
    use hsm::runtime::{PjrtEngine, StepEngine};

    /// Trained artifact weights + the live engine, when loadable.
    pub fn load(preset: &str, variant: &str) -> Option<(Arc<Model>, PjrtEngine)> {
        let m = Manifest::load_variant("artifacts".as_ref(), preset, variant).ok()?;
        let mut eng = PjrtEngine::new(m.clone()).ok()?;
        eng.init(3).ok()?;
        let w = ModelWeights::from_flat(&m, &eng.get_params().ok()?).ok()?;
        Some((Model::shared(m, w).ok()?, eng))
    }

    /// Decode through the artifact itself (same `Decoder` trait) and
    /// compare against the native greedy sequence.
    pub fn compare(eng: Option<PjrtEngine>, variant: &str, nat: &[u32], n: usize) {
        let Some(mut eng) = eng else { return };
        match greedy(&mut WindowDecoder::new(&mut eng, 0), n) {
            Ok((pj, pj_per_tok)) => println!(
                "{variant:10} (pjrt artifact): {:8.3} ms/tok | matches native: {}",
                pj_per_tok * 1e3,
                if pj == nat { "YES" } else { "within fp tolerance only" },
            ),
            Err(e) => eprintln!("  (pjrt decode skipped: {e})"),
        }
    }

    pub fn pick(
        preset: &str,
        variant: &str,
        kind: &str,
        ctx: usize,
    ) -> Result<(Arc<Model>, &'static str, Option<PjrtEngine>)> {
        if let Some((m, e)) = load(preset, variant) {
            return Ok((m, "artifacts", Some(e)));
        }
        Ok((synthetic(variant, kind, ctx)?, "synthetic", None))
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_path {
    use super::*;

    /// Placeholder engine type for builds without the PJRT runtime.
    pub enum Never {}

    pub fn compare(_eng: Option<Never>, _variant: &str, _nat: &[u32], _n: usize) {}

    pub fn pick(
        _preset: &str,
        variant: &str,
        kind: &str,
        ctx: usize,
    ) -> Result<(Arc<Model>, &'static str, Option<Never>)> {
        Ok((synthetic(variant, kind, ctx)?, "synthetic", None))
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::new("incremental_decode")
        .flag("preset", "ci", "artifact preset (used when artifacts exist)")
        .flag("tokens", "96", "tokens to decode")
        .flag("ctx", "192", "context length for the synthetic fallback model")
        .parse(&argv)
        .map_err(|e| anyhow!(e))?;
    let preset = a.str("preset");
    let n_tokens = a.usize("tokens").map_err(|e| anyhow!(e))?;
    let synth_ctx = a.usize("ctx").map_err(|e| anyhow!(e))?;

    for (variant, kind) in [("hsm_ab", "ab"), ("gpt", "attn")] {
        // Prefer real trained artifacts when the PJRT runtime can load
        // them; otherwise deterministic synthetic weights.
        let (model, source, pjrt_engine) = pjrt_path::pick(&preset, variant, kind, synth_ctx)?;
        let ctx = model.manifest.ctx;
        let n = n_tokens.min(ctx - 2);

        // 1. Windowed baseline: full-context forward per token.
        let mut weng = WindowEngine::new(Arc::clone(&model));
        let (win, win_per_tok) = greedy(&mut WindowDecoder::new(&mut weng, 0), n)?;

        // 2. Native incremental decode.
        let (nat, nat_per_tok) = greedy(&mut model.session(), n)?;

        let agree = win == nat;
        println!(
            "{variant:10} ({source}, ctx {ctx}): windowed {:8.3} ms/tok | incremental {:8.3} ms/tok ({:5.1}×) | greedy match: {}",
            win_per_tok * 1e3,
            nat_per_tok * 1e3,
            win_per_tok / nat_per_tok,
            if agree { "YES" } else { "NO (fp tie-break)" },
        );

        // 3. PJRT artifact decode, when a real xla build + artifacts exist.
        pjrt_path::compare(pjrt_engine, variant, &nat, n);
    }
    println!(
        "\nHSM's ring-buffer decode does O(1) work per layer per token; the\n\
         attention KV-cache path grows with position — the paper's complexity\n\
         claim, visible as the gap between the two rows at long ctx."
    );
    Ok(())
}
