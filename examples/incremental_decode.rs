//! The linear-time inference story: native incremental decoding vs the
//! full-context PJRT decode.
//!
//! The paper's complexity argument (§3) says HSM needs O(1) work per layer
//! per generated token, while attention needs O(t).  The PJRT `decode`
//! artifact recomputes the whole window every token, so this example
//! decodes the same continuation three ways and reports per-token cost:
//!
//! 1. PJRT full-context forward (what `hsm generate` uses),
//! 2. native incremental engine, HSM variant (ring buffers, O(1)/layer),
//! 3. native incremental engine, GPT variant (KV cache, O(t)/layer),
//!
//! and verifies 1 ≡ 2 on logits argmax along the way.
//!
//! ```bash
//! cargo run --release --example incremental_decode -- --tokens 48
//! ```

use std::time::Instant;

use anyhow::{anyhow, Result};
use hsm::config::Manifest;
use hsm::generation::argmax;
use hsm::infer::{InferenceEngine, ModelWeights};
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::new("incremental_decode")
        .flag("preset", "ci", "artifact preset")
        .flag("tokens", "48", "tokens to decode")
        .parse(&argv)
        .map_err(|e| anyhow!(e))?;
    let preset = a.str("preset");
    let n_tokens = a.usize("tokens").map_err(|e| anyhow!(e))?;

    for variant in ["hsm_ab", "gpt"] {
        let m = Manifest::load_variant("artifacts".as_ref(), &preset, variant)?;
        let ctx = m.ctx;
        let vocab = m.vocab;
        let n = n_tokens.min(ctx - 1);

        let mut pjrt = PjrtEngine::new(m.clone())?;
        pjrt.init(3)?;
        let weights = ModelWeights::from_flat(&m, &pjrt.get_params()?)?;
        let mut native = InferenceEngine::new(m.clone(), weights)?;

        // --- PJRT full-context greedy decode ---
        let mut toks: Vec<i32> = vec![1];
        pjrt.decode(&{
            let mut w = toks.clone();
            w.resize(ctx, 0);
            w
        })?; // compile outside timing
        let t0 = Instant::now();
        for _ in 0..n {
            let mut window = toks.clone();
            window.resize(ctx, 0);
            let logits = pjrt.decode(&window)?;
            let pos = toks.len() - 1;
            let next = argmax(&logits[pos * vocab..(pos + 1) * vocab]);
            toks.push(next as i32);
        }
        let pjrt_per_tok = t0.elapsed().as_secs_f64() / n as f64;

        // --- native incremental greedy decode ---
        let t0 = Instant::now();
        let mut ntoks: Vec<u32> = vec![1];
        for _ in 0..n {
            let logits = native.step(*ntoks.last().unwrap())?;
            ntoks.push(argmax(logits));
        }
        let native_per_tok = t0.elapsed().as_secs_f64() / n as f64;

        // Greedy sequences must agree (logits parity is asserted to 2e-3
        // in runtime_e2e; argmax equality is the user-visible form).
        let agree = toks.iter().map(|&t| t as u32).eq(ntoks.iter().copied());
        println!(
            "{variant:10} ({preset}): PJRT full-ctx {:8.3} ms/tok | native incremental {:8.3} ms/tok ({:4.1}× ) | greedy match: {}",
            pjrt_per_tok * 1e3,
            native_per_tok * 1e3,
            pjrt_per_tok / native_per_tok,
            if agree { "YES" } else { "NO (fp tie-break)" },
        );
    }
    println!(
        "\nHSM's ring-buffer decode does O(1) work per layer per token; the\n\
         attention KV-cache path grows with position — the paper's complexity\n\
         claim, visible as the gap between the two native rows at long ctx."
    );
    Ok(())
}
