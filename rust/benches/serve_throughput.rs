//! Bench: continuous-batching serve throughput vs the fixed round-robin
//! baseline, plus the blocked-matvec before/after.
//!
//! Three questions, answered over identical synthetic weights (no
//! artifacts, no PJRT):
//!
//! 1. **Scaling** — tokens/sec of the threaded [`hsm::serve::Scheduler`]
//!    across a threads × max-active grid, against single-threaded
//!    [`hsm::generation::generate_batch`] round-robin over the same
//!    requests.  The acceptance bar: T ≥ 4 threads beats the
//!    single-threaded round-robin.
//! 2. **Overhead** — the scheduler at 1 thread vs raw `generate_batch`:
//!    what admission/queue bookkeeping costs when there is no
//!    parallelism to win.
//! 3. **Kernel tiers** — naive vs blocked vs dispatched `matvec` /
//!    `matvec_t` on the forward hot-path shapes.  "Dispatched" is what
//!    the engine actually calls: the blocked forms by default, the
//!    explicit-SIMD forms under `--features simd` (the row reports
//!    [`tensor::kernel_backend`], so a `scalar` row and an `avx2` row
//!    are directly comparable across runs).  Bit parity against naive
//!    is asserted before timing.
//! 4. **Batched verify shape** — one fused `matmul` / `matmul_t` over
//!    m = draft+1 rows vs m sequential single-row calls: the kernel-
//!    level half of the fused speculative verify pass.
//!
//! Every scheduling shape decodes byte-identical text (per-request RNG
//! streams), which this bench asserts as a side effect — a throughput
//! number from diverging outputs would be meaningless.
//!
//! Results land in `BENCH_serve.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the request count.
//!
//! Run: `cargo bench --bench serve_throughput`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{generate_batch, SampleCfg, TABLE3_PROMPTS};
use hsm::infer::tensor;
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{serve, Request, ServeCfg};
use hsm::tokenizer::Tokenizer;
use hsm::util::bench::black_box;

const THREAD_GRID: &[usize] = &[1, 2, 4, 8];
const ACTIVE_GRID: &[usize] = &[8, 32];

fn synthetic_model(ctx: usize, vocab: usize) -> Arc<Model> {
    let (dim, heads, ffn) = (64, 4, 128);
    let layers: Vec<LayerInfo> = (0..4)
        .map(|l| LayerInfo {
            kind: "ab".to_string(),
            heads,
            shifts: vec![(1usize << l.min(5)).min(ctx / 2)],
            ffn,
        })
        .collect();
    let m = Manifest::synthetic("hsm_ab", layers, dim, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 17);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::new(i as u64, TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]))
        .collect()
}

/// Best-of-2 wall time for `pass` (first call doubles as warmup), plus
/// the digest of generated text for the parity assertion.
fn timed<F: FnMut() -> (usize, u64)>(mut pass: F) -> (f64, usize, u64) {
    pass();
    let mut best = f64::INFINITY;
    let (mut tokens, mut digest) = (0, 0);
    for _ in 0..2 {
        let t0 = Instant::now();
        let (t, d) = pass();
        let dt = t0.elapsed().as_secs_f64();
        if dt < best {
            best = dt;
        }
        tokens = t;
        digest = d;
    }
    (best, tokens, digest)
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 256;
    let model = synthetic_model(ctx, tok.vocab_size());
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 64,
        seed: 5,
        stop_at_eot: true,
    };

    // 1. Baseline: fixed-membership round-robin on one thread (what
    //    generate_batch was before the scheduler existed — every request
    //    admitted up front, breadth-first single-token rounds).
    let prompts: Vec<&str> =
        (0..n).map(|i| TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]).collect();
    let (rr_secs, rr_tokens, rr_digest) = timed(|| {
        let mut sessions: Vec<_> = (0..n).map(|_| model.session()).collect();
        let gens = generate_batch(&mut sessions, &tok, &prompts, &sample).unwrap();
        let mut digest = 0xcbf2_9ce4_8422_2325u64;
        let mut toks = 0;
        for g in &gens {
            toks += g.tokens_generated;
            fnv(&mut digest, &g.completion);
        }
        (toks, digest)
    });
    let rr_tps = rr_tokens as f64 / rr_secs;
    println!(
        "round-robin generate_batch (1 thread, {n} requests): {rr_tokens} tokens, \
         {rr_secs:.3}s → {rr_tps:.1} tok/s"
    );

    // 2. Scheduler grid.
    println!("\ncontinuous batching (quantum 16):");
    println!(
        "{:>8} {:>11} {:>12} {:>14} {:>10}",
        "threads", "max_active", "tok/s", "vs round-robin", "parity"
    );
    let mut grid: Vec<(usize, usize, f64)> = Vec::new();
    let mut overhead_ratio = f64::NAN;
    for &threads in THREAD_GRID {
        for &max_active in ACTIVE_GRID {
            let cfg = ServeCfg {
                max_active,
                threads,
                quantum: 16,
                sample: sample.clone(),
                ..Default::default()
            };
            let (secs, tokens, digest) = timed(|| {
                let comps = serve(&model, &tok, requests(n), &cfg).unwrap();
                let mut d = 0xcbf2_9ce4_8422_2325u64;
                let mut toks = 0;
                for c in &comps {
                    toks += c.tokens_generated;
                    fnv(&mut d, &c.completion);
                }
                (toks, d)
            });
            assert_eq!(tokens, rr_tokens, "scheduler token count diverged from round-robin");
            assert_eq!(digest, rr_digest, "scheduler text diverged from round-robin");
            let tps = tokens as f64 / secs;
            if threads == 1 && max_active == ACTIVE_GRID[ACTIVE_GRID.len() - 1] {
                // Scheduler bookkeeping cost with no parallelism to win.
                overhead_ratio = rr_secs / secs;
            }
            println!(
                "{threads:>8} {max_active:>11} {tps:>12.1} {:>13.2}× {:>10}",
                tps / rr_tps,
                "ok"
            );
            grid.push((threads, max_active, tps));
        }
    }

    let best_t4 = grid
        .iter()
        .filter(|(t, _, _)| *t >= 4)
        .map(|(_, _, tps)| *tps)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nbest T≥4 continuous batching: {best_t4:.1} tok/s vs {rr_tps:.1} round-robin \
         ({:.2}×) — {}",
        best_t4 / rr_tps,
        if best_t4 > rr_tps { "PASS" } else { "FAIL (expected on <4-core machines)" }
    );
    println!("scheduler overhead at 1 thread: {overhead_ratio:.2}× round-robin speed");

    // 3. Kernel tiers on the hot-path shapes (the FFN/mixer shape and
    //    the tied-embedding logit shape).  Parity vs naive is asserted
    //    on every tier before it is timed.
    let backend = tensor::kernel_backend();
    let run_tier = |tier: &str, transpose: bool, x: &[f32], w: &[f32], nn: usize, y: &mut [f32]| {
        match (tier, transpose) {
            ("naive", false) => tensor::matvec_naive(x, w, nn, y),
            ("blocked", false) => tensor::matvec_blocked(x, w, nn, y),
            (_, false) => tensor::matvec(x, w, nn, y),
            ("naive", true) => tensor::matvec_t_naive(x, w, nn, y),
            ("blocked", true) => tensor::matvec_t_blocked(x, w, nn, y),
            (_, true) => tensor::matvec_t(x, w, nn, y),
        }
    };
    let bench_matvec = |k: usize, nn: usize, tier: &str, transpose: bool| -> f64 {
        let x: Vec<f32> = (0..k).map(|i| 0.01 * ((i * 13 % 37) as f32) - 0.17).collect();
        let w: Vec<f32> = (0..k * nn).map(|i| 0.003 * ((i * 7 % 53) as f32) - 0.08).collect();
        let mut y = vec![0.0f32; nn];
        let mut want = vec![0.0f32; nn];
        run_tier("naive", transpose, &x, &w, nn, &mut want);
        run_tier(tier, transpose, &x, &w, nn, &mut y);
        for (a, b) in y.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "{tier} diverged from naive");
        }
        let reps = (50_000_000 / (k * nn).max(1)).max(16);
        let t0 = Instant::now();
        for _ in 0..reps {
            run_tier(tier, transpose, &x, &w, nn, &mut y);
            black_box(&y);
        }
        t0.elapsed().as_secs_f64() / reps as f64 * 1e9
    };
    let mv_naive = bench_matvec(128, 512, "naive", false);
    let mv_blocked = bench_matvec(128, 512, "blocked", false);
    let mv_disp = bench_matvec(128, 512, "dispatched", false);
    let mvt_naive = bench_matvec(64, 512, "naive", true);
    let mvt_blocked = bench_matvec(64, 512, "blocked", true);
    let mvt_disp = bench_matvec(64, 512, "dispatched", true);
    println!(
        "\nmatvec (128×512):   {mv_naive:>8.0} ns naive → {mv_blocked:>8.0} ns blocked → \
         {mv_disp:>8.0} ns {backend} ({:.2}× over naive)",
        mv_naive / mv_disp
    );
    println!(
        "matvec_t (512×64):  {mvt_naive:>8.0} ns naive → {mvt_blocked:>8.0} ns blocked → \
         {mvt_disp:>8.0} ns {backend} ({:.2}× over naive)",
        mvt_naive / mvt_disp
    );

    // 4. The fused-verify kernel shape: m = draft+1 rows through one
    //    matmul vs m sequential single-row calls over the same weights.
    let bench_batched = |m: usize, k: usize, nn: usize, transpose: bool, fused: bool| -> f64 {
        let xs: Vec<f32> = (0..m * k).map(|i| 0.01 * ((i * 13 % 37) as f32) - 0.17).collect();
        let w: Vec<f32> = (0..k * nn).map(|i| 0.003 * ((i * 7 % 53) as f32) - 0.08).collect();
        let mut ys = vec![0.0f32; m * nn];
        let mut want = vec![0.0f32; m * nn];
        for r in 0..m {
            let (x, y) = (&xs[r * k..(r + 1) * k], &mut want[r * nn..(r + 1) * nn]);
            run_tier("naive", transpose, x, &w, nn, y);
        }
        let pass = |ys: &mut [f32]| {
            if fused && transpose {
                tensor::matmul_t(&xs, m, &w, nn, ys);
            } else if fused {
                tensor::matmul(&xs, m, &w, nn, ys);
            } else {
                for r in 0..m {
                    let (x, y) = (&xs[r * k..(r + 1) * k], &mut ys[r * nn..(r + 1) * nn]);
                    run_tier("dispatched", transpose, x, &w, nn, y);
                }
            }
        };
        pass(&mut ys);
        for (a, b) in ys.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "batched (fused={fused}) diverged from naive");
        }
        let reps = (50_000_000 / (m * k * nn).max(1)).max(16);
        let t0 = Instant::now();
        for _ in 0..reps {
            pass(&mut ys);
            black_box(&ys);
        }
        t0.elapsed().as_secs_f64() / reps as f64 * 1e9
    };
    let rows = 5; // draft_len 4 + the committed token
    let bm_seq = bench_batched(rows, 64, 128, false, false);
    let bm_fused = bench_batched(rows, 64, 128, false, true);
    let bmt_seq = bench_batched(rows, 64, 512, true, false);
    let bmt_fused = bench_batched(rows, 64, 512, true, true);
    println!(
        "batched matmul ({rows}×64×128):    {bm_seq:>8.0} ns sequential → {bm_fused:>8.0} ns \
         fused ({:.2}×)",
        bm_seq / bm_fused
    );
    println!(
        "batched matmul_t ({rows}×64×512):  {bmt_seq:>8.0} ns sequential → {bmt_fused:>8.0} ns \
         fused ({:.2}×)",
        bmt_seq / bmt_fused
    );

    // JSON for the perf trajectory.
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"serve_throughput\",\n");
    json.push_str(&format!(
        "  \"requests\": {n}, \"ctx\": {ctx}, \"dim\": 64, \"layers\": 4, \"max_new_tokens\": {},\n",
        sample.max_new_tokens
    ));
    json.push_str(&format!(
        "  \"round_robin_tok_per_s\": {rr_tps:.1},\n  \"scheduler_overhead_at_1_thread\": {overhead_ratio:.3},\n"
    ));
    json.push_str("  \"scheduler\": [\n");
    for (i, (threads, max_active, tps)) in grid.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {threads}, \"max_active\": {max_active}, \"tok_per_s\": {tps:.1}, \"speedup_vs_round_robin\": {:.3}}}{}\n",
            tps / rr_tps,
            if i + 1 < grid.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"best_t4_plus_tok_per_s\": {best_t4:.1}, \"t4_beats_round_robin\": {},\n",
        best_t4 > rr_tps
    ));
    json.push_str(&format!("  \"kernel_backend\": \"{backend}\",\n"));
    json.push_str(&format!(
        "  \"matvec\": {{\"naive_ns\": {mv_naive:.0}, \"blocked_ns\": {mv_blocked:.0}, \"dispatched_ns\": {mv_disp:.0}, \"speedup\": {:.3}, \"dispatched_speedup\": {:.3},\n",
        mv_naive / mv_blocked,
        mv_naive / mv_disp
    ));
    json.push_str(&format!(
        "             \"t_naive_ns\": {mvt_naive:.0}, \"t_blocked_ns\": {mvt_blocked:.0}, \"t_dispatched_ns\": {mvt_disp:.0}, \"t_speedup\": {:.3}, \"t_dispatched_speedup\": {:.3}}},\n",
        mvt_naive / mvt_blocked,
        mvt_naive / mvt_disp
    ));
    json.push_str(&format!(
        "  \"batched_verify\": {{\"rows\": {rows}, \"sequential_ns\": {bm_seq:.0}, \"fused_ns\": {bm_fused:.0}, \"speedup\": {:.3},\n",
        bm_seq / bm_fused
    ));
    json.push_str(&format!(
        "                     \"t_sequential_ns\": {bmt_seq:.0}, \"t_fused_ns\": {bmt_fused:.0}, \"t_speedup\": {:.3}}}\n",
        bmt_seq / bmt_fused
    ));
    json.push_str("}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
