//! Bench: continuous-batching serve throughput vs the fixed round-robin
//! baseline, plus the blocked-matvec before/after.
//!
//! Three questions, answered over identical synthetic weights (no
//! artifacts, no PJRT):
//!
//! 1. **Scaling** — tokens/sec of the threaded [`hsm::serve::Scheduler`]
//!    across a threads × max-active grid, against single-threaded
//!    [`hsm::generation::generate_batch`] round-robin over the same
//!    requests.  The acceptance bar: T ≥ 4 threads beats the
//!    single-threaded round-robin.
//! 2. **Overhead** — the scheduler at 1 thread vs raw `generate_batch`:
//!    what admission/queue bookkeeping costs when there is no
//!    parallelism to win.
//! 3. **Blocked matvec** — the cache-tiled `matvec` / `matvec_t`
//!    (4 rows per pass) against the unblocked reference implementations
//!    they replaced on the forward hot path.
//!
//! Every scheduling shape decodes byte-identical text (per-request RNG
//! streams), which this bench asserts as a side effect — a throughput
//! number from diverging outputs would be meaningless.
//!
//! Results land in `BENCH_serve.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the request count.
//!
//! Run: `cargo bench --bench serve_throughput`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{generate_batch, SampleCfg, TABLE3_PROMPTS};
use hsm::infer::tensor;
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{serve, Request, ServeCfg};
use hsm::tokenizer::Tokenizer;
use hsm::util::bench::black_box;

const THREAD_GRID: &[usize] = &[1, 2, 4, 8];
const ACTIVE_GRID: &[usize] = &[8, 32];

fn synthetic_model(ctx: usize, vocab: usize) -> Arc<Model> {
    let (dim, heads, ffn) = (64, 4, 128);
    let layers: Vec<LayerInfo> = (0..4)
        .map(|l| LayerInfo {
            kind: "ab".to_string(),
            heads,
            shifts: vec![(1usize << l.min(5)).min(ctx / 2)],
            ffn,
        })
        .collect();
    let m = Manifest::synthetic("hsm_ab", layers, dim, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 17);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::new(i as u64, TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]))
        .collect()
}

/// Best-of-2 wall time for `pass` (first call doubles as warmup), plus
/// the digest of generated text for the parity assertion.
fn timed<F: FnMut() -> (usize, u64)>(mut pass: F) -> (f64, usize, u64) {
    pass();
    let mut best = f64::INFINITY;
    let (mut tokens, mut digest) = (0, 0);
    for _ in 0..2 {
        let t0 = Instant::now();
        let (t, d) = pass();
        let dt = t0.elapsed().as_secs_f64();
        if dt < best {
            best = dt;
        }
        tokens = t;
        digest = d;
    }
    (best, tokens, digest)
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 256;
    let model = synthetic_model(ctx, tok.vocab_size());
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 64,
        seed: 5,
        stop_at_eot: true,
    };

    // 1. Baseline: fixed-membership round-robin on one thread (what
    //    generate_batch was before the scheduler existed — every request
    //    admitted up front, breadth-first single-token rounds).
    let prompts: Vec<&str> =
        (0..n).map(|i| TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]).collect();
    let (rr_secs, rr_tokens, rr_digest) = timed(|| {
        let mut sessions: Vec<_> = (0..n).map(|_| model.session()).collect();
        let gens = generate_batch(&mut sessions, &tok, &prompts, &sample).unwrap();
        let mut digest = 0xcbf2_9ce4_8422_2325u64;
        let mut toks = 0;
        for g in &gens {
            toks += g.tokens_generated;
            fnv(&mut digest, &g.completion);
        }
        (toks, digest)
    });
    let rr_tps = rr_tokens as f64 / rr_secs;
    println!(
        "round-robin generate_batch (1 thread, {n} requests): {rr_tokens} tokens, \
         {rr_secs:.3}s → {rr_tps:.1} tok/s"
    );

    // 2. Scheduler grid.
    println!("\ncontinuous batching (quantum 16):");
    println!(
        "{:>8} {:>11} {:>12} {:>14} {:>10}",
        "threads", "max_active", "tok/s", "vs round-robin", "parity"
    );
    let mut grid: Vec<(usize, usize, f64)> = Vec::new();
    let mut overhead_ratio = f64::NAN;
    for &threads in THREAD_GRID {
        for &max_active in ACTIVE_GRID {
            let cfg = ServeCfg {
                max_active,
                threads,
                quantum: 16,
                sample: sample.clone(),
                ..Default::default()
            };
            let (secs, tokens, digest) = timed(|| {
                let comps = serve(&model, &tok, requests(n), &cfg).unwrap();
                let mut d = 0xcbf2_9ce4_8422_2325u64;
                let mut toks = 0;
                for c in &comps {
                    toks += c.tokens_generated;
                    fnv(&mut d, &c.completion);
                }
                (toks, d)
            });
            assert_eq!(tokens, rr_tokens, "scheduler token count diverged from round-robin");
            assert_eq!(digest, rr_digest, "scheduler text diverged from round-robin");
            let tps = tokens as f64 / secs;
            if threads == 1 && max_active == ACTIVE_GRID[ACTIVE_GRID.len() - 1] {
                // Scheduler bookkeeping cost with no parallelism to win.
                overhead_ratio = rr_secs / secs;
            }
            println!(
                "{threads:>8} {max_active:>11} {tps:>12.1} {:>13.2}× {:>10}",
                tps / rr_tps,
                "ok"
            );
            grid.push((threads, max_active, tps));
        }
    }

    let best_t4 = grid
        .iter()
        .filter(|(t, _, _)| *t >= 4)
        .map(|(_, _, tps)| *tps)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nbest T≥4 continuous batching: {best_t4:.1} tok/s vs {rr_tps:.1} round-robin \
         ({:.2}×) — {}",
        best_t4 / rr_tps,
        if best_t4 > rr_tps { "PASS" } else { "FAIL (expected on <4-core machines)" }
    );
    println!("scheduler overhead at 1 thread: {overhead_ratio:.2}× round-robin speed");

    // 3. Blocked matvec vs the unblocked reference (the FFN/mixer shape
    //    and the tied-embedding logit shape).
    let bench_matvec = |k: usize, nn: usize, blocked: bool, transpose: bool| -> f64 {
        let x: Vec<f32> = (0..k).map(|i| 0.01 * ((i * 13 % 37) as f32) - 0.17).collect();
        let w: Vec<f32> = (0..k * nn).map(|i| 0.003 * ((i * 7 % 53) as f32) - 0.08).collect();
        let mut y = vec![0.0f32; nn];
        let reps = 50_000_000 / (k * nn).max(1);
        let t0 = Instant::now();
        for _ in 0..reps.max(16) {
            match (blocked, transpose) {
                (true, false) => tensor::matvec(&x, &w, nn, &mut y),
                (false, false) => tensor::matvec_naive(&x, &w, nn, &mut y),
                (true, true) => tensor::matvec_t(&x, &w, nn, &mut y),
                (false, true) => tensor::matvec_t_naive(&x, &w, nn, &mut y),
            }
            black_box(&y);
        }
        t0.elapsed().as_secs_f64() / reps.max(16) as f64 * 1e9
    };
    let mv_naive = bench_matvec(128, 512, false, false);
    let mv_blocked = bench_matvec(128, 512, true, false);
    let mvt_naive = bench_matvec(64, 512, false, true);
    let mvt_blocked = bench_matvec(64, 512, true, true);
    println!(
        "\nblocked matvec (128×512):   {mv_naive:>8.0} ns naive → {mv_blocked:>8.0} ns \
         blocked ({:.2}×)",
        mv_naive / mv_blocked
    );
    println!(
        "blocked matvec_t (512×64):  {mvt_naive:>8.0} ns naive → {mvt_blocked:>8.0} ns \
         blocked ({:.2}×)",
        mvt_naive / mvt_blocked
    );

    // JSON for the perf trajectory.
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"serve_throughput\",\n");
    json.push_str(&format!(
        "  \"requests\": {n}, \"ctx\": {ctx}, \"dim\": 64, \"layers\": 4, \"max_new_tokens\": {},\n",
        sample.max_new_tokens
    ));
    json.push_str(&format!(
        "  \"round_robin_tok_per_s\": {rr_tps:.1},\n  \"scheduler_overhead_at_1_thread\": {overhead_ratio:.3},\n"
    ));
    json.push_str("  \"scheduler\": [\n");
    for (i, (threads, max_active, tps)) in grid.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"threads\": {threads}, \"max_active\": {max_active}, \"tok_per_s\": {tps:.1}, \"speedup_vs_round_robin\": {:.3}}}{}\n",
            tps / rr_tps,
            if i + 1 < grid.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"best_t4_plus_tok_per_s\": {best_t4:.1}, \"t4_beats_round_robin\": {},\n",
        best_t4 > rr_tps
    ));
    json.push_str(&format!(
        "  \"matvec\": {{\"naive_ns\": {mv_naive:.0}, \"blocked_ns\": {mv_blocked:.0}, \"speedup\": {:.3},\n",
        mv_naive / mv_blocked
    ));
    json.push_str(&format!(
        "             \"t_naive_ns\": {mvt_naive:.0}, \"t_blocked_ns\": {mvt_blocked:.0}, \"t_speedup\": {:.3}}}\n",
        mvt_naive / mvt_blocked
    ));
    json.push_str("}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
