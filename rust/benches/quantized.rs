//! Bench: int8 weight quantization — resident bytes and end-to-end
//! serving tok/s for f32 vs int8 weights across model shapes, plus the
//! quantized shallow drafter (`shallow-q`) vs its f32 twin, with **byte
//! parity asserted** for every speculative run against plain f32
//! decoding (drafts may come from int8 weights; served bytes may not
//! move).
//!
//! Two workloads:
//!
//! 1. **Shape sweep** — the Table-3 prompt suite served at temperature
//!    0.8 on the same seeded checkpoint loaded twice, once at each
//!    precision: resident weight bytes (ratio asserted ≤ 0.30), tok/s,
//!    and the int8/f32 speedup per shape.  The two precisions produce
//!    different bytes by design; the tolerance suite pins how different.
//! 2. **Drafter duel** — `shallow` vs `shallow-q` on the f32 serving
//!    model: acceptance rate and accepted tokens per verify round, with
//!    both digests asserted equal to the plain f32 digest (verification
//!    always scores f32, so quantized drafts can cost acceptance but
//!    never change output).
//!
//! Results land in `BENCH_quant.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the request count.
//!
//! Run: `cargo bench --bench quantized`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{weights, DrafterKind, Model, ModelWeights, Precision, SpecCfg, SpecStats};
use hsm::serve::{serve, Request, ServeCfg};
use hsm::tokenizer::Tokenizer;

fn layers_for(kind: &str, layers: usize, ffn: usize) -> Vec<LayerInfo> {
    (0..layers)
        .map(|l| LayerInfo {
            kind: kind.into(),
            heads: 4,
            shifts: if kind == "attn" { vec![1] } else { vec![1usize << l.min(5)] },
            ffn,
        })
        .collect()
}

/// The same seeded checkpoint at both precisions.
fn model_pair(
    kind: &str,
    dim: usize,
    layers: usize,
    ctx: usize,
    vocab: usize,
    seed: u64,
) -> (Arc<Model>, Arc<Model>) {
    let m = Manifest::synthetic(kind, layers_for(kind, layers, 2 * dim), dim, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, seed);
    let f = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap();
    let w = ModelWeights::from_flat(&m, &flat).unwrap();
    let q = Model::shared_with_precision(m, w, Precision::Int8).unwrap();
    (f, q)
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

struct RunOut {
    secs: f64,
    tokens: usize,
    digest: u64,
    stats: SpecStats,
}

fn run(
    model: &Arc<Model>,
    tok: &Tokenizer,
    prompts: &[String],
    sample: &SampleCfg,
    speculation: Option<SpecCfg>,
) -> RunOut {
    let cfg = ServeCfg {
        max_active: 4,
        threads: 2,
        quantum: 8,
        prefix_cache_size: 0,
        speculation,
        sample: sample.clone(),
        precision: model.precision(),
        ..Default::default()
    };
    let requests: Vec<Request> =
        prompts.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
    let t0 = Instant::now();
    let completions = serve(model, tok, requests, &cfg).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    let mut tokens = 0usize;
    let mut stats = SpecStats::default();
    for c in &completions {
        fnv(&mut digest, &c.completion);
        tokens += c.tokens_generated;
        if let Some(s) = &c.spec {
            stats.add(s);
        }
    }
    RunOut { secs, tokens, digest, stats }
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12)
        .max(2);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_quant.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 384;
    let prompts: Vec<String> =
        (0..n).map(|i| TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()].to_string()).collect();
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 32,
        seed: 5,
        stop_at_eot: true,
    };

    // Shape sweep: f32 vs int8 resident bytes + tok/s.  Larger dims
    // favour int8 (a quarter of the weight traffic per matvec row);
    // the smallest shape is where f32 may still win on overhead.
    let mut shapes_json = Vec::new();
    for (kind, dim, layers) in [("ab", 64usize, 2usize), ("ab", 192, 4), ("attn", 128, 3)] {
        let (f, q) = model_pair(kind, dim, layers, ctx, tok.vocab_size(), 17);
        let (fb, qb) = (f.resident_weight_bytes(), q.resident_weight_bytes());
        let ratio = qb as f64 / fb as f64;
        assert!(
            ratio <= 0.30,
            "[{kind} d{dim}] int8 resident ratio {ratio:.3} exceeds 0.30 ({qb} / {fb} bytes)"
        );
        let rf = run(&f, &tok, &prompts, &sample, None);
        let rq = run(&q, &tok, &prompts, &sample, None);
        assert!(rf.tokens > 0, "[{kind} d{dim}] f32 run produced no tokens");
        let f_tps = rf.tokens as f64 / rf.secs.max(1e-9);
        let q_tps = rq.tokens as f64 / rq.secs.max(1e-9);
        println!(
            "[{kind} d{dim} L{layers}] f32 {fb} B @ {f_tps:.0} tok/s — \
             int8 {qb} B ({ratio:.3}×) @ {q_tps:.0} tok/s ({:.2}× f32)",
            q_tps / f_tps.max(1e-9)
        );
        shapes_json.push(format!(
            "    {{\"kind\": \"{kind}\", \"dim\": {dim}, \"layers\": {layers}, \
             \"f32_resident_bytes\": {fb}, \"int8_resident_bytes\": {qb}, \
             \"resident_ratio\": {ratio:.4}, \"f32_tok_per_s\": {f_tps:.1}, \
             \"int8_tok_per_s\": {q_tps:.1}, \"int8_speedup\": {:.3}}}",
            q_tps / f_tps.max(1e-9)
        ));
    }

    // Drafter duel on the f32 serving model: quantized drafts must keep
    // served bytes identical to plain f32 decoding — the whole point.
    let (f, _) = model_pair("ab", 64, 2, ctx, tok.vocab_size(), 17);
    let plain = run(&f, &tok, &prompts, &sample, None);
    let plain_tps = plain.tokens as f64 / plain.secs.max(1e-9);
    let mut drafters_json = Vec::new();
    for drafter in [
        DrafterKind::Shallow { layers: 1 },
        DrafterKind::ShallowQuant { layers: 1 },
    ] {
        let spec = run(
            &f,
            &tok,
            &prompts,
            &sample,
            Some(SpecCfg { drafter, draft_len: 4, fused: true }),
        );
        assert_eq!(
            spec.digest,
            plain.digest,
            "{} drafting changed served bytes",
            drafter.label()
        );
        assert_eq!(spec.tokens, plain.tokens);
        let tps = spec.tokens as f64 / spec.secs.max(1e-9);
        let per_round = spec.stats.emitted_per_round();
        let accept = spec.stats.acceptance_rate();
        println!(
            "[draft] {:<9}  {tps:>6.0} tok/s ({:.2}× plain)  {per_round:.2} tok/round  \
             {:.0}% drafts accepted",
            drafter.label(),
            tps / plain_tps.max(1e-9),
            accept * 100.0
        );
        drafters_json.push(format!(
            "    {{\"drafter\": \"{}\", \"draft_len\": 4, \"tok_per_s\": {tps:.1}, \
             \"speedup\": {:.3}, \"tokens_per_round\": {per_round:.3}, \
             \"acceptance_rate\": {accept:.3}, \"rounds\": {}, \"parity\": true}}",
            drafter.label(),
            tps / plain_tps.max(1e-9),
            spec.stats.rounds
        ));
    }

    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"quantized\",\n");
    json.push_str(&format!(
        "  \"requests\": {n}, \"ctx\": {ctx}, \"max_new_tokens\": {}, \
         \"kernel_backend\": \"{}\",\n",
        sample.max_new_tokens,
        hsm::infer::tensor::kernel_backend()
    ));
    json.push_str("  \"shapes\": [\n");
    json.push_str(&shapes_json.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str("  \"drafters\": [\n");
    json.push_str(&drafters_json.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str("  \"resident_ratio_le_030\": true,\n  \"parity\": true\n");
    json.push_str("}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
