//! Bench: weight quantization — resident bytes and end-to-end serving
//! tok/s for f32 vs int8 vs int4 weights across model shapes, plus the
//! quantized shallow drafter (`shallow-q`) vs its f32 twin, with **byte
//! parity asserted** for every speculative run against plain f32
//! decoding (drafts may come from quantized weights; served bytes may
//! not move).
//!
//! Four workloads:
//!
//! 1. **Shape sweep** — the Table-3 prompt suite served at temperature
//!    0.8 on the same seeded checkpoint loaded three times, once at
//!    each precision: resident weight bytes (int8 ratio asserted
//!    ≤ 0.30, int4 ≤ 0.20), tok/s, and each precision's speedup per
//!    shape.  The precisions produce different bytes by design; the
//!    tolerance suite pins how different.
//! 2. **Hoist A/B** — quantized decoding with the hoisted activation
//!    quantization on vs off (per-call), int8 and int4, **digest
//!    parity asserted**: hoisting reuses the one `(q, scale)` image a
//!    layer's consumers share, so it may only change speed, never bits.
//! 3. **Prefix-cache footprint** — hydrated vs at-rest snapshot bytes
//!    per precision: quantized models store ring history as int8
//!    images at rest, f32 models store full rows.
//! 4. **Drafter duel** — `shallow` vs `shallow-q` on the f32 serving
//!    model: acceptance rate and accepted tokens per verify round, with
//!    both digests asserted equal to the plain f32 digest (verification
//!    always scores f32, so quantized drafts can cost acceptance but
//!    never change output).
//!
//! Results land in `BENCH_quant.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the request count.
//!
//! Run: `cargo bench --bench quantized`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{argmax, SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{
    weights, DecodeSession, DrafterKind, Model, ModelWeights, Precision, SpecCfg, SpecStats,
};
use hsm::serve::{serve, PrefixCache, Request, ServeCfg};
use hsm::tokenizer::Tokenizer;

fn layers_for(kind: &str, layers: usize, ffn: usize) -> Vec<LayerInfo> {
    (0..layers)
        .map(|l| LayerInfo {
            kind: kind.into(),
            heads: 4,
            shifts: if kind == "attn" { vec![1] } else { vec![1usize << l.min(5)] },
            ffn,
        })
        .collect()
}

/// The same seeded checkpoint at all three precisions.
fn model_triple(
    kind: &str,
    dim: usize,
    layers: usize,
    ctx: usize,
    vocab: usize,
    seed: u64,
) -> (Arc<Model>, Arc<Model>, Arc<Model>) {
    let m = Manifest::synthetic(kind, layers_for(kind, layers, 2 * dim), dim, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, seed);
    let f = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap();
    let w8 = ModelWeights::from_flat(&m, &flat).unwrap();
    let q8 = Model::shared_with_precision(m.clone(), w8, Precision::Int8).unwrap();
    let w4 = ModelWeights::from_flat(&m, &flat).unwrap();
    let q4 = Model::shared_with_precision(m, w4, Precision::Int4).unwrap();
    (f, q8, q4)
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

struct RunOut {
    secs: f64,
    tokens: usize,
    digest: u64,
    stats: SpecStats,
}

fn run(
    model: &Arc<Model>,
    tok: &Tokenizer,
    prompts: &[String],
    sample: &SampleCfg,
    speculation: Option<SpecCfg>,
) -> RunOut {
    let cfg = ServeCfg {
        max_active: 4,
        threads: 2,
        quantum: 8,
        prefix_cache_size: 0,
        speculation,
        sample: sample.clone(),
        precision: model.precision(),
        ..Default::default()
    };
    let requests: Vec<Request> =
        prompts.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
    let t0 = Instant::now();
    let completions = serve(model, tok, requests, &cfg).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    let mut tokens = 0usize;
    let mut stats = SpecStats::default();
    for c in &completions {
        fnv(&mut digest, &c.completion);
        tokens += c.tokens_generated;
        if let Some(s) = &c.spec {
            stats.add(s);
        }
    }
    RunOut { secs, tokens, digest, stats }
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12)
        .max(2);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_quant.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 384;
    let prompts: Vec<String> =
        (0..n).map(|i| TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()].to_string()).collect();
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 32,
        seed: 5,
        stop_at_eot: true,
    };

    // Shape sweep: f32 vs int8 vs int4 resident bytes + tok/s.  Larger
    // dims favour the quantized tiers (a quarter / an eighth of the
    // weight traffic per matvec row); the smallest shape is where f32
    // may still win on overhead.
    let mut shapes_json = Vec::new();
    for (kind, dim, layers) in [("ab", 64usize, 2usize), ("ab", 192, 4), ("attn", 128, 3)] {
        let (f, q8, q4) = model_triple(kind, dim, layers, ctx, tok.vocab_size(), 17);
        let (fb, q8b, q4b) = (
            f.resident_weight_bytes(),
            q8.resident_weight_bytes(),
            q4.resident_weight_bytes(),
        );
        let ratio8 = q8b as f64 / fb as f64;
        let ratio4 = q4b as f64 / fb as f64;
        assert!(
            ratio8 <= 0.30,
            "[{kind} d{dim}] int8 resident ratio {ratio8:.3} exceeds 0.30 ({q8b} / {fb} bytes)"
        );
        assert!(
            ratio4 <= 0.20,
            "[{kind} d{dim}] int4 resident ratio {ratio4:.3} exceeds 0.20 ({q4b} / {fb} bytes)"
        );
        let rf = run(&f, &tok, &prompts, &sample, None);
        let r8 = run(&q8, &tok, &prompts, &sample, None);
        let r4 = run(&q4, &tok, &prompts, &sample, None);
        assert!(rf.tokens > 0, "[{kind} d{dim}] f32 run produced no tokens");
        let f_tps = rf.tokens as f64 / rf.secs.max(1e-9);
        let q8_tps = r8.tokens as f64 / r8.secs.max(1e-9);
        let q4_tps = r4.tokens as f64 / r4.secs.max(1e-9);
        println!(
            "[{kind} d{dim} L{layers}] f32 {fb} B @ {f_tps:.0} tok/s — \
             int8 {q8b} B ({ratio8:.3}×) @ {q8_tps:.0} tok/s ({:.2}× f32) — \
             int4 {q4b} B ({ratio4:.3}×) @ {q4_tps:.0} tok/s ({:.2}× f32)",
            q8_tps / f_tps.max(1e-9),
            q4_tps / f_tps.max(1e-9)
        );
        shapes_json.push(format!(
            "    {{\"kind\": \"{kind}\", \"dim\": {dim}, \"layers\": {layers}, \
             \"f32_resident_bytes\": {fb}, \"int8_resident_bytes\": {q8b}, \
             \"int4_resident_bytes\": {q4b}, \"resident_ratio\": {ratio8:.4}, \
             \"int4_resident_ratio\": {ratio4:.4}, \"f32_tok_per_s\": {f_tps:.1}, \
             \"int8_tok_per_s\": {q8_tps:.1}, \"int8_speedup\": {:.3}, \
             \"int4_tok_per_s\": {q4_tps:.1}, \"int4_speedup\": {:.3}}}",
            q8_tps / f_tps.max(1e-9),
            q4_tps / f_tps.max(1e-9)
        ));
    }

    // Hoist A/B: the hoisted activation-quantization slab on vs off
    // (per-call re-quantization), driven through a raw DecodeSession so
    // nothing but the decode loop is timed.  Hoisting shares one
    // `(q, scale)` image across a layer's consumers (attn Q/K/V: 3 → 1
    // quantize_row per layer; mat/gate1: 2 → 1 with the ring push) and
    // must be bit-identical — the digest folds every logit of every
    // step.
    let hoist_steps = 256usize.min(ctx - 8);
    let mut hoist_json = Vec::new();
    for (label, kind, dim, layers) in [("int8", "attn", 128usize, 3usize), ("int4", "attn", 128, 3)]
    {
        let (_, q8, q4) = model_triple(kind, dim, layers, ctx, tok.vocab_size(), 17);
        let m = if label == "int4" { q4 } else { q8 };
        let mut outs = Vec::new();
        for hoist in [true, false] {
            let mut sess = DecodeSession::new(&m.manifest, None).unwrap();
            sess.set_quant_hoist(hoist);
            let mut token = 7u32;
            let mut digest = 0xcbf2_9ce4_8422_2325u64;
            let t0 = Instant::now();
            for _ in 0..hoist_steps {
                let logits = sess.step(&m, token).unwrap();
                token = argmax(logits);
                for v in logits {
                    digest = (digest ^ u64::from(v.to_bits())).wrapping_mul(0x100_0000_01b3);
                }
            }
            outs.push((t0.elapsed().as_secs_f64(), digest));
        }
        let ((on_secs, on_digest), (off_secs, off_digest)) = (outs[0], outs[1]);
        assert_eq!(
            on_digest, off_digest,
            "[{label} {kind} d{dim}] hoisted activation quantization changed decoded bits"
        );
        let on_tps = hoist_steps as f64 / on_secs.max(1e-9);
        let off_tps = hoist_steps as f64 / off_secs.max(1e-9);
        println!(
            "[hoist] {label} {kind} d{dim}: per-call {off_tps:.0} tok/s — \
             hoisted {on_tps:.0} tok/s ({:.3}×, parity ok)",
            on_tps / off_tps.max(1e-9)
        );
        hoist_json.push(format!(
            "    {{\"precision\": \"{label}\", \"kind\": \"{kind}\", \"dim\": {dim}, \
             \"layers\": {layers}, \"steps\": {hoist_steps}, \
             \"per_call_tok_per_s\": {off_tps:.1}, \"hoisted_tok_per_s\": {on_tps:.1}, \
             \"hoist_speedup\": {:.3}, \"parity\": true}}",
            on_tps / off_tps.max(1e-9)
        ));
    }

    // Prefix-cache footprint: hydrated vs at-rest snapshot bytes per
    // precision.  Quantized models compact ring history down to the
    // int8 images at rest (restores are byte-exact); f32 snapshots are
    // stored as-is.
    let mut cache_json = Vec::new();
    {
        let (f, q8, q4) = model_triple("ab", 192, 4, ctx, tok.vocab_size(), 17);
        for (label, m) in [("f32", &f), ("int8", &q8), ("int4", &q4)] {
            let mut sess = DecodeSession::new(&m.manifest, None).unwrap();
            let mut toks = Vec::new();
            let mut token = 7u32;
            for _ in 0..48 {
                toks.push(token);
                token = argmax(sess.step(m, token).unwrap());
            }
            let snap = sess.snapshot();
            let hydrated = snap.resident_bytes();
            let cache = PrefixCache::new(m.fingerprint(), 4);
            cache.insert(m.fingerprint(), &toks, snap);
            let s = cache.stats();
            let at_rest = s.resident_bytes;
            let (len, restored) =
                cache.lookup(m.fingerprint(), &toks).expect("inserted prefix must hit");
            assert_eq!(len, toks.len());
            assert!(!restored.is_compacted(), "lookup must hand out hydrated state");
            let ratio = at_rest as f64 / (hydrated as f64).max(1e-9);
            println!(
                "[cache] {label}: hydrated {hydrated} B — at rest {at_rest} B ({ratio:.3}×), \
                 {} quantized entries",
                s.quantized_entries
            );
            cache_json.push(format!(
                "    {{\"precision\": \"{label}\", \"prefix_tokens\": {}, \
                 \"hydrated_bytes\": {hydrated}, \"at_rest_bytes\": {at_rest}, \
                 \"at_rest_ratio\": {ratio:.4}, \"quantized_entries\": {}}}",
                toks.len(),
                s.quantized_entries
            ));
        }
    }

    // Drafter duel on the f32 serving model: quantized drafts must keep
    // served bytes identical to plain f32 decoding — the whole point.
    let (f, _, _) = model_triple("ab", 64, 2, ctx, tok.vocab_size(), 17);
    let plain = run(&f, &tok, &prompts, &sample, None);
    let plain_tps = plain.tokens as f64 / plain.secs.max(1e-9);
    let mut drafters_json = Vec::new();
    for drafter in [
        DrafterKind::Shallow { layers: 1 },
        DrafterKind::ShallowQuant { layers: 1 },
    ] {
        let spec = run(
            &f,
            &tok,
            &prompts,
            &sample,
            Some(SpecCfg { drafter, draft_len: 4, fused: true }),
        );
        assert_eq!(
            spec.digest,
            plain.digest,
            "{} drafting changed served bytes",
            drafter.label()
        );
        assert_eq!(spec.tokens, plain.tokens);
        let tps = spec.tokens as f64 / spec.secs.max(1e-9);
        let per_round = spec.stats.emitted_per_round();
        let accept = spec.stats.acceptance_rate();
        println!(
            "[draft] {:<9}  {tps:>6.0} tok/s ({:.2}× plain)  {per_round:.2} tok/round  \
             {:.0}% drafts accepted",
            drafter.label(),
            tps / plain_tps.max(1e-9),
            accept * 100.0
        );
        drafters_json.push(format!(
            "    {{\"drafter\": \"{}\", \"draft_len\": 4, \"tok_per_s\": {tps:.1}, \
             \"speedup\": {:.3}, \"tokens_per_round\": {per_round:.3}, \
             \"acceptance_rate\": {accept:.3}, \"rounds\": {}, \"parity\": true}}",
            drafter.label(),
            tps / plain_tps.max(1e-9),
            spec.stats.rounds
        ));
    }

    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"quantized\",\n");
    json.push_str(&format!(
        "  \"requests\": {n}, \"ctx\": {ctx}, \"max_new_tokens\": {}, \
         \"kernel_backend\": \"{}\",\n",
        sample.max_new_tokens,
        hsm::infer::tensor::kernel_backend()
    ));
    json.push_str("  \"shapes\": [\n");
    json.push_str(&shapes_json.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str("  \"hoist\": [\n");
    json.push_str(&hoist_json.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str("  \"prefix_cache\": [\n");
    json.push_str(&cache_json.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str("  \"drafters\": [\n");
    json.push_str(&drafters_json.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str(
        "  \"resident_ratio_le_030\": true,\n  \"int4_resident_ratio_le_020\": true,\n  \
         \"hoist_parity\": true,\n  \"parity\": true\n",
    );
    json.push_str("}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
