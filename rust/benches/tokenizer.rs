//! Bench: tokenizer substrate — BPE training and encode/decode throughput.
//!
//! No artifacts needed.  Guards the data-pipeline side of Table 1's
//! training-time claims: tokenization must never be the bottleneck
//! (training steps are tens of milliseconds; encoding a whole epoch of
//! text must stay far below that).

use hsm::corpus;
use hsm::tokenizer::trainer;
use hsm::util::bench::{black_box, Bench};

fn main() {
    let mut bench = Bench::quick();

    let text_small = corpus::generate(7, 200);
    let text_big = corpus::generate(8, 2000);

    bench.run("bpe_train/vocab512_200stories", || {
        black_box(trainer::train(&text_small, 512).unwrap());
    });

    let tok = trainer::train(&text_big, 512).unwrap();
    let sample = &text_big[..text_big.len().min(100_000)];

    let stats = bench.run("encode/100kB", || {
        black_box(tok.encode(sample));
    });
    println!(
        "encode throughput: {:.1} MB/s",
        sample.len() as f64 / stats.mean.as_secs_f64() / 1e6
    );

    let ids = tok.encode(sample);
    let dstats = bench.run("decode/100kB", || {
        black_box(tok.decode(&ids));
    });
    println!(
        "decode throughput: {:.1} Mtok/s",
        ids.len() as f64 / dstats.mean.as_secs_f64() / 1e6
    );

    bench.run("corpus_generate/500stories", || {
        black_box(corpus::generate(9, 500));
    });
}
