//! Bench: inference-path latency — full-context decode per variant.
//!
//! The paper's complexity claim (linear-time HSM vs quadratic attention)
//! shows up at inference as well as training.  This bench measures the
//! `decode` artifact (one `[1, ctx]` forward) and derives tokens/second
//! for the autoregressive loop, comparing pure-HSM, hybrid and GPT mixers.
//!
//! Run: `cargo bench --bench decode_latency`.

use hsm::config::Manifest;
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::util::bench::Bench;

const SET: &[&str] = &["hsm_ab", "hsm_ab_mh", "hsm_fusion", "hybrid_mh_06", "gpt"];

fn main() {
    let root = std::path::Path::new("artifacts");
    let preset = std::env::var("HSM_BENCH_PRESET").unwrap_or_else(|_| "ci".into());
    let mut bench = Bench::quick();
    let mut rows = Vec::new();

    for v in SET {
        let Ok(m) = Manifest::load_variant(root, &preset, v) else {
            eprintln!("skip {v}: no {preset} artifacts (run `make artifacts`)");
            continue;
        };
        let ctx = m.ctx;
        let toks: Vec<i32> = (0..ctx as i32).map(|i| i % m.vocab as i32).collect();
        let Ok(mut eng) = PjrtEngine::new(m) else { continue };
        eng.init(0).unwrap();
        eng.decode(&toks).unwrap(); // compile outside measurement
        let stats = bench.run(&format!("decode/{v}"), || {
            eng.decode(&toks).unwrap();
        });
        rows.push((v.to_string(), stats.mean.as_secs_f64(), ctx));
    }

    println!("\nAutoregressive decoding throughput ({preset} preset):");
    println!("{:<16} {:>12} {:>14}", "variant", "ms/forward", "tokens/s*");
    for (v, s, _ctx) in &rows {
        println!("{:<16} {:>12.2} {:>14.0}", v, s * 1e3, 1.0 / s);
    }
    println!("*one token generated per full-context forward (no KV caching)");
}
