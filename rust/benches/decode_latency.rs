//! Bench: inference-path latency — windowed vs incremental decode, and
//! multi-session serving throughput.
//!
//! The paper's complexity claim (linear-time HSM vs quadratic attention)
//! is a *serving* claim: the windowed path re-runs a full-context
//! forward per generated token (O(ctx) work/token, what the PJRT
//! `decode` artifact forces), while the native incremental engine does
//! O(1) work per HSM layer per token.  This bench measures both paths
//! over identical synthetic weights — no artifacts needed — at 1, 4 and
//! 16 concurrent sessions sharing one `Arc<Model>`, and reports
//! per-token-cost flatness in position (late/early ratio ≈ 1 for pure
//! HSM, > 1 for attention's growing KV scan).
//!
//! Results land in `BENCH_decode.json` (override with `HSM_BENCH_OUT`)
//! for the perf trajectory.  `HSM_BENCH_CTX` scales the context.
//! If real PJRT artifacts are present (and the `pjrt` feature is a real
//! xla build), the artifact decode latency is appended for reference.
//!
//! Run: `cargo bench --bench decode_latency`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::WindowDecoder;
use hsm::infer::{weights, Decoder, Model, ModelWeights, WindowEngine};

const SESSIONS: &[usize] = &[1, 4, 16];

fn synthetic_model(variant: &str, kind: &str, n_layers: usize, ctx: usize) -> Arc<Model> {
    let (dim, heads, ffn, vocab) = (64, 4, 128, 512);
    let layers: Vec<LayerInfo> = (0..n_layers)
        .map(|l| LayerInfo {
            kind: kind.to_string(),
            heads,
            // Layer-doubling shifts, capped inside the window.
            shifts: if kind == "attn" { vec![] } else { vec![(1usize << l.min(5)).min(ctx / 2)] },
            ffn,
        })
        .collect();
    let m = Manifest::synthetic(variant, layers, dim, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 17);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

/// Run `pass` (returns tokens decoded) once for warmup, then repeatedly;
/// returns aggregate tokens/second.
fn throughput<F: FnMut() -> usize>(mut pass: F) -> f64 {
    pass();
    let mut toks = 0usize;
    let mut reps = 0usize;
    let t0 = Instant::now();
    loop {
        toks += pass();
        reps += 1;
        if t0.elapsed().as_secs_f64() > 0.3 || reps >= 5 {
            break;
        }
    }
    toks as f64 / t0.elapsed().as_secs_f64()
}

struct Row {
    variant: String,
    windowed: f64,
    incremental: f64,
    flatness: f64,
    multi: Vec<(usize, f64)>,
}

fn bench_variant(variant: &str, kind: &str, ctx: usize) -> Row {
    let model = synthetic_model(variant, kind, 4, ctx);
    let vocab = model.manifest.vocab as u32;
    let prompt: Vec<u32> = (0..8u32).map(|i| (i * 31 + 7) % vocab).collect();
    let budget = ctx - prompt.len() - 1;
    let stream: Vec<u32> = (0..budget as u32).map(|i| (i * 37 + 11) % vocab).collect();

    // Windowed: full-context forward per token (the artifact path shape).
    let mut weng = WindowEngine::new(Arc::clone(&model));
    let mut wdec = WindowDecoder::new(&mut weng, 0);
    let windowed = throughput(|| {
        wdec.reset();
        wdec.prefill(&prompt).unwrap();
        for &t in &stream {
            wdec.step(t).unwrap();
        }
        stream.len()
    });

    // Incremental: one session, O(1)/token for pure HSM.
    let mut dec = model.session();
    let incremental = throughput(|| {
        dec.reset();
        dec.prefill(&prompt).unwrap();
        for &t in &stream {
            dec.step(t).unwrap();
        }
        stream.len()
    });

    // Flatness: per-token cost in the first vs last quarter of the
    // window, summed over a few passes.
    let q = budget / 4;
    let (mut early, mut late) = (0.0f64, 0.0f64);
    for _ in 0..3 {
        dec.reset();
        dec.prefill(&prompt).unwrap();
        for (i, &t) in stream.iter().enumerate() {
            let t0 = Instant::now();
            dec.step(t).unwrap();
            let dt = t0.elapsed().as_secs_f64();
            if i < q {
                early += dt;
            } else if i >= budget - q {
                late += dt;
            }
        }
    }
    let flatness = late / early.max(1e-12);

    // Multi-session serving: S sessions share one weight set, stepped
    // round-robin (breadth-first), aggregate throughput.
    let mut multi = Vec::new();
    for &s in SESSIONS {
        let mut sessions: Vec<_> = (0..s).map(|_| model.session()).collect();
        let agg = throughput(|| {
            for sess in &mut sessions {
                sess.reset();
                sess.prefill(&prompt).unwrap();
            }
            for &t in &stream {
                for sess in &mut sessions {
                    sess.step(t).unwrap();
                }
            }
            s * stream.len()
        });
        multi.push((s, agg));
    }

    Row { variant: variant.to_string(), windowed, incremental, flatness, multi }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_reference(_preset: &str) {}

#[cfg(feature = "pjrt")]
fn pjrt_reference(preset: &str) {
    use hsm::runtime::{PjrtEngine, StepEngine};
    let root = std::path::Path::new("artifacts");
    let mut printed = false;
    for v in ["hsm_ab", "gpt"] {
        let Ok(m) = Manifest::load_variant(root, preset, v) else { continue };
        let ctx = m.ctx;
        let toks: Vec<i32> = (0..ctx as i32).map(|i| i % m.vocab as i32).collect();
        let Ok(mut eng) = PjrtEngine::new(m) else { continue };
        if eng.init(0).is_err() {
            continue;
        }
        if eng.decode(&toks).is_err() {
            continue; // compile outside measurement
        }
        let tok_s = throughput(|| {
            eng.decode(&toks).unwrap();
            1
        });
        if !printed {
            println!("\nPJRT artifact decode ({preset} preset), one token per full-ctx forward:");
            printed = true;
        }
        println!("  {v:<12} {tok_s:>10.1} tok/s");
    }
    if !printed {
        eprintln!("(PJRT reference skipped — no {preset} artifacts or stub xla build)");
    }
}

fn main() {
    let ctx: usize = std::env::var("HSM_BENCH_CTX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(192);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_decode.json".to_string());

    let set = [("hsm_ab", "ab"), ("hsm_fusion", "fusion"), ("gpt", "attn")];
    let rows: Vec<Row> = set.iter().map(|(v, k)| bench_variant(v, k, ctx)).collect();

    println!("\nDecode throughput (synthetic weights, dim 64 × 4 layers, ctx {ctx}):");
    println!(
        "{:<12} {:>14} {:>14} {:>9} {:>12} {:>12} {:>12}",
        "variant", "windowed t/s", "incremental", "speedup", "1 session", "4 sessions", "16 sessions"
    );
    for r in &rows {
        println!(
            "{:<12} {:>14.1} {:>14.1} {:>8.1}× {:>12.1} {:>12.1} {:>12.1}",
            r.variant,
            r.windowed,
            r.incremental,
            r.incremental / r.windowed,
            r.multi[0].1,
            r.multi[1].1,
            r.multi[2].1,
        );
    }
    println!("\nPer-token cost, last vs first quarter of the window (flat ≈ 1.0 is the");
    println!("paper's linearity claim; attention grows with its KV scan):");
    for r in &rows {
        println!("  {:<12} {:>6.2}×", r.variant, r.flatness);
    }

    // JSON for the perf trajectory.
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"bench\": \"decode_latency\",\n  \"ctx\": {ctx},\n  \"dim\": 64,\n  \"layers\": 4,\n"));
    json.push_str("  \"variants\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"variant\": \"{}\", \"windowed_tok_per_s\": {:.1}, \"incremental_tok_per_s\": {:.1}, \"speedup\": {:.2}, \"late_vs_early_per_token\": {:.3}, \"multi_session\": [",
            r.variant,
            r.windowed,
            r.incremental,
            r.incremental / r.windowed,
            r.flatness
        ));
        for (j, (s, agg)) in r.multi.iter().enumerate() {
            json.push_str(&format!(
                "{{\"sessions\": {s}, \"aggregate_tok_per_s\": {agg:.1}}}{}",
                if j + 1 < r.multi.len() { ", " } else { "" }
            ));
        }
        json.push_str(&format!("]}}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");

    pjrt_reference(&std::env::var("HSM_BENCH_PRESET").unwrap_or_else(|_| "ci".into()));
}
