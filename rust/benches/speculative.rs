//! Bench: speculative decoding vs plain decoding — **accepted tokens
//! per verify round** and end-to-end tok/s, across draft-block lengths,
//! both drafters, and both an HSM mixer and the hybrid attention mixer,
//! with **byte parity asserted** between every speculative run and its
//! plain twin (the whole point: speedup economics may vary, the bytes
//! never do).
//!
//! Two workloads:
//!
//! 1. **Grid** — the Table-3 prompt suite served at temperature 0.8:
//!    tok/s and acceptance for drafter × draft-length × mixer kind,
//!    with the verify pass both **sequential** (step + snapshot per
//!    position, `fused: false`) and **fused** (one `step_batch` over
//!    draft+1 rows, the default) — the before/after of the fused
//!    verify optimisation, byte parity asserted between all three.
//! 2. **Repetitive greedy** — a highly repetitive prompt decoded
//!    greedily with the n-gram drafter: once the model's output cycles,
//!    prompt-lookup predicts it exactly, and accepted-tokens-per-round
//!    must exceed 1 (asserted — the economic claim of the subsystem,
//!    deterministic under fixed weights).
//!
//! Results land in `BENCH_spec.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the grid's request count.
//!
//! Run: `cargo bench --bench speculative`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{weights, DrafterKind, Model, ModelWeights, SpecCfg, SpecStats};
use hsm::serve::{serve, Request, ServeCfg};
use hsm::tokenizer::Tokenizer;

fn layers_for(kind: &str) -> Vec<LayerInfo> {
    match kind {
        "attn" => vec![
            LayerInfo { kind: "attn".into(), heads: 4, shifts: vec![1], ffn: 64 },
            LayerInfo { kind: "attn".into(), heads: 4, shifts: vec![1], ffn: 64 },
            LayerInfo { kind: "attn".into(), heads: 4, shifts: vec![1], ffn: 64 },
            LayerInfo { kind: "attn".into(), heads: 4, shifts: vec![1], ffn: 64 },
        ],
        _ => (0..4)
            .map(|l| LayerInfo {
                kind: "ab".into(),
                heads: 4,
                shifts: vec![1usize << l.min(5)],
                ffn: 64,
            })
            .collect(),
    }
}

fn model_for(kind: &str, ctx: usize, vocab: usize, seed: u64) -> Arc<Model> {
    let m = Manifest::synthetic(kind, layers_for(kind), 32, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, seed);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

struct RunOut {
    secs: f64,
    tokens: usize,
    digest: u64,
    stats: SpecStats,
}

fn run(
    model: &Arc<Model>,
    tok: &Tokenizer,
    prompts: &[String],
    sample: &SampleCfg,
    speculation: Option<SpecCfg>,
) -> RunOut {
    let cfg = ServeCfg {
        max_active: 4,
        threads: 2,
        quantum: 8,
        prefix_cache_size: 0,
        speculation,
        sample: sample.clone(),
        ..Default::default()
    };
    let requests: Vec<Request> =
        prompts.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
    let t0 = Instant::now();
    let completions = serve(model, tok, requests, &cfg).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    let mut tokens = 0usize;
    let mut stats = SpecStats::default();
    for c in &completions {
        fnv(&mut digest, &c.completion);
        tokens += c.tokens_generated;
        if let Some(s) = &c.spec {
            stats.add(s);
        }
    }
    RunOut { secs, tokens, digest, stats }
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16)
        .max(2);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_spec.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 512;
    let prompts: Vec<String> =
        (0..n).map(|i| TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()].to_string()).collect();
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 32,
        seed: 5,
        stop_at_eot: true,
    };

    let mut grid_json = Vec::new();
    for kind in ["ab", "attn"] {
        let model = model_for(kind, ctx, tok.vocab_size(), 17);
        let plain = run(&model, &tok, &prompts, &sample, None);
        let plain_tps = plain.tokens as f64 / plain.secs.max(1e-9);
        println!(
            "[{kind}] plain: {} tokens in {:.3}s — {plain_tps:.0} tok/s",
            plain.tokens, plain.secs
        );
        for drafter in [
            DrafterKind::NGram { max_ngram: 3 },
            DrafterKind::Shallow { layers: 2 },
        ] {
            for draft_len in [2usize, 4, 8] {
                for fused in [false, true] {
                    let spec = run(
                        &model,
                        &tok,
                        &prompts,
                        &sample,
                        Some(SpecCfg { drafter, draft_len, fused }),
                    );
                    assert_eq!(
                        spec.digest, plain.digest,
                        "[{kind}] {drafter:?} draft_len={draft_len} fused={fused}: \
                         speculation changed bytes"
                    );
                    assert_eq!(spec.tokens, plain.tokens);
                    if fused {
                        assert_eq!(
                            spec.stats.fused_passes, spec.stats.rounds,
                            "[{kind}] fused accounting"
                        );
                    } else {
                        assert_eq!(spec.stats.fused_passes, 0);
                    }
                    let tps = spec.tokens as f64 / spec.secs.max(1e-9);
                    let per_round = spec.stats.emitted_per_round();
                    let accept = spec.stats.acceptance_rate();
                    let verify = if fused { "fused" } else { "seq" };
                    println!(
                        "[{kind}] {}:{draft_len} {verify:<5}  {tps:>6.0} tok/s ({:.2}× plain)  \
                         {per_round:.2} tok/round  {:.0}% drafts accepted",
                        drafter.label(),
                        tps / plain_tps.max(1e-9),
                        accept * 100.0
                    );
                    grid_json.push(format!(
                        "    {{\"kind\": \"{kind}\", \"drafter\": \"{}\", \"draft_len\": {draft_len}, \
                         \"fused\": {fused}, \"tok_per_s\": {tps:.1}, \
                         \"plain_tok_per_s\": {plain_tps:.1}, \
                         \"speedup\": {:.3}, \"tokens_per_round\": {per_round:.3}, \
                         \"acceptance_rate\": {accept:.3}, \"rounds\": {}, \
                         \"rows_per_fused_pass\": {:.3}, \"parity\": true}}",
                        drafter.label(),
                        tps / plain_tps.max(1e-9),
                        spec.stats.rounds,
                        spec.stats.rows_per_fused_pass()
                    ));
                }
            }
        }
    }

    // Repetitive greedy workload: the n-gram drafter's best case, and
    // the acceptance-criterion assertion (>1 token per verify round).
    // The model's greedy decode is made a pure token→token map (zeroed
    // position embeddings + zeroed mixer/FFN mats), so the output is
    // structurally forced into a cycle within ~√V tokens; once
    // periodic, prompt-lookup predicts whole blocks.  Several fixed
    // weight seeds are tried so the claim never rides on one map.
    let markov_model = |seed: u64| -> Arc<Model> {
        let m = Manifest::synthetic("ab", layers_for("ab"), 32, ctx, tok.vocab_size(), 1);
        let flat = weights::seeded_flat(&m, seed);
        let mut w = ModelWeights::from_flat(&m, &flat).unwrap();
        w.pos_emb.fill(0.0);
        for lw in &mut w.layers {
            lw.mixer.mix_a.fill(0.0);
            lw.mixer.mix_b.fill(0.0);
            lw.ffn_w1.fill(0.0);
            lw.ffn_w2.fill(0.0);
        }
        Model::shared(m, w).unwrap()
    };
    let rep_prompt =
        "the cat sat on the mat. the cat sat on the mat. the cat sat on the mat.".to_string();
    let rep_sample = SampleCfg {
        temperature: 0.0,
        top_k: 0,
        max_new_tokens: 160,
        seed: 0,
        stop_at_eot: false,
    };
    let mut best = SpecStats::default();
    let mut best_per_round = 0.0f64;
    let mut best_speedup = 0.0f64;
    let mut best_fused_vs_seq = 0.0f64;
    for weight_seed in [17u64, 31, 7, 91, 13, 57] {
        let model = markov_model(weight_seed);
        let plain = run(&model, &tok, std::slice::from_ref(&rep_prompt), &rep_sample, None);
        let spec_cfg =
            SpecCfg { drafter: DrafterKind::NGram { max_ngram: 4 }, draft_len: 6, fused: true };
        let spec = run(
            &model,
            &tok,
            std::slice::from_ref(&rep_prompt),
            &rep_sample,
            Some(spec_cfg.clone()),
        );
        assert_eq!(spec.digest, plain.digest, "repetitive workload parity (seed {weight_seed})");
        let seq = run(
            &model,
            &tok,
            std::slice::from_ref(&rep_prompt),
            &rep_sample,
            Some(SpecCfg { fused: false, ..spec_cfg }),
        );
        assert_eq!(seq.digest, plain.digest, "sequential-verify parity (seed {weight_seed})");
        let per_round = spec.stats.emitted_per_round();
        if per_round > best_per_round {
            best_per_round = per_round;
            best = spec.stats;
            best_speedup = (spec.tokens as f64 / spec.secs.max(1e-9))
                / (plain.tokens as f64 / plain.secs.max(1e-9));
            best_fused_vs_seq = seq.secs / spec.secs.max(1e-9);
        }
    }
    println!(
        "repetitive greedy + ngram: best {best_per_round:.2} tokens/verify round \
         ({} accepted / {} drafted over {} rounds), {best_speedup:.2}× plain tok/s, \
         fused verify {best_fused_vs_seq:.2}× sequential",
        best.accepted, best.drafted, best.rounds
    );
    assert!(
        best_per_round > 1.0,
        "n-gram drafter must accept >1 token per verify round on repetitive prompts \
         (got {best_per_round:.3})"
    );

    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"speculative\",\n");
    json.push_str(&format!(
        "  \"requests\": {n}, \"ctx\": {ctx}, \"dim\": 32, \"layers\": 4, \
         \"max_new_tokens\": {}, \"kernel_backend\": \"{}\",\n",
        sample.max_new_tokens,
        hsm::infer::tensor::kernel_backend()
    ));
    json.push_str("  \"grid\": [\n");
    json.push_str(&grid_json.join(",\n"));
    json.push_str("\n  ],\n");
    json.push_str(&format!(
        "  \"repetitive_ngram\": {{\"tokens_per_round\": {best_per_round:.3}, \
         \"rounds\": {}, \"drafted\": {}, \"accepted\": {}, \"emitted\": {}, \
         \"speedup_vs_plain\": {best_speedup:.3}, \
         \"fused_vs_sequential\": {best_fused_vs_seq:.3}}},\n",
        best.rounds, best.drafted, best.accepted, best.emitted
    ));
    json.push_str(&format!(
        "  \"tokens_per_round_gt_1\": {},\n  \"parity\": true\n",
        best_per_round > 1.0
    ));
    json.push_str("}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
