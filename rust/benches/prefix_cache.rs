//! Bench: the shared prefix cache on a shared-prompt-head workload —
//! **time-to-first-token** (TTFT) and **prefill tok/s**, with the cache
//! on vs off.
//!
//! The workload models the dominant short-completion serving pattern:
//! every request shares one long system-prompt head and differs only in
//! a short user tail.  Without the cache each request re-prefills the
//! whole head; with it, the first request pays the prefill once and
//! every later request restores the head snapshot and prefills only its
//! tail.
//!
//! Three measurements over identical synthetic weights (no artifacts):
//!
//! 1. **Session microbench** — cold prefill of the head vs a snapshot
//!    restore: the raw cost the cache removes.
//! 2. **Scheduler TTFT** — a resident `StreamScheduler`, requests
//!    submitted one at a time: per-request submit → first event, cold
//!    (`prefix_cache_size = 0`) vs warm (cache enabled).  The warm run's
//!    first request is the seeding miss and is reported separately.
//! 3. **HTTP keep-alive RTT** — the same shared-head request twice over
//!    one kept-alive connection ([`client::Client`]): cold-cache RTT vs
//!    hit RTT, connection reused.
//!
//! Cold and warm runs must produce byte-identical text (the cache is
//! bit-exact); the bench asserts it.
//!
//! Results land in `BENCH_prefix.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the request count.
//!
//! Run: `cargo bench --bench prefix_cache`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{weights, Decoder, Model, ModelWeights};
use hsm::serve::{Request, ServeCfg, StreamScheduler, TokenEvent};
use hsm::server::api::GenerateRequest;
use hsm::server::{client, HttpServer};
use hsm::tokenizer::Tokenizer;

fn synthetic_model(ctx: usize, vocab: usize) -> Arc<Model> {
    let (dim, heads, ffn) = (64, 4, 128);
    let layers: Vec<LayerInfo> = (0..4)
        .map(|l| LayerInfo {
            kind: "ab".to_string(),
            heads,
            shifts: vec![(1usize << l.min(5)).min(ctx / 2)],
            ffn,
        })
        .collect();
    let m = Manifest::synthetic("hsm_ab", layers, dim, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 17);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

struct Percentiles {
    mean: f64,
    p50: f64,
    p95: f64,
}

fn percentiles(samples: &mut [f64]) -> Percentiles {
    samples.sort_by(|a, b| a.total_cmp(b));
    let at = |q: f64| samples[((samples.len() - 1) as f64 * q).round() as usize];
    Percentiles {
        mean: samples.iter().sum::<f64>() / samples.len() as f64,
        p50: at(0.5),
        p95: at(0.95),
    }
}

/// Submit `prompts` one at a time to a fresh scheduler with the given
/// cache size; returns (per-request TTFT ms, per-request cached prefix
/// lens, text digest, total tokens).
fn run_sequential(
    model: &Arc<Model>,
    tok: &Tokenizer,
    prompts: &[String],
    sample: &SampleCfg,
    prefix_cache_size: usize,
) -> (Vec<f64>, Vec<usize>, u64, usize) {
    let cfg = ServeCfg {
        max_active: 2,
        threads: 2,
        quantum: 8,
        prefix_cache_size,
        sample: sample.clone(),
        ..Default::default()
    };
    let sched = StreamScheduler::start(Arc::clone(model), tok.clone(), cfg).unwrap();
    let mut ttfts = Vec::with_capacity(prompts.len());
    let mut cached = Vec::with_capacity(prompts.len());
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    let mut tokens = 0usize;
    for (i, p) in prompts.iter().enumerate() {
        let stream = sched.submit(Request::new(i as u64, p)).unwrap();
        let submitted = Instant::now();
        let mut first: Option<f64> = None;
        let mut text = String::new();
        for ev in stream {
            if first.is_none() {
                first = Some(submitted.elapsed().as_secs_f64() * 1e3);
            }
            match ev {
                TokenEvent::Token { text_delta, .. } => {
                    tokens += 1;
                    text.push_str(&text_delta);
                }
                TokenEvent::Done { text_delta, completion } => {
                    text.push_str(&text_delta);
                    cached.push(completion.cached_prefix_len);
                }
            }
        }
        fnv(&mut digest, &text);
        ttfts.push(first.unwrap_or(f64::NAN));
    }
    sched.shutdown();
    (ttfts, cached, digest, tokens)
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16)
        .max(2);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_prefix.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 1024;
    let model = synthetic_model(ctx, tok.vocab_size());

    // One long shared system-prompt head + short per-request tails.
    let head: String = TABLE3_PROMPTS[..8].join(" ");
    let head_tokens = tok.encode(&head).len();
    let prompts: Vec<String> = (0..n)
        .map(|i| format!("{head} {}", TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]))
        .collect();
    let prompt_tokens = tok.encode(&prompts[0]).len();
    assert!(prompt_tokens + 24 < ctx, "prompt must fit the context window");
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 16,
        seed: 5,
        stop_at_eot: true,
    };
    println!(
        "shared head: {head_tokens} tokens; full prompt ≈ {prompt_tokens} tokens; \
         {n} requests, {} new tokens each",
        sample.max_new_tokens
    );

    // 1. Session microbench: cold head prefill vs snapshot restore.
    let head_ids = tok.encode(&head);
    let mut warmup = model.session();
    warmup.prefill(&head_ids).unwrap();
    let reps = 5;
    let t0 = Instant::now();
    for _ in 0..reps {
        let mut s = model.session();
        s.prefill(&head_ids).unwrap();
    }
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    let snap = {
        let mut s = model.session();
        s.prefill(&head_ids).unwrap();
        s.snapshot().unwrap()
    };
    let t0 = Instant::now();
    for _ in 0..reps {
        let s = model.session_from(snap.clone()).unwrap();
        assert_eq!(s.position(), head_ids.len());
    }
    let restore_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    let prefill_tps = head_ids.len() as f64 / (prefill_ms / 1e3);
    println!(
        "head prefill: {prefill_ms:.3}ms ({prefill_tps:.0} tok/s) vs snapshot restore: \
         {restore_ms:.3}ms — {:.1}× cheaper",
        prefill_ms / restore_ms.max(1e-9)
    );

    // 2. Scheduler TTFT, cold vs warm cache.
    let (mut cold_ttft, cold_cached, cold_digest, cold_tokens) =
        run_sequential(&model, &tok, &prompts, &sample, 0);
    assert!(cold_cached.iter().all(|&c| c == 0), "disabled cache must stay cold");
    let (warm_ttft, warm_cached, warm_digest, warm_tokens) =
        run_sequential(&model, &tok, &prompts, &sample, 64);
    assert_eq!(cold_digest, warm_digest, "prefix cache changed sampled text");
    assert_eq!(cold_tokens, warm_tokens);
    assert_eq!(warm_cached[0], 0, "first warm request seeds the cache");
    // Later requests share only the head (distinct tails), so they hit
    // the last stride-aligned boundary inside it — within one stride
    // (plus tokenizer boundary slack) of the full shared head.
    assert!(
        warm_cached[1..].iter().all(|&c| c > 0 && c + 40 >= head_tokens),
        "every later request must hit near the shared head ({head_tokens} tokens): \
         {warm_cached:?}"
    );

    let cold_p = percentiles(&mut cold_ttft);
    // Hits only: drop the seeding (cold) first request.
    let mut hits_ttft: Vec<f64> = warm_ttft[1..].to_vec();
    let hit_p = percentiles(&mut hits_ttft);
    let cold_prefill_tps = (prompt_tokens - 1) as f64 / (cold_p.mean / 1e3);
    let hit_prefill_tps = (prompt_tokens - 1) as f64 / (hit_p.mean / 1e3);
    println!(
        "TTFT cold:  mean {:.2}ms p50 {:.2}ms p95 {:.2}ms (effective prefill {:.0} tok/s)",
        cold_p.mean, cold_p.p50, cold_p.p95, cold_prefill_tps
    );
    println!(
        "TTFT hit:   mean {:.2}ms p50 {:.2}ms p95 {:.2}ms (effective prefill {:.0} tok/s)",
        hit_p.mean, hit_p.p50, hit_p.p95, hit_prefill_tps
    );
    let speedup = cold_p.mean / hit_p.mean.max(1e-9);
    println!("TTFT speedup on cache hits: {speedup:.2}×");
    println!("parity: cold and warm runs produced byte-identical text");

    // 3. HTTP keep-alive: the same request twice over one connection —
    //    second call hits both the prefix cache and the reused socket.
    let http_cfg = ServeCfg {
        max_active: 2,
        threads: 2,
        quantum: 8,
        prefix_cache_size: 64,
        sample: sample.clone(),
        ..Default::default()
    };
    let sched =
        Arc::new(StreamScheduler::start(Arc::clone(&model), tok.clone(), http_cfg).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", sched).unwrap();
    let addr = server.local_addr().to_string();
    let mut http = client::Client::new(&addr);
    let mut req = GenerateRequest::new(&prompts[0]);
    req.id = Some(0);
    let t0 = Instant::now();
    let first = http.generate(&req).unwrap();
    let http_cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    req.id = Some(1);
    let t0 = Instant::now();
    let second = http.generate(&req).unwrap();
    let http_hit_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(first.cached_prefix_len, 0);
    assert!(second.cached_prefix_len >= head_tokens.min(prompt_tokens - 1));
    server.shutdown();
    println!(
        "http keep-alive generate RTT: cold {http_cold_ms:.2}ms → hit {http_hit_ms:.2}ms \
         ({:.2}×)",
        http_cold_ms / http_hit_ms.max(1e-9)
    );

    // JSON for the perf trajectory.
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"prefix_cache\",\n");
    json.push_str(&format!(
        "  \"requests\": {n}, \"ctx\": {ctx}, \"dim\": 64, \"layers\": 4, \
         \"head_tokens\": {head_tokens}, \"prompt_tokens\": {prompt_tokens}, \
         \"max_new_tokens\": {},\n",
        sample.max_new_tokens
    ));
    json.push_str(&format!(
        "  \"session\": {{\"head_prefill_ms\": {prefill_ms:.4}, \"restore_ms\": {restore_ms:.4}, \
         \"restore_speedup\": {:.3}}},\n",
        prefill_ms / restore_ms.max(1e-9)
    ));
    json.push_str(&format!(
        "  \"ttft_cold_ms\": {{\"mean\": {:.3}, \"p50\": {:.3}, \"p95\": {:.3}}},\n",
        cold_p.mean, cold_p.p50, cold_p.p95
    ));
    json.push_str(&format!(
        "  \"ttft_hit_ms\": {{\"mean\": {:.3}, \"p50\": {:.3}, \"p95\": {:.3}}},\n",
        hit_p.mean, hit_p.p50, hit_p.p95
    ));
    json.push_str(&format!(
        "  \"prefill_tok_per_s\": {{\"cold\": {cold_prefill_tps:.1}, \"hit\": {hit_prefill_tps:.1}}},\n"
    ));
    json.push_str(&format!("  \"ttft_speedup_on_hit\": {speedup:.3},\n"));
    json.push_str(&format!(
        "  \"http_keep_alive\": {{\"cold_rtt_ms\": {http_cold_ms:.3}, \"hit_rtt_ms\": {http_hit_ms:.3}}},\n"
    ));
    json.push_str(&format!(
        "  \"ttft_improved\": {},\n  \"parity\": true\n",
        hit_p.mean < cold_p.mean
    ));
    json.push_str("}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
