//! Bench: the HTTP streaming front-end vs the in-process scheduler —
//! **time-to-first-token** (TTFT) and **streamed tok/s**.
//!
//! Three measurements over identical synthetic weights (no artifacts, no
//! PJRT):
//!
//! 1. **In-process batch** — `serve()` over N requests: the tok/s
//!    ceiling with zero transport and zero streaming.
//! 2. **In-process streaming** — a resident `StreamScheduler`, all N
//!    requests submitted at once, one collector thread per stream:
//!    per-request TTFT (submit → first `TokenEvent`) and drained tok/s.
//! 3. **HTTP streaming** — the same requests through a loopback
//!    `HttpServer` (`POST /v1/stream`, chunked SSE), C client threads:
//!    per-request TTFT (connect → first delta) and end-to-end streamed
//!    tok/s.
//!
//! Every path must produce byte-identical text (same request ids → same
//! RNG streams); the bench asserts that, because a throughput number
//! from diverging outputs would be meaningless.
//!
//! Results land in `BENCH_http.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the request count.
//!
//! Run: `cargo bench --bench http_streaming`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{serve, Request, ServeCfg, StreamScheduler, TokenEvent};
use hsm::server::api::GenerateRequest;
use hsm::server::{client, HttpServer};
use hsm::tokenizer::Tokenizer;

fn synthetic_model(ctx: usize, vocab: usize) -> Arc<Model> {
    let (dim, heads, ffn) = (64, 4, 128);
    let layers: Vec<LayerInfo> = (0..4)
        .map(|l| LayerInfo {
            kind: "ab".to_string(),
            heads,
            shifts: vec![(1usize << l.min(5)).min(ctx / 2)],
            ffn,
        })
        .collect();
    let m = Manifest::synthetic("hsm_ab", layers, dim, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 17);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::new(i as u64, TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]))
        .collect()
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

/// Digest completions in request-id order so every path hashes the same
/// sequence regardless of arrival order.
fn digest_ordered(texts: &mut [(u64, String)]) -> u64 {
    texts.sort_by_key(|(id, _)| *id);
    let mut d = 0xcbf2_9ce4_8422_2325u64;
    for (_, t) in texts.iter() {
        fnv(&mut d, t);
    }
    d
}

struct Percentiles {
    mean: f64,
    p50: f64,
    p95: f64,
}

fn percentiles(samples: &mut [f64]) -> Percentiles {
    samples.sort_by(|a, b| a.total_cmp(b));
    let at = |q: f64| samples[((samples.len() - 1) as f64 * q).round() as usize];
    Percentiles {
        mean: samples.iter().sum::<f64>() / samples.len() as f64,
        p50: at(0.5),
        p95: at(0.95),
    }
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24)
        .max(1);
    let clients: usize = 6.min(n);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_http.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 256;
    let model = synthetic_model(ctx, tok.vocab_size());
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 48,
        seed: 5,
        stop_at_eot: true,
    };
    let cfg = ServeCfg { max_active: 8, threads: 4, quantum: 16, sample, ..Default::default() };

    // 1. In-process batch: throughput ceiling, whole completions only.
    let run_batch = || {
        let comps = serve(&model, &tok, requests(n), &cfg).unwrap();
        let mut texts: Vec<(u64, String)> =
            comps.iter().map(|c| (c.request_id, c.completion.clone())).collect();
        let tokens: usize = comps.iter().map(|c| c.tokens_generated).sum();
        (tokens, digest_ordered(&mut texts))
    };
    run_batch(); // warmup
    let t0 = Instant::now();
    let (batch_tokens, batch_digest) = run_batch();
    let batch_secs = t0.elapsed().as_secs_f64();
    let batch_tps = batch_tokens as f64 / batch_secs;
    println!(
        "in-process batch:     {batch_tokens} tokens in {batch_secs:.3}s → {batch_tps:>8.1} tok/s"
    );

    // 2. In-process streaming: resident scheduler, TTFT per request.
    let sched =
        StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg.clone()).unwrap();
    let t0 = Instant::now();
    let collectors: Vec<_> = requests(n)
        .into_iter()
        .map(|r| {
            let stream = sched.submit(r).unwrap();
            let submitted = Instant::now();
            std::thread::spawn(move || {
                let mut first: Option<f64> = None;
                let mut text = String::new();
                let mut id = 0u64;
                let mut tokens = 0usize;
                for ev in stream {
                    if first.is_none() {
                        first = Some(submitted.elapsed().as_secs_f64() * 1e3);
                    }
                    match ev {
                        TokenEvent::Token { text_delta, .. } => {
                            tokens += 1;
                            text.push_str(&text_delta);
                        }
                        TokenEvent::Done { text_delta, completion } => {
                            text.push_str(&text_delta);
                            id = completion.request_id;
                        }
                    }
                }
                (id, text, tokens, first.unwrap_or(f64::NAN))
            })
        })
        .collect();
    let mut inproc_texts = Vec::new();
    let mut inproc_ttft = Vec::new();
    let mut inproc_tokens = 0usize;
    for c in collectors {
        let (id, text, tokens, ttft) = c.join().unwrap();
        inproc_texts.push((id, text));
        inproc_ttft.push(ttft);
        inproc_tokens += tokens;
    }
    let inproc_secs = t0.elapsed().as_secs_f64();
    sched.shutdown();
    let inproc_tps = inproc_tokens as f64 / inproc_secs;
    assert_eq!(
        digest_ordered(&mut inproc_texts),
        batch_digest,
        "in-process streamed text diverged from batch"
    );
    let inproc_p = percentiles(&mut inproc_ttft);
    println!(
        "in-process streaming: {inproc_tokens} tokens in {inproc_secs:.3}s → {inproc_tps:>8.1} tok/s \
         | TTFT mean {:.1}ms p50 {:.1}ms p95 {:.1}ms",
        inproc_p.mean, inproc_p.p50, inproc_p.p95
    );

    // 3. HTTP streaming over loopback.
    let sched =
        Arc::new(StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg.clone()).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", sched).unwrap();
    let addr = server.local_addr().to_string();

    // One non-streaming request-response RTT for scale.
    let mut rtt_req = GenerateRequest::new(TABLE3_PROMPTS[0]);
    rtt_req.id = Some(0);
    let t0 = Instant::now();
    client::generate(&addr, &rtt_req).unwrap();
    let generate_rtt_ms = t0.elapsed().as_secs_f64() * 1e3;

    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|w| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut texts: Vec<(u64, String)> = Vec::new();
                let mut ttfts: Vec<f64> = Vec::new();
                let mut tokens = 0usize;
                for i in (w..n).step_by(clients.max(1)) {
                    let mut req = GenerateRequest::new(TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]);
                    req.id = Some(i as u64);
                    let sent = Instant::now();
                    let mut first: Option<f64> = None;
                    let mut text = String::new();
                    let completion = client::stream(&addr, &req, |token, delta| {
                        if first.is_none() {
                            first = Some(sent.elapsed().as_secs_f64() * 1e3);
                        }
                        if token.is_some() {
                            tokens += 1;
                        }
                        text.push_str(delta);
                    })
                    .unwrap();
                    texts.push((completion.request_id, text));
                    ttfts.push(first.unwrap_or(f64::NAN));
                }
                (texts, ttfts, tokens)
            })
        })
        .collect();
    let mut http_texts = Vec::new();
    let mut http_ttft = Vec::new();
    let mut http_tokens = 0usize;
    for w in workers {
        let (texts, ttfts, tokens) = w.join().unwrap();
        http_texts.extend(texts);
        http_ttft.extend(ttfts);
        http_tokens += tokens;
    }
    let http_secs = t0.elapsed().as_secs_f64();
    server.shutdown();
    let http_tps = http_tokens as f64 / http_secs;
    assert_eq!(
        digest_ordered(&mut http_texts),
        batch_digest,
        "HTTP streamed text diverged from the in-process scheduler"
    );
    let http_p = percentiles(&mut http_ttft);
    println!(
        "http streaming:       {http_tokens} tokens in {http_secs:.3}s → {http_tps:>8.1} tok/s \
         | TTFT mean {:.1}ms p50 {:.1}ms p95 {:.1}ms | {clients} clients",
        http_p.mean, http_p.p50, http_p.p95
    );
    println!("\nhttp vs in-process streaming: {:.2}× tok/s", http_tps / inproc_tps);
    println!("generate (non-streaming) RTT: {generate_rtt_ms:.1}ms");
    println!("parity: all three paths produced byte-identical text");

    // JSON for the perf trajectory.
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"http_streaming\",\n");
    json.push_str(&format!(
        "  \"requests\": {n}, \"clients\": {clients}, \"ctx\": {ctx}, \"dim\": 64, \
         \"layers\": 4, \"max_new_tokens\": {},\n",
        cfg.sample.max_new_tokens
    ));
    json.push_str(&format!("  \"batch_tok_per_s\": {batch_tps:.1},\n"));
    json.push_str(&format!(
        "  \"inproc_stream\": {{\"tok_per_s\": {inproc_tps:.1}, \"ttft_ms_mean\": {:.2}, \
         \"ttft_ms_p50\": {:.2}, \"ttft_ms_p95\": {:.2}}},\n",
        inproc_p.mean, inproc_p.p50, inproc_p.p95
    ));
    json.push_str(&format!(
        "  \"http_stream\": {{\"tok_per_s\": {http_tps:.1}, \"ttft_ms_mean\": {:.2}, \
         \"ttft_ms_p50\": {:.2}, \"ttft_ms_p95\": {:.2}, \"generate_rtt_ms\": {:.2}}},\n",
        http_p.mean, http_p.p50, http_p.p95, generate_rtt_ms
    ));
    json.push_str(&format!(
        "  \"http_vs_inproc_stream\": {:.3},\n  \"parity\": true\n",
        http_tps / inproc_tps
    ));
    json.push_str("}\n");
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
