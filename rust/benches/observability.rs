//! Bench: what serving telemetry costs — tok/s with observability
//! **off** (`ObsCfg::off`), **counters only** (atomic adds, no clock
//! reads), and **full** (counters + latency histograms + sampled
//! per-stage timing + a request log to a sink), on the same
//! continuous-batching workload.
//!
//! Two claims are asserted, not just printed:
//!
//! 1. **Byte parity** — all three modes produce identical completion
//!    bytes (telemetry is a pure tap; it must never touch sampling).
//! 2. **Overhead bound** — full telemetry costs at most a few percent
//!    of throughput (best-of-N against best-of-N, interleaved so the
//!    modes see the same machine state).
//!
//! The full-mode run also sanity-checks the registry itself: admitted
//! and finished counts, generated-token totals, and a non-empty
//! Prometheus rendering with stage samples present.
//!
//! Results land in `BENCH_obs.json` (override with `HSM_BENCH_OUT`);
//! `HSM_BENCH_REQUESTS` scales the request count and
//! `HSM_BENCH_REPEATS` the best-of repeat count.
//!
//! Run: `cargo bench --bench observability`.

use std::sync::Arc;
use std::time::Instant;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{weights, Model, ModelWeights};
use hsm::obs::{MetricsRegistry, ObsCfg, RequestLog};
use hsm::serve::{serve, Request, ServeCfg};
use hsm::tokenizer::Tokenizer;

/// Full telemetry may cost at most this fraction of off-mode
/// throughput (best-of-N vs best-of-N).
const MAX_OVERHEAD: f64 = 0.03;

fn layers() -> Vec<LayerInfo> {
    (0..4)
        .map(|l| LayerInfo {
            kind: "ab".into(),
            heads: 4,
            shifts: vec![1usize << l.min(5)],
            ffn: 64,
        })
        .collect()
}

fn fnv(digest: &mut u64, s: &str) {
    for b in s.as_bytes() {
        *digest = (*digest ^ *b as u64).wrapping_mul(0x100_0000_01b3);
    }
}

struct RunOut {
    secs: f64,
    tokens: usize,
    digest: u64,
}

fn run(
    model: &Arc<Model>,
    tok: &Tokenizer,
    prompts: &[String],
    sample: &SampleCfg,
    obs: ObsCfg,
) -> RunOut {
    let cfg = ServeCfg {
        max_active: 4,
        threads: 2,
        quantum: 8,
        prefix_cache_size: 8,
        sample: sample.clone(),
        obs,
        ..Default::default()
    };
    let requests: Vec<Request> =
        prompts.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
    let t0 = Instant::now();
    let completions = serve(model, tok, requests, &cfg).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    let mut tokens = 0usize;
    for c in &completions {
        fnv(&mut digest, &c.completion);
        tokens += c.tokens_generated;
    }
    RunOut { secs, tokens, digest }
}

fn main() {
    let n: usize = std::env::var("HSM_BENCH_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16)
        .max(2);
    let repeats: usize = std::env::var("HSM_BENCH_REPEATS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5)
        .max(1);
    let out_path =
        std::env::var("HSM_BENCH_OUT").unwrap_or_else(|_| "BENCH_obs.json".to_string());

    let text = hsm::corpus::generate(1234, 400);
    let tok: Tokenizer = hsm::tokenizer::trainer::train(&text, 512).unwrap();
    let ctx = 512;
    let model = {
        let m = Manifest::synthetic("ab", layers(), 32, ctx, tok.vocab_size(), 1);
        let flat = weights::seeded_flat(&m, 17);
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
    };
    let prompts: Vec<String> =
        (0..n).map(|i| TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()].to_string()).collect();
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 40,
        max_new_tokens: 32,
        seed: 5,
        stop_at_eot: true,
    };

    // Full mode records into this registry (and a sink-backed request
    // log), so the run's numbers can be checked after the fact.
    let registry = MetricsRegistry::new();
    let full_cfg = || ObsCfg {
        metrics: Some(Arc::clone(&registry)),
        request_log: Some(RequestLog::to_writer(Box::new(std::io::sink()))),
        ..ObsCfg::default()
    };
    let counters_cfg = || ObsCfg { timing: false, stage_sample_every: 0, ..ObsCfg::default() };

    // Interleave the modes across repeats so no mode systematically
    // sees a warmer (or noisier) machine; keep the best of each.
    let mut best: [Option<RunOut>; 3] = [None, None, None];
    for _ in 0..repeats {
        for (slot, obs) in
            [(0, ObsCfg::off()), (1, counters_cfg()), (2, full_cfg())]
        {
            let out = run(&model, &tok, &prompts, &sample, obs);
            let better = best[slot].as_ref().map_or(true, |b| out.secs < b.secs);
            if better {
                best[slot] = Some(out);
            }
        }
    }
    let off = best[0].take().unwrap();
    let counters = best[1].take().unwrap();
    let full = best[2].take().unwrap();

    // Claim 1: telemetry is a pure tap — the bytes never change.
    assert_eq!(counters.digest, off.digest, "counters-only telemetry changed sampled bytes");
    assert_eq!(full.digest, off.digest, "full telemetry changed sampled bytes");
    assert_eq!(counters.tokens, off.tokens);
    assert_eq!(full.tokens, off.tokens);

    let tps = |r: &RunOut| r.tokens as f64 / r.secs.max(1e-9);
    let (off_tps, counters_tps, full_tps) = (tps(&off), tps(&counters), tps(&full));
    let counters_overhead = 1.0 - counters_tps / off_tps.max(1e-9);
    let full_overhead = 1.0 - full_tps / off_tps.max(1e-9);
    println!("off:           {off_tps:>7.0} tok/s  ({} tokens, {n} requests)", off.tokens);
    println!("counters-only: {counters_tps:>7.0} tok/s  ({:+.2}%)", counters_overhead * 100.0);
    println!("full:          {full_tps:>7.0} tok/s  ({:+.2}%)", full_overhead * 100.0);

    // Claim 2: full telemetry stays within the overhead budget.
    assert!(
        full_overhead <= MAX_OVERHEAD,
        "full telemetry cost {:.2}% tok/s (budget {:.0}%)",
        full_overhead * 100.0,
        MAX_OVERHEAD * 100.0
    );

    // The full-mode registry actually saw the workload (repeats × n
    // requests; every repeat decoded the same token count).
    let full_runs = repeats as u64;
    assert_eq!(registry.admitted(), full_runs * n as u64, "admitted count");
    assert_eq!(registry.finished_total(), full_runs * n as u64, "finished count");
    assert_eq!(registry.tokens_generated(), full_runs * off.tokens as u64, "token count");
    let rendered = registry.render_prometheus();
    assert!(rendered.contains("hsm_ttft_seconds_bucket"), "TTFT histogram missing");
    assert!(rendered.contains("hsm_stage_seconds_total"), "stage timing missing");
    assert!(
        registry.stage_snapshot().iter().any(|(_, _, samples)| *samples > 0),
        "stage sampling recorded nothing"
    );

    let json = format!(
        "{{\n  \"bench\": \"observability\",\n  \"requests\": {n}, \"repeats\": {repeats}, \
         \"ctx\": {ctx}, \"dim\": 32, \"layers\": 4, \"max_new_tokens\": {}, \
         \"kernel_backend\": \"{}\",\n  \
         \"off_tok_per_s\": {off_tps:.1},\n  \
         \"counters_tok_per_s\": {counters_tps:.1},\n  \
         \"full_tok_per_s\": {full_tps:.1},\n  \
         \"counters_overhead\": {counters_overhead:.4},\n  \
         \"full_overhead\": {full_overhead:.4},\n  \
         \"overhead_budget\": {MAX_OVERHEAD},\n  \"parity\": true\n}}\n",
        sample.max_new_tokens,
        hsm::infer::tensor::kernel_backend()
    );
    std::fs::write(&out_path, &json).expect("writing bench json");
    println!("\nwrote {out_path}");
}
