//! Bench: Table 1's timing column — training-step latency per variant.
//!
//! The paper reports seconds/epoch for all 11 configurations and claims
//! HSM (a,b) trains ~40 % faster than GPT, the hybrids 7–15 % faster.
//! Absolute numbers are machine-specific; the *ratios* are the claim.
//! This bench measures steady-state `train_step` latency (compile time
//! excluded) for each variant with artifacts present and prints both the
//! absolute latency and the ratio vs GPT.
//!
//! Run: `cargo bench --bench table1_training` (after `make artifacts`).

use hsm::config::{Manifest, TABLE1_VARIANTS};
use hsm::data::Batch;
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::util::bench::Bench;

fn main() {
    let root = std::path::Path::new("artifacts");
    let preset = std::env::var("HSM_BENCH_PRESET").unwrap_or_else(|_| "ci".into());
    let mut bench = Bench::quick();
    let mut rows: Vec<(String, f64)> = Vec::new();

    // HSM_BENCH_VARIANTS=a,b,c time-boxes the run (each variant pays an
    // ~40 s XLA compile before measurement starts).
    let subset = std::env::var("HSM_BENCH_VARIANTS").ok();
    let chosen: Vec<&str> = match &subset {
        Some(s) => s.split(',').collect(),
        None => TABLE1_VARIANTS.to_vec(),
    };
    for v in &chosen {
        let Ok(m) = Manifest::load_variant(root, &preset, v) else {
            eprintln!("skip {v}: no {preset} artifacts (run `make artifacts`)");
            continue;
        };
        let (b, t, vocab) = (m.train.batch, m.ctx, m.vocab as i32);
        let Ok(mut eng) = PjrtEngine::new(m) else { continue };
        eng.init(0).unwrap();
        let batch = Batch {
            x: (0..b * t).map(|i| (i as i32 * 7) % vocab).collect(),
            y: (0..b * t).map(|i| (i as i32 * 7 + 1) % vocab).collect(),
            batch: b,
            ctx: t,
        };
        // Pay the XLA compile outside the measurement.
        let mut step = 0i32;
        eng.train_step(step, &batch).unwrap();
        let stats = bench.run(&format!("train_step/{v}"), || {
            step += 1;
            eng.train_step(step, &batch).unwrap();
        });
        rows.push((v.to_string(), stats.mean.as_secs_f64()));
    }

    if let Some(gpt) = rows.iter().find(|(v, _)| v == "gpt").map(|(_, s)| *s) {
        println!("\nTable 1 timing shape (steady-state step latency, {preset} preset):");
        println!("{:<16} {:>12} {:>10}", "variant", "ms/step", "vs GPT");
        for (v, s) in &rows {
            println!("{:<16} {:>12.1} {:>9.2}×", v, s * 1e3, s / gpt);
        }
        println!("\npaper: HSM(a,b) 0.60×, hybrids 0.85–0.93× of GPT epoch time");
    }
}
