//! Bench: forward-only mixer cost (eval_step) — the per-layer complexity
//! story behind the paper's §3 (O(T) HSM vs O(T²) attention per layer).
//!
//! Also benches the pallas-vs-jnp kernel ablation when both artifact
//! flavours exist (`make artifacts-jnp` lowers the jnp reference backend
//! into `artifacts-jnp/`), quantifying the interpret-mode Pallas overhead
//! that DESIGN.md §8 discusses.
//!
//! Run: `cargo bench --bench mixer_step`.

use std::path::Path;

use hsm::config::Manifest;
use hsm::data::Batch;
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::util::bench::Bench;

const SET: &[&str] = &["hsm_ab", "hsm_vec", "hsm_mat", "hsm_gate1", "hsm_gate2", "hsm_fusion", "hsm_ab_mh", "gpt"];

fn bench_root(bench: &mut Bench, root: &Path, preset: &str, tag: &str) -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for v in SET {
        let Ok(m) = Manifest::load_variant(root, preset, v) else { continue };
        let (b, t, vocab) = (m.train.batch, m.ctx, m.vocab as i32);
        let Ok(mut eng) = PjrtEngine::new(m) else { continue };
        eng.init(0).unwrap();
        let batch = Batch {
            x: (0..b * t).map(|i| (i as i32 * 13) % vocab).collect(),
            y: (0..b * t).map(|i| (i as i32 * 13 + 1) % vocab).collect(),
            batch: b,
            ctx: t,
        };
        eng.eval_step(&batch).unwrap(); // compile
        let stats = bench.run(&format!("eval{tag}/{v}"), || {
            eng.eval_step(&batch).unwrap();
        });
        rows.push((v.to_string(), stats.mean.as_secs_f64()));
    }
    rows
}

fn main() {
    let preset = std::env::var("HSM_BENCH_PRESET").unwrap_or_else(|_| "ci".into());
    let mut bench = Bench::quick();

    let pallas = bench_root(&mut bench, Path::new("artifacts"), &preset, "");
    if pallas.is_empty() {
        eprintln!("no {preset} artifacts — run `make artifacts`");
        return;
    }
    if let Some(gpt) = pallas.iter().find(|(v, _)| v == "gpt").map(|(_, s)| *s) {
        println!("\nForward-only mixer cost ({preset} preset):");
        println!("{:<16} {:>12} {:>10}", "variant", "ms/eval", "vs GPT");
        for (v, s) in &pallas {
            println!("{:<16} {:>12.2} {:>9.2}×", v, s * 1e3, s / gpt);
        }
    }

    // Kernel-backend ablation, if the jnp flavour has been lowered.
    let jnp_root = Path::new("artifacts-jnp");
    if jnp_root.exists() {
        let jnp = bench_root(&mut bench, jnp_root, &preset, "-jnp");
        println!("\nPallas(interpret) vs pure-jnp lowering:");
        println!("{:<16} {:>12} {:>12} {:>8}", "variant", "pallas ms", "jnp ms", "ratio");
        for (v, sp) in &pallas {
            if let Some((_, sj)) = jnp.iter().find(|(vj, _)| vj == v) {
                println!("{:<16} {:>12.2} {:>12.2} {:>7.2}×", v, sp * 1e3, sj * 1e3, sp / sj);
            }
        }
    } else {
        println!("\n(jnp-backend ablation skipped — run `make artifacts-jnp`)");
    }
}
