//! End-to-end tests against REAL PJRT artifacts.
//!
//! The whole file needs the `pjrt` feature (it drives `PjrtEngine`);
//! `--no-default-features` builds compile it to nothing.
//!
//! These require `make artifacts` (ci preset) to have run; if the
//! artifacts are missing the tests skip with a notice rather than fail, so
//! `cargo test` stays usable on a fresh checkout.
//!
//! NOTE: XLA 0.5.1 spends ~40 s compiling the ci train_step, so the
//! training-path assertions share ONE engine in a single #[test] rather
//! than paying the compile per test.

#![cfg(feature = "pjrt")]

use std::path::Path;

use hsm::config::Manifest;
use hsm::data::Batch;
use hsm::runtime::{PjrtEngine, StepEngine};

fn manifest(variant: &str) -> Option<Manifest> {
    let root = Path::new("artifacts");
    Manifest::load_variant(root, "ci", variant).ok()
}

fn skip(name: &str) {
    eprintln!("SKIP {name}: no ci artifacts — run `make artifacts` first");
}

fn test_batch(m: &Manifest, seed: i32) -> Batch {
    let (b, t, v) = (m.train.batch, m.ctx, m.vocab as i32);
    let x: Vec<i32> = (0..b * t).map(|i| (i as i32 * 31 + seed) % v).collect();
    // Learnable structure: y is x shifted by one (next-token of a known seq).
    let y: Vec<i32> = (0..b * t)
        .map(|i| {
            let col = i % t;
            if col + 1 < t { x[i + 1] } else { x[i - col] }
        })
        .collect();
    Batch { x, y, batch: b, ctx: t }
}

/// The big one: init → params sane → loss at ln(V) → loss drops over steps
/// → eval matches → decode shape/finite → checkpoint roundtrip bit-exact.
#[test]
fn training_path_end_to_end() {
    let Some(m) = manifest("hsm_ab") else { return skip("training_path_end_to_end") };
    let n_params = m.params.len();
    let vocab = m.vocab;
    let mut eng = PjrtEngine::new(m.clone()).unwrap();

    // init: deterministic per seed.
    eng.init(7).unwrap();
    let p1 = eng.get_params().unwrap();
    eng.init(7).unwrap();
    let p2 = eng.get_params().unwrap();
    assert_eq!(p1.len(), n_params);
    assert_eq!(p1, p2, "init must be deterministic per seed");
    eng.init(8).unwrap();
    assert_ne!(eng.get_params().unwrap(), p1, "different seed, different init");

    // Initial loss ≈ ln(vocab) on random tokens.
    eng.init(7).unwrap();
    let batch = test_batch(&m, 3);
    let m0 = eng.eval_step(&batch).unwrap();
    let uniform = (vocab as f32).ln();
    assert!((m0.loss - uniform).abs() < 0.7, "initial loss {} vs ln(V) {uniform}", m0.loss);

    // Loss decreases over a few steps on a fixed batch.
    let mut losses = Vec::new();
    for step in 0..6 {
        let sm = eng.train_step(step, &batch).unwrap();
        assert!(sm.loss.is_finite());
        losses.push(sm.loss);
    }
    assert!(
        losses.last().unwrap() < &(losses[0] - 0.2),
        "loss should drop: {losses:?}"
    );

    // eval after training < eval before.
    let m1 = eng.eval_step(&batch).unwrap();
    assert!(m1.loss < m0.loss);

    // decode: right shape, finite, and consistent with params.
    let toks: Vec<i32> = (0..m.ctx as i32).map(|i| i % vocab as i32).collect();
    let logits = eng.decode(&toks).unwrap();
    assert_eq!(logits.len(), m.ctx * vocab);
    assert!(logits.iter().all(|x| x.is_finite()));

    // Params roundtrip through host bit-exactly (set_params(get_params)).
    let params = eng.get_params().unwrap();
    let (mm, vv) = eng.get_state().unwrap();
    eng.set_params(params.clone()).unwrap();
    eng.set_state(mm.clone(), vv.clone()).unwrap();
    assert_eq!(eng.get_params().unwrap(), params);
    let logits2 = eng.decode(&toks).unwrap();
    assert_eq!(logits, logits2, "decode must be bit-stable across state roundtrip");

    // Error paths.
    let bad = Batch { x: vec![0; 4], y: vec![0; 4], batch: 2, ctx: 2 };
    assert!(eng.train_step(99, &bad).is_err(), "wrong batch shape must fail");
    assert!(eng.decode(&[1, 2, 3]).is_err(), "wrong token count must fail");
}

/// Artifact/manifest consistency for every lowered ci variant.
#[test]
fn manifests_consistent_with_artifacts() {
    let root = Path::new("artifacts/ci");
    if !root.exists() {
        return skip("manifests_consistent_with_artifacts");
    }
    let mut found = 0;
    for v in hsm::config::VARIANTS {
        let Some(m) = manifest(v) else { continue };
        found += 1;
        assert_eq!(&m.variant, v);
        assert_eq!(m.layers.len(), 7, "{v}");
        assert_eq!(m.total_elems(), m.param_count, "{v}: manifest param count mismatch");
        for kind in ["init", "train_step", "eval_step", "decode"] {
            assert!(m.artifact(kind).exists(), "{v}/{kind} missing");
        }
        // Shift schedule sanity per variant family.
        match *v {
            "hsm_ab" | "hsm_vec" | "hsm_mat" | "hsm_gate1" => {
                let shifts: Vec<usize> = m.layers.iter().map(|l| l.shifts[0]).collect();
                assert_eq!(shifts[0], 1, "{v}");
                assert!(shifts.windows(2).all(|w| w[1] >= w[0]), "{v}: {shifts:?}");
            }
            "hsm_ab_mh" => {
                assert!(m.layers.iter().all(|l| l.shifts.len() == l.heads), "{v}");
                assert_eq!(m.layers[0].shifts, m.layers[1].shifts, "{v}: same per layer");
            }
            "hsm_ab_mhext" => {
                assert_ne!(m.layers[0].shifts, m.layers[1].shifts, "{v}: must rotate");
            }
            "gpt" => assert!(m.layers.iter().all(|l| l.kind == "attn")),
            "hybrid_06" | "hybrid_mh_06" => {
                assert_ne!(m.layers[0].kind, "attn", "{v}");
                assert_eq!(m.layers[2].kind, "attn", "{v}");
            }
            _ => {}
        }
    }
    assert!(found > 0, "artifacts/ci exists but no variant loaded");
}

/// Native incremental engine vs PJRT decode artifact: logits parity.
///
/// This is the strongest cross-layer check in the repo: the from-scratch
/// rust forward pass (ring buffers, KV cache, hand-written matvec) must
/// reproduce the JAX/Pallas model's logits through a completely
/// independent code path, for both a pure-HSM and an attention variant.
#[test]
fn native_engine_matches_pjrt_decode() {
    use hsm::infer::{Decoder, ModelWeights, NativeDecoder};

    for variant in ["hsm_ab", "gpt", "hsm_fusion"] {
        let Some(m) = manifest(variant) else { return skip("native_engine_matches_pjrt_decode") };
        let mut pjrt = PjrtEngine::new(m.clone()).unwrap();
        pjrt.init(3).unwrap();

        let weights = ModelWeights::from_flat(&m, &pjrt.get_params().unwrap()).unwrap();
        let mut native = NativeDecoder::from_parts(m.clone(), weights).unwrap();

        // A short "prompt" of varied tokens.
        let toks: Vec<i32> = (0..m.ctx as i32).map(|i| (i * 37 + 11) % m.vocab as i32).collect();
        let pjrt_logits = pjrt.decode(&toks).unwrap(); // [ctx * vocab]

        for (p, &t) in toks.iter().enumerate().take(12) {
            let nat = native.step(t as u32).unwrap();
            let row = &pjrt_logits[p * m.vocab..(p + 1) * m.vocab];
            let mut max_abs = 0f32;
            let mut max_err = 0f32;
            for (a, b) in nat.iter().zip(row) {
                max_abs = max_abs.max(b.abs());
                max_err = max_err.max((a - b).abs());
            }
            assert!(
                max_err <= 2e-3 * max_abs.max(1.0),
                "{variant} pos {p}: max err {max_err} (scale {max_abs})"
            );
        }
        eprintln!("parity OK: {variant}");
    }
}

/// Different variants must disagree on architecture but agree on data
/// contract (ctx, vocab, batch) within a preset.
#[test]
fn preset_data_contract_is_uniform() {
    let Some(a) = manifest("hsm_ab") else { return skip("preset_data_contract") };
    let Some(b) = manifest("gpt") else { return skip("preset_data_contract") };
    assert_eq!(a.ctx, b.ctx);
    assert_eq!(a.vocab, b.vocab);
    assert_eq!(a.train.batch, b.train.batch);
    assert_ne!(a.layers[1].kind, b.layers[1].kind);
}
