//! Property tests for the tensor kernel stack: every tier (dispatched,
//! blocked, and — when the `simd` feature is on — the explicit SIMD
//! paths behind the dispatchers) must be **bit-identical** to the naive
//! reference on arbitrary shapes and contents.
//!
//! The generator deliberately hits the shapes and values that break
//! vectorised kernels: `k % 4 != 0` and `k % 8 != 0` (remainder
//! handling), `n = 0` and `n = 1` (empty / degenerate outputs), `k = 0`
//! (empty reduction), NaN and ±∞ (order-sensitive propagation), signed
//! zeros (`-0.0 == 0.0` must still take the zero-skip path), and
//! subnormals (no flush-to-zero allowed).  Comparison is on raw bits —
//! `assert_eq!` on f32 would call NaN ≠ NaN and miss -0.0 vs 0.0.
//!
//! The int8 tier gets the same treatment: every quantized kernel
//! (blocked, dispatched, and the explicit `simd` entry points) against
//! `matvec_q_naive`/`matmul_q_naive` bit-for-bit, across lane-remainder
//! shapes, empty/degenerate outputs, saturated ±127 rows, zero rows,
//! and extreme per-row scales.
//!
//! The int4 tier adds the group axis: shapes straddling `Q4_GROUP`
//! boundaries (`k % 32 != 0`, including 31/33/64/65), saturated ±7
//! nibbles, all-zero groups (scale 0), degenerate quantization of
//! NaN/∞-bearing rows, and extreme per-group scales — every tier
//! bit-for-bit against `matvec_q4_naive`/`matmul_q4_naive`.

use hsm::infer::tensor::{
    matmul, matmul_blocked, matmul_naive, matmul_q, matmul_q4, matmul_q4_blocked, matmul_q4_naive,
    matmul_q_blocked, matmul_q_naive, matmul_t, matmul_t_blocked, matmul_t_naive, matmul_t_q,
    matmul_t_q4, matvec, matvec_blocked, matvec_naive, matvec_q, matvec_q4, matvec_q4_blocked,
    matvec_q4_naive, matvec_q_blocked, matvec_q_naive, matvec_t, matvec_t_blocked, matvec_t_naive,
    matvec_t_q, matvec_t_q4, q4_row_bytes, q4_row_groups, quantize_row, quantize_row_q4, Q4_GROUP,
};
#[cfg(feature = "simd")]
use hsm::infer::tensor::simd;
use hsm::util::prop;
use hsm::util::rng::Rng;

/// Uniform f32s with edge values (NaN, ±∞, ±0.0, subnormals) sprinkled
/// in — roughly one slot in seven.
fn arb_edge_f32s(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    let edges = [
        0.0f32,
        -0.0,
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE / 2.0, // subnormal
        -1.0e-41,                // subnormal
        1.0e37,                  // overflow bait under accumulation
    ];
    let mut v = prop::arb_f32s(rng, len, scale);
    for x in v.iter_mut() {
        if rng.chance(1.0 / 7.0) {
            *x = *rng.pick(&edges);
        }
    }
    v
}

/// Bit-exact comparison with a shape-carrying failure message.
fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} diverged ({g:?} vs {w:?})"
        );
    }
}

/// Shapes biased toward the awkward cases: k not a multiple of the
/// 4-wide block or 8-wide SIMD lane count, and tiny n.
fn arb_shape(rng: &mut Rng) -> (usize, usize) {
    let k = *rng.pick(&[0usize, 1, 3, 4, 7, 8, 9, 13, 16, 31, 33]);
    let n = *rng.pick(&[0usize, 1, 2, 7, 8, 11, 24]);
    (k, n)
}

#[test]
fn prop_matvec_tiers_match_naive_bit_for_bit() {
    prop::check_n("matvec-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let x = arb_edge_f32s(rng, k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_naive(&x, &w, n, &mut want);

        let mut got = vec![7.0f32; n]; // poison: kernels must overwrite
        matvec_blocked(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec dispatched k={k} n={n}"));
    });
}

#[test]
fn prop_matvec_t_tiers_match_naive_bit_for_bit() {
    prop::check_n("matvec-t-tiers", prop::default_cases(), |rng| {
        // For the transposed kernel the *output* dimension n is the
        // SIMD-vectorised axis, so make it hit lane remainders too.
        let (n, k) = arb_shape(rng);
        let x = arb_edge_f32s(rng, k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_t_naive(&x, &w, n, &mut want);

        let mut got = vec![7.0f32; n];
        matvec_t_blocked(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec_t(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t dispatched k={k} n={n}"));
    });
}

#[test]
fn prop_batched_kernels_match_per_row_naive_bit_for_bit() {
    prop::check_n("matmul-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let m = rng.below(5); // includes the empty batch
        let xs = arb_edge_f32s(rng, m * k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; m * n];
        matmul_naive(&xs, m, &w, n, &mut want);
        // The naive batched form must itself be m independent matvecs.
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_naive(&xs[r * k..(r + 1) * k], &w, n, &mut row);
            assert_bits_eq(&row, &want[r * n..(r + 1) * n], &format!("matmul_naive row {r}"));
        }

        let mut got = vec![7.0f32; m * n];
        matmul_blocked(&xs, m, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_blocked m={m} k={k} n={n}"));

        got.fill(7.0);
        matmul(&xs, m, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul dispatched m={m} k={k} n={n}"));

        // Transposed batched kernel against its own naive reference.
        let mut want_t = vec![0.0f32; m * n];
        matmul_t_naive(&xs, m, &w, n, &mut want_t);
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_t_naive(&xs[r * k..(r + 1) * k], &w, n, &mut row);
            assert_bits_eq(&row, &want_t[r * n..(r + 1) * n], &format!("matmul_t_naive row {r}"));
        }

        let mut got_t = vec![7.0f32; m * n];
        matmul_t_blocked(&xs, m, &w, n, &mut got_t);
        assert_bits_eq(&got_t, &want_t, &format!("matmul_t_blocked m={m} k={k} n={n}"));

        got_t.fill(7.0);
        matmul_t(&xs, m, &w, n, &mut got_t);
        assert_bits_eq(&got_t, &want_t, &format!("matmul_t dispatched m={m} k={k} n={n}"));
    });
}

// ---------------------------------------------------------------------------
// Int8 tier (quantized weights + activations)
// ---------------------------------------------------------------------------

/// Random int8 row in the quantizer's range `[-127, 127]` (never −128 —
/// the AVX2 maddubs trick requires it), biased toward the saturation
/// endpoints and zero.
fn arb_qrow(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len)
        .map(|_| {
            if rng.chance(0.2) {
                *rng.pick(&[-127i8, 127, 0])
            } else {
                (rng.below(255) as i32 - 127) as i8
            }
        })
        .collect()
}

/// Per-row scales spanning ordinary magnitudes and the extremes that
/// expose premature f32 scaling (1e-30 underflow bait, 3.4e30 overflow
/// bait, exact-zero rows from degenerate quantization).
fn arb_scales(rng: &mut Rng, n: usize) -> Vec<f32> {
    let extremes = [0.0f32, 1.0e-30, 3.4e30, 1.0, 7.25e-3];
    (0..n)
        .map(|_| if rng.chance(0.3) { *rng.pick(&extremes) } else { rng.f32() * 0.1 + 1.0e-3 })
        .collect()
}

/// Every int8 tier must be bit-identical to the naive int8 reference:
/// exact i32 accumulation makes the integer sum unique, and the shared
/// `scale_out` expression makes the f32 conversion unique.  Activations
/// arrive both pre-built and through the real `quantize_row`, so the
/// fuzz covers exactly the values decode produces.
#[test]
fn prop_int8_matvec_tiers_match_naive_bit_for_bit() {
    prop::check_n("int8-matvec-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let (qx, sx) = if rng.chance(0.5) {
            (arb_qrow(rng, k), *rng.pick(&[0.0f32, 1.0e-30, 3.4e30, 2.0e-2]))
        } else {
            let x = arb_edge_f32s(rng, k, 2.0);
            let mut q = vec![0i8; k];
            let s = quantize_row(&x, &mut q);
            (q, s)
        };
        let wq = arb_qrow(rng, k * n);
        let scales = arb_scales(rng, n);

        let mut want = vec![0.0f32; n];
        matvec_q_naive(&qx, sx, &wq, &scales, &mut want);

        let mut got = vec![7.0f32; n]; // poison: kernels must overwrite
        matvec_q_blocked(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_q_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec_q(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_q dispatched k={k} n={n}"));

        // The transposed entry point is documented as the same kernel
        // (quantized storage is always out-major).
        got.fill(7.0);
        matvec_t_q(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t_q k={k} n={n}"));

        #[cfg(feature = "simd")]
        {
            got.fill(7.0);
            simd::matvec_q(&qx, sx, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("simd::matvec_q k={k} n={n}"));
        }
    });
}

/// Batched int8 tiers: row r of every tier must be bit-identical to a
/// single-row `matvec_q_naive` call — the fused speculative verify pass
/// depends on this (`rewind` + re-step must reproduce the same bits).
#[test]
fn prop_int8_batched_kernels_match_per_row_naive_bit_for_bit() {
    prop::check_n("int8-matmul-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let m = rng.below(5); // includes the empty batch
        let qxs = arb_qrow(rng, m * k);
        let sxs = arb_scales(rng, m);
        let wq = arb_qrow(rng, k * n);
        let scales = arb_scales(rng, n);

        let mut want = vec![0.0f32; m * n];
        matmul_q_naive(&qxs, m, &sxs, &wq, &scales, &mut want);
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_q_naive(&qxs[r * k..(r + 1) * k], sxs[r], &wq, &scales, &mut row);
            assert_bits_eq(&row, &want[r * n..(r + 1) * n], &format!("matmul_q_naive row {r}"));
        }

        let mut got = vec![7.0f32; m * n];
        if m > 0 {
            // The blocked core itself (the dispatcher handles m = 0).
            matmul_q_blocked(&qxs, m, &sxs, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("matmul_q_blocked m={m} k={k} n={n}"));
            got.fill(7.0);
        }
        matmul_q(&qxs, m, &sxs, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_q dispatched m={m} k={k} n={n}"));

        got.fill(7.0);
        matmul_t_q(&qxs, m, &sxs, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_t_q m={m} k={k} n={n}"));

        #[cfg(feature = "simd")]
        if m > 0 {
            got.fill(7.0);
            simd::matmul_q(&qxs, m, &sxs, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("simd::matmul_q m={m} k={k} n={n}"));
        }
    });
}

/// Saturated rows (all entries ±127) push the AVX2 pairwise i16 sums to
/// their ceiling (2·127² = 32258 < i16::MAX — the reason `quantize_row`
/// never emits −128) and must stay exact in every tier; all-zero
/// quantized rows must come out as exact zeros whatever the scales.
#[test]
fn prop_int8_saturated_and_zero_rows_stay_exact() {
    prop::check_n("int8-saturation", prop::default_cases(), |rng| {
        let k = *rng.pick(&[1usize, 31, 32, 33, 64, 257]);
        let n = *rng.pick(&[1usize, 4, 5]);
        let qx: Vec<i8> = (0..k).map(|_| if rng.chance(0.5) { 127i8 } else { -127 }).collect();
        let wq: Vec<i8> = (0..k * n).map(|_| if rng.chance(0.5) { 127i8 } else { -127 }).collect();
        let scales = arb_scales(rng, n);
        let sx = 3.1e-2f32;

        let mut want = vec![0.0f32; n];
        matvec_q_naive(&qx, sx, &wq, &scales, &mut want);
        // The reference itself must carry the exact integer dot (±k·127²
        // fits i32 easily at these k).
        for (j, &y) in want.iter().enumerate() {
            let mut sum = 0i64;
            for i in 0..k {
                sum += qx[i] as i64 * wq[j * k + i] as i64;
            }
            assert_eq!(y.to_bits(), ((sum as i32 as f32) * (sx * scales[j])).to_bits());
        }

        let mut got = vec![7.0f32; n];
        matvec_q_blocked(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("saturated blocked k={k} n={n}"));
        got.fill(7.0);
        matvec_q(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("saturated dispatched k={k} n={n}"));

        // Degenerate quantization (all-zero row, scale 0) must produce
        // exact zeros out of every tier, not tiny scaled noise.
        let zeros = vec![0i8; k];
        let mut zy = vec![7.0f32; n];
        matvec_q(&zeros, 0.0, &wq, &scales, &mut zy);
        for (j, y) in zy.iter().enumerate() {
            assert_eq!(y.to_bits(), 0.0f32.to_bits(), "zero row must stay exactly zero (j={j})");
        }
    });
}

/// All-zero inputs must take the zero-skip fast path in every tier and
/// still write exact (positive) zeros, even when weights hold NaN/∞
/// (the skip is semantic: `0.0 * NaN` never happens because the naive
/// reference skips it too).
#[test]
fn prop_zero_rows_skip_nan_weights_in_every_tier() {
    prop::check_n("zero-row-skip", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        // x of zeros with random signs: -0.0 == 0.0 must also skip.
        let x: Vec<f32> =
            (0..k).map(|_| if rng.chance(0.5) { 0.0 } else { -0.0 }).collect();
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_naive(&x, &w, n, &mut want);

        for (tier, f) in [
            ("blocked", matvec_blocked as fn(&[f32], &[f32], usize, &mut [f32])),
            ("dispatched", matvec),
        ] {
            let mut got = vec![7.0f32; n];
            f(&x, &w, n, &mut got);
            assert_bits_eq(&got, &want, &format!("zero-skip {tier} k={k} n={n}"));
        }
    });
}

// ---------------------------------------------------------------------------
// Int4 tier (packed group-wise weights, int8 activations)
// ---------------------------------------------------------------------------

/// Shapes biased toward `Q4_GROUP` boundaries: k one off either side of
/// a group edge (31/33/64/65) as well as the sub-group and exact-group
/// sizes the int8 shapes cover.
fn arb_shape4(rng: &mut Rng) -> (usize, usize) {
    let k = *rng.pick(&[0usize, 1, 3, 7, 8, 13, 16, 31, 32, 33, 64, 65, 96]);
    let n = *rng.pick(&[0usize, 1, 2, 7, 8, 11, 24]);
    (k, n)
}

/// An out-major packed int4 matrix (`[n, ⌈k/2⌉]` bytes, `[n, ⌈k/32⌉]`
/// group scales).  Half the time the rows come from the real
/// `quantize_row_q4` on edge-valued f32s — exactly what
/// `Quant4Weights` stores, including scale-0 degenerate groups — and
/// half the time from adversarial nibbles in `[-7, 7]` (the quantizer
/// never emits −8) biased toward saturation and zero, under extreme
/// group scales.
fn arb_q4matrix(rng: &mut Rng, k: usize, n: usize) -> (Vec<u8>, Vec<f32>) {
    let kb = q4_row_bytes(k);
    let groups = q4_row_groups(k);
    let mut wq = vec![0u8; n * kb];
    let mut scales = vec![0.0f32; n * groups];
    if rng.chance(0.5) {
        for j in 0..n {
            let row = arb_edge_f32s(rng, k, 2.0);
            quantize_row_q4(
                &row,
                &mut wq[j * kb..(j + 1) * kb],
                &mut scales[j * groups..(j + 1) * groups],
            );
        }
    } else {
        for j in 0..n {
            for i in 0..k {
                let v: i8 = if rng.chance(0.25) {
                    *rng.pick(&[-7i8, 7, 0])
                } else {
                    (rng.below(15) as i32 - 7) as i8
                };
                let nib = (v as u8) & 0x0F;
                wq[j * kb + i / 2] |= if i % 2 == 0 { nib } else { nib << 4 };
            }
        }
        let s = arb_scales(rng, n * groups);
        scales.copy_from_slice(&s);
    }
    (wq, scales)
}

/// Every int4 tier must be bit-identical to the naive int4 reference:
/// the per-group i32 dot is exact, and the ascending-group f32 fold
/// through the shared `scale_out` expression makes the conversion
/// unique.  Activations arrive both pre-built and through the real
/// `quantize_row`, matching what decode feeds the kernels.
#[test]
fn prop_int4_matvec_tiers_match_naive_bit_for_bit() {
    prop::check_n("int4-matvec-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape4(rng);
        let (qx, sx) = if rng.chance(0.5) {
            (arb_qrow(rng, k), *rng.pick(&[0.0f32, 1.0e-30, 3.4e30, 2.0e-2]))
        } else {
            let x = arb_edge_f32s(rng, k, 2.0);
            let mut q = vec![0i8; k];
            let s = quantize_row(&x, &mut q);
            (q, s)
        };
        let (wq, scales) = arb_q4matrix(rng, k, n);

        let mut want = vec![0.0f32; n];
        matvec_q4_naive(&qx, sx, &wq, &scales, &mut want);

        let mut got = vec![7.0f32; n]; // poison: kernels must overwrite
        matvec_q4_blocked(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_q4_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec_q4(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_q4 dispatched k={k} n={n}"));

        // The transposed entry point is documented as the same kernel
        // (packed int4 storage is always out-major).
        got.fill(7.0);
        matvec_t_q4(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t_q4 k={k} n={n}"));

        #[cfg(feature = "simd")]
        {
            got.fill(7.0);
            simd::matvec_q4(&qx, sx, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("simd::matvec_q4 k={k} n={n}"));
        }
    });
}

/// Batched int4 tiers: row r of every tier must be bit-identical to a
/// single-row `matvec_q4_naive` call — the fused speculative verify
/// pass and `rewind` + re-step depend on this.
#[test]
fn prop_int4_batched_kernels_match_per_row_naive_bit_for_bit() {
    prop::check_n("int4-matmul-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape4(rng);
        let m = rng.below(5); // includes the empty batch
        let qxs = arb_qrow(rng, m * k);
        let sxs = arb_scales(rng, m);
        let (wq, scales) = arb_q4matrix(rng, k, n);

        let mut want = vec![0.0f32; m * n];
        matmul_q4_naive(&qxs, m, &sxs, &wq, &scales, &mut want);
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_q4_naive(&qxs[r * k..(r + 1) * k], sxs[r], &wq, &scales, &mut row);
            assert_bits_eq(&row, &want[r * n..(r + 1) * n], &format!("matmul_q4_naive row {r}"));
        }

        let mut got = vec![7.0f32; m * n];
        if m > 0 {
            // The blocked core itself (the dispatcher handles m = 0).
            matmul_q4_blocked(&qxs, m, &sxs, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("matmul_q4_blocked m={m} k={k} n={n}"));
            got.fill(7.0);
        }
        matmul_q4(&qxs, m, &sxs, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_q4 dispatched m={m} k={k} n={n}"));

        got.fill(7.0);
        matmul_t_q4(&qxs, m, &sxs, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_t_q4 m={m} k={k} n={n}"));

        #[cfg(feature = "simd")]
        if m > 0 {
            got.fill(7.0);
            simd::matmul_q4(&qxs, m, &sxs, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("simd::matmul_q4 m={m} k={k} n={n}"));
        }
    });
}

/// Saturated int4 groups (all nibbles ±7 against ±127 activations)
/// peak each group's i32 dot at 32·127·7 = 28 448 — comfortably exact
/// — and every tier must reproduce the reference's per-group
/// `(sum as f32) * (sx * scale)` ascending-group fold bit-for-bit,
/// including on k that straddles a group boundary.  All-zero groups
/// (scale 0, zero nibbles) must contribute nothing in every tier.
#[test]
fn prop_int4_saturated_and_zero_groups_stay_exact() {
    prop::check_n("int4-saturation", prop::default_cases(), |rng| {
        let k = *rng.pick(&[1usize, 31, 32, 33, 64, 65, 96, 257]);
        let n = *rng.pick(&[1usize, 4, 5]);
        let kb = q4_row_bytes(k);
        let groups = q4_row_groups(k);
        let qx: Vec<i8> = (0..k).map(|_| if rng.chance(0.5) { 127i8 } else { -127 }).collect();
        let mut wq = vec![0u8; n * kb];
        for j in 0..n {
            for i in 0..k {
                let v: i8 = if rng.chance(0.5) { 7 } else { -7 };
                let nib = (v as u8) & 0x0F;
                wq[j * kb + i / 2] |= if i % 2 == 0 { nib } else { nib << 4 };
            }
        }
        // Knock a random group per row down to the degenerate contract:
        // zero nibbles, scale 0 — the shape an all-zero f32 group takes.
        let mut scales = arb_scales(rng, n * groups);
        for j in 0..n {
            let g = rng.below(groups);
            let lo = g * Q4_GROUP;
            let hi = (lo + Q4_GROUP).min(k);
            for i in lo..hi {
                let mask = if i % 2 == 0 { 0xF0u8 } else { 0x0F };
                wq[j * kb + i / 2] &= mask;
            }
            scales[j * groups + g] = 0.0;
        }
        let sx = 3.1e-2f32;

        let mut want = vec![0.0f32; n];
        matvec_q4_naive(&qx, sx, &wq, &scales, &mut want);
        // The reference itself must carry the exact per-group integer
        // dot, folded in ascending group order.
        for (j, &y) in want.iter().enumerate() {
            let row = &wq[j * kb..(j + 1) * kb];
            let mut acc = 0.0f32;
            for g in 0..groups {
                let lo = g * Q4_GROUP;
                let hi = (lo + Q4_GROUP).min(k);
                let mut sum = 0i64;
                for i in lo..hi {
                    let b = row[i / 2];
                    let nib =
                        if i % 2 == 0 { ((b << 4) as i8 >> 4) as i64 } else { (b as i8 >> 4) as i64 };
                    sum += qx[i] as i64 * nib;
                }
                acc += (sum as i32 as f32) * (sx * scales[j * groups + g]);
            }
            assert_eq!(y.to_bits(), acc.to_bits(), "reference group fold diverged (j={j})");
        }

        let mut got = vec![7.0f32; n];
        matvec_q4_blocked(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("saturated q4 blocked k={k} n={n}"));
        got.fill(7.0);
        matvec_q4(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("saturated q4 dispatched k={k} n={n}"));

        // A fully degenerate activation (zero row, scale 0) must come
        // out as exact zeros from every tier, not tiny scaled noise.
        let zeros = vec![0i8; k];
        let mut zy = vec![7.0f32; n];
        matvec_q4(&zeros, 0.0, &wq, &scales, &mut zy);
        for (j, y) in zy.iter().enumerate() {
            assert_eq!(y.to_bits(), 0.0f32.to_bits(), "zero q4 row must stay exactly zero (j={j})");
        }
    });
}

/// `quantize_row_q4`'s degenerate contract, fuzzed: an all-zero group
/// (or one whose max is non-finite) must produce scale 0 and zero
/// nibbles; NaN entries under a finite group max must quantize to 0;
/// finite entries must round-trip within half a quantization step of
/// their group's scale.
#[test]
fn prop_quantize_row_q4_degenerate_groups_follow_the_contract() {
    prop::check_n("q4-quantizer-contract", prop::default_cases(), |rng| {
        let k = *rng.pick(&[31usize, 32, 33, 64, 65, 96]);
        let groups = q4_row_groups(k);
        let mut x = prop::arb_f32s(rng, k, 2.0);
        // Group 0 all zeros; one group gets an ∞ (non-finite max); one
        // finite-max group gets a NaN entry.
        for v in x.iter_mut().take(Q4_GROUP.min(k)) {
            *v = 0.0;
        }
        let ginf = rng.below(groups);
        if ginf != 0 {
            x[ginf * Q4_GROUP] = f32::INFINITY;
        }
        let gnan = rng.below(groups);
        if gnan != 0 && gnan != ginf {
            let lo = gnan * Q4_GROUP;
            x[lo] = f32::NAN;
            if lo + 1 < k {
                x[lo + 1] = 1.5; // keep the group max finite and nonzero
            }
        }

        let mut q = vec![0xAAu8; q4_row_bytes(k)]; // poison
        let mut scales = vec![7.0f32; groups];
        quantize_row_q4(&x, &mut q, &mut scales);

        let nib_at = |i: usize| -> i32 {
            let b = q[i / 2];
            if i % 2 == 0 { ((b << 4) as i8 >> 4) as i32 } else { (b as i8 >> 4) as i32 }
        };
        for (g, &sg) in scales.iter().enumerate() {
            let lo = g * Q4_GROUP;
            let hi = (lo + Q4_GROUP).min(k);
            let maxabs = x[lo..hi].iter().fold(0.0f32, |m, v| m.max(v.abs()));
            if maxabs == 0.0 || !maxabs.is_finite() {
                assert_eq!(sg, 0.0, "degenerate group {g} must get scale 0");
                for i in lo..hi {
                    assert_eq!(nib_at(i), 0, "degenerate group {g} must pack zero nibbles");
                }
                continue;
            }
            assert!((sg - maxabs / 7.0).abs() <= f32::EPSILON * maxabs, "group {g} scale");
            for i in lo..hi {
                let v = nib_at(i);
                assert!((-7..=7).contains(&v), "nibble out of range in group {g}");
                if x[i].is_nan() {
                    assert_eq!(v, 0, "NaN under a finite max must quantize to 0");
                } else {
                    let back = v as f32 * sg;
                    assert!(
                        (x[i] - back).abs() <= 0.5 * sg + 1e-6,
                        "round-trip out of tolerance at {i}: {} vs {back}",
                        x[i]
                    );
                }
            }
        }
    });
}
