//! Property tests for the tensor kernel stack: every tier (dispatched,
//! blocked, and — when the `simd` feature is on — the explicit SIMD
//! paths behind the dispatchers) must be **bit-identical** to the naive
//! reference on arbitrary shapes and contents.
//!
//! The generator deliberately hits the shapes and values that break
//! vectorised kernels: `k % 4 != 0` and `k % 8 != 0` (remainder
//! handling), `n = 0` and `n = 1` (empty / degenerate outputs), `k = 0`
//! (empty reduction), NaN and ±∞ (order-sensitive propagation), signed
//! zeros (`-0.0 == 0.0` must still take the zero-skip path), and
//! subnormals (no flush-to-zero allowed).  Comparison is on raw bits —
//! `assert_eq!` on f32 would call NaN ≠ NaN and miss -0.0 vs 0.0.

use hsm::infer::tensor::{
    matmul, matmul_blocked, matmul_naive, matmul_t, matmul_t_blocked, matmul_t_naive, matvec,
    matvec_blocked, matvec_naive, matvec_t, matvec_t_blocked, matvec_t_naive,
};
use hsm::util::prop;
use hsm::util::rng::Rng;

/// Uniform f32s with edge values (NaN, ±∞, ±0.0, subnormals) sprinkled
/// in — roughly one slot in seven.
fn arb_edge_f32s(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    let edges = [
        0.0f32,
        -0.0,
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE / 2.0, // subnormal
        -1.0e-41,                // subnormal
        1.0e37,                  // overflow bait under accumulation
    ];
    let mut v = prop::arb_f32s(rng, len, scale);
    for x in v.iter_mut() {
        if rng.chance(1.0 / 7.0) {
            *x = *rng.pick(&edges);
        }
    }
    v
}

/// Bit-exact comparison with a shape-carrying failure message.
fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} diverged ({g:?} vs {w:?})"
        );
    }
}

/// Shapes biased toward the awkward cases: k not a multiple of the
/// 4-wide block or 8-wide SIMD lane count, and tiny n.
fn arb_shape(rng: &mut Rng) -> (usize, usize) {
    let k = *rng.pick(&[0usize, 1, 3, 4, 7, 8, 9, 13, 16, 31, 33]);
    let n = *rng.pick(&[0usize, 1, 2, 7, 8, 11, 24]);
    (k, n)
}

#[test]
fn prop_matvec_tiers_match_naive_bit_for_bit() {
    prop::check_n("matvec-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let x = arb_edge_f32s(rng, k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_naive(&x, &w, n, &mut want);

        let mut got = vec![7.0f32; n]; // poison: kernels must overwrite
        matvec_blocked(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec dispatched k={k} n={n}"));
    });
}

#[test]
fn prop_matvec_t_tiers_match_naive_bit_for_bit() {
    prop::check_n("matvec-t-tiers", prop::default_cases(), |rng| {
        // For the transposed kernel the *output* dimension n is the
        // SIMD-vectorised axis, so make it hit lane remainders too.
        let (n, k) = arb_shape(rng);
        let x = arb_edge_f32s(rng, k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_t_naive(&x, &w, n, &mut want);

        let mut got = vec![7.0f32; n];
        matvec_t_blocked(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec_t(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t dispatched k={k} n={n}"));
    });
}

#[test]
fn prop_batched_kernels_match_per_row_naive_bit_for_bit() {
    prop::check_n("matmul-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let m = rng.below(5); // includes the empty batch
        let xs = arb_edge_f32s(rng, m * k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; m * n];
        matmul_naive(&xs, m, &w, n, &mut want);
        // The naive batched form must itself be m independent matvecs.
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_naive(&xs[r * k..(r + 1) * k], &w, n, &mut row);
            assert_bits_eq(&row, &want[r * n..(r + 1) * n], &format!("matmul_naive row {r}"));
        }

        let mut got = vec![7.0f32; m * n];
        matmul_blocked(&xs, m, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_blocked m={m} k={k} n={n}"));

        got.fill(7.0);
        matmul(&xs, m, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul dispatched m={m} k={k} n={n}"));

        // Transposed batched kernel against its own naive reference.
        let mut want_t = vec![0.0f32; m * n];
        matmul_t_naive(&xs, m, &w, n, &mut want_t);
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_t_naive(&xs[r * k..(r + 1) * k], &w, n, &mut row);
            assert_bits_eq(&row, &want_t[r * n..(r + 1) * n], &format!("matmul_t_naive row {r}"));
        }

        let mut got_t = vec![7.0f32; m * n];
        matmul_t_blocked(&xs, m, &w, n, &mut got_t);
        assert_bits_eq(&got_t, &want_t, &format!("matmul_t_blocked m={m} k={k} n={n}"));

        got_t.fill(7.0);
        matmul_t(&xs, m, &w, n, &mut got_t);
        assert_bits_eq(&got_t, &want_t, &format!("matmul_t dispatched m={m} k={k} n={n}"));
    });
}

/// All-zero inputs must take the zero-skip fast path in every tier and
/// still write exact (positive) zeros, even when weights hold NaN/∞
/// (the skip is semantic: `0.0 * NaN` never happens because the naive
/// reference skips it too).
#[test]
fn prop_zero_rows_skip_nan_weights_in_every_tier() {
    prop::check_n("zero-row-skip", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        // x of zeros with random signs: -0.0 == 0.0 must also skip.
        let x: Vec<f32> =
            (0..k).map(|_| if rng.chance(0.5) { 0.0 } else { -0.0 }).collect();
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_naive(&x, &w, n, &mut want);

        for (tier, f) in [
            ("blocked", matvec_blocked as fn(&[f32], &[f32], usize, &mut [f32])),
            ("dispatched", matvec),
        ] {
            let mut got = vec![7.0f32; n];
            f(&x, &w, n, &mut got);
            assert_bits_eq(&got, &want, &format!("zero-skip {tier} k={k} n={n}"));
        }
    });
}
