//! Property tests for the tensor kernel stack: every tier (dispatched,
//! blocked, and — when the `simd` feature is on — the explicit SIMD
//! paths behind the dispatchers) must be **bit-identical** to the naive
//! reference on arbitrary shapes and contents.
//!
//! The generator deliberately hits the shapes and values that break
//! vectorised kernels: `k % 4 != 0` and `k % 8 != 0` (remainder
//! handling), `n = 0` and `n = 1` (empty / degenerate outputs), `k = 0`
//! (empty reduction), NaN and ±∞ (order-sensitive propagation), signed
//! zeros (`-0.0 == 0.0` must still take the zero-skip path), and
//! subnormals (no flush-to-zero allowed).  Comparison is on raw bits —
//! `assert_eq!` on f32 would call NaN ≠ NaN and miss -0.0 vs 0.0.
//!
//! The int8 tier gets the same treatment: every quantized kernel
//! (blocked, dispatched, and the explicit `simd` entry points) against
//! `matvec_q_naive`/`matmul_q_naive` bit-for-bit, across lane-remainder
//! shapes, empty/degenerate outputs, saturated ±127 rows, zero rows,
//! and extreme per-row scales.

use hsm::infer::tensor::{
    matmul, matmul_blocked, matmul_naive, matmul_q, matmul_q_blocked, matmul_q_naive, matmul_t,
    matmul_t_blocked, matmul_t_naive, matmul_t_q, matvec, matvec_blocked, matvec_naive, matvec_q,
    matvec_q_blocked, matvec_q_naive, matvec_t, matvec_t_blocked, matvec_t_naive, matvec_t_q,
    quantize_row,
};
#[cfg(feature = "simd")]
use hsm::infer::tensor::simd;
use hsm::util::prop;
use hsm::util::rng::Rng;

/// Uniform f32s with edge values (NaN, ±∞, ±0.0, subnormals) sprinkled
/// in — roughly one slot in seven.
fn arb_edge_f32s(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    let edges = [
        0.0f32,
        -0.0,
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE / 2.0, // subnormal
        -1.0e-41,                // subnormal
        1.0e37,                  // overflow bait under accumulation
    ];
    let mut v = prop::arb_f32s(rng, len, scale);
    for x in v.iter_mut() {
        if rng.chance(1.0 / 7.0) {
            *x = *rng.pick(&edges);
        }
    }
    v
}

/// Bit-exact comparison with a shape-carrying failure message.
fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{what}: element {i} diverged ({g:?} vs {w:?})"
        );
    }
}

/// Shapes biased toward the awkward cases: k not a multiple of the
/// 4-wide block or 8-wide SIMD lane count, and tiny n.
fn arb_shape(rng: &mut Rng) -> (usize, usize) {
    let k = *rng.pick(&[0usize, 1, 3, 4, 7, 8, 9, 13, 16, 31, 33]);
    let n = *rng.pick(&[0usize, 1, 2, 7, 8, 11, 24]);
    (k, n)
}

#[test]
fn prop_matvec_tiers_match_naive_bit_for_bit() {
    prop::check_n("matvec-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let x = arb_edge_f32s(rng, k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_naive(&x, &w, n, &mut want);

        let mut got = vec![7.0f32; n]; // poison: kernels must overwrite
        matvec_blocked(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec dispatched k={k} n={n}"));
    });
}

#[test]
fn prop_matvec_t_tiers_match_naive_bit_for_bit() {
    prop::check_n("matvec-t-tiers", prop::default_cases(), |rng| {
        // For the transposed kernel the *output* dimension n is the
        // SIMD-vectorised axis, so make it hit lane remainders too.
        let (n, k) = arb_shape(rng);
        let x = arb_edge_f32s(rng, k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_t_naive(&x, &w, n, &mut want);

        let mut got = vec![7.0f32; n];
        matvec_t_blocked(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec_t(&x, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t dispatched k={k} n={n}"));
    });
}

#[test]
fn prop_batched_kernels_match_per_row_naive_bit_for_bit() {
    prop::check_n("matmul-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let m = rng.below(5); // includes the empty batch
        let xs = arb_edge_f32s(rng, m * k, 2.0);
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; m * n];
        matmul_naive(&xs, m, &w, n, &mut want);
        // The naive batched form must itself be m independent matvecs.
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_naive(&xs[r * k..(r + 1) * k], &w, n, &mut row);
            assert_bits_eq(&row, &want[r * n..(r + 1) * n], &format!("matmul_naive row {r}"));
        }

        let mut got = vec![7.0f32; m * n];
        matmul_blocked(&xs, m, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_blocked m={m} k={k} n={n}"));

        got.fill(7.0);
        matmul(&xs, m, &w, n, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul dispatched m={m} k={k} n={n}"));

        // Transposed batched kernel against its own naive reference.
        let mut want_t = vec![0.0f32; m * n];
        matmul_t_naive(&xs, m, &w, n, &mut want_t);
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_t_naive(&xs[r * k..(r + 1) * k], &w, n, &mut row);
            assert_bits_eq(&row, &want_t[r * n..(r + 1) * n], &format!("matmul_t_naive row {r}"));
        }

        let mut got_t = vec![7.0f32; m * n];
        matmul_t_blocked(&xs, m, &w, n, &mut got_t);
        assert_bits_eq(&got_t, &want_t, &format!("matmul_t_blocked m={m} k={k} n={n}"));

        got_t.fill(7.0);
        matmul_t(&xs, m, &w, n, &mut got_t);
        assert_bits_eq(&got_t, &want_t, &format!("matmul_t dispatched m={m} k={k} n={n}"));
    });
}

// ---------------------------------------------------------------------------
// Int8 tier (quantized weights + activations)
// ---------------------------------------------------------------------------

/// Random int8 row in the quantizer's range `[-127, 127]` (never −128 —
/// the AVX2 maddubs trick requires it), biased toward the saturation
/// endpoints and zero.
fn arb_qrow(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len)
        .map(|_| {
            if rng.chance(0.2) {
                *rng.pick(&[-127i8, 127, 0])
            } else {
                (rng.below(255) as i32 - 127) as i8
            }
        })
        .collect()
}

/// Per-row scales spanning ordinary magnitudes and the extremes that
/// expose premature f32 scaling (1e-30 underflow bait, 3.4e30 overflow
/// bait, exact-zero rows from degenerate quantization).
fn arb_scales(rng: &mut Rng, n: usize) -> Vec<f32> {
    let extremes = [0.0f32, 1.0e-30, 3.4e30, 1.0, 7.25e-3];
    (0..n)
        .map(|_| if rng.chance(0.3) { *rng.pick(&extremes) } else { rng.f32() * 0.1 + 1.0e-3 })
        .collect()
}

/// Every int8 tier must be bit-identical to the naive int8 reference:
/// exact i32 accumulation makes the integer sum unique, and the shared
/// `scale_out` expression makes the f32 conversion unique.  Activations
/// arrive both pre-built and through the real `quantize_row`, so the
/// fuzz covers exactly the values decode produces.
#[test]
fn prop_int8_matvec_tiers_match_naive_bit_for_bit() {
    prop::check_n("int8-matvec-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let (qx, sx) = if rng.chance(0.5) {
            (arb_qrow(rng, k), *rng.pick(&[0.0f32, 1.0e-30, 3.4e30, 2.0e-2]))
        } else {
            let x = arb_edge_f32s(rng, k, 2.0);
            let mut q = vec![0i8; k];
            let s = quantize_row(&x, &mut q);
            (q, s)
        };
        let wq = arb_qrow(rng, k * n);
        let scales = arb_scales(rng, n);

        let mut want = vec![0.0f32; n];
        matvec_q_naive(&qx, sx, &wq, &scales, &mut want);

        let mut got = vec![7.0f32; n]; // poison: kernels must overwrite
        matvec_q_blocked(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_q_blocked k={k} n={n}"));

        got.fill(7.0);
        matvec_q(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_q dispatched k={k} n={n}"));

        // The transposed entry point is documented as the same kernel
        // (quantized storage is always out-major).
        got.fill(7.0);
        matvec_t_q(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matvec_t_q k={k} n={n}"));

        #[cfg(feature = "simd")]
        {
            got.fill(7.0);
            simd::matvec_q(&qx, sx, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("simd::matvec_q k={k} n={n}"));
        }
    });
}

/// Batched int8 tiers: row r of every tier must be bit-identical to a
/// single-row `matvec_q_naive` call — the fused speculative verify pass
/// depends on this (`rewind` + re-step must reproduce the same bits).
#[test]
fn prop_int8_batched_kernels_match_per_row_naive_bit_for_bit() {
    prop::check_n("int8-matmul-tiers", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        let m = rng.below(5); // includes the empty batch
        let qxs = arb_qrow(rng, m * k);
        let sxs = arb_scales(rng, m);
        let wq = arb_qrow(rng, k * n);
        let scales = arb_scales(rng, n);

        let mut want = vec![0.0f32; m * n];
        matmul_q_naive(&qxs, m, &sxs, &wq, &scales, &mut want);
        for r in 0..m {
            let mut row = vec![0.0f32; n];
            matvec_q_naive(&qxs[r * k..(r + 1) * k], sxs[r], &wq, &scales, &mut row);
            assert_bits_eq(&row, &want[r * n..(r + 1) * n], &format!("matmul_q_naive row {r}"));
        }

        let mut got = vec![7.0f32; m * n];
        if m > 0 {
            // The blocked core itself (the dispatcher handles m = 0).
            matmul_q_blocked(&qxs, m, &sxs, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("matmul_q_blocked m={m} k={k} n={n}"));
            got.fill(7.0);
        }
        matmul_q(&qxs, m, &sxs, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_q dispatched m={m} k={k} n={n}"));

        got.fill(7.0);
        matmul_t_q(&qxs, m, &sxs, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("matmul_t_q m={m} k={k} n={n}"));

        #[cfg(feature = "simd")]
        if m > 0 {
            got.fill(7.0);
            simd::matmul_q(&qxs, m, &sxs, &wq, &scales, &mut got);
            assert_bits_eq(&got, &want, &format!("simd::matmul_q m={m} k={k} n={n}"));
        }
    });
}

/// Saturated rows (all entries ±127) push the AVX2 pairwise i16 sums to
/// their ceiling (2·127² = 32258 < i16::MAX — the reason `quantize_row`
/// never emits −128) and must stay exact in every tier; all-zero
/// quantized rows must come out as exact zeros whatever the scales.
#[test]
fn prop_int8_saturated_and_zero_rows_stay_exact() {
    prop::check_n("int8-saturation", prop::default_cases(), |rng| {
        let k = *rng.pick(&[1usize, 31, 32, 33, 64, 257]);
        let n = *rng.pick(&[1usize, 4, 5]);
        let qx: Vec<i8> = (0..k).map(|_| if rng.chance(0.5) { 127i8 } else { -127 }).collect();
        let wq: Vec<i8> = (0..k * n).map(|_| if rng.chance(0.5) { 127i8 } else { -127 }).collect();
        let scales = arb_scales(rng, n);
        let sx = 3.1e-2f32;

        let mut want = vec![0.0f32; n];
        matvec_q_naive(&qx, sx, &wq, &scales, &mut want);
        // The reference itself must carry the exact integer dot (±k·127²
        // fits i32 easily at these k).
        for (j, &y) in want.iter().enumerate() {
            let mut sum = 0i64;
            for i in 0..k {
                sum += qx[i] as i64 * wq[j * k + i] as i64;
            }
            assert_eq!(y.to_bits(), ((sum as i32 as f32) * (sx * scales[j])).to_bits());
        }

        let mut got = vec![7.0f32; n];
        matvec_q_blocked(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("saturated blocked k={k} n={n}"));
        got.fill(7.0);
        matvec_q(&qx, sx, &wq, &scales, &mut got);
        assert_bits_eq(&got, &want, &format!("saturated dispatched k={k} n={n}"));

        // Degenerate quantization (all-zero row, scale 0) must produce
        // exact zeros out of every tier, not tiny scaled noise.
        let zeros = vec![0i8; k];
        let mut zy = vec![7.0f32; n];
        matvec_q(&zeros, 0.0, &wq, &scales, &mut zy);
        for (j, y) in zy.iter().enumerate() {
            assert_eq!(y.to_bits(), 0.0f32.to_bits(), "zero row must stay exactly zero (j={j})");
        }
    });
}

/// All-zero inputs must take the zero-skip fast path in every tier and
/// still write exact (positive) zeros, even when weights hold NaN/∞
/// (the skip is semantic: `0.0 * NaN` never happens because the naive
/// reference skips it too).
#[test]
fn prop_zero_rows_skip_nan_weights_in_every_tier() {
    prop::check_n("zero-row-skip", prop::default_cases(), |rng| {
        let (k, n) = arb_shape(rng);
        // x of zeros with random signs: -0.0 == 0.0 must also skip.
        let x: Vec<f32> =
            (0..k).map(|_| if rng.chance(0.5) { 0.0 } else { -0.0 }).collect();
        let w = arb_edge_f32s(rng, k * n, 2.0);

        let mut want = vec![0.0f32; n];
        matvec_naive(&x, &w, n, &mut want);

        for (tier, f) in [
            ("blocked", matvec_blocked as fn(&[f32], &[f32], usize, &mut [f32])),
            ("dispatched", matvec),
        ] {
            let mut got = vec![7.0f32; n];
            f(&x, &w, n, &mut got);
            assert_bits_eq(&got, &want, &format!("zero-skip {tier} k={k} n={n}"));
        }
    });
}
