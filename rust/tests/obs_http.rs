//! Loopback integration tests for the observability surface: the
//! `GET /metrics` Prometheus route, its agreement with the request
//! traffic actually served, the request-lifecycle log, and the
//! telemetry-off determinism guarantee. PJRT-free (synthetic
//! weights), so it runs under both feature sets.

use std::collections::HashMap;
use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::SampleCfg;
use hsm::infer::{weights, Model, ModelWeights};
use hsm::obs::{ObsCfg, RequestEvent, RequestLog};
use hsm::serve::{ServeCfg, StreamScheduler};
use hsm::server::api::GenerateRequest;
use hsm::server::{client, HttpServer};
use hsm::tokenizer::Tokenizer;
use hsm::util::json;

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

fn model(vocab: usize, ctx: usize) -> Arc<Model> {
    let layers = vec![
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
    ];
    let m = Manifest::synthetic("hsm_ab", layers, 8, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 21);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn sample() -> SampleCfg {
    SampleCfg { temperature: 0.8, top_k: 8, max_new_tokens: 8, seed: 9, stop_at_eot: true }
}

fn start(cfg: ServeCfg) -> (HttpServer, Tokenizer, Arc<Model>, String) {
    let tok = tok();
    let model = model(tok.vocab_size(), 64);
    let cfg = ServeCfg { sample: sample(), ..cfg };
    let sched =
        Arc::new(StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", sched).unwrap();
    let addr = server.local_addr().to_string();
    (server, tok, model, addr)
}

/// Raw close-framed GET; returns (head, body).
fn http_get(addr: &str, path: &str) -> (String, String) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let (head, body) = resp.split_once("\r\n\r\n").expect("response must have a header block");
    (head.to_string(), body.to_string())
}

/// Parse a Prometheus text body into `name{labels} -> value`, keeping
/// the label block verbatim, plus the set of `# TYPE`d family names.
fn parse_prometheus(body: &str) -> (HashMap<String, f64>, Vec<String>) {
    let mut series = HashMap::new();
    let mut families = Vec::new();
    for line in body.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            families.push(rest.split_whitespace().next().unwrap().to_string());
            continue;
        }
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').expect("sample line must have a value");
        let value: f64 = value.parse().unwrap_or_else(|_| panic!("unparseable value: {line}"));
        series.insert(name.to_string(), value);
    }
    (series, families)
}

#[test]
fn metrics_route_exposes_every_family_and_counts_the_traffic() {
    let (server, _tok, _model, addr) = start(ServeCfg::default());

    // Serve some known traffic first (same prompt three times: the
    // later requests hit the prefix cache).
    let mut generated = 0u64;
    let mut nonempty = 0u64; // requests that emitted at least one token
    for id in [1u64, 2, 3] {
        let mut req = GenerateRequest::new("Once upon a time");
        req.id = Some(id);
        let n = client::generate(&addr, &req).unwrap().tokens_generated as u64;
        generated += n;
        nonempty += u64::from(n > 0);
    }

    let (head, body) = http_get(&addr, "/metrics");
    assert!(head.starts_with("HTTP/1.1 200 OK"), "got: {head}");
    assert!(
        head.to_ascii_lowercase().contains("content-type: text/plain; version=0.0.4"),
        "metrics must use the Prometheus text content type: {head}"
    );

    let (series, families) = parse_prometheus(&body);
    for family in [
        "hsm_queue_wait_seconds",
        "hsm_ttft_seconds",
        "hsm_token_latency_seconds",
        "hsm_request_seconds",
        "hsm_spec_verify_round_seconds",
        "hsm_requests_admitted_total",
        "hsm_requests_finished_total",
        "hsm_requests_throttled_total",
        "hsm_queue_depth",
        "hsm_quota_tokens_charged_total",
        "hsm_tokens_generated_total",
        "hsm_prompt_tokens_total",
        "hsm_prefix_cache_events_total",
        "hsm_prefix_cache_entries",
        "hsm_prefix_cache_resident_bytes",
        "hsm_prefix_cache_quantized_entries",
        "hsm_model_resident_weight_bytes",
        "hsm_spec_rounds_total",
        "hsm_spec_tokens_total",
        "hsm_spec_fused_passes_total",
        "hsm_spec_fused_rows_total",
        "hsm_stage_seconds_total",
        "hsm_stage_samples_total",
    ] {
        assert!(families.iter().any(|f| f == family), "family {family} missing from scrape");
    }

    // The counters reflect the traffic we just served.
    assert_eq!(series["hsm_requests_admitted_total"], 3.0);
    assert_eq!(series["hsm_requests_finished_total{finish=\"eot\"}"]
        + series["hsm_requests_finished_total{finish=\"max_tokens\"}"]
        + series["hsm_requests_finished_total{finish=\"ctx_full\"}"], 3.0);
    assert_eq!(series["hsm_tokens_generated_total"], generated as f64);
    assert_eq!(series["hsm_request_seconds_count"], 3.0);
    // One TTFT sample per request that emitted anything; every further
    // token lands in the inter-token latency histogram.
    assert_eq!(series["hsm_ttft_seconds_count"], nonempty as f64);
    assert_eq!(series["hsm_token_latency_seconds_count"], (generated - nonempty) as f64);
    assert!(series["hsm_prefix_cache_events_total{event=\"hit\"}"] >= 1.0);
    // An f32 model: the resident-weight gauge carries the precision
    // label and the cache holds unquantized snapshots with a real
    // byte footprint.
    assert!(series["hsm_model_resident_weight_bytes{precision=\"f32\"}"] > 0.0);
    assert!(series["hsm_prefix_cache_resident_bytes"] > 0.0);
    assert_eq!(series["hsm_prefix_cache_quantized_entries"], 0.0);
    // No speculation configured: those families render but stay zero.
    assert_eq!(series["hsm_spec_rounds_total"], 0.0);

    // Histogram bucket series are cumulative and end at the count.
    for name in ["hsm_ttft_seconds", "hsm_request_seconds"] {
        let mut cum = Vec::new();
        for line in body.lines() {
            if let Some(rest) = line.strip_prefix(&format!("{name}_bucket{{le=\"")) {
                let (_, count) = rest.split_once("\"} ").unwrap();
                cum.push(count.parse::<u64>().unwrap());
            }
        }
        assert!(cum.windows(2).all(|w| w[0] <= w[1]), "{name} buckets must be cumulative");
        assert_eq!(*cum.last().unwrap() as f64, series[&format!("{name}_count")]);
    }
    server.shutdown();
}

#[test]
fn metrics_stage_timing_appears_once_steps_are_sampled() {
    // stage_sample_every = 1: every step is timed, so even a short run
    // must produce stage series for both phases and all three stages.
    let cfg = ServeCfg {
        obs: ObsCfg { stage_sample_every: 1, ..ObsCfg::default() },
        ..ServeCfg::default()
    };
    let (server, _tok, _model, addr) = start(cfg);
    let mut req = GenerateRequest::new("Lily likes cats");
    req.id = Some(4);
    client::generate(&addr, &req).unwrap();

    let (_, body) = http_get(&addr, "/metrics");
    let (series, _) = parse_prometheus(&body);
    // Prefill skips logit computation entirely (the native decoder's
    // whole point), so that cell must exist but stay at zero samples.
    for (phase, stage, mixer, expect_samples) in [
        ("prefill", "mixer", "ab", true),
        ("prefill", "ffn", "ab", true),
        ("prefill", "logits", "-", false),
        ("step", "mixer", "ab", true),
        ("step", "ffn", "ab", true),
        ("step", "logits", "-", true),
    ] {
        let key = format!(
            "hsm_stage_samples_total{{phase=\"{phase}\",stage=\"{stage}\",\
             mixer=\"{mixer}\",precision=\"f32\"}}"
        );
        let samples = *series.get(&key).unwrap_or_else(|| panic!("missing series {key}"));
        if expect_samples {
            assert!(samples > 0.0, "{key} recorded no samples");
        } else {
            assert_eq!(samples, 0.0, "{key} must not be sampled");
        }
        let secs_key = format!(
            "hsm_stage_seconds_total{{phase=\"{phase}\",stage=\"{stage}\",\
             mixer=\"{mixer}\",precision=\"f32\"}}"
        );
        assert!(series.contains_key(&secs_key), "missing series {secs_key}");
    }
    server.shutdown();
}

#[test]
fn metrics_route_answers_even_with_telemetry_off() {
    let cfg = ServeCfg { obs: ObsCfg::off(), ..ServeCfg::default() };
    let (server, _tok, _model, addr) = start(cfg);
    let mut req = GenerateRequest::new("Once upon a time");
    req.id = Some(1);
    client::generate(&addr, &req).unwrap();
    let (head, body) = http_get(&addr, "/metrics");
    assert!(head.starts_with("HTTP/1.1 200 OK"), "got: {head}");
    let (series, families) = parse_prometheus(&body);
    assert!(families.iter().any(|f| f == "hsm_requests_admitted_total"));
    // Nothing recorded: the schema is stable, the values are zero.
    assert_eq!(series["hsm_requests_admitted_total"], 0.0);
    assert_eq!(series["hsm_ttft_seconds_count"], 0.0);
    server.shutdown();
}

#[test]
fn telemetry_never_changes_sampled_bytes() {
    let prompts = ["Once upon a time", "Lily likes cats", "Jack went to"];
    let run = |obs: ObsCfg| -> Vec<String> {
        let cfg = ServeCfg { obs, ..ServeCfg::default() };
        let (server, _tok, _model, addr) = start(cfg);
        let out = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let mut req = GenerateRequest::new(p);
                req.id = Some(i as u64);
                client::generate(&addr, &req).unwrap().completion
            })
            .collect();
        server.shutdown();
        out
    };
    let with_obs = run(ObsCfg { stage_sample_every: 1, ..ObsCfg::default() });
    let without = run(ObsCfg::off());
    assert_eq!(with_obs, without, "telemetry must be a pure tap on the decode loop");
}

#[test]
fn request_log_records_the_full_lifecycle() {
    let path = std::env::temp_dir().join(format!("hsm_obs_reqlog_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let obs = ObsCfg {
        request_log: Some(RequestLog::to_file(&path).unwrap()),
        ..ObsCfg::default()
    };
    let cfg = ServeCfg { obs, ..ServeCfg::default() };
    let (server, _tok, _model, addr) = start(cfg);
    let ids = [31u64, 32];
    let mut tokens = HashMap::new();
    for id in ids {
        let mut req = GenerateRequest::new("Once upon a time");
        req.id = Some(id);
        let c = client::generate(&addr, &req).unwrap();
        tokens.insert(id, c.tokens_generated as u64);
    }
    server.shutdown();

    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let mut by_request: HashMap<u64, Vec<RequestEvent>> = HashMap::new();
    for line in text.lines() {
        let ev = RequestEvent::from_json(&json::parse(line).unwrap()).unwrap();
        by_request.entry(ev.request_id()).or_default().push(ev);
    }
    for id in ids {
        let evs = &by_request[&id];
        let labels: Vec<&str> = evs.iter().map(|e| e.label()).collect();
        // A request that samples EOT on its very first step emits no
        // tokens, hence no first_token event — still a valid lifecycle.
        let expected: &[&str] = if tokens[&id] > 0 {
            &["admitted", "started", "first_token", "finished"]
        } else {
            &["admitted", "started", "finished"]
        };
        assert_eq!(labels, expected, "request {id} lifecycle out of order: {labels:?}");
        match evs.last().unwrap() {
            RequestEvent::Finished { tokens_generated, mixer, precision, drafter, .. } => {
                assert_eq!(*tokens_generated, tokens[&id]);
                assert_eq!(mixer, "hsm_ab");
                assert_eq!(precision, "f32");
                assert!(drafter.is_none(), "no speculation configured");
            }
            other => panic!("last event must be finished, got {other:?}"),
        }
    }
    server.shutdown();
}
