//! Speculative-decoding parity: decode with speculation on must be
//! **byte-identical** to plain decode — for every mixer kind, every
//! drafter (including the int8 `shallow-q` self-draft), greedy and
//! sampled paths — because the verify loop samples
//! every emitted token from the full model's logits with the request's
//! own RNG stream (the drafter only decides how many tokens a round
//! attempts).  Plus property tests that randomize draft-block length,
//! sampling shape, and budgets (mid-block `max_tokens` edges), cancel
//! edges on streamed speculative requests, and the acceptance counters
//! surfaced per request and on `GET /healthz`.

use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::SampleCfg;
use hsm::infer::{weights, DrafterKind, Model, ModelWeights, SpecCfg};
use hsm::serve::{serve, FinishReason, Request, ServeCfg, StreamScheduler, TokenEvent};
use hsm::server::{api::GenerateRequest, client, HttpServer};
use hsm::tokenizer::Tokenizer;
use hsm::util::prop;

const KINDS: &[&str] = &["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"];

fn layers_for(kind: &str) -> Vec<LayerInfo> {
    match kind {
        "ab" => vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 24 },
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![2, 4, 8, 16], ffn: 24 },
        ],
        _ => vec![
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![1], ffn: 24 },
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![3], ffn: 24 },
        ],
    }
}

fn model_for(kind: &str, ctx: usize, vocab: usize) -> Arc<Model> {
    let m = Manifest::synthetic(kind, layers_for(kind), 16, ctx, vocab, 2);
    let flat = weights::seeded_flat(&m, 31);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

fn drafters() -> [DrafterKind; 4] {
    [
        DrafterKind::NGram { max_ngram: 3 },
        DrafterKind::Shallow { layers: 0 },
        // Full-depth self-draft: the drafter is the model, so greedy
        // acceptance is total — the strongest stress on the rewind path.
        DrafterKind::Shallow { layers: 2 },
        // Quantized self-draft: proposals come from the int8 shadow
        // weights while verification scores f32 — quantization error may
        // move acceptance, but served bytes must not move.
        DrafterKind::ShallowQuant { layers: 0 },
    ]
}

fn requests() -> Vec<Request> {
    [
        "Once upon a time",
        "Lily likes cats and dogs. She asked her mom",
        "Once upon a time",
    ]
    .iter()
    .enumerate()
    .map(|(i, p)| Request::new(i as u64, p))
    .collect()
}

/// Compare speculative and plain serving on completion text, finish
/// reason and token counts, and sanity-check the acceptance stats —
/// with the verify pass both fused (one `step_batch` per round) and
/// sequential (step + snapshot per position), which must be
/// byte-identical to each other and to plain decoding.
fn assert_spec_parity(model: &Arc<Model>, tok: &Tokenizer, base: &ServeCfg, what: &str) {
    let plain = serve(model, tok, requests(), base).unwrap();
    assert!(plain.iter().all(|c| c.spec.is_none()));
    for drafter in drafters() {
        for draft_len in [2usize, 5] {
            for fused in [true, false] {
                let cfg = ServeCfg {
                    speculation: Some(SpecCfg { drafter, draft_len, fused }),
                    ..base.clone()
                };
                let spec = serve(model, tok, requests(), &cfg).unwrap();
                for (p, s) in plain.iter().zip(&spec) {
                    assert_eq!(
                        p.completion, s.completion,
                        "{what} {drafter:?} draft_len={draft_len} fused={fused}: \
                         speculation changed text"
                    );
                    assert_eq!(
                        p.finish, s.finish,
                        "{what} {drafter:?} draft_len={draft_len} fused={fused}"
                    );
                    assert_eq!(p.tokens_generated, s.tokens_generated);
                    let st = s.spec.expect("speculation on ⇒ per-request stats");
                    assert_eq!(st.emitted as usize, s.tokens_generated);
                    assert!(st.accepted <= st.drafted);
                    // Every round but the last emits at least one token (a
                    // final round may emit zero when its first sample is EOT).
                    assert!(st.rounds as usize <= s.tokens_generated + 1);
                    assert!(st.rounds >= 1);
                    if fused {
                        // Native decoders honour the fused request: every
                        // round is one batch pass of draft + 1 rows.
                        assert_eq!(st.fused_passes, st.rounds, "{what} fused accounting");
                        assert_eq!(st.fused_rows, st.drafted + st.rounds);
                        assert!(st.rows_per_fused_pass() >= 1.0);
                    } else {
                        assert_eq!(st.fused_passes, 0, "{what} sequential ⇒ no fused passes");
                        assert_eq!(st.fused_rows, 0);
                    }
                }
            }
        }
    }
}

/// Byte parity for all 7 mixer kinds × every drafter (ngram, shallow,
/// shallow-q) × greedy and sampled decoding, on both driver shapes.
#[test]
fn speculative_decode_is_byte_identical_for_every_mixer_kind() {
    let tok = tok();
    for kind in KINDS {
        let model = model_for(kind, 64, tok.vocab_size());
        for temperature in [0.0f32, 0.8] {
            let base = ServeCfg {
                max_active: 2,
                threads: 1,
                quantum: 3,
                prefix_cache_size: 0,
                sample: SampleCfg {
                    temperature,
                    top_k: 8,
                    max_new_tokens: 8,
                    seed: 11,
                    stop_at_eot: true,
                },
                ..Default::default()
            };
            assert_spec_parity(&model, &tok, &base, &format!("{kind} t={temperature}"));
        }
    }
    // Threaded driver on one representative HSM kind and the hybrid
    // attention kind (whose snapshots carry growing KV caches).
    for kind in ["ab", "attn"] {
        let model = model_for(kind, 64, tok.vocab_size());
        let base = ServeCfg {
            max_active: 2,
            threads: 2,
            quantum: 2,
            prefix_cache_size: 8,
            sample: SampleCfg {
                temperature: 0.8,
                top_k: 8,
                max_new_tokens: 8,
                seed: 5,
                stop_at_eot: true,
            },
            ..Default::default()
        };
        assert_spec_parity(&model, &tok, &base, &format!("{kind} threaded"));
    }
}

/// Tight budgets force verify rounds to end mid-block: the emitted
/// count and finish reason must still match plain decoding exactly.
#[test]
fn mid_block_max_tokens_edges_stay_byte_exact() {
    let tok = tok();
    let model = model_for("ab", 64, tok.vocab_size());
    for budget in 1usize..=5 {
        for draft_len in [1usize, 3, 7] {
            let base = ServeCfg {
                max_active: 1,
                threads: 1,
                quantum: 2,
                prefix_cache_size: 0,
                sample: SampleCfg {
                    temperature: 0.8,
                    top_k: 8,
                    max_new_tokens: budget,
                    seed: 3,
                    stop_at_eot: false, // force the budget to be the stop
                },
                ..Default::default()
            };
            let plain = serve(&model, &tok, requests(), &base).unwrap();
            let cfg = ServeCfg {
                speculation: Some(SpecCfg {
                    drafter: DrafterKind::NGram { max_ngram: 3 },
                    draft_len,
                    ..Default::default()
                }),
                ..base
            };
            let spec = serve(&model, &tok, requests(), &cfg).unwrap();
            for (p, s) in plain.iter().zip(&spec) {
                assert_eq!(p.completion, s.completion, "budget={budget} draft_len={draft_len}");
                assert_eq!(p.finish, s.finish);
                assert_eq!(s.finish, FinishReason::MaxTokens);
                assert_eq!(s.tokens_generated, budget);
            }
        }
    }
}

/// Property: random draft lengths, sampling shapes, budgets, quanta and
/// prompts — speculative serving is byte-identical to plain serving
/// (run on an HSM kind and the hybrid attention kind).
#[test]
fn prop_random_speculation_parity() {
    let tok = tok();
    let words = ["Once", "upon", "a", "time", "Lily", "likes", "cats", "and", "dogs", "Jack"];
    for kind in ["ab", "attn"] {
        let model = model_for(kind, 48, tok.vocab_size());
        prop::check_n(&format!("spec-parity-{kind}"), 16, |rng| {
            let n_words = 1 + rng.below(8);
            let prompt =
                (0..n_words).map(|_| *rng.pick(&words)).collect::<Vec<_>>().join(" ");
            let sample = SampleCfg {
                temperature: *rng.pick(&[0.0f32, 0.7, 1.1]),
                top_k: *rng.pick(&[0usize, 5, 40]),
                max_new_tokens: 1 + rng.below(14),
                seed: rng.next_u64(),
                stop_at_eot: rng.chance(0.5),
            };
            let base = ServeCfg {
                max_active: 1 + rng.below(2),
                threads: 1,
                quantum: 1 + rng.below(4),
                prefix_cache_size: *rng.pick(&[0usize, 8]),
                sample,
                ..Default::default()
            };
            let drafter = match rng.below(3) {
                0 => DrafterKind::NGram { max_ngram: 1 + rng.below(4) },
                1 => DrafterKind::Shallow { layers: rng.below(3) },
                _ => DrafterKind::ShallowQuant { layers: rng.below(3) },
            };
            let reqs = || {
                vec![Request::new(0, &prompt), Request::new(1, &prompt)]
            };
            let plain = serve(&model, &tok, reqs(), &base).unwrap();
            let cfg = ServeCfg {
                speculation: Some(SpecCfg {
                    drafter,
                    draft_len: 1 + rng.below(8),
                    fused: rng.chance(0.5),
                }),
                ..base
            };
            let spec = serve(&model, &tok, reqs(), &cfg).unwrap();
            for (p, s) in plain.iter().zip(&spec) {
                assert_eq!(p.completion, s.completion, "{drafter:?}");
                assert_eq!(p.finish, s.finish);
                assert_eq!(p.tokens_generated, s.tokens_generated);
            }
        });
    }
}

/// Dropping a speculative stream mid-decode cancels it without
/// perturbing siblings, and a huge-budget abandoned speculative stream
/// never starves the next request (cancel fires inside a verify round).
#[test]
fn speculative_streams_cancel_cleanly_mid_block() {
    let tok = tok();
    let model = model_for("ab", 128, tok.vocab_size());
    let cfg = ServeCfg {
        max_active: 1,
        threads: 1,
        quantum: 1,
        prefix_cache_size: 0,
        speculation: Some(SpecCfg {
            drafter: DrafterKind::NGram { max_ngram: 3 },
            draft_len: 4,
            ..Default::default()
        }),
        sample: SampleCfg {
            max_new_tokens: 100,
            seed: 5,
            stop_at_eot: false,
            ..Default::default()
        },
        ..Default::default()
    };
    let sched = StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap();
    let abandoned = sched.submit(Request::new(0, "Once upon a time")).unwrap();
    let first = abandoned.recv();
    assert!(matches!(first, Some(TokenEvent::Token { .. })));
    drop(abandoned);

    let survivor = sched.submit(Request::new(1, "Lily likes cats")).unwrap();
    let done = survivor.wait(|_| {}).expect("survivor finishes");
    assert_ne!(done.finish, FinishReason::Cancelled);
    assert!(done.tokens_generated > 0);
    sched.shutdown();
}

/// Streamed speculative text is byte-identical to batch plain text, and
/// the scheduler + `/healthz` report acceptance counters.
#[test]
fn streamed_speculation_matches_plain_and_reports_counters() {
    let tok = tok();
    let model = model_for("ab", 64, tok.vocab_size());
    let sample =
        SampleCfg { temperature: 0.8, top_k: 8, max_new_tokens: 8, seed: 9, stop_at_eot: true };
    let plain_cfg = ServeCfg {
        max_active: 2,
        threads: 1,
        quantum: 2,
        prefix_cache_size: 0,
        sample: sample.clone(),
        ..Default::default()
    };
    let reference = serve(&model, &tok, requests(), &plain_cfg).unwrap();

    let spec_cfg = ServeCfg {
        speculation: Some(SpecCfg {
            drafter: DrafterKind::NGram { max_ngram: 3 },
            draft_len: 3,
            ..Default::default()
        }),
        threads: 2,
        ..plain_cfg
    };
    let sched =
        Arc::new(StreamScheduler::start(Arc::clone(&model), tok.clone(), spec_cfg).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", Arc::clone(&sched)).unwrap();
    let addr = server.local_addr().to_string();

    for (i, want) in reference.iter().enumerate() {
        let mut req = GenerateRequest::new(&want.prompt);
        req.id = Some(i as u64);
        let got = client::generate(&addr, &req).unwrap();
        assert_eq!(got.completion, want.completion, "HTTP speculative decode diverged");
        let st = got.spec.expect("speculative responses carry stats over the wire");
        assert_eq!(st.emitted as usize, got.tokens_generated);
        assert_eq!(st.fused_passes, st.rounds, "fused verify is the default on native decode");
        assert_eq!(st.fused_rows, st.drafted + st.rounds);
    }

    let agg = sched.spec_stats();
    assert!(agg.rounds >= 1, "scheduler-wide counters must accumulate");
    assert_eq!(agg.emitted as usize, reference.iter().map(|c| c.tokens_generated).sum::<usize>());

    let v = client::health(&addr).unwrap();
    let spec = v.get("speculation");
    assert_eq!(spec.get("drafter").as_str(), Some("ngram"));
    assert_eq!(spec.get("draft_len").as_usize(), Some(3));
    assert_eq!(spec.get("rounds").as_usize(), Some(agg.rounds as usize));
    assert!(spec.get("tokens_per_round").as_f64().unwrap_or(0.0) > 0.0);
    assert_eq!(spec.get("fused").as_bool(), Some(true));
    assert_eq!(spec.get("fused_passes").as_usize(), Some(agg.fused_passes as usize));
    assert!(spec.get("rows_per_fused_pass").as_f64().unwrap_or(0.0) >= 1.0);
    server.shutdown();
}

/// Build a model whose greedy decode is a pure token→token map: zeroed
/// position embeddings and zeroed mixer/FFN mats leave the residual
/// stream a function of the current token alone, so the deterministic
/// next-token map over a finite vocabulary must enter a cycle (in
/// practice within ~√V ≈ 17 tokens) — the structurally guaranteed
/// repetitive regime where prompt-lookup drafting shines.
fn markov_model(ctx: usize, vocab: usize, seed: u64) -> Arc<Model> {
    let m = Manifest::synthetic("ab", layers_for("ab"), 16, ctx, vocab, 2);
    let flat = weights::seeded_flat(&m, seed);
    let mut w = ModelWeights::from_flat(&m, &flat).unwrap();
    w.pos_emb.fill(0.0);
    for lw in &mut w.layers {
        lw.mixer.mix_a.fill(0.0);
        lw.mixer.mix_b.fill(0.0);
        lw.ffn_w1.fill(0.0);
        lw.ffn_w2.fill(0.0);
    }
    Model::shared(m, w).unwrap()
}

/// A repetitive greedy decode: the n-gram drafter must land more than
/// one token per verify round once the model's output becomes periodic
/// — the economic point of speculation.  The Markov-map model makes
/// the periodicity structural, so this is deterministic, not hopeful.
#[test]
fn ngram_drafter_accepts_multiple_tokens_on_repetitive_decode() {
    let tok = tok();
    let mut best = 0.0f64;
    for weight_seed in [31u64, 7, 91, 13] {
        let model = markov_model(256, tok.vocab_size(), weight_seed);
        let cfg = ServeCfg {
            max_active: 1,
            threads: 1,
            quantum: 8,
            prefix_cache_size: 0,
            speculation: Some(SpecCfg {
                drafter: DrafterKind::NGram { max_ngram: 4 },
                draft_len: 6,
                ..Default::default()
            }),
            sample: SampleCfg {
                temperature: 0.0,
                top_k: 0,
                max_new_tokens: 160,
                seed: 0,
                stop_at_eot: false,
            },
            ..Default::default()
        };
        let prompt = "the cat sat on the mat. the cat sat on the mat. the cat sat on the mat.";
        let done = serve(&model, &tok, vec![Request::new(0, prompt)], &cfg).unwrap();
        let st = done[0].spec.expect("stats");
        best = best.max(st.emitted_per_round());
        if best > 1.0 {
            break;
        }
    }
    assert!(
        best > 1.0,
        "greedy repetitive decode should accept >1 token per verify round, got {best:.3}"
    );
}
