//! Property tests for the telemetry subsystem: histogram bucketing and
//! quantile math against a sorted shadow array, snapshot-merge algebra,
//! request-log schema round-trips, and the Prometheus rendering's
//! structural invariants. Pure CPU, PJRT-free — runs under both
//! feature sets.

use std::time::Duration;

use hsm::obs::hist::{bucket_bounds, bucket_index, HistSnapshot, Histogram, N_BUCKETS, SUB_BUCKETS};
use hsm::obs::{MetricsRegistry, RequestEvent};
use hsm::util::json;

fn xorshift(x: &mut u64) -> u64 {
    *x ^= *x << 13;
    *x ^= *x >> 7;
    *x ^= *x << 17;
    *x
}

/// Every family the registry renders, scraped or not.
const FAMILIES: [&str; 17] = [
    "hsm_queue_wait_seconds",
    "hsm_ttft_seconds",
    "hsm_token_latency_seconds",
    "hsm_request_seconds",
    "hsm_spec_verify_round_seconds",
    "hsm_requests_admitted_total",
    "hsm_requests_finished_total",
    "hsm_tokens_generated_total",
    "hsm_prompt_tokens_total",
    "hsm_prefix_cache_events_total",
    "hsm_prefix_cache_entries",
    "hsm_spec_rounds_total",
    "hsm_spec_tokens_total",
    "hsm_spec_fused_passes_total",
    "hsm_spec_fused_rows_total",
    "hsm_stage_seconds_total",
    "hsm_stage_samples_total",
];

#[test]
fn quantiles_bracket_order_statistics_across_distributions() {
    // Uniform, heavy-tailed, constant, and bimodal value streams: the
    // reported quantile bucket must contain the exact order statistic,
    // and the bucket's upper bound is at most 6.25% above it (for
    // values past the unit-resolution region).
    let gen_uniform = |x: &mut u64| xorshift(x) % 50_000_000;
    let gen_tail = |x: &mut u64| {
        let v = xorshift(x);
        (v % 1000) * ((v >> 32) % 1_000_000 + 1)
    };
    let gen_const = |_: &mut u64| 123_456u64;
    let gen_bimodal =
        |x: &mut u64| if xorshift(x) % 2 == 0 { 100 } else { 10_000_000 };
    let distributions: [(&str, &dyn Fn(&mut u64) -> u64); 4] = [
        ("uniform", &gen_uniform),
        ("tail", &gen_tail),
        ("const", &gen_const),
        ("bimodal", &gen_bimodal),
    ];
    for (name, gen) in distributions {
        let mut seed = 0x9e37_79b9_7f4a_7c15u64;
        let mut s = HistSnapshot::empty();
        let mut shadow = Vec::with_capacity(4000);
        for _ in 0..4000 {
            let v = gen(&mut seed);
            s.record(v);
            shadow.push(v);
        }
        shadow.sort_unstable();
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let rank = (q * (shadow.len() - 1) as f64).round() as usize;
            let exact = shadow[rank];
            let (lo, hi) = s.quantile_bounds(q).unwrap();
            assert!(
                lo <= exact && exact <= hi,
                "{name} q={q}: exact {exact} outside [{lo}, {hi}]"
            );
            let reported = s.quantile(q);
            if exact >= SUB_BUCKETS as u64 {
                let err = (reported - exact) as f64 / exact as f64;
                assert!(err <= 1.0 / SUB_BUCKETS as f64 + 1e-12, "{name} q={q}: err {err}");
            } else {
                assert_eq!(reported, exact, "{name} q={q}: unit region must be exact");
            }
        }
    }
}

#[test]
fn snapshot_merge_is_associative_commutative_and_matches_union() {
    let mut seed = 7u64;
    let parts: Vec<HistSnapshot> = (0..3)
        .map(|_| {
            let mut s = HistSnapshot::empty();
            for _ in 0..500 {
                s.record(xorshift(&mut seed) % 1_000_000);
            }
            s
        })
        .collect();
    // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c) and a ⊕ b == b ⊕ a.
    let mut left = parts[0].clone();
    left.merge(&parts[1]);
    left.merge(&parts[2]);
    let mut right_tail = parts[1].clone();
    right_tail.merge(&parts[2]);
    let mut right = parts[0].clone();
    right.merge(&right_tail);
    assert_eq!(left, right, "merge must be associative");
    let mut ab = parts[0].clone();
    ab.merge(&parts[1]);
    let mut ba = parts[1].clone();
    ba.merge(&parts[0]);
    assert_eq!(ab, ba, "merge must be commutative");
    // Merging equals recording the union stream directly.
    seed = 7;
    let mut union = HistSnapshot::empty();
    for _ in 0..1500 {
        union.record(xorshift(&mut seed) % 1_000_000);
    }
    assert_eq!(left, union, "merged parts must equal the union stream");
}

#[test]
fn bucket_edges_tile_and_contain() {
    // Edge values around the linear/log boundary, octave boundaries,
    // and the extremes.
    let mut probes = vec![0u64, 1, 15, 16, 17, 31, 32, 33, u64::MAX - 1, u64::MAX];
    for p in 4..63u32 {
        let v = 1u64 << p;
        probes.extend([v - 1, v, v + 1]);
    }
    let mut last_ix = 0usize;
    let mut sorted = probes.clone();
    sorted.sort_unstable();
    for v in sorted {
        let i = bucket_index(v);
        assert!(i < N_BUCKETS);
        assert!(i >= last_ix, "index not monotonic at {v}");
        last_ix = i;
        let (lo, hi) = bucket_bounds(i);
        assert!(lo <= v && v <= hi, "{v} outside its bucket [{lo}, {hi}]");
    }
    // Below the linear max every value is its own bucket.
    for v in 0..SUB_BUCKETS as u64 {
        assert_eq!(bucket_bounds(bucket_index(v)), (v, v));
    }
}

#[test]
fn concurrent_recording_with_more_threads_than_shards_loses_nothing() {
    use std::sync::Arc;
    let h = Arc::new(Histogram::new());
    let threads = 16usize; // > the 8 internal shards: slots must share.
    let per = 2_000u64;
    std::thread::scope(|s| {
        for t in 0..threads as u64 {
            let h = Arc::clone(&h);
            s.spawn(move || {
                for i in 0..per {
                    h.record(t * 1_000 + i);
                }
            });
        }
    });
    let snap = h.snapshot();
    assert_eq!(snap.count, threads as u64 * per);
    let total: u64 = (0..threads as u64).map(|t| (0..per).map(|i| t * 1_000 + i).sum::<u64>()).sum();
    assert_eq!(snap.sum, total);
}

#[test]
fn request_events_round_trip_through_json_lines() {
    let events = vec![
        RequestEvent::Admitted { request_id: 0, prompt_tokens: 0, queue_wait_ms: 0.0 },
        RequestEvent::Admitted {
            request_id: (1 << 53) - 1, // f64-exact ceiling of the id space
            prompt_tokens: 4096,
            queue_wait_ms: 12345.678,
        },
        RequestEvent::Started { request_id: 3, cached_prefix_len: 0, prefill_ms: 0.001 },
        RequestEvent::FirstToken { request_id: 3, ttft_ms: 9000.25 },
        RequestEvent::Finished {
            request_id: 3,
            finish: "eot".into(),
            tokens_generated: 48,
            e2e_ms: 77.5,
            mixer: "hsm_ab".into(),
            precision: "f32".into(),
            drafter: None,
            spec_rounds: 0,
            spec_drafted: 0,
            spec_accepted: 0,
            cached_prefix_len: 5,
        },
        RequestEvent::Finished {
            request_id: 9,
            finish: "max_tokens".into(),
            tokens_generated: 32,
            e2e_ms: 150.125,
            mixer: "attn".into(),
            precision: "int8".into(),
            drafter: Some("shallow-q:2".into()),
            spec_rounds: 11,
            spec_drafted: 44,
            spec_accepted: 40,
            cached_prefix_len: 0,
        },
    ];
    for ev in &events {
        let line = ev.to_json().to_string();
        assert!(!line.contains('\n'), "one event must be one line");
        let back = RequestEvent::from_json(&json::parse(&line).unwrap()).unwrap();
        assert_eq!(&back, ev, "round-trip changed the event");
    }
    // Unknown event names are rejected, not silently misparsed.
    let bogus = json::parse("{\"event\": \"nope\", \"request_id\": 1}").unwrap();
    assert!(RequestEvent::from_json(&bogus).is_err());
}

#[test]
fn prometheus_rendering_is_structurally_sound() {
    let reg = MetricsRegistry::new();
    // An untouched registry still renders every family (stable scrape
    // schema)...
    let empty = reg.render_prometheus();
    for family in FAMILIES {
        assert!(
            empty.contains(&format!("# TYPE {family} ")),
            "family {family} missing from empty render"
        );
    }
    // ...and zero-valued histograms are well-formed.
    assert!(empty.contains("hsm_ttft_seconds_bucket{le=\"+Inf\"} 0"));
    assert!(empty.contains("hsm_ttft_seconds_count 0"));

    // Populate and re-check: bucket series must be cumulative and agree
    // with _count; counters must reflect the recorded values.
    for ns in [5_000u64, 40_000, 40_000, 1_000_000, 25_000_000_000] {
        reg.record_ttft(Duration::from_nanos(ns));
    }
    reg.inc_admitted();
    reg.inc_admitted();
    reg.inc_finished("eot");
    reg.inc_finished("cancelled");
    reg.add_tokens_generated(96);
    let text = reg.render_prometheus();
    assert!(text.contains("hsm_requests_admitted_total 2"));
    assert!(text.contains("hsm_requests_finished_total{finish=\"eot\"} 1"));
    assert!(text.contains("hsm_requests_finished_total{finish=\"cancelled\"} 1"));
    assert!(text.contains("hsm_requests_finished_total{finish=\"timed_out\"} 0"));
    assert!(text.contains("hsm_tokens_generated_total 96"));

    let mut cum = Vec::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("hsm_ttft_seconds_bucket{le=\"") {
            let (le, count) = rest.split_once("\"} ").unwrap();
            let count: u64 = count.parse().unwrap();
            if le != "+Inf" {
                let le: f64 = le.parse().expect("le must be plain decimal");
                assert!(le.is_finite() && le >= 0.0);
            }
            cum.push(count);
        }
    }
    assert!(cum.len() >= 2, "expected bucket series plus +Inf");
    assert!(cum.windows(2).all(|w| w[0] <= w[1]), "bucket series must be cumulative");
    assert_eq!(*cum.last().unwrap(), 5, "+Inf bucket must equal the count");
    assert!(text.contains("hsm_ttft_seconds_count 5"));
}

#[test]
fn stage_cells_register_once_per_key_and_accumulate() {
    use hsm::obs::StageKey;
    let reg = MetricsRegistry::new();
    let key = StageKey {
        phase: "step",
        stage: "mixer",
        mixer: "hsm_ab".into(),
        precision: "f32".into(),
    };
    let a = reg.stage_cell(key.clone());
    let b = reg.stage_cell(key.clone());
    a.record(1_000);
    b.record(2_000);
    let snap = reg.stage_snapshot();
    let (_, ns, samples) = snap.iter().find(|(k, _, _)| *k == key).expect("key registered");
    assert_eq!(*ns, 3_000, "both handles must hit the same cell");
    assert_eq!(*samples, 2);
    let text = reg.render_prometheus();
    assert!(text.contains(
        "hsm_stage_seconds_total{phase=\"step\",stage=\"mixer\",mixer=\"hsm_ab\",precision=\"f32\"}"
    ));
    assert!(text.contains(
        "hsm_stage_samples_total{phase=\"step\",stage=\"mixer\",mixer=\"hsm_ab\",precision=\"f32\"} 2"
    ));
}
