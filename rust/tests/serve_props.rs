//! Serving-infrastructure property and stress tests:
//!
//! * [`serve::PrefixCache`] invariants under random insert/lookup
//!   sequences — the size bound always holds, a longest-prefix-match
//!   result is always a true prefix that was actually inserted, with no
//!   eviction the match is exactly the longest present prefix, and the
//!   hit/miss/insertion/eviction counters stay consistent with the
//!   observed operations.
//! * [`serve::StreamScheduler`] shutdown/submit race: concurrent
//!   `submit()` calls during a graceful drain either complete (their
//!   streams still deliver a final `Done`) or return a clean error —
//!   no deadlock, no stranded sinks.  Guarded by a watchdog so a
//!   regression fails fast instead of hanging CI.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::SampleCfg;
use hsm::infer::{weights, Decoder, Model, ModelWeights, SessionState};
use hsm::serve::{PrefixCache, Request, ServeCfg, StreamScheduler};
use hsm::tokenizer::Tokenizer;
use hsm::util::prop;

fn model(seed: u64) -> Arc<Model> {
    let layers = vec![
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
    ];
    let m = Manifest::synthetic("hsm_ab", layers, 8, 64, 300, 1);
    let flat = weights::seeded_flat(&m, seed);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

/// Snapshots for every prefix of `base` (index i → prefix of length
/// i+1), taken from one incremental prefill so the test's insert cost
/// is linear, not quadratic.
fn prefix_snapshots(model: &Arc<Model>, base: &[u32]) -> Vec<SessionState> {
    let mut sess = model.session();
    let mut snaps = Vec::with_capacity(base.len());
    for &t in base {
        sess.prefill(&[t]).unwrap();
        snaps.push(sess.snapshot().unwrap());
    }
    snaps
}

/// Random insert/lookup sequences against small capacities: the size
/// bound, true-prefix property, position consistency and counter
/// arithmetic all hold at every step.
#[test]
fn prop_prefix_cache_invariants_under_random_ops() {
    let md = model(1);
    let fp = md.fingerprint();
    // Two base sequences sharing a head, so lookups exercise real
    // longest-prefix competition.
    let base_a: Vec<u32> = (0..10u32).map(|i| (i * 37 + 11) % 300).collect();
    let base_b: Vec<u32> = {
        let mut b = base_a.clone();
        for x in b.iter_mut().skip(5) {
            *x = (*x + 101) % 300;
        }
        b
    };
    let snaps_a = prefix_snapshots(&md, &base_a);
    let snaps_b = prefix_snapshots(&md, &base_b);

    prop::check_n("prefix-cache-invariants", 24, |rng| {
        let capacity = 1 + rng.below(5);
        let cache = PrefixCache::new(fp, capacity);
        let mut ever_inserted: HashSet<Vec<u32>> = HashSet::new();
        let mut lookups = 0u64;
        let mut insert_ops = 0u64;
        for _ in 0..40 {
            let (base, snaps) = if rng.chance(0.5) {
                (&base_a, &snaps_a)
            } else {
                (&base_b, &snaps_b)
            };
            if rng.chance(0.5) {
                // Insert a random prefix (occasionally under a foreign
                // fingerprint, which must be ignored).
                let len = 1 + rng.below(base.len());
                let foreign = rng.chance(0.2);
                let use_fp = if foreign { fp ^ 0xdead } else { fp };
                cache.insert(use_fp, &base[..len], snaps[len - 1].clone());
                if !foreign {
                    insert_ops += 1;
                    ever_inserted.insert(base[..len].to_vec());
                }
            } else {
                let len = 1 + rng.below(base.len());
                lookups += 1;
                if let Some((hit_len, state)) = cache.lookup(fp, &base[..len]) {
                    assert!(hit_len <= len, "match longer than the query");
                    assert!(
                        ever_inserted.contains(&base[..hit_len].to_vec()),
                        "hit on a prefix that was never inserted"
                    );
                    assert_eq!(
                        state.position(),
                        hit_len,
                        "snapshot position must sit at the prefix boundary"
                    );
                }
            }
            let s = cache.stats();
            assert!(s.entries <= capacity, "size bound violated: {} > {capacity}", s.entries);
            assert_eq!(s.entries, cache.len());
            assert_eq!(s.hits + s.misses, lookups, "every lookup is a hit or a miss");
            // Duplicate inserts refresh without counting; an evicted key
            // re-inserted counts again — so insertions is bounded by the
            // op count below and the distinct-key count above.
            assert!(
                s.insertions <= insert_ops,
                "insertions {} cannot exceed accepted insert ops {insert_ops}",
                s.insertions
            );
            assert!(
                s.insertions >= ever_inserted.len() as u64,
                "every distinct key's first insert must count"
            );
            assert_eq!(
                s.entries as u64,
                s.insertions - s.evictions,
                "entries must equal insertions minus evictions"
            );
        }
    });
}

/// With capacity ≥ every distinct prefix (no eviction pressure), the
/// cache's longest-prefix-match is *exactly* the longest inserted
/// prefix of the query — pinned against a shadow set.
#[test]
fn prop_prefix_cache_longest_match_is_exact_without_eviction() {
    let md = model(2);
    let fp = md.fingerprint();
    let base: Vec<u32> = (0..12u32).map(|i| (i * 53 + 7) % 300).collect();
    let snaps = prefix_snapshots(&md, &base);

    prop::check_n("prefix-cache-longest-match", 24, |rng| {
        let cache = PrefixCache::new(fp, 64); // never evicts here
        let mut shadow: HashSet<usize> = HashSet::new(); // inserted prefix lengths
        for _ in 0..30 {
            if rng.chance(0.4) {
                let len = 1 + rng.below(base.len());
                cache.insert(fp, &base[..len], snaps[len - 1].clone());
                shadow.insert(len);
            } else {
                let qlen = 1 + rng.below(base.len());
                let want = shadow.iter().copied().filter(|&l| l <= qlen).max();
                let got = cache.lookup(fp, &base[..qlen]).map(|(l, _)| l);
                assert_eq!(got, want, "longest-prefix-match diverged from the shadow set");
            }
        }
        assert_eq!(cache.stats().evictions, 0, "capacity 64 must never evict here");
    });
}

/// Heavy eviction churn: hammer a capacity-2 cache with distinct
/// prefixes; the bound and counters must hold and hits must still
/// return true prefixes.
#[test]
fn prefix_cache_eviction_churn_stays_bounded_and_consistent() {
    let md = model(3);
    let fp = md.fingerprint();
    let base: Vec<u32> = (0..10u32).map(|i| (i * 29 + 3) % 300).collect();
    let snaps = prefix_snapshots(&md, &base);
    let cache = PrefixCache::new(fp, 2);
    for round in 0..20 {
        for len in 1..=base.len() {
            cache.insert(fp, &base[..len], snaps[len - 1].clone());
            assert!(cache.len() <= 2, "round {round}: capacity exceeded");
        }
    }
    let s = cache.stats();
    assert_eq!(s.entries as u64, s.insertions - s.evictions);
    assert!(s.evictions > 0, "churn must evict");
    let (len, state) = cache.lookup(fp, &base).expect("full base must hit something");
    assert!(len >= 1 && len <= base.len());
    assert_eq!(state.position(), len);
}

/// Concurrent `submit()` during graceful shutdown: every call either
/// returns a stream that still finishes with a `Done` event, or a
/// clean error — and the whole dance completes well inside the
/// watchdog budget (no deadlock, no stranded sinks).
#[test]
fn stream_scheduler_shutdown_submit_race_is_clean() {
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    let scenario = std::thread::spawn(move || {
        let tok = tok();
        let md = model(4);
        let cfg = ServeCfg {
            max_active: 2,
            threads: 2,
            quantum: 1,
            prefix_cache_size: 4,
            sample: SampleCfg { max_new_tokens: 4, seed: 7, ..Default::default() },
            ..Default::default()
        };
        let sched = Arc::new(StreamScheduler::start(md, tok, cfg).unwrap());
        let accepted = Arc::new(AtomicUsize::new(0));
        let rejected = Arc::new(AtomicUsize::new(0));
        let stranded = Arc::new(AtomicUsize::new(0));

        std::thread::scope(|s| {
            for t in 0..4u64 {
                let sched = Arc::clone(&sched);
                let accepted = Arc::clone(&accepted);
                let rejected = Arc::clone(&rejected);
                let stranded = Arc::clone(&stranded);
                s.spawn(move || {
                    for i in 0..40u64 {
                        match sched.submit(Request::new(t * 1000 + i, "Once upon a time")) {
                            Ok(stream) => {
                                accepted.fetch_add(1, Ordering::Relaxed);
                                // An accepted stream must still deliver
                                // its Done through the graceful drain.
                                if stream.wait(|_| {}).is_none() {
                                    stranded.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Err(_) => {
                                rejected.fetch_add(1, Ordering::Relaxed);
                                // Once shutdown, submission stays closed;
                                // stop hammering.
                                break;
                            }
                        }
                    }
                });
            }
            // Let the submitters get going, then pull the plug
            // mid-flight.
            std::thread::sleep(Duration::from_millis(30));
            sched.shutdown();
        });

        let a = accepted.load(Ordering::Relaxed);
        let r = rejected.load(Ordering::Relaxed);
        let s = stranded.load(Ordering::Relaxed);
        assert!(a > 0, "some submissions must land before shutdown");
        assert_eq!(s, 0, "accepted streams must never be stranded ({a} accepted)");
        // Post-shutdown, a fresh submit is a clean rejection.
        assert!(sched.submit(Request::new(999_999, "hi")).is_err());
        (a, r)
    });

    // Watchdog: the scenario must finish comfortably within CI budgets;
    // a deadlock fails the test instead of hanging the job.
    let handle = std::thread::spawn(move || {
        let result = scenario.join();
        let _ = done_tx.send(result);
    });
    match done_rx.recv_timeout(Duration::from_secs(120)) {
        Ok(Ok((accepted, rejected))) => {
            println!("shutdown/submit race: {accepted} accepted, {rejected} rejected");
            handle.join().unwrap();
        }
        Ok(Err(e)) => std::panic::resume_unwind(e),
        Err(_) => panic!("shutdown/submit race deadlocked (watchdog fired)"),
    }
}
