//! Decode parity: the incremental [`NativeDecoder`] (ring buffers / KV
//! cache, O(1) state per HSM layer) against [`WindowDecoder`] over the
//! independent full-sequence forward ([`WindowEngine`]) — **token for
//! token**, for every mixer kind.
//!
//! The two paths share only the tensor primitives; all state machinery
//! (ring ages, push ordering, KV growth, window padding, position
//! bookkeeping) is implemented twice.  Op order is mirrored exactly, so
//! the assertion is bit-equality of logits, not a tolerance.

use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::coordinator::MockEngine;
use hsm::generation::{self, argmax, SampleCfg, WindowDecoder};
use hsm::infer::{Decoder, Model, ModelWeights, WindowEngine};
use hsm::runtime::StepEngine;

const KINDS: &[&str] = &["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"];

/// Multi-layer stacks with growing shifts so ring history, the
/// zero-history start, and (for multihead ab) per-head shifts are all
/// exercised.
fn layers_for(kind: &str) -> Vec<LayerInfo> {
    match kind {
        "ab" => vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 24 },
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![2, 4, 8, 16], ffn: 24 },
        ],
        _ => vec![
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![1], ffn: 24 },
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![3], ffn: 24 },
        ],
    }
}

/// A MockEngine-initialized model: constant mock init, perturbed
/// deterministically so tokens and positions are distinguishable.
fn model_from_mock(manifest: Manifest) -> Arc<Model> {
    let mut mock = MockEngine::new(manifest.clone(), 1.8, 0.01);
    mock.init(0).unwrap();
    let mut flat = mock.get_params().unwrap();
    for (ti, t) in flat.iter_mut().enumerate() {
        for (i, x) in t.iter_mut().enumerate() {
            *x += 0.07 * (((i * 29 + ti * 13 + 3) % 31) as f32 - 15.0) / 15.0;
        }
    }
    let w = ModelWeights::from_flat(&manifest, &flat).unwrap();
    Model::shared(manifest, w).unwrap()
}

/// Greedy-decode to the window edge through both decoders, asserting
/// bit-equal logits and identical token choices at every step.
fn check_parity(model: &Arc<Model>, tag: &str) {
    let vocab = model.manifest.vocab as u32;
    let ctx = model.manifest.ctx;

    let mut native = model.session();
    let mut weng = WindowEngine::new(Arc::clone(model));
    let mut windowed = WindowDecoder::new(&mut weng, 0);

    let prompt: Vec<u32> = [3u32, 17, 8, 42, 5].iter().map(|&t| t % vocab).collect();
    native.prefill(&prompt[..prompt.len() - 1]).unwrap();
    windowed.prefill(&prompt[..prompt.len() - 1]).unwrap();

    let mut nat_last = *prompt.last().unwrap();
    let mut win_last = nat_last;
    for step in 0..(ctx - prompt.len()) {
        let nat_logits = native.step(nat_last).unwrap().to_vec();
        let win_logits = windowed.step(win_last).unwrap().to_vec();
        assert!(
            nat_logits.iter().all(|x| x.is_finite()),
            "{tag}: non-finite logits at step {step}"
        );
        assert_eq!(nat_logits, win_logits, "{tag}: logits diverge at step {step}");
        nat_last = argmax(&nat_logits);
        win_last = argmax(&win_logits);
        assert_eq!(nat_last, win_last, "{tag}: greedy token diverges at step {step}");
    }
    assert_eq!(native.position(), windowed.position(), "{tag}: position cursors diverge");
}

#[test]
fn native_matches_windowed_token_for_token_all_mixer_kinds() {
    for kind in KINDS {
        let m = Manifest::synthetic(kind, layers_for(kind), 16, 32, 120, 2);
        let model = model_from_mock(m);
        check_parity(&model, kind);
        eprintln!("parity OK: {kind}");
    }
}

#[test]
fn hybrid_stack_parity() {
    // HSM → attention → fusion in one stack: ring state and a growing KV
    // cache must coexist in one session.
    let layers = vec![
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 24 },
        LayerInfo { kind: "attn".into(), heads: 2, shifts: vec![], ffn: 24 },
        LayerInfo { kind: "fusion".into(), heads: 2, shifts: vec![2], ffn: 24 },
    ];
    let m = Manifest::synthetic("hybrid", layers, 16, 32, 120, 2);
    let model = model_from_mock(m);
    check_parity(&model, "hybrid");
}

#[test]
fn generate_parity_through_the_tokenizer_path() {
    // Full generate(): prompt encoding, prefill split, EOT handling —
    // native incremental vs windowed must produce the same completion.
    let text = hsm::corpus::generate(7, 80);
    let tok = hsm::tokenizer::trainer::train(&text, 300).unwrap();
    let m = Manifest::synthetic("hsm_ab", layers_for("ab"), 16, 48, tok.vocab_size(), 2);
    let model = model_from_mock(m);

    let cfg = SampleCfg { temperature: 0.0, top_k: 0, max_new_tokens: 12, seed: 0, stop_at_eot: true };
    let g_nat = generation::generate(&mut model.session(), &tok, "Once upon a time", &cfg).unwrap();
    let mut weng = WindowEngine::new(Arc::clone(&model));
    let g_win = generation::generate_windowed(&mut weng, &tok, "Once upon a time", &cfg).unwrap();
    assert_eq!(g_nat.completion, g_win.completion);
    assert_eq!(g_nat.tokens_generated, g_win.tokens_generated);
    assert_eq!(g_nat.stopped_at_eot, g_win.stopped_at_eot);

    // Sessions must be reusable: a second run after the internal reset
    // reproduces the first (no leaked ring/KV state).
    let mut dec = model.session();
    let a = generation::generate(&mut dec, &tok, "Once upon a time", &cfg).unwrap();
    let b = generation::generate(&mut dec, &tok, "Once upon a time", &cfg).unwrap();
    assert_eq!(a.completion, b.completion);
}
