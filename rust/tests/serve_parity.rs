//! Serve-scheduler parity: continuous batching at arbitrary
//! (`max_active`, `threads`, `quantum`) must produce **byte-identical**
//! completions to sequential single-session decoding, for every mixer
//! kind — the determinism invariant the serve module promises (per-
//! request RNG streams `seed ^ id`, disjoint per-sequence state).
//!
//! Also pins the admission/eviction edge cases: context-window eviction
//! frees slots for pending requests (more requests than `max_active`),
//! per-request token budgets, rejected prompts, and the fixed-membership
//! wrapper's length-mismatch check.

use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{self, SampleCfg};
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{serve, FinishReason, Request, Scheduler, ServeCfg};
use hsm::tokenizer::Tokenizer;

const KINDS: &[&str] = &["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"];

/// Scheduling shapes to sweep: single-file, more threads than sessions,
/// more sessions than threads, and a wide parallel pool.
const SHAPES: &[(usize, usize, usize)] = &[
    // (max_active, threads, quantum)
    (1, 1, 0),
    (2, 4, 1),
    (3, 2, 5),
    (8, 4, 2),
];

const PROMPTS: &[&str] = &[
    "Once upon a time",
    "Lily likes cats",
    "Jack went to",
    "Once upon a time",
    "Ben and Lily wanted cake",
    "The moon was big",
];

fn layers_for(kind: &str) -> Vec<LayerInfo> {
    match kind {
        "ab" => vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 24 },
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![2, 4, 8, 16], ffn: 24 },
        ],
        _ => vec![
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![1], ffn: 24 },
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![3], ffn: 24 },
        ],
    }
}

fn model_for(kind: &str, ctx: usize, vocab: usize) -> Arc<Model> {
    let m = Manifest::synthetic(kind, layers_for(kind), 16, ctx, vocab, 2);
    let flat = weights::seeded_flat(&m, 31);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

/// The ground truth the scheduler must match: each request decoded alone
/// in a fresh session, with its RNG stream seeded `cfg.seed ^ id`.
fn sequential_reference(
    model: &Arc<Model>,
    tok: &Tokenizer,
    prompts: &[&str],
    cfg: &SampleCfg,
) -> Vec<generation::Generation> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let solo = SampleCfg { seed: cfg.seed ^ i as u64, ..cfg.clone() };
            generation::generate(&mut model.session(), tok, p, &solo).unwrap()
        })
        .collect()
}

#[test]
fn continuous_batching_matches_sequential_for_every_mixer_kind() {
    let tok = tok();
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 8,
        max_new_tokens: 8,
        seed: 11,
        stop_at_eot: true,
    };
    for kind in KINDS {
        let model = model_for(kind, 48, tok.vocab_size());
        let reference = sequential_reference(&model, &tok, PROMPTS, &cfg);
        for &(max_active, threads, quantum) in SHAPES {
            let scfg =
                ServeCfg { max_active, threads, quantum, sample: cfg.clone(), ..Default::default() };
            let requests: Vec<Request> =
                PROMPTS.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
            let comps = serve(&model, &tok, requests, &scfg).unwrap();
            assert_eq!(comps.len(), reference.len(), "{kind}: completion count");
            for (i, (c, r)) in comps.iter().zip(&reference).enumerate() {
                assert_eq!(c.request_id, i as u64, "{kind}: order not preserved");
                assert_eq!(
                    c.completion, r.completion,
                    "{kind}: request {i} diverged at max_active={max_active} \
                     threads={threads} quantum={quantum}"
                );
                assert_eq!(c.tokens_generated, r.tokens_generated, "{kind}: request {i} length");
                assert_eq!(c.stopped_at_eot(), r.stopped_at_eot, "{kind}: request {i} eot flag");
            }
        }
    }
}

#[test]
fn eviction_frees_slots_and_preserves_order() {
    // Tiny context, no EOT stop, huge budget: every sequence runs to
    // context eviction, and 9 requests must flow through 2 sessions.
    let tok = tok();
    let ctx = 24;
    let model = model_for("ab", ctx, tok.vocab_size());
    let cfg = SampleCfg {
        temperature: 0.9,
        top_k: 0,
        max_new_tokens: 500,
        seed: 3,
        stop_at_eot: false,
    };
    let prompts: Vec<&str> = (0..9).map(|i| PROMPTS[i % PROMPTS.len()]).collect();
    let reference = sequential_reference(&model, &tok, &prompts, &cfg);

    let scfg =
        ServeCfg { max_active: 2, threads: 3, quantum: 4, sample: cfg, ..Default::default() };
    let requests: Vec<Request> =
        prompts.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
    let comps = Scheduler::new(Arc::clone(&model), scfg).unwrap().serve(&tok, requests).unwrap();

    assert_eq!(comps.len(), 9);
    for (i, (c, r)) in comps.iter().zip(&reference).enumerate() {
        assert_eq!(c.request_id, i as u64, "order not preserved");
        assert_eq!(c.finish, FinishReason::CtxFull, "request {i} should evict on a full window");
        assert_eq!(c.completion, r.completion, "request {i} diverged under eviction pressure");
        let prompt_tokens = tok.encode(&c.prompt).len();
        assert_eq!(c.tokens_generated, ctx - prompt_tokens, "request {i} fills the window");
    }
}

#[test]
fn per_request_budget_overrides_the_shared_cap() {
    let tok = tok();
    let model = model_for("ab", 64, tok.vocab_size());
    let sample = SampleCfg {
        temperature: 0.7,
        top_k: 0,
        max_new_tokens: 12,
        seed: 7,
        stop_at_eot: false,
    };
    let scfg = ServeCfg { max_active: 2, threads: 2, quantum: 3, sample, ..Default::default() };
    let mut short = Request::new(0, "Once upon a time");
    short.max_new_tokens = Some(3);
    let long = Request::new(1, "Once upon a time");
    let comps = serve(&model, &tok, vec![short, long], &scfg).unwrap();
    assert_eq!(comps[0].tokens_generated, 3);
    assert_eq!(comps[0].finish, FinishReason::MaxTokens);
    assert_eq!(comps[1].tokens_generated, 12);
    // Same id-stream prefix: the capped request is a prefix of the long
    // one only when ids differ... they don't share a stream (0 vs 1), so
    // just pin that both decoded independently and deterministically.
    let again = serve(
        &model,
        &tok,
        vec![
            { let mut r = Request::new(0, "Once upon a time"); r.max_new_tokens = Some(3); r },
            Request::new(1, "Once upon a time"),
        ],
        &scfg,
    )
    .unwrap();
    assert_eq!(comps[0].completion, again[0].completion);
    assert_eq!(comps[1].completion, again[1].completion);
}

#[test]
fn rejection_and_length_mismatch_edges() {
    let tok = tok();
    let model = model_for("ab", 32, tok.vocab_size());

    // A prompt longer than the context window is rejected per-request;
    // the rest of the batch still completes.
    let monster = "Once upon a time there was a pumpkin. ".repeat(40);
    let reqs = vec![Request::new(0, &monster), Request::new(1, "Lily likes cats")];
    let scfg = ServeCfg {
        max_active: 2,
        threads: 2,
        quantum: 2,
        sample: SampleCfg { max_new_tokens: 4, ..Default::default() },
        ..Default::default()
    };
    let comps = serve(&model, &tok, reqs, &scfg).unwrap();
    assert!(matches!(comps[0].finish, FinishReason::Rejected(_)), "oversize prompt must reject");
    assert_eq!(comps[0].tokens_generated, 0);
    assert!(!matches!(comps[1].finish, FinishReason::Rejected(_)));

    // The fixed-membership wrapper still pins its length check.
    let mut sessions = vec![model.session()];
    assert!(
        generation::generate_batch(&mut sessions, &tok, &["a", "b"], &SampleCfg::default())
            .is_err(),
        "decoder/prompt length mismatch must error"
    );
}
