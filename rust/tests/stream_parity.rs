//! Streaming parity: for **every mixer kind**, concatenating the
//! `text_delta`s from the streaming path is byte-identical to the
//! non-streaming [`hsm::serve::Completion::completion`] — and to
//! sequential single-session `generate`.  Streaming is a pure tap on the
//! decode loop; this pins that it can never change sampled text.

use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{self, SampleCfg};
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{serve, Request, ServeCfg, StreamScheduler, TokenEvent, TokenStream};
use hsm::tokenizer::Tokenizer;

const KINDS: &[&str] = &["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"];

const PROMPTS: &[&str] = &[
    "Once upon a time",
    "Lily likes cats",
    "Jack went to",
    "Ben and Lily wanted cake",
];

fn layers_for(kind: &str) -> Vec<LayerInfo> {
    match kind {
        "ab" => vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 24 },
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![2, 4, 8, 16], ffn: 24 },
        ],
        _ => vec![
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![1], ffn: 24 },
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![3], ffn: 24 },
        ],
    }
}

fn model_for(kind: &str, ctx: usize, vocab: usize) -> Arc<Model> {
    let m = Manifest::synthetic(kind, layers_for(kind), 16, ctx, vocab, 2);
    let flat = weights::seeded_flat(&m, 31);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

#[test]
fn streamed_deltas_concat_to_batch_and_sequential_text_for_every_mixer_kind() {
    let tok = tok();
    let cfg = SampleCfg {
        temperature: 0.8,
        top_k: 8,
        max_new_tokens: 8,
        seed: 11,
        stop_at_eot: true,
    };
    for kind in KINDS {
        let model = model_for(kind, 48, tok.vocab_size());

        // Sequential ground truth: each request alone in a fresh session,
        // RNG stream seed ^ id.
        let sequential: Vec<String> = PROMPTS
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let solo = SampleCfg { seed: cfg.seed ^ i as u64, ..cfg.clone() };
                generation::generate(&mut model.session(), &tok, p, &solo).unwrap().completion
            })
            .collect();

        // Non-streaming scheduler output.
        let scfg = ServeCfg {
            max_active: 2,
            threads: 3,
            quantum: 2,
            sample: cfg.clone(),
            ..Default::default()
        };
        let requests: Vec<Request> =
            PROMPTS.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
        let batch = serve(&model, &tok, requests.clone(), &scfg).unwrap();

        // Streaming path: submit everything up front so the sequences
        // genuinely interleave across workers, then drain each stream.
        let sched = StreamScheduler::start(Arc::clone(&model), tok.clone(), scfg).unwrap();
        let streams: Vec<TokenStream> =
            requests.into_iter().map(|r| sched.submit(r).unwrap()).collect();
        for ((stream, want), solo) in streams.into_iter().zip(&batch).zip(&sequential) {
            let mut streamed = String::new();
            let mut token_events = 0usize;
            let mut done = None;
            for ev in stream {
                match ev {
                    TokenEvent::Token { text_delta, .. } => {
                        token_events += 1;
                        streamed.push_str(&text_delta);
                    }
                    TokenEvent::Done { text_delta, completion } => {
                        streamed.push_str(&text_delta);
                        done = Some(completion);
                    }
                }
            }
            let done = done.expect("stream must end with Done");
            assert_eq!(
                streamed, want.completion,
                "{kind}: request {} streamed text diverged from batch",
                want.request_id
            );
            assert_eq!(
                &streamed, solo,
                "{kind}: request {} streamed text diverged from sequential",
                want.request_id
            );
            assert_eq!(done.completion, want.completion, "{kind}: Done completion mismatch");
            assert_eq!(done.finish, want.finish, "{kind}: finish reason mismatch");
            assert_eq!(
                token_events, want.tokens_generated,
                "{kind}: one Token event per sampled token"
            );
        }
        sched.shutdown();
    }
}
