//! Property/fuzz tests for the hand-rolled HTTP/1.1 wire layer
//! (`server::http`): request parsing must survive arbitrary read-split
//! boundaries, byte soup, heads/bodies at exactly the caps, and
//! malformed chunked encodings — always a clean `Ok`/`Err`, never a
//! panic, never unbounded buffering.  (Hangs are structurally
//! impossible here: every reader is in-memory, so the risk surface is
//! panics and cap bypasses.)

use std::io::{BufReader, Cursor, Read};

use hsm::server::http::{read_chunks, read_request, MAX_BODY_BYTES, MAX_HEAD_BYTES};
use hsm::util::prop;
use hsm::util::rng::Rng;

/// A reader that hands back the payload in pre-chosen fragment sizes,
/// simulating TCP delivering a request in arbitrary pieces.
struct Shreds {
    data: Vec<u8>,
    pos: usize,
    cuts: Vec<usize>,
    i: usize,
}

impl Shreds {
    fn new(data: Vec<u8>, rng: &mut Rng) -> Self {
        let cuts = (0..64).map(|_| 1 + rng.below(13)).collect();
        Shreds { data, pos: 0, cuts, i: 0 }
    }
}

impl Read for Shreds {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        let want = self.cuts.get(self.i).copied().unwrap_or(usize::MAX).max(1);
        self.i += 1;
        let n = want.min(buf.len()).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// Valid requests parse identically however the bytes are split across
/// reads (random fragment sizes, tiny BufReader capacities).
#[test]
fn prop_request_parsing_is_split_invariant() {
    prop::check_n("http-split-invariance", 48, |rng| {
        let n_headers = rng.below(6);
        let mut headers = String::new();
        let mut names = Vec::new();
        for h in 0..n_headers {
            let name = format!("x-h{h}");
            let value: String = (0..rng.below(20))
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            headers.push_str(&format!("{name}: {value}\r\n"));
            names.push((name, value));
        }
        let body: Vec<u8> = (0..rng.below(64)).map(|_| rng.next_u64() as u8).collect();
        let raw = format!(
            "POST /v1/generate HTTP/1.1\r\n{headers}Content-Length: {}\r\n\r\n",
            body.len()
        );
        let mut wire = raw.into_bytes();
        wire.extend_from_slice(&body);

        let cap = 1 + rng.below(17);
        let mut r = BufReader::with_capacity(cap, Shreds::new(wire, rng));
        let req = read_request(&mut r).unwrap().expect("valid request parses");
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.body, body);
        for (name, value) in &names {
            assert_eq!(req.header(name), Some(value.as_str()), "header {name} lost");
        }
    });
}

/// Arbitrary byte soup — printable garbage, raw bytes, truncations —
/// must produce a clean result, never a panic.
#[test]
fn prop_garbage_never_panics_the_parser() {
    prop::check_n("http-garbage", 96, |rng| {
        let len = rng.below(200);
        let data: Vec<u8> = (0..len)
            .map(|_| match rng.below(4) {
                // Bias toward protocol-ish bytes so parsing gets deep.
                0 => *rng.pick(&[b'\r', b'\n', b':', b' ', b'/']),
                1 => b"POST /v1 HTTP/1.1 Content-Length"[rng.below(32)],
                _ => rng.next_u64() as u8,
            })
            .collect();
        let cap = 1 + rng.below(9);
        let mut r = BufReader::with_capacity(cap, Shreds::new(data, rng));
        // Ok(Some), Ok(None) and Err are all acceptable; panics are not.
        let _ = read_request(&mut r);
    });
}

/// Truncated valid prefixes (connection died mid-request) never panic,
/// never invent body bytes, and only report a clean EOF (`Ok(None)`)
/// for the zero-byte cut — exercised at every cut point of a real
/// request.  (An EOF exactly at a header boundary parses as a
/// headerless request by design; a declared Content-Length must then
/// still be honored exactly or the parse must error.)
#[test]
fn truncated_requests_fail_cleanly_at_every_byte() {
    let full = b"POST /v1/generate HTTP/1.1\r\nContent-Type: application/json\r\n\
                 Content-Length: 14\r\n\r\n{\"prompt\":\"a\"}";
    for cut in 0..full.len() {
        let mut r = Cursor::new(&full[..cut]);
        match read_request(&mut r) {
            Ok(None) => assert_eq!(cut, 0, "only an immediate EOF is a clean None"),
            Ok(Some(req)) => {
                if let Some(cl) = req.header("content-length") {
                    assert_eq!(
                        req.body.len(),
                        cl.parse::<usize>().unwrap(),
                        "cut {cut}: body must match the declared Content-Length"
                    );
                } else {
                    assert!(req.body.is_empty(), "cut {cut}: no declared body, none read");
                }
            }
            Err(_) => {}
        }
    }
    let mut r = Cursor::new(&full[..]);
    let req = read_request(&mut r).unwrap().expect("the untruncated request parses");
    assert_eq!(req.body_str().unwrap(), "{\"prompt\":\"a\"}");
}

/// Heads and bodies exactly at their caps parse; content past the cap
/// errors — and the error fires without buffering the excess.
#[test]
fn caps_are_exact_boundaries() {
    // Head: request line + one fat header padded to land the head's
    // total byte count exactly at the cap.
    let head_with = |pad: usize| {
        let s = format!("GET / HTTP/1.1\r\nx-pad: {}\r\n\r\n", "a".repeat(pad));
        let n = s.len();
        (s, n)
    };
    let base = head_with(0).1; // head size with an empty pad
    let (at_cap, n) = head_with(MAX_HEAD_BYTES - base);
    assert_eq!(n, MAX_HEAD_BYTES);
    let req = read_request(&mut Cursor::new(at_cap.as_bytes())).unwrap();
    assert!(req.is_some(), "a head of exactly {MAX_HEAD_BYTES} bytes parses");

    // Header *content* crossing the cap must error (the size-capped
    // reader cuts the line and the next read observes the exhausted
    // budget) — and with real content beyond the cut, never misparse.
    let (over, n) = head_with(MAX_HEAD_BYTES);
    assert!(n > MAX_HEAD_BYTES);
    assert!(
        read_request(&mut Cursor::new(over.as_bytes())).is_err(),
        "header content past the head cap must error"
    );

    // Body: exactly MAX_BODY_BYTES parses; one more is rejected from
    // the Content-Length alone (no allocation of the oversized body).
    let mut ok = format!("POST / HTTP/1.1\r\nContent-Length: {MAX_BODY_BYTES}\r\n\r\n")
        .into_bytes();
    let head_len = ok.len();
    ok.resize(head_len + MAX_BODY_BYTES, b'x');
    let req = read_request(&mut Cursor::new(&ok[..])).unwrap().unwrap();
    assert_eq!(req.body.len(), MAX_BODY_BYTES);

    let over = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
    assert!(read_request(&mut Cursor::new(over.as_bytes())).is_err());

    // Nonsense Content-Length values error rather than default.
    for bad in ["-1", "1e3", "0x10", "huge", "18446744073709551616"] {
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {bad}\r\n\r\nxx");
        assert!(
            read_request(&mut Cursor::new(raw.as_bytes())).is_err(),
            "Content-Length {bad:?} must be rejected"
        );
    }
}

/// Malformed chunked encodings error cleanly in the client-side
/// decoder: bad size lines, missing CRLF terminators, oversized chunks,
/// truncation mid-chunk.
#[test]
fn malformed_chunked_encoding_errors_cleanly() {
    let decode = |wire: &[u8]| {
        let mut got: Vec<Vec<u8>> = Vec::new();
        let mut r = Cursor::new(wire.to_vec());
        read_chunks(&mut r, |c| {
            got.push(c.to_vec());
            Ok(())
        })
        .map(|()| got)
    };

    // A valid two-chunk stream decodes (the baseline).
    assert_eq!(
        decode(b"3\r\nabc\r\n2\r\nde\r\n0\r\n\r\n").unwrap(),
        vec![b"abc".to_vec(), b"de".to_vec()]
    );
    // Chunk-size extensions after ';' are tolerated.
    assert!(decode(b"3;ext=1\r\nabc\r\n0\r\n\r\n").is_ok());

    // Garbage size line.
    assert!(decode(b"zz\r\nabc\r\n0\r\n\r\n").is_err());
    // Negative / overflowing sizes.
    assert!(decode(b"-3\r\nabc\r\n0\r\n\r\n").is_err());
    assert!(decode(b"ffffffffffffffffff\r\nx\r\n0\r\n\r\n").is_err());
    // Size past the body cap is refused before reading the payload.
    let huge = format!("{:x}\r\n", MAX_BODY_BYTES + 1);
    assert!(decode(huge.as_bytes()).is_err());
    // Missing CRLF after the payload.
    assert!(decode(b"3\r\nabcXX0\r\n\r\n").is_err());
    // Truncation mid-chunk and mid-stream.
    assert!(decode(b"5\r\nab").is_err());
    assert!(decode(b"3\r\nabc\r\n").is_err(), "stream must end with a 0 chunk");
    // Empty wire: connection closed before any chunk.
    assert!(decode(b"").is_err());
}

/// Random chunk streams round-trip through write_chunk/read_chunks
/// whatever the fragment boundaries (split-invariance on the client
/// decode path).
#[test]
fn prop_chunk_roundtrip_is_split_invariant() {
    use hsm::server::http::{finish_chunks, write_chunk};
    prop::check_n("chunk-split-invariance", 48, |rng| {
        let n = rng.below(5);
        let chunks: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..1 + rng.below(40)).map(|_| rng.next_u64() as u8).collect())
            .collect();
        let mut wire = Vec::new();
        for c in &chunks {
            write_chunk(&mut wire, c).unwrap();
        }
        finish_chunks(&mut wire).unwrap();

        let cap = 1 + rng.below(9);
        let mut r = BufReader::with_capacity(cap, Shreds::new(wire, rng));
        let mut got: Vec<Vec<u8>> = Vec::new();
        read_chunks(&mut r, |c| {
            got.push(c.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, chunks);
    });
}
