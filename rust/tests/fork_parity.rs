//! Fork/restore parity: decoding from a restored [`SessionState`]
//! snapshot (or a forked session) must be **bit-identical** to cold
//! prefill, for every mixer kind — the invariant the serving stack's
//! prefix cache is built on (a cache hit can never change sampled
//! text).  Plus end-to-end: serving with the prefix cache enabled is
//! byte-identical to serving without it, and a dropped stream consumer
//! cancels its request instead of decoding unobserved.

use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::SampleCfg;
use hsm::infer::{weights, Decoder, Model, ModelWeights, SessionState};
use hsm::serve::{serve, FinishReason, Request, ServeCfg, StreamScheduler, TokenEvent};
use hsm::tokenizer::Tokenizer;
use hsm::util::prop;

const KINDS: &[&str] = &["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"];

fn layers_for(kind: &str) -> Vec<LayerInfo> {
    match kind {
        "ab" => vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 24 },
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![2, 4, 8, 16], ffn: 24 },
        ],
        _ => vec![
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![1], ffn: 24 },
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![3], ffn: 24 },
        ],
    }
}

fn model_for(kind: &str, ctx: usize, vocab: usize) -> Arc<Model> {
    let m = Manifest::synthetic(kind, layers_for(kind), 16, ctx, vocab, 2);
    let flat = weights::seeded_flat(&m, 31);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

fn bits(logits: &[f32]) -> Vec<u32> {
    logits.iter().map(|x| x.to_bits()).collect()
}

/// Step both decoders through `tokens`, asserting bit-identical logits
/// at every position.
fn assert_lockstep<A: Decoder, B: Decoder>(a: &mut A, b: &mut B, tokens: &[u32], what: &str) {
    for (i, &t) in tokens.iter().enumerate() {
        let want = bits(a.step(t).unwrap());
        let got = bits(b.step(t).unwrap());
        assert_eq!(want, got, "{what}: logits diverged at step {i}");
    }
}

/// Restored-snapshot decode is bit-identical to cold prefill, at every
/// split point of the prompt, for every mixer kind.
#[test]
fn restored_prefix_decode_is_bit_identical_for_every_mixer_kind() {
    let vocab = 300usize;
    let prompt: Vec<u32> = (0..20u32).map(|i| (i * 37 + 11) % vocab as u32).collect();
    let tail_probe: [u32; 6] = [5, 9, 3, 250, 1, 17];
    for kind in KINDS {
        let model = model_for(kind, 64, vocab);
        for split in [1usize, 5, 19, 20] {
            // Cold reference: one uninterrupted prefill.
            let mut cold = model.session();
            cold.prefill(&prompt).unwrap();

            // Snapshot at the split, restore into a fresh session,
            // prefill only the tail.
            let snap: SessionState = {
                let mut head = model.session();
                head.prefill(&prompt[..split]).unwrap();
                head.snapshot().unwrap()
            };
            assert_eq!(snap.position(), split);
            let mut warm = model.session_from(snap).unwrap();
            warm.prefill(&prompt[split..]).unwrap();
            assert_eq!(warm.position(), prompt.len());

            assert_lockstep(&mut cold, &mut warm, &tail_probe, &format!("{kind} split {split}"));
        }
    }
}

/// A forked session and its original decode independently and
/// identically: stepping the fork never perturbs the original.
#[test]
fn forked_sessions_are_independent_and_identical_for_every_mixer_kind() {
    let vocab = 300usize;
    let prompt: Vec<u32> = (0..12u32).map(|i| (i * 53 + 7) % vocab as u32).collect();
    for kind in KINDS {
        let model = model_for(kind, 64, vocab);
        let mut original = model.session();
        original.prefill(&prompt).unwrap();
        let mut fork = original.fork();

        // Both continuations from the same state must match bit-for-bit.
        let mut fork2 = original.fork();
        assert_lockstep(&mut fork, &mut fork2, &[4, 8, 15], &format!("{kind} fork-vs-fork"));

        // Diverge the (first) fork, then check the original against a
        // cold session that never saw any fork.
        fork.step(99).unwrap();
        let mut cold = model.session();
        cold.prefill(&prompt).unwrap();
        assert_lockstep(&mut cold, &mut original, &[16, 23, 42], &format!("{kind} original"));
    }
}

/// Property: for random prompts and random split points, restore +
/// tail-prefill is bit-identical to cold prefill (run on the hybrid
/// attention kind too, whose KV cache grows with the prefix).
#[test]
fn prop_random_split_restore_parity() {
    let vocab = 300u32;
    for kind in ["ab", "attn"] {
        let model = model_for(kind, 64, vocab as usize);
        prop::check_n(&format!("split-restore-{kind}"), 24, |rng| {
            let mut prompt = prop::arb_tokens(rng, vocab, 40);
            prompt.push(rng.next_u64() as u32 % vocab); // never empty
            let split = 1 + rng.below(prompt.len());

            let mut cold = model.session();
            cold.prefill(&prompt).unwrap();

            let mut head = model.session();
            head.prefill(&prompt[..split]).unwrap();
            let mut warm = model.session_from(head.snapshot().unwrap()).unwrap();
            warm.prefill(&prompt[split..]).unwrap();

            let t = rng.next_u64() as u32 % vocab;
            assert_eq!(
                bits(cold.step(t).unwrap()),
                bits(warm.step(t).unwrap()),
                "split {split} of {}",
                prompt.len()
            );
        });
    }
}

/// End-to-end: the scheduler with the prefix cache enabled produces
/// byte-identical completions to the scheduler without it, for every
/// mixer kind, on a workload full of shared prompt heads.
#[test]
fn cached_serving_is_byte_identical_to_cold_serving_for_every_mixer_kind() {
    let tok = tok();
    let prompts = [
        "Once upon a time",
        "Once upon a time there was",
        "Once upon a time there was a pumpkin",
        "Once upon a time",
        "Lily likes cats",
    ];
    let sample =
        SampleCfg { temperature: 0.8, top_k: 8, max_new_tokens: 8, seed: 11, stop_at_eot: true };
    for kind in KINDS {
        let model = model_for(kind, 64, tok.vocab_size());
        let cfg = |prefix_cache_size| ServeCfg {
            max_active: 2,
            threads: 2,
            quantum: 2,
            prefix_cache_size,
            sample: sample.clone(),
            ..Default::default()
        };
        let requests: Vec<Request> =
            prompts.iter().enumerate().map(|(i, p)| Request::new(i as u64, p)).collect();
        let cold = serve(&model, &tok, requests.clone(), &cfg(0)).unwrap();
        let warm = serve(&model, &tok, requests, &cfg(16)).unwrap();
        for (c, w) in cold.iter().zip(&warm) {
            assert_eq!(c.completion, w.completion, "{kind}: cache changed sampled text");
            assert_eq!(c.finish, w.finish, "{kind}: finish reason changed");
            assert_eq!(c.tokens_generated, w.tokens_generated);
            assert_eq!(c.cached_prefix_len, 0, "{kind}: disabled cache must stay cold");
        }
    }
}

/// The resident scheduler's cache accumulates across submissions and
/// reports hits; repeated shared-head prompts stream identical bytes.
#[test]
fn stream_scheduler_cache_hits_across_submissions() {
    let tok = tok();
    let model = model_for("ab", 64, tok.vocab_size());
    let cfg = ServeCfg {
        max_active: 1,
        threads: 1,
        quantum: 2,
        prefix_cache_size: 8,
        sample: SampleCfg { max_new_tokens: 6, seed: 3, ..Default::default() },
        ..Default::default()
    };
    let sched = StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap();
    let run = |sched: &StreamScheduler| {
        let stream = sched.submit(Request::new(7, "Once upon a time")).unwrap();
        let mut text = String::new();
        let done = stream.wait(|d| text.push_str(d)).expect("stream finishes");
        (text, done)
    };
    let (t1, d1) = run(&sched);
    let (t2, d2) = run(&sched);
    assert_eq!(t1, t2, "identical request id ⇒ identical bytes, cached or not");
    assert_eq!(d1.completion, d2.completion);
    assert_eq!(d1.cached_prefix_len, 0, "first submission is cold");
    let head_len = tok.encode("Once upon a time").len() - 1;
    assert_eq!(d2.cached_prefix_len, head_len, "second submission hits the whole head");
    let stats = sched.prefix_cache().unwrap().stats();
    assert!(stats.hits >= 1 && stats.insertions >= 1);
    sched.shutdown();
}

/// Liveness of cancel-on-disconnect end to end: with one session and a
/// huge token budget, an abandoned stream must not starve the next
/// request (the scheduler cancels it at the next sampled token).
#[test]
fn dropped_stream_frees_the_slot_for_the_next_request() {
    let tok = tok();
    let model = model_for("ab", 128, tok.vocab_size());
    let cfg = ServeCfg {
        max_active: 1,
        threads: 1,
        quantum: 1,
        prefix_cache_size: 0,
        sample: SampleCfg {
            max_new_tokens: 100,
            seed: 5,
            stop_at_eot: false,
            ..Default::default()
        },
        ..Default::default()
    };
    let sched = StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap();
    // Consume one token so the request is definitely decoding, then
    // vanish; the scheduler should notice at the next sampled token.
    let abandoned = sched.submit(Request::new(0, "Once upon a time")).unwrap();
    let first = abandoned.recv();
    assert!(matches!(first, Some(TokenEvent::Token { .. })));
    drop(abandoned);

    let survivor = sched.submit(Request::new(1, "Lily likes cats")).unwrap();
    let done = survivor.wait(|_| {}).expect("survivor finishes");
    assert_ne!(done.finish, FinishReason::Cancelled);
    assert!(done.tokens_generated > 0);
    sched.shutdown();
}
