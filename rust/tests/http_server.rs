//! Loopback integration tests for the HTTP serving front-end: real
//! sockets on 127.0.0.1 (port 0 → OS-assigned), the real accept loop,
//! concurrent streaming clients, and the cross-process determinism
//! guarantee — streamed bytes identical to in-process decoding.
//! PJRT-free (synthetic weights), so it runs under both feature sets.

use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::{self, SampleCfg};
use hsm::infer::{weights, Model, ModelWeights};
use hsm::serve::{FinishReason, ServeCfg, StreamScheduler};
use hsm::server::api::GenerateRequest;
use hsm::server::{client, HttpServer};
use hsm::tokenizer::Tokenizer;

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

fn model(vocab: usize, ctx: usize) -> Arc<Model> {
    let layers = vec![
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
    ];
    let m = Manifest::synthetic("hsm_ab", layers, 8, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 21);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

/// Server + everything needed to compute in-process references.
fn start(sample: SampleCfg, cfg: ServeCfg) -> (HttpServer, Tokenizer, Arc<Model>, String) {
    let tok = tok();
    let model = model(tok.vocab_size(), 64);
    let cfg = ServeCfg { sample, ..cfg };
    let sched =
        Arc::new(StreamScheduler::start(Arc::clone(&model), tok.clone(), cfg).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", sched).unwrap();
    let addr = server.local_addr().to_string();
    (server, tok, model, addr)
}

fn sample() -> SampleCfg {
    SampleCfg { temperature: 0.8, top_k: 8, max_new_tokens: 8, seed: 9, stop_at_eot: true }
}

fn reference(model: &Arc<Model>, tok: &Tokenizer, prompt: &str, id: u64) -> String {
    let solo = SampleCfg { seed: sample().seed ^ id, ..sample() };
    generation::generate(&mut model.session(), tok, prompt, &solo).unwrap().completion
}

#[test]
fn generate_endpoint_matches_in_process_decoding() {
    let (server, tok, model, addr) = start(sample(), ServeCfg::default());
    let mut req = GenerateRequest::new("Once upon a time");
    req.id = Some(3);
    let got = client::generate(&addr, &req).unwrap();
    assert_eq!(got.request_id, 3);
    assert_eq!(got.completion, reference(&model, &tok, "Once upon a time", 3));
    assert!(got.tokens_generated > 0);
    server.shutdown();
}

#[test]
fn stream_endpoint_deltas_concat_to_in_process_text() {
    let (server, tok, model, addr) = start(sample(), ServeCfg::default());
    let mut req = GenerateRequest::new("Lily likes cats");
    req.id = Some(5);
    let mut events = 0usize;
    let mut streamed = String::new();
    let completion = client::stream(&addr, &req, |token, delta| {
        if token.is_some() {
            events += 1;
        }
        streamed.push_str(delta);
    })
    .unwrap();
    let want = reference(&model, &tok, "Lily likes cats", 5);
    assert_eq!(streamed, want, "streamed deltas must reassemble the completion");
    assert_eq!(completion.completion, want);
    assert_eq!(events, completion.tokens_generated, "one Token event per sampled token");
    server.shutdown();
}

#[test]
fn concurrent_stream_clients_get_byte_identical_text() {
    let (server, tok, model, addr) = start(sample(), ServeCfg::default());
    let prompts = ["Once upon a time", "Lily likes cats", "Jack went to", "Once upon a time"];
    let want: Vec<String> =
        prompts.iter().enumerate().map(|(i, p)| reference(&model, &tok, p, i as u64)).collect();

    std::thread::scope(|s| {
        let handles: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, prompt)| {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut req = GenerateRequest::new(prompt);
                    req.id = Some(i as u64);
                    let mut streamed = String::new();
                    let completion =
                        client::stream(&addr, &req, |_, delta| streamed.push_str(delta)).unwrap();
                    (streamed, completion)
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let (streamed, completion) = h.join().unwrap();
            assert_eq!(streamed, want[i], "concurrent client {i} diverged");
            assert_eq!(completion.completion, want[i]);
        }
    });
    server.shutdown();
}

#[test]
fn server_assigns_distinct_ids_when_client_omits_them() {
    let (server, _tok, _model, addr) = start(sample(), ServeCfg::default());
    let a = client::generate(&addr, &GenerateRequest::new("Once upon a time")).unwrap();
    let b = client::generate(&addr, &GenerateRequest::new("Once upon a time")).unwrap();
    assert_ne!(a.request_id, b.request_id);
    server.shutdown();
}

#[test]
fn bad_requests_and_routes_get_http_errors() {
    let (server, _tok, _model, addr) = start(sample(), ServeCfg::default());

    // Malformed JSON → 400 from /v1/generate.
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(
            s,
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\
             Connection: close\r\n\r\nnot json!"
        )
        .unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 400 "), "got: {resp}");
    }

    // Unknown route → 404; wrong method → 405.
    {
        use std::io::{Read, Write};
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(s, "GET /nope HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 404 "), "got: {resp}");

        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write!(s, "GET /v1/stream HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 405 "), "got: {resp}");
    }

    // A rejected prompt still delivers its completion document (now
    // graded 400 on the wire — pinned in tests/slo.rs); the client
    // parses it whatever the status.
    let rejected = client::generate(&addr, &GenerateRequest::new("")).unwrap();
    assert!(matches!(rejected.finish, FinishReason::Rejected(_)));
    assert_eq!(rejected.tokens_generated, 0);

    server.shutdown();
}

#[test]
fn healthz_reports_the_model() {
    let (server, tok, model, addr) = start(sample(), ServeCfg::default());
    let v = client::health(&addr).unwrap();
    assert_eq!(v.get("status").as_str(), Some("ok"));
    assert_eq!(v.get("vocab").as_usize(), Some(tok.vocab_size()));
    // Deployment facts: precision, dispatched kernel tier, and the
    // resident weight footprint of the serving model.
    let info = v.get("model");
    assert_eq!(info.get("precision").as_str(), Some(model.precision().label()));
    assert_eq!(info.get("kernel_backend").as_str(), Some(hsm::infer::tensor::kernel_backend()));
    assert_eq!(info.get("resident_weight_bytes").as_usize(), Some(model.resident_weight_bytes()));
    server.shutdown();
}

/// One TCP connection, two requests: an explicit `Connection:
/// keep-alive` gets a keep-alive response and the socket stays usable
/// for the next request (the pre-keep-alive close framing would EOF).
#[test]
fn keep_alive_serves_two_requests_on_one_connection() {
    use std::io::{BufRead, BufReader, Read, Write};
    let (server, tok, model, addr) = start(sample(), ServeCfg::default());

    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    // Read one Content-Length-framed response, returning (head, body).
    let read_response = |r: &mut BufReader<std::net::TcpStream>| -> (String, String) {
        let mut head = String::new();
        loop {
            let mut line = String::new();
            assert_ne!(r.read_line(&mut line).unwrap(), 0, "connection closed early");
            if line.trim_end_matches(['\r', '\n']).is_empty() {
                break;
            }
            head.push_str(&line);
        }
        let len: usize = head
            .lines()
            .find_map(|l| {
                l.to_ascii_lowercase().strip_prefix("content-length:").map(str::to_string)
            })
            .and_then(|v| v.trim().parse().ok())
            .expect("keep-alive responses must be length-framed");
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).unwrap();
        (head, String::from_utf8(body).unwrap())
    };

    for id in [11u64, 12] {
        let body = format!("{{\"prompt\": \"Once upon a time\", \"id\": {id}}}");
        write!(
            w,
            "POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        w.flush().unwrap();
        let (head, body) = read_response(&mut r);
        assert!(head.starts_with("HTTP/1.1 200 OK"), "request {id}: {head}");
        assert!(
            head.to_ascii_lowercase().contains("connection: keep-alive"),
            "request {id} must be answered keep-alive: {head}"
        );
        let got = hsm::server::api::completion_from_json(
            &hsm::util::json::parse(&body).unwrap(),
        )
        .unwrap();
        assert_eq!(got.request_id, id);
        assert_eq!(got.completion, reference(&model, &tok, "Once upon a time", id));
    }
    server.shutdown();
}

/// The keep-alive `Client` survives server-side idle closes by
/// transparently reconnecting, and round-trips both endpoints.
#[test]
fn keep_alive_client_reuses_and_reconnects() {
    let (server, tok, model, addr) = start(sample(), ServeCfg::default());
    let mut c = client::Client::new(&addr);
    for id in [21u64, 22, 23] {
        let mut req = GenerateRequest::new("Lily likes cats");
        req.id = Some(id);
        let got = c.generate(&req).unwrap();
        assert_eq!(got.completion, reference(&model, &tok, "Lily likes cats", id));
    }
    let v = c.health().unwrap();
    assert_eq!(v.get("status").as_str(), Some("ok"));
    // Fully tear the server down (dropping it releases the listener) so
    // the reconnect path sees connection-refused, not a dead backlog.
    server.shutdown();
    drop(server);

    // Dead server: the client reports an error instead of hanging.
    assert!(c.generate(&GenerateRequest::new("hi")).is_err());
}

/// Shared prompt heads across HTTP requests hit the scheduler's prefix
/// cache; /healthz exposes the counters and responses carry
/// `cached_prefix_len`.
#[test]
fn healthz_reports_prefix_cache_hits_across_requests() {
    let cfg = ServeCfg { max_active: 1, threads: 1, ..Default::default() };
    let (server, tok, _model, addr) = start(sample(), cfg);
    let mut req = GenerateRequest::new("Once upon a time");
    req.id = Some(1);
    let first = client::generate(&addr, &req).unwrap();
    assert_eq!(first.cached_prefix_len, 0, "first request is a cold prefill");
    req.id = Some(2);
    let second = client::generate(&addr, &req).unwrap();
    let head_len = tok.encode("Once upon a time").len() - 1;
    assert_eq!(second.cached_prefix_len, head_len, "second request hits the cached head");

    let v = client::health(&addr).unwrap();
    let cache = v.get("prefix_cache");
    assert!(cache.get("hits").as_usize().unwrap_or(0) >= 1, "healthz must report hits");
    assert!(cache.get("capacity").as_usize().unwrap_or(0) > 0);
    server.shutdown();
}

#[test]
fn zero_queue_wait_times_out_over_http() {
    let cfg = ServeCfg {
        max_active: 1,
        threads: 1,
        max_queue_wait: Some(std::time::Duration::ZERO),
        ..Default::default()
    };
    let (server, _tok, _model, addr) = start(sample(), cfg);
    let got = client::generate(&addr, &GenerateRequest::new("Once upon a time")).unwrap();
    assert_eq!(got.finish, FinishReason::TimedOut);
    assert_eq!(got.tokens_generated, 0);
    server.shutdown();
}

#[test]
fn shutdown_is_graceful_and_final() {
    let (server, tok, model, addr) = start(sample(), ServeCfg::default());
    // A request completes fine before shutdown...
    let mut req = GenerateRequest::new("Jack went to");
    req.id = Some(1);
    let before = client::generate(&addr, &req).unwrap();
    assert_eq!(before.completion, reference(&model, &tok, "Jack went to", 1));
    // ...then shutdown is idempotent and the port stops answering.
    server.shutdown();
    server.shutdown();
    drop(server);
    assert!(client::generate(&addr, &req).is_err(), "server must be gone after shutdown");
}
