//! Loopback SLO-enforcement tests: queue-depth backpressure answered
//! as HTTP 429 + `Retry-After`, per-user quotas under concurrent
//! clients, completion-status mapping (400 rejected / 503 timed out),
//! and the load generator's byte-deterministic schedules.  Real
//! sockets, synthetic weights — PJRT-free, runs under both feature
//! sets.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::SampleCfg;
use hsm::infer::{weights, Model, ModelWeights};
use hsm::loadgen;
use hsm::serve::{FinishReason, QuotaCfg, ServeCfg, StreamScheduler};
use hsm::server::api::GenerateRequest;
use hsm::server::{client, HttpServer};
use hsm::tokenizer::Tokenizer;

fn tok() -> Tokenizer {
    let text = hsm::corpus::generate(9, 80);
    hsm::tokenizer::trainer::train(&text, 300).unwrap()
}

fn model(vocab: usize, ctx: usize) -> Arc<Model> {
    let layers = vec![
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
    ];
    let m = Manifest::synthetic("hsm_ab", layers, 8, ctx, vocab, 1);
    let flat = weights::seeded_flat(&m, 21);
    Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
}

fn start(sample: SampleCfg, cfg: ServeCfg, ctx: usize) -> (HttpServer, String) {
    let tok = tok();
    let model = model(tok.vocab_size(), ctx);
    let cfg = ServeCfg { sample, ..cfg };
    let sched = Arc::new(StreamScheduler::start(model, tok, cfg).unwrap());
    let server = HttpServer::bind("127.0.0.1:0", sched).unwrap();
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn sample() -> SampleCfg {
    SampleCfg { temperature: 0.8, top_k: 8, max_new_tokens: 8, seed: 9, stop_at_eot: true }
}

/// Raw response text for one `Connection: close` POST — for asserting
/// on the literal status line and headers.
fn raw_post(addr: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    resp
}

/// Saturate a single-session server (one giant request holds the
/// session, a second fills the depth-1 queue), then probe: the third
/// submission must be refused as `429 Too Many Requests` with a
/// parseable `Retry-After`, on both endpoints.
#[test]
fn saturated_server_answers_429_with_retry_after() {
    let cfg = ServeCfg {
        max_active: 1,
        threads: 1,
        quantum: 1,
        max_queue_depth: 1,
        ..Default::default()
    };
    let sample = SampleCfg {
        temperature: 0.8,
        top_k: 8,
        max_new_tokens: 4000,
        seed: 9,
        stop_at_eot: false,
    };
    let (server, addr) = start(sample, cfg, 4096);

    // A metrics line must appear before the deadline, or the test fails
    // with the last scrape in the message.
    let wait_for = |line: &str| {
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            let text = client::metrics_text(&addr).unwrap();
            if text.lines().any(|l| l == line) {
                return;
            }
            assert!(std::time::Instant::now() < deadline, "never saw {line:?}:\n{text}");
            std::thread::sleep(Duration::from_millis(10));
        }
    };

    // Hold the only session with a long-running stream, and wait until
    // it has actually been admitted (left the queue) before parking a
    // second request — otherwise the depth-1 queue could refuse it.
    let addr2 = addr.clone();
    let holder = std::thread::spawn(move || {
        let mut req = GenerateRequest::new("Once upon a time");
        req.id = Some(1);
        client::stream(&addr2, &req, |_, _| {})
    });
    wait_for("hsm_requests_admitted_total 1");
    // Second request parks in the queue (fire-and-forget thread).
    let addr3 = addr.clone();
    let parked = std::thread::spawn(move || {
        let mut req = GenerateRequest::new("Lily likes cats");
        req.id = Some(2);
        let _ = client::try_generate(&addr3, &req);
    });
    wait_for("hsm_queue_depth 1");

    // /v1/generate: refused with 429 + Retry-After.
    match client::try_generate(&addr, &GenerateRequest::new("Jack went to")).unwrap() {
        client::ApiOutcome::Throttled { retry_after, message } => {
            assert!(retry_after >= Duration::from_secs(1), "hint was {retry_after:?}");
            assert!(message.contains("queue"), "message: {message}");
        }
        other => panic!("expected a throttled outcome, got {other:?}"),
    }
    // Literal wire format, on the streaming endpoint too.
    let resp = raw_post(&addr, "/v1/stream", "{\"prompt\": \"Jack went to\"}");
    assert!(resp.starts_with("HTTP/1.1 429 Too Many Requests"), "got: {resp}");
    let retry: u64 = resp
        .lines()
        .find_map(|l| l.to_ascii_lowercase().strip_prefix("retry-after:").map(str::to_string))
        .expect("429 must carry Retry-After")
        .trim()
        .parse()
        .expect("Retry-After must be integral seconds");
    assert!(retry >= 1);
    assert!(resp.contains("\"cause\":\"queue_full\""), "got: {resp}");

    // Throttle counters landed on /metrics.
    let text = client::metrics_text(&addr).unwrap();
    assert!(
        text.lines().any(|l| l.starts_with("hsm_requests_throttled_total{cause=\"queue_full\"}")
            && !l.ends_with(" 0")),
        "throttles must be counted:\n{text}"
    );

    server.shutdown();
    let _ = holder.join().unwrap(); // stream cut (or cancelled) by shutdown
    parked.join().unwrap();
}

/// Per-user quotas under concurrent clients: with a 1-request window,
/// each user gets exactly one admission per window whatever the
/// interleaving — and other users are unaffected.
#[test]
fn per_user_quota_enforced_across_concurrent_clients() {
    let cfg = ServeCfg {
        max_active: 2,
        threads: 2,
        quota: Some(QuotaCfg {
            max_requests: 1,
            max_tokens: 0,
            window: Duration::from_secs(3600),
        }),
        ..Default::default()
    };
    let (server, addr) = start(sample(), cfg, 64);

    let fire = |user: &str, id: u64| {
        let mut req = GenerateRequest::new("Once upon a time");
        req.id = Some(id);
        req.user = Some(user.to_string());
        client::try_generate(&addr, &req).unwrap()
    };
    let outcomes: Vec<(String, bool)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..6u64)
            .map(|i| {
                let user = format!("user-{}", i % 3);
                let fire = &fire;
                s.spawn(move || {
                    let done = matches!(fire(&user, i), client::ApiOutcome::Done(_));
                    (user, done)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for u in 0..3 {
        let user = format!("user-{u}");
        let admitted = outcomes.iter().filter(|(w, done)| *w == user && *done).count();
        let throttled = outcomes.iter().filter(|(w, done)| *w == user && !*done).count();
        assert_eq!(admitted, 1, "{user}: exactly one admission per window");
        assert_eq!(throttled, 1, "{user}: the second request must be throttled");
    }
    // A fresh user still gets in: quotas are per-user, not global.
    assert!(matches!(fire("fresh", 99), client::ApiOutcome::Done(_)));

    let text = client::metrics_text(&addr).unwrap();
    assert!(
        text.lines().any(|l| l == "hsm_requests_throttled_total{cause=\"quota\"} 3"),
        "3 quota refusals must be counted:\n{text}"
    );
    server.shutdown();
}

/// Completion statuses are graded: a client error (empty prompt) is
/// 400 with the rejected completion as body; a queue-deadline expiry
/// is 503 (+ Retry-After) with the timed_out completion as body.  Both
/// bodies still parse as completions through the client.
#[test]
fn rejected_is_400_and_timed_out_is_503_on_the_wire() {
    let cfg = ServeCfg { max_active: 1, threads: 1, ..Default::default() };
    let (server, addr) = start(sample(), cfg, 64);
    let resp = raw_post(&addr, "/v1/generate", "{\"prompt\": \"\"}");
    assert!(resp.starts_with("HTTP/1.1 400 Bad Request"), "got: {resp}");
    assert!(resp.contains("\"finish\":\"rejected\""), "got: {resp}");
    let c = client::generate(&addr, &GenerateRequest::new("")).unwrap();
    assert!(matches!(c.finish, FinishReason::Rejected(_)));
    server.shutdown();

    let cfg = ServeCfg {
        max_active: 1,
        threads: 1,
        max_queue_wait: Some(Duration::ZERO),
        ..Default::default()
    };
    let (server, addr) = start(sample(), cfg, 64);
    let resp = raw_post(&addr, "/v1/generate", "{\"prompt\": \"Once upon a time\"}");
    assert!(resp.starts_with("HTTP/1.1 503 Service Unavailable"), "got: {resp}");
    assert!(resp.contains("\"finish\":\"timed_out\""), "got: {resp}");
    assert!(
        resp.lines().any(|l| l.to_ascii_lowercase().starts_with("retry-after:")),
        "503 should hint a retry: {resp}"
    );
    let c = client::generate(&addr, &GenerateRequest::new("Once upon a time")).unwrap();
    assert_eq!(c.finish, FinishReason::TimedOut);
    server.shutdown();
}

/// With backpressure and quotas off (the defaults), the decoded bytes
/// are identical to the pre-harness path: the `user` field and the SLO
/// plumbing must not perturb sampling.
#[test]
fn slo_knobs_off_leave_decoded_bytes_identical() {
    let (server, addr) = start(sample(), ServeCfg::default(), 64);
    let mut plain = GenerateRequest::new("Once upon a time");
    plain.id = Some(7);
    let baseline = client::generate(&addr, &plain).unwrap();

    let mut tagged = GenerateRequest::new("Once upon a time");
    tagged.id = Some(7);
    tagged.user = Some("alice".into());
    tagged.deadline_ms = Some(60_000);
    let got = client::generate(&addr, &tagged).unwrap();
    assert_eq!(got.completion, baseline.completion, "user/deadline fields must not move bytes");
    server.shutdown();
}

/// Property: the load generator's schedule is a pure function of
/// `(scenario, seed)` — byte-identical on regeneration, distinct
/// across seeds and scenarios — so `BENCH_load.json`'s
/// `schedule_digest` proves two runs offered the same traffic.
#[test]
fn loadgen_schedules_are_byte_deterministic() {
    let scenarios = loadgen::builtin_scenarios(32, 25.0);
    assert_eq!(scenarios.len(), 3, "the built-in grid covers three scenarios");
    let mut digests = Vec::new();
    for seed in [0u64, 1, 7, 42, 0xdead_beef] {
        for cfg in &scenarios {
            let a = loadgen::schedule(cfg, seed);
            let b = loadgen::schedule(cfg, seed);
            assert_eq!(a, b, "{}/{seed}: schedule must be reproducible", cfg.name);
            digests.push(loadgen::schedule_digest(&a));
        }
    }
    let n = digests.len();
    digests.sort_unstable();
    digests.dedup();
    assert_eq!(digests.len(), n, "every (scenario, seed) pair must give distinct traffic");
}

/// End-to-end smoke of the harness against a self-hosted target with
/// backpressure on: every request is accounted for, and the server
/// metrics the report is built from move.
#[test]
fn loadgen_runs_against_a_selfhosted_target() {
    let hosted = loadgen::SelfHosted::start(ServeCfg {
        max_active: 2,
        threads: 2,
        max_queue_depth: 2,
        sample: SampleCfg { max_new_tokens: 4, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    let cfg = loadgen::ScenarioCfg {
        name: "smoke".into(),
        requests: 8,
        rate_per_s: 200.0,
        zipf_s: 1.1,
        pool_size: 4,
        users: 2,
        min_new_tokens: 2,
        max_new_tokens: 4,
        stream: false,
    };
    let o = loadgen::run_scenario(hosted.addr(), &cfg, 42).unwrap();
    assert_eq!(o.sent, 8);
    assert_eq!(
        o.completed + o.throttled + o.rejected + o.timed_out + o.errors,
        o.sent,
        "every request must be classified: {o:?}"
    );
    assert!(o.completed >= 1, "something must get through: {o:?}");
    assert!(o.tokens_generated > 0, "completions generate tokens: {o:?}");
    assert_eq!(o.digest, loadgen::schedule_digest(&loadgen::schedule(&cfg, 42)));
    hosted.shutdown();
}
