//! Quantization tolerance harness: pins how far int8 and int4 decoding
//! may drift from f32 on the *same* checkpoint, for every mixer kind.
//!
//! Three pinned metrics, measured over a teacher-forced greedy decode
//! (both models consume the f32 model's greedy continuation, so every
//! position compares the same context):
//!
//! * **logit max-abs-delta**, relative to the f32 logit scale — the
//!   rawest view of accumulated quantization error through the stack;
//! * **perplexity ratio** `exp(|nll_int8 − nll_f32|)` of the decoded
//!   continuation — the aggregate quality cost;
//! * **greedy agreement rate** — how often int8 argmax equals f32
//!   argmax, the number that predicts `shallow-q` draft acceptance.
//!
//! The pins are deliberately several× looser than the error a healthy
//! per-row-scale int8 path produces (~1–5% relative), but orders of
//! magnitude tighter than any real kernel/quantizer regression — and a
//! companion test corrupts the quantized weights to prove the harness
//! actually trips.  All precisions share one `seeded_flat` checkpoint,
//! so a failure here is quantization drift, never weight drift.
//!
//! The int4 tier gets its own (much looser) pins: at d = 16 every
//! weight row is a single 32-element scale group, so per-weight error
//! runs ~7% and compounds through two layers — int4 on a tiny random
//! checkpoint is expected to disagree with f32 often, and the pins
//! only guard against the step change a kernel or group-scale bug
//! produces (the int4 trip test corrupts group scales to prove it).

use std::sync::Arc;

use hsm::config::{LayerInfo, Manifest};
use hsm::generation::argmax;
use hsm::infer::{weights, DecodeSession, Model, ModelWeights, Precision, Quant4Weights};

const KINDS: &[&str] = &["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"];

fn layers_for(kind: &str) -> Vec<LayerInfo> {
    match kind {
        "ab" => vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 24 },
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![2, 4, 8, 16], ffn: 24 },
        ],
        _ => vec![
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![1], ffn: 24 },
            LayerInfo { kind: kind.into(), heads: 2, shifts: vec![3], ffn: 24 },
        ],
    }
}

fn manifest_for(kind: &str) -> Manifest {
    Manifest::synthetic(kind, layers_for(kind), 16, 96, 300, 1)
}

/// f32 and int8 models over the identical flat checkpoint.
fn pair_for(kind: &str) -> (Arc<Model>, Arc<Model>) {
    pair_at(kind, Precision::Int8)
}

/// f32 and int4 models over the identical flat checkpoint.
fn pair4_for(kind: &str) -> (Arc<Model>, Arc<Model>) {
    pair_at(kind, Precision::Int4)
}

fn pair_at(kind: &str, precision: Precision) -> (Arc<Model>, Arc<Model>) {
    let m = manifest_for(kind);
    let flat = weights::seeded_flat(&m, 31);
    let f = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap();
    let w = ModelWeights::from_flat(&m, &flat).unwrap();
    let q = Model::shared_with_precision(m, w, precision).unwrap();
    (f, q)
}

/// Negative log-likelihood of `target` under `logits` (f64 log-softmax:
/// the metric must not add its own rounding story).
fn nll(logits: &[f32], target: u32) -> f64 {
    let mx = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|&v| f64::from(v - mx).exp()).sum::<f64>().ln() + f64::from(mx);
    lse - f64::from(logits[target as usize])
}

struct Tolerance {
    /// max over positions of max-abs logit delta.
    max_logit_delta: f32,
    /// max over positions of max-abs f32 logit (the scale reference).
    logit_scale: f32,
    /// `exp(|mean nll_int8 − mean nll_f32|)` on the decoded tokens.
    ppl_ratio: f64,
    /// Fraction of positions where both argmaxes agree.
    agreement: f64,
}

/// Teacher-forced comparison: both sessions consume the f32 model's
/// greedy continuation (so int8 is always judged in the same context),
/// accumulating the three pinned metrics over `steps` positions.
fn measure(f32_model: &Arc<Model>, q_model: &Arc<Model>, steps: usize) -> Tolerance {
    let mut a = DecodeSession::new(&f32_model.manifest, None).unwrap();
    let mut b = DecodeSession::new(&q_model.manifest, None).unwrap();
    let mut token = 7u32;
    let (mut max_delta, mut scale) = (0.0f32, 0.0f32);
    let (mut nll_f, mut nll_q) = (0.0f64, 0.0f64);
    let mut agree = 0usize;
    for _ in 0..steps {
        let lf = a.step(f32_model, token).unwrap().to_vec();
        let lq = b.step(q_model, token).unwrap();
        let next = argmax(&lf);
        if argmax(lq) == next {
            agree += 1;
        }
        for (&f, &q) in lf.iter().zip(lq.iter()) {
            max_delta = max_delta.max((f - q).abs());
            scale = scale.max(f.abs());
        }
        nll_f += nll(&lf, next);
        nll_q += nll(lq, next);
        token = next;
    }
    let n = steps as f64;
    Tolerance {
        max_logit_delta: max_delta,
        logit_scale: scale,
        ppl_ratio: ((nll_q / n) - (nll_f / n)).abs().exp(),
        agreement: agree as f64 / n,
    }
}

const STEPS: usize = 48;
/// Relative logit error pin (healthy: ~0.01–0.05).
const MAX_REL_LOGIT_DELTA: f32 = 0.15;
/// Perplexity-ratio pin (healthy: < 1.05).
const MAX_PPL_RATIO: f64 = 1.30;
/// Greedy agreement pin (healthy: > 0.8; chance: 1/300).
const MIN_AGREEMENT: f64 = 0.5;

#[test]
fn quantized_decoding_stays_within_tolerance_for_every_mixer_kind() {
    for kind in KINDS {
        let (f, q) = pair_for(kind);
        let t = measure(&f, &q, STEPS);
        assert!(
            t.max_logit_delta.is_finite() && t.logit_scale.is_finite() && t.logit_scale > 0.0,
            "{kind}: degenerate logits (delta {} scale {})",
            t.max_logit_delta,
            t.logit_scale
        );
        let rel = t.max_logit_delta / t.logit_scale.max(1.0);
        assert!(
            rel <= MAX_REL_LOGIT_DELTA,
            "{kind}: int8 logit drift {rel:.4} exceeds {MAX_REL_LOGIT_DELTA} \
             (max delta {} at scale {})",
            t.max_logit_delta,
            t.logit_scale
        );
        assert!(
            t.ppl_ratio <= MAX_PPL_RATIO,
            "{kind}: perplexity ratio {:.4} exceeds {MAX_PPL_RATIO}",
            t.ppl_ratio
        );
        assert!(
            t.agreement >= MIN_AGREEMENT,
            "{kind}: greedy agreement {:.3} below {MIN_AGREEMENT}",
            t.agreement
        );
    }
}

/// Int8 decoding must be *exactly* reproducible: tolerance is about
/// f32↔int8 distance, never about run-to-run noise — a second measure
/// over fresh sessions yields bit-identical metrics.
#[test]
fn tolerance_metrics_are_deterministic() {
    let (f, q) = pair_for("ab");
    let x = measure(&f, &q, STEPS);
    let y = measure(&f, &q, STEPS);
    assert_eq!(x.max_logit_delta.to_bits(), y.max_logit_delta.to_bits());
    assert_eq!(x.logit_scale.to_bits(), y.logit_scale.to_bits());
    assert_eq!(x.ppl_ratio.to_bits(), y.ppl_ratio.to_bits());
    assert_eq!(x.agreement.to_bits(), y.agreement.to_bits());
}

/// The harness must actually trip on a regression: decode against a
/// deliberately corrupted quantized model (a 3× embedding blow-up — the
/// kind of scale bug a broken quantizer produces) and require the logit
/// pin to fire.  If loosening the pins ever silences this test, they no
/// longer guard anything.
#[test]
fn tolerance_harness_detects_a_corrupted_quantization() {
    let m = manifest_for("ab");
    let flat = weights::seeded_flat(&m, 31);
    let f = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap();
    let mut w = ModelWeights::from_flat(&m, &flat).unwrap();
    for v in w.tok_emb.iter_mut() {
        *v *= 3.0;
    }
    let bad = Model::shared_with_precision(m, w, Precision::Int8).unwrap();
    let t = measure(&f, &bad, STEPS);
    let rel = t.max_logit_delta / t.logit_scale.max(1.0);
    assert!(
        rel > MAX_REL_LOGIT_DELTA,
        "corrupted weights must exceed the logit pin (got {rel:.4})"
    );
}

// ---------------------------------------------------------------------------
// Int4 tier
// ---------------------------------------------------------------------------

/// Relative logit error pin for int4 (healthy on this checkpoint:
/// ~0.1–0.4 — one scale group per row at d = 16 means ~7% per-weight
/// error before compounding).
const MAX_REL_LOGIT_DELTA_I4: f32 = 0.75;
/// Perplexity-ratio pin for int4 (healthy: < 2).
const MAX_PPL_RATIO_I4: f64 = 4.0;
/// Greedy agreement pin for int4 (healthy: > 0.3; chance: 1/300).
const MIN_AGREEMENT_I4: f64 = 0.10;

#[test]
fn int4_decoding_stays_within_tolerance_for_every_mixer_kind() {
    for kind in KINDS {
        let (f, q) = pair4_for(kind);
        let t = measure(&f, &q, STEPS);
        assert!(
            t.max_logit_delta.is_finite() && t.logit_scale.is_finite() && t.logit_scale > 0.0,
            "{kind}: degenerate int4 logits (delta {} scale {})",
            t.max_logit_delta,
            t.logit_scale
        );
        let rel = t.max_logit_delta / t.logit_scale.max(1.0);
        assert!(
            rel <= MAX_REL_LOGIT_DELTA_I4,
            "{kind}: int4 logit drift {rel:.4} exceeds {MAX_REL_LOGIT_DELTA_I4} \
             (max delta {} at scale {})",
            t.max_logit_delta,
            t.logit_scale
        );
        assert!(
            t.ppl_ratio <= MAX_PPL_RATIO_I4,
            "{kind}: int4 perplexity ratio {:.4} exceeds {MAX_PPL_RATIO_I4}",
            t.ppl_ratio
        );
        assert!(
            t.agreement >= MIN_AGREEMENT_I4,
            "{kind}: int4 greedy agreement {:.3} below {MIN_AGREEMENT_I4}",
            t.agreement
        );
    }
}

/// Int4 decoding must be exactly reproducible, same as int8: the loose
/// pins bound f32↔int4 distance, never run-to-run noise.
#[test]
fn int4_tolerance_metrics_are_deterministic() {
    let (f, q) = pair4_for("ab");
    let x = measure(&f, &q, STEPS);
    let y = measure(&f, &q, STEPS);
    assert_eq!(x.max_logit_delta.to_bits(), y.max_logit_delta.to_bits());
    assert_eq!(x.logit_scale.to_bits(), y.logit_scale.to_bits());
    assert_eq!(x.ppl_ratio.to_bits(), y.ppl_ratio.to_bits());
    assert_eq!(x.agreement.to_bits(), y.agreement.to_bits());
}

/// The int4 pins must actually trip on a group-scale regression: blow
/// up the already-quantized embedding group scales 4× (the int4
/// analogue of a broken group quantizer — the corruption happens
/// *after* quantization, so only the dequantization story changes) and
/// require the int4 logit pin to fire.
#[test]
fn int4_tolerance_harness_detects_corrupted_group_scales() {
    let m = manifest_for("ab");
    let flat = weights::seeded_flat(&m, 31);
    let f = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap();
    let w = ModelWeights::from_flat(&m, &flat).unwrap();
    let mut q4 = Quant4Weights::from_weights(&m, &w);
    for s in q4.tok_emb.scale.iter_mut() {
        *s *= 4.0;
    }
    let bad = Model::from_quant4(m, q4).unwrap();
    let t = measure(&f, &bad, STEPS);
    let rel = t.max_logit_delta / t.logit_scale.max(1.0);
    assert!(
        rel > MAX_REL_LOGIT_DELTA_I4,
        "corrupted group scales must exceed the int4 logit pin (got {rel:.4})"
    );
}
