//! Cross-module integration tests that need no artifacts: the full
//! corpus → tokenizer → dataset → coordinator → reports pipeline over the
//! mock engine, plus checkpoint interop and property-based invariants.



use hsm::checkpoint::Checkpoint;
use hsm::config::{Manifest, TABLE1_VARIANTS, VARIANTS};
use hsm::coordinator::{test_manifest, MockEngine, Trainer, TrainerOptions};
use hsm::corpus;
use hsm::data::Dataset;
use hsm::generation::{self, SampleCfg};
use hsm::runtime::StepEngine;
use hsm::tokenizer::{trainer as tok_trainer, Tokenizer};
use hsm::util::prop;
use hsm::util::rng::Rng;

fn pipeline(ctx: usize, vocab: usize) -> (Tokenizer, Dataset, Dataset) {
    let text = corpus::generate(21, 150);
    let tok = tok_trainer::train(&text, vocab).unwrap();
    let (tr, va, _) = Dataset::build(&text, &tok, ctx, 0.9, 5).unwrap();
    (tok, tr, va)
}

#[test]
fn corpus_to_dataset_to_training_pipeline() {
    let (_tok, tr, va) = pipeline(48, 400);
    let mut eng = MockEngine::new(test_manifest("hsm_ab", 4, 48, 400), 1.9, 0.02);
    let mut t = Trainer::new(&mut eng, TrainerOptions { epochs: 2, ..Default::default() });
    let out = t.run(&tr, &va).unwrap();
    assert_eq!(out.epochs.len(), 2);
    assert!(out.final_val_loss() < (400f32).ln());
}

#[test]
fn checkpoint_roundtrip_through_engine() {
    let m = test_manifest("hsm_ab", 4, 32, 300);
    let mut eng = MockEngine::new(m.clone(), 1.8, 0.01);
    eng.init(0).unwrap();
    let params = eng.get_params().unwrap();
    let (mm, vv) = eng.get_state().unwrap();
    let ck = Checkpoint::from_training(&m, 10, params.clone(), mm, vv);
    let path = std::env::temp_dir().join("hsm_integ_ckpt.bin");
    ck.save(&path).unwrap();
    let re = Checkpoint::load(&path).unwrap();
    let mut eng2 = MockEngine::new(m.clone(), 1.8, 0.01);
    eng2.set_params(re.group("param")).unwrap();
    assert_eq!(eng2.get_params().unwrap(), params);
    assert_eq!(re.step(), 10);
    // The embedded manifest snapshot round-trips the model shape.
    let m2 = re.manifest().unwrap().expect("manifest snapshot");
    assert_eq!(m2.variant, m.variant);
    assert_eq!(m2.params, m.params);
}

#[test]
fn generation_over_trained_mock_is_deterministic_greedy() {
    let (tok, _, _) = pipeline(32, 300);
    let mut eng = MockEngine::new(test_manifest("gpt", 4, 32, tok.vocab_size()), 1.7, 0.02);
    eng.init(0).unwrap();
    let cfg = SampleCfg { temperature: 0.0, max_new_tokens: 6, ..Default::default() };
    let a = generation::generate_windowed(&mut eng, &tok, "Once upon a time", &cfg).unwrap();
    let b = generation::generate_windowed(&mut eng, &tok, "Once upon a time", &cfg).unwrap();
    assert_eq!(a.completion, b.completion);
}

#[test]
fn shared_weight_batch_generation_over_native_model() {
    use hsm::config::LayerInfo;
    use hsm::infer::{weights, Model, ModelWeights};

    let (tok, _, _) = pipeline(32, 300);
    let layers = vec![
        LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
        LayerInfo { kind: "gate2".into(), heads: 2, shifts: vec![2], ffn: 16 },
    ];
    let m = Manifest::synthetic("hsm_mix", layers, 8, 48, tok.vocab_size(), 1);
    let flat = weights::seeded_flat(&m, 3);
    let model = Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap();

    let prompts = ["Once upon a time", "Lily likes cats", "Jack went to"];
    let mut sessions: Vec<_> = prompts.iter().map(|_| model.session()).collect();
    let cfg = SampleCfg { temperature: 0.0, max_new_tokens: 5, ..Default::default() };
    let gens = generation::generate_batch(&mut sessions, &tok, &prompts, &cfg).unwrap();
    assert_eq!(gens.len(), 3);
    for (g, p) in gens.iter().zip(&prompts) {
        assert_eq!(&g.prompt, p);
        // Greedy batched decoding must equal a fresh solo session.
        let solo = generation::generate(&mut model.session(), &tok, p, &cfg).unwrap();
        assert_eq!(solo.completion, g.completion);
    }
}

#[test]
fn registry_and_manifest_agree_on_variants() {
    // Every registry id round-trips through a manifest built for it.
    for v in VARIANTS {
        let m = test_manifest(v, 2, 16, 300);
        assert_eq!(&m.variant, v);
    }
    assert!(TABLE1_VARIANTS.iter().all(|v| VARIANTS.contains(v)));
}

// ---------------------------------------------------------------------------
// Property-based invariants across module boundaries
// ---------------------------------------------------------------------------

#[test]
fn prop_tokenizer_roundtrips_corpus_stories() {
    let text = corpus::generate(31, 60);
    let tok = tok_trainer::train(&text, 350).unwrap();
    let stories: Vec<&str> = text.lines().collect();
    prop::check_n("story-roundtrip", 40, |rng| {
        let s = stories[rng.below(stories.len())];
        assert_eq!(tok.decode(&tok.encode(s)), s);
    });
}

#[test]
fn prop_batches_partition_epoch_without_duplication() {
    let (_tok, tr, _) = pipeline(32, 300);
    prop::check_n("epoch-partition", 8, |rng| {
        let bs = 1 + rng.below(6);
        let seed = rng.next_u64();
        let mut seen_rows: Vec<Vec<i32>> = Vec::new();
        for b in tr.epoch(bs, seed) {
            for r in 0..b.batch {
                seen_rows.push(b.x[r * b.ctx..(r + 1) * b.ctx].to_vec());
            }
        }
        // No window may appear more often in the epoch than it exists in
        // the dataset (identical windows CAN occur twice in a templated
        // corpus — compare multiset counts, not uniqueness)...
        let mut ds_counts: std::collections::HashMap<Vec<i32>, usize> = Default::default();
        for seq in &tr.sequences {
            let row: Vec<i32> = seq[..tr.ctx].iter().map(|&t| t as i32).collect();
            *ds_counts.entry(row).or_insert(0) += 1;
        }
        let mut ep_counts: std::collections::HashMap<&Vec<i32>, usize> = Default::default();
        for row in &seen_rows {
            *ep_counts.entry(row).or_insert(0) += 1;
        }
        for (row, &n) in &ep_counts {
            assert!(n <= ds_counts[*row], "window over-represented in epoch");
        }
        // ...and the number of rows is a multiple of the batch size.
        assert_eq!(seen_rows.len() % bs, 0);
    });
}

#[test]
fn prop_trainer_step_accounting() {
    // Coordinator invariant: total_steps == epochs × batches_per_epoch
    // (or exactly max_steps when capped), for arbitrary sizes.
    prop::check_n("step-accounting", 12, |rng: &mut Rng| {
        let ctx = 16;
        let n_seq = 8 + rng.below(40);
        let bs = 1 + rng.below(4);
        let ds = Dataset {
            sequences: (0..n_seq).map(|i| vec![(i % 100) as u32; ctx + 1]).collect(),
            ctx,
        };
        let epochs = 1 + rng.below(3);
        let cap = 1 + rng.below(20);
        let use_cap = rng.chance(0.5);
        let mut eng = MockEngine::new(test_manifest("hsm_ab", bs, ctx, 300), 1.8, 0.01);
        let mut t = Trainer::new(
            &mut eng,
            TrainerOptions {
                epochs,
                max_steps: use_cap.then_some(cap),
                ..Default::default()
            },
        );
        let out = t.run(&ds, &ds).unwrap();
        let per_epoch = ds.batches_per_epoch(bs);
        if use_cap {
            assert_eq!(out.total_steps, cap.min(epochs * per_epoch));
        } else {
            assert_eq!(out.total_steps, epochs * per_epoch);
        }
    });
}

#[test]
fn prop_sampler_respects_vocab_bounds() {
    prop::check_n("sampler-bounds", 64, |rng| {
        let vocab = 2 + rng.below(100);
        let logits = prop::arb_f32s(rng, vocab, 8.0);
        let cfg = SampleCfg {
            temperature: rng.f32() * 2.0,
            top_k: rng.below(vocab + 4),
            ..Default::default()
        };
        let t = generation::sample_logits(&logits, &cfg, rng);
        assert!((t as usize) < vocab);
    });
}

#[test]
fn prop_checkpoint_roundtrip_arbitrary_tensors() {
    prop::check_n("ckpt-roundtrip", 16, |rng| {
        let n_tensors = 1 + rng.below(5);
        let mut ck = Checkpoint::default();
        for i in 0..n_tensors {
            let len = 1 + rng.below(200);
            ck.tensors.push((format!("t{i}"), vec![len], prop::arb_f32s(rng, len, 100.0)));
        }
        let path = std::env::temp_dir().join(format!("hsm_prop_ckpt_{}.bin", rng.next_u64()));
        ck.save(&path).unwrap();
        let re = Checkpoint::load(&path).unwrap();
        for (a, b) in ck.tensors.iter().zip(&re.tensors) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.2.len(), b.2.len());
            for (x, y) in a.2.iter().zip(&b.2) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn manifest_rejects_wrong_files() {
    let dir = std::env::temp_dir().join("hsm_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), "{}").unwrap();
    assert!(Manifest::load(&dir).is_err());
}
