//! Offline API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the subset of anyhow the `hsm` crate uses:
//!
//! * [`Error`] — a context-chain error type (`{}` prints the outermost
//!   message, `{:#}` the full `a: b: c` chain, like anyhow).
//! * [`Result<T>`] with the `E = Error` default parameter.
//! * [`anyhow!`] / [`bail!`] macros (literal, format-args and
//!   single-expression forms).
//! * The [`Context`] extension trait (`.context(..)` /
//!   `.with_context(..)`) on any `Result` whose error converts into
//!   [`Error`].
//! * A blanket `From<E: std::error::Error>` so `?` works on io/fmt/etc.
//!   errors.
//!
//! If the real crate ever becomes available, deleting `vendor/anyhow`
//! and switching the manifest to `anyhow = "1"` is a drop-in change.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed-free error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost context, later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error` —
// exactly like the real anyhow — so this blanket conversion does not
// overlap with the reflexive `From<Error> for Error`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// `Display` expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::msg("inner").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: inner");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "gone");
    }

    #[test]
    fn context_on_results() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: gone");
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: gone");
    }

    #[test]
    fn macro_forms() {
        let x = 7;
        assert_eq!(format!("{}", anyhow!("plain")), "plain");
        assert_eq!(format!("{}", anyhow!("inline {x}")), "inline 7");
        assert_eq!(format!("{}", anyhow!("args {} {x}", 1)), "args 1 7");
        assert_eq!(format!("{}", anyhow!(String::from("expr"))), "expr");
        fn f() -> Result<()> {
            bail!("boom {}", 2)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 2");
    }
}
