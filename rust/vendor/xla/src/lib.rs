//! Offline stub of the `xla` crate (xla-rs over xla_extension 0.5.1).
//!
//! The real crate downloads the xla_extension C library at build time,
//! which is impossible in this offline environment.  This stub keeps the
//! `pjrt` feature of the `hsm` crate *compilable* everywhere:
//!
//! * [`Literal`] is a real little host-tensor container (construction,
//!   reshape, download helpers all work), so code that only shapes
//!   literals behaves normally.
//! * [`PjRtClient::cpu`] returns an error, so `PjrtEngine::new` fails
//!   fast with an actionable message and every downstream device entry
//!   point stays unreachable.  Callers that probe with `let Ok(..) = ..`
//!   (benches, examples) degrade gracefully to the native engine.
//!
//! Replacing this stub with the real crate is a one-line manifest change;
//! no `hsm` source changes are required.

use std::fmt;
use std::path::Path;

const UNAVAILABLE: &str = "PJRT runtime unavailable: built against the offline xla stub \
     (vendor/xla). Install the real xla crate + xla_extension to execute HLO artifacts, \
     or use the native incremental decoder (hsm::infer) which needs no artifacts";

/// Stub error type (implements `std::error::Error` so `?` and
/// `anyhow::Error: From<_>` conversions work at call sites).
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type XlaResult<T> = std::result::Result<T, Error>;

/// XLA element types (the subset the hsm manifests use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    Pred,
    S32,
    U32,
    F32,
    F64,
}

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn unwrap(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::U32(v)
    }
    fn unwrap(d: &Data) -> Option<&[Self]> {
        match d {
            Data::U32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host-resident tensor (fully functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![v]) }
    }

    /// Zero-filled literal of the given shape (F32 only in the stub).
    pub fn create_from_shape(ty: PrimitiveType, dims: &[usize]) -> Literal {
        let n: usize = dims.iter().product::<usize>().max(1);
        let data = match ty {
            PrimitiveType::S32 => Data::I32(vec![0; n]),
            PrimitiveType::U32 => Data::U32(vec![0; n]),
            _ => Data::F32(vec![0.0; n]),
        };
        Literal { data, dims: dims.iter().map(|&d| d as i64).collect() }
    }

    /// Reinterpret the element buffer under new dimensions.
    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {:?} ({n} elems) from {} elems",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Download the elements (typed).
    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        T::unwrap(&self.data)
            .map(<[T]>::to_vec)
            .ok_or_else(|| Error("literal element type mismatch".to_string()))
    }

    /// First element (used for scalar loss/accuracy outputs).
    pub fn get_first_element<T: NativeType>(&self) -> XlaResult<T> {
        T::unwrap(&self.data)
            .and_then(|v| v.first().copied())
            .ok_or_else(|| Error("empty or mistyped literal".to_string()))
    }

    /// Decompose a tuple literal (never produced by the stub).
    pub fn to_tuple(self) -> XlaResult<Vec<Literal>> {
        Err(Error::unavailable())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (construction always fails in the stub).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> XlaResult<Self> {
        Err(Error::unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (never constructable through the stub client).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(Error::unavailable())
    }
}

/// Compiled executable (never constructable through the stub client).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// PJRT client: construction reports the stub condition.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> XlaResult<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7i32).get_first_element::<i32>().unwrap(), 7);
        assert!(Literal::scalar(7i32).to_vec::<f32>().is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nope").is_err());
    }
}
