//! Word banks for the synthetic TinyStories grammar.
//!
//! Restricted to the vocabulary register of TinyStories (words a
//! 3–4-year-old knows), which is what lets a 5 M-parameter model produce
//! coherent completions — the property the paper's qualitative Table 3
//! depends on.

pub const NAMES: &[&str] = &[
    "Lily", "Ben", "Tom", "Mia", "Sam", "Anna", "Max", "Sue", "Tim", "Amy",
    "Jack", "Lucy", "Leo", "Emma", "Finn", "Zoe", "Alice", "Peter", "Mary",
    "Bobo", "Momo", "Pip",
];

pub const ANIMALS: &[&str] = &[
    "dog", "cat", "bird", "bunny", "duck", "frog", "bear", "mouse", "fish",
    "pony", "fox", "owl", "pig", "hen", "squirrel", "butterfly", "puppy",
    "kitten", "turtle",
];

pub const OBJECTS: &[&str] = &[
    "ball", "doll", "kite", "hat", "book", "cake", "apple", "banana", "toy",
    "balloon", "stick", "drum", "block", "boat", "car", "flower", "cookie",
    "spoon", "cup", "sock", "box", "teddy", "pumpkin",
];

pub const PLACES: &[&str] = &[
    "park", "garden", "forest", "beach", "house", "farm", "pond", "hill",
    "yard", "kitchen", "school", "library", "barn", "meadow", "playground",
];

pub const ADJECTIVES: &[&str] = &[
    "big", "small", "little", "kind", "funny", "happy", "silly", "brave",
    "soft", "shiny", "pretty", "old", "new", "tiny", "friendly", "gentle",
];

pub const FEELINGS: &[&str] = &[
    "sad", "scared", "worried", "surprised", "upset", "lonely", "confused",
];

pub const COLORS: &[&str] = &[
    "red", "blue", "green", "yellow", "pink", "purple", "orange", "brown",
    "white", "black",
];

pub const MORALS: &[&str] = &[
    "From that day on, they always shared their toys.",
    "They learned that helping friends is the best thing to do.",
    "It is always good to be kind to others.",
    "Being brave can help you find what you love.",
    "Good friends always help each other.",
    "Sharing makes everyone happy.",
    "And they all lived happily ever after.",
    "They promised to always tell the truth.",
    "Everyone was proud of them for being so kind.",
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banks_are_nonempty_and_unique() {
        for bank in [NAMES, ANIMALS, OBJECTS, PLACES, ADJECTIVES, FEELINGS, COLORS, MORALS] {
            assert!(!bank.is_empty());
            let set: std::collections::HashSet<&&str> = bank.iter().collect();
            assert_eq!(set.len(), bank.len(), "duplicate in bank");
        }
    }
}
