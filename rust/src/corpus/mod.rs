//! Synthetic TinyStories corpus.
//!
//! The paper trains on TinyStories (Eldan & Li 2023), 1.9 GB of short
//! stories in the register of a 3–4-year-old's vocabulary.  That dataset
//! is not reachable from this offline sandbox, so this module synthesises
//! the closest structural equivalent: a seeded, templated story grammar
//! producing short narratives with the same shape — a named child or
//! animal protagonist, a simple want/problem, an event, dialogue, a
//! resolution and often a gentle moral (see DESIGN.md §6 for why this
//! substitution preserves the paper's *relative* claims).
//!
//! The generator is deterministic per seed, emits `<|endoftext|>`-free raw
//! text (document boundaries are newline-delimited; the data pipeline adds
//! the sentinel), and can produce corpora of any requested size.  A loader
//! for a real TinyStories dump is provided too ([`load_or_generate`]).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::rng::Rng;

pub mod banks;

use banks::*;

/// Generate one story (2–5 short paragraphs, ≈400–900 characters).
pub fn story(rng: &mut Rng) -> String {
    let name = rng.pick(NAMES);
    let friend = loop {
        let f = rng.pick(NAMES);
        if f != name {
            break f;
        }
    };
    let animal = rng.pick(ANIMALS);
    let object = rng.pick(OBJECTS);
    let place = rng.pick(PLACES);
    let adj = rng.pick(ADJECTIVES);
    let feeling = rng.pick(FEELINGS);
    let color = rng.pick(COLORS);

    let mut s = String::with_capacity(900);

    // Opening.
    match rng.below(4) {
        0 => s.push_str(&format!(
            "Once upon a time, there was a little {} named {}. ",
            rng.pick(&["girl", "boy", "bird", "dog", "cat"]),
            name
        )),
        1 => s.push_str(&format!(
            "One day, {} went to the {} with {}. ",
            name, place, friend
        )),
        2 => s.push_str(&format!(
            "{} was a {} {} who loved to play. ",
            name, adj, animal
        )),
        _ => s.push_str(&format!(
            "There once was a {} {} that lived near the {}. ",
            color, animal, place
        )),
    }

    // Desire / setup.
    match rng.below(4) {
        0 => s.push_str(&format!(
            "{} loved to play with the {} {} every day. ",
            name, color, object
        )),
        1 => s.push_str(&format!(
            "{} wanted to find a {} {} more than anything. ",
            name, adj, object
        )),
        2 => s.push_str(&format!(
            "Every morning, {} would run to the {} to see the {}. ",
            name, place, animal
        )),
        _ => s.push_str(&format!(
            "{} had a {} {} that was very special. ",
            name, adj, object
        )),
    }

    // Complication.
    match rng.below(5) {
        0 => s.push_str(&format!(
            "One day, the {} was gone! {} looked everywhere and felt very {}. ",
            object, name, feeling
        )),
        1 => s.push_str(&format!(
            "Suddenly, a big {} came to the {}. {} was {} and did not know what to do. ",
            animal, place, name, feeling
        )),
        2 => s.push_str(&format!(
            "But then it started to rain, and the {} got all wet. ",
            object
        )),
        3 => s.push_str(&format!(
            "{} tried to climb the big tree, but it was too {}. ",
            name, rng.pick(&["tall", "high", "slippery", "scary"])
        )),
        _ => s.push_str(&format!(
            "Then {} saw that {} was sad and alone by the {}. ",
            name, friend, place
        )),
    }

    // Dialogue.
    match rng.below(4) {
        0 => s.push_str(&format!(
            "\"Don't worry,\" said {}. \"I will help you.\" ",
            friend
        )),
        1 => s.push_str(&format!(
            "\"{}, where are you?\" {} called out. ",
            object, name
        )),
        2 => s.push_str(&format!(
            "{} said, \"Please can you help me find my {}?\" \"Yes,\" said the {} {}. ",
            name, object, adj, animal
        )),
        _ => s.push_str(&format!(
            "\"Look!\" said {}. \"The {} is by the {}!\" ",
            friend, object, place
        )),
    }

    // Resolution.
    match rng.below(4) {
        0 => s.push_str(&format!(
            "Together, {} and {} found the {} under a big leaf. {} was so {} and hugged {}. ",
            name, friend, object, name, rng.pick(&["happy", "glad", "excited"]), friend
        )),
        1 => s.push_str(&format!(
            "The {} {} helped {} and soon everything was all right again. ",
            adj, animal, name
        )),
        2 => s.push_str(&format!(
            "{} shared the {} with {} and they played in the {} all day. ",
            name, object, friend, place
        )),
        _ => s.push_str(&format!(
            "In the end, {} learned to be brave, and the {} became {}'s best friend. ",
            name, animal, name
        )),
    }

    // Moral (sometimes).
    if rng.chance(0.6) {
        let moral: &&str = rng.pick(MORALS);
        s.push_str(moral);
        s.push(' ');
    }
    s.push_str("The end.");
    s
}

/// Generate a corpus of `n_stories` stories, newline-separated.
pub fn generate(seed: u64, n_stories: usize) -> String {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(n_stories * 700);
    for i in 0..n_stories {
        let mut srng = rng.split(i as u64);
        out.push_str(&story(&mut srng));
        out.push('\n');
    }
    out
}

/// Generate roughly `target_bytes` of corpus.
pub fn generate_bytes(seed: u64, target_bytes: usize) -> String {
    // Stories average ~650 bytes; overshoot slightly then trim whole stories.
    let n = target_bytes / 500 + 1;
    let mut text = String::with_capacity(target_bytes + 2048);
    let mut rng = Rng::new(seed);
    let mut i = 0;
    while text.len() < target_bytes {
        let mut srng = rng.split(i);
        text.push_str(&story(&mut srng));
        text.push('\n');
        i += 1;
        if i as usize > 4 * n {
            break; // safety
        }
    }
    text
}

/// Load a real TinyStories dump if `path` exists, else synthesise one.
///
/// A real dump is expected as plain UTF-8 text with stories separated by
/// blank lines or `<|endoftext|>` markers (both are normalised to single
/// newlines, the format [`generate`] emits).
pub fn load_or_generate(path: Option<&Path>, seed: u64, target_bytes: usize) -> Result<String> {
    if let Some(p) = path {
        if p.exists() {
            let raw = std::fs::read_to_string(p)
                .with_context(|| format!("reading corpus from {}", p.display()))?;
            let norm = raw
                .replace("<|endoftext|>", "\n")
                .replace("\r\n", "\n")
                .split("\n\n")
                .map(|s| s.trim().replace('\n', " "))
                .filter(|s| !s.is_empty())
                .collect::<Vec<_>>()
                .join("\n");
            return Ok(norm);
        }
    }
    Ok(generate_bytes(seed, target_bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(generate(7, 20), generate(7, 20));
        assert_ne!(generate(7, 20), generate(8, 20));
    }

    #[test]
    fn stories_have_structure() {
        let text = generate(1, 50);
        let stories: Vec<&str> = text.lines().collect();
        assert_eq!(stories.len(), 50);
        for st in &stories {
            assert!(st.ends_with("The end."), "missing ending: {st:?}");
            assert!(st.len() > 150, "too short: {st:?}");
        }
    }

    #[test]
    fn vocabulary_is_childlike() {
        // No token longer than 12 chars should appear (simple register).
        let text = generate(2, 100);
        for w in text.split_whitespace() {
            let w = w.trim_matches(|c: char| !c.is_alphabetic());
            assert!(w.len() <= 12, "long word {w:?}");
        }
    }

    #[test]
    fn generate_bytes_hits_target() {
        let text = generate_bytes(3, 50_000);
        assert!(text.len() >= 50_000);
        assert!(text.len() < 80_000);
    }

    #[test]
    fn stories_vary() {
        let text = generate(4, 200);
        let stories: Vec<&str> = text.lines().collect();
        let unique: std::collections::HashSet<&&str> = stories.iter().collect();
        assert!(unique.len() > 190, "only {} unique stories", unique.len());
    }
}
