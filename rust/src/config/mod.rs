//! Configuration: artifact manifests, presets and the variant registry.
//!
//! The Python side (`python/compile/configs.py`) is the source of truth
//! for model hyperparameters; it serialises everything the coordinator
//! needs into `artifacts/<preset>/<variant>/manifest.json`.  This module
//! parses those manifests and mirrors the static registry (variant ids,
//! display names) used by CLI validation and the report drivers.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// Table-1 row order (GPT last, as in the paper) plus the Fig-7 hybrid.
pub const VARIANTS: &[&str] = &[
    "hsm_ab",
    "hsm_vec",
    "hsm_mat",
    "hsm_gate1",
    "hsm_gate2",
    "hsm_fusion",
    "hsm_ab_mh",
    "hsm_ab_mhext",
    "hybrid_06",
    "hybrid_mh_06",
    "gpt",
    "hybrid_l3gpt",
];

/// The 11 rows of Table 1 (excludes the Figure-7-only hybrid).
pub const TABLE1_VARIANTS: &[&str] = &[
    "hsm_ab",
    "hsm_vec",
    "hsm_mat",
    "hsm_gate1",
    "hsm_gate2",
    "hsm_fusion",
    "hsm_ab_mh",
    "hsm_ab_mhext",
    "hybrid_06",
    "hybrid_mh_06",
    "gpt",
];

pub const PRESETS: &[&str] = &["paper", "desktop", "ci"];

/// One trainable parameter tensor as described by the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub decay: bool,
}

impl ParamInfo {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Training hyperparameters (paper §7 plus preset-specific batch size).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainHp {
    pub batch: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub dropout: f64,
    pub epochs: usize,
}

/// One layer's mixer spec, mirrored from the manifest for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerInfo {
    pub kind: String,
    pub heads: usize,
    pub shifts: Vec<usize>,
    pub ffn: usize,
}

/// Parsed `manifest.json` for one (preset, variant) artifact set.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub variant: String,
    pub display_name: String,
    pub kernels: String,
    pub dim: usize,
    pub ctx: usize,
    pub vocab: usize,
    pub layers: Vec<LayerInfo>,
    pub param_count: usize,
    pub params: Vec<ParamInfo>,
    pub train: TrainHp,
    /// Directory the manifest was loaded from (artifact files live here).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&v, dir)
    }

    pub fn from_json(v: &Value, dir: &Path) -> Result<Self> {
        let str_field = |field: &Value, what: &str| -> Result<String> {
            field
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow!("manifest missing {what}"))
        };
        let cfg = v.get("config");
        let layers = cfg
            .get("layers")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing config.layers"))?
            .iter()
            .map(|l| -> Result<LayerInfo> {
                Ok(LayerInfo {
                    kind: str_field(l.get("kind"), "layer.kind")?,
                    heads: l.get("heads").as_usize().ok_or_else(|| anyhow!("layer.heads"))?,
                    shifts: l
                        .get("shifts")
                        .as_usize_vec()
                        .ok_or_else(|| anyhow!("layer.shifts"))?,
                    ffn: l.get("ffn").as_usize().ok_or_else(|| anyhow!("layer.ffn"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let params = v
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| -> Result<ParamInfo> {
                Ok(ParamInfo {
                    name: str_field(p.get("name"), "param.name")?,
                    shape: p
                        .get("shape")
                        .as_usize_vec()
                        .ok_or_else(|| anyhow!("param.shape"))?,
                    decay: p.get("decay").as_bool().unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        if params.is_empty() {
            bail!("manifest has no parameters");
        }

        let t = v.get("train");
        let train = TrainHp {
            batch: t.get("batch").as_usize().ok_or_else(|| anyhow!("train.batch"))?,
            lr: t.get("lr").as_f64().ok_or_else(|| anyhow!("train.lr"))?,
            weight_decay: t.get("weight_decay").as_f64().unwrap_or(0.0),
            beta1: t.get("beta1").as_f64().unwrap_or(0.9),
            beta2: t.get("beta2").as_f64().unwrap_or(0.999),
            eps: t.get("eps").as_f64().unwrap_or(1e-8),
            dropout: t.get("dropout").as_f64().unwrap_or(0.0),
            epochs: t.get("epochs").as_usize().unwrap_or(20),
        };

        Ok(Manifest {
            preset: str_field(v.get("preset"), "preset")?,
            variant: str_field(v.get("variant"), "variant")?,
            display_name: str_field(v.get("display_name"), "display_name")?,
            kernels: v.get("kernels").as_str().unwrap_or("pallas").to_string(),
            dim: cfg.get("dim").as_usize().ok_or_else(|| anyhow!("config.dim"))?,
            ctx: cfg.get("ctx").as_usize().ok_or_else(|| anyhow!("config.ctx"))?,
            vocab: cfg.get("vocab").as_usize().ok_or_else(|| anyhow!("config.vocab"))?,
            layers,
            param_count: cfg.get("param_count").as_usize().unwrap_or(0),
            params,
            train,
            dir: dir.to_path_buf(),
        })
    }

    /// Path of one artifact kind (`init`, `train_step`, `eval_step`, `decode`).
    pub fn artifact(&self, kind: &str) -> PathBuf {
        self.dir.join(format!("{kind}.hlo.txt"))
    }

    /// Serialize to the exact JSON schema [`Manifest::from_json`] parses
    /// (everything except `dir`, which is a load-site property).  This is
    /// what checkpoints embed so `hsm generate/serve --engine native` can
    /// run straight from a checkpoint with no artifact directory.
    pub fn to_json(&self) -> Value {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                json::obj(vec![
                    ("kind", json::s(&l.kind)),
                    ("heads", json::num(l.heads as f64)),
                    (
                        "shifts",
                        Value::Arr(l.shifts.iter().map(|&s| json::num(s as f64)).collect()),
                    ),
                    ("ffn", json::num(l.ffn as f64)),
                ])
            })
            .collect();
        let params = self
            .params
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("name", json::s(&p.name)),
                    (
                        "shape",
                        Value::Arr(p.shape.iter().map(|&d| json::num(d as f64)).collect()),
                    ),
                    ("decay", Value::Bool(p.decay)),
                ])
            })
            .collect();
        json::obj(vec![
            ("preset", json::s(&self.preset)),
            ("variant", json::s(&self.variant)),
            ("display_name", json::s(&self.display_name)),
            ("kernels", json::s(&self.kernels)),
            (
                "config",
                json::obj(vec![
                    ("dim", json::num(self.dim as f64)),
                    ("ctx", json::num(self.ctx as f64)),
                    ("vocab", json::num(self.vocab as f64)),
                    ("param_count", json::num(self.param_count as f64)),
                    ("layers", Value::Arr(layers)),
                ]),
            ),
            (
                "train",
                json::obj(vec![
                    ("batch", json::num(self.train.batch as f64)),
                    ("lr", json::num(self.train.lr)),
                    ("weight_decay", json::num(self.train.weight_decay)),
                    ("beta1", json::num(self.train.beta1)),
                    ("beta2", json::num(self.train.beta2)),
                    ("eps", json::num(self.train.eps)),
                    ("dropout", json::num(self.train.dropout)),
                    ("epochs", json::num(self.train.epochs as f64)),
                ]),
            ),
            ("params", Value::Arr(params)),
        ])
    }

    /// Total parameter elements (must match `param_count` from python).
    pub fn total_elems(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }

    /// Artifact directory for (root, preset, variant).
    pub fn dir_for(root: &Path, preset: &str, variant: &str) -> PathBuf {
        root.join(preset).join(variant)
    }

    /// Build a complete in-memory manifest (no artifacts on disk) for the
    /// given layer stack: correct per-kind mixer parameter shapes, named
    /// exactly as `infer::ModelWeights::from_flat` expects.  This is what
    /// lets the native decoder, parity tests and decode benches run fully
    /// artifact-free.
    ///
    /// Panics if a layer's `heads` does not divide `dim` (caller bug).
    pub fn synthetic(
        variant: &str,
        layers: Vec<LayerInfo>,
        dim: usize,
        ctx: usize,
        vocab: usize,
        batch: usize,
    ) -> Self {
        let mut params: Vec<ParamInfo> = Vec::new();
        let mut push = |name: String, shape: Vec<usize>, decay: bool| {
            params.push(ParamInfo { name, shape, decay });
        };
        push("tok_emb".into(), vec![vocab, dim], true);
        push("pos_emb".into(), vec![ctx, dim], false);
        for (l, spec) in layers.iter().enumerate() {
            assert!(
                spec.heads > 0 && dim % spec.heads == 0,
                "layer {l}: heads {} must divide dim {dim}",
                spec.heads
            );
            let hd = dim / spec.heads;
            let p = |s: &str| format!("layer{l}.{s}");
            push(p("ln1_g"), vec![dim], false);
            push(p("ln1_b"), vec![dim], false);
            match spec.kind.as_str() {
                "ab" => {
                    push(p("mix_a"), vec![spec.heads], false);
                    push(p("mix_b"), vec![spec.heads], false);
                }
                "vec" => {
                    push(p("mix_a"), vec![dim], false);
                    push(p("mix_b"), vec![dim], false);
                }
                "mat" => {
                    push(p("mix_A"), vec![dim, dim], true);
                    push(p("mix_B"), vec![dim, dim], true);
                    push(p("mix_bias"), vec![dim], false);
                }
                "gate1" => {
                    push(p("gate_w1"), vec![dim, dim], true);
                    push(p("gate_b1"), vec![dim], false);
                    push(p("gate_w2"), vec![dim, dim], true);
                    push(p("gate_b2"), vec![dim], false);
                }
                "gate2" => {
                    push(p("gate_w"), vec![spec.heads, 2 * hd, hd], true);
                    push(p("gate_b"), vec![spec.heads, hd], false);
                }
                "fusion" => {
                    push(p("fuse_w1"), vec![spec.heads, 2 * hd, hd], true);
                    push(p("fuse_b1"), vec![spec.heads, hd], false);
                    push(p("fuse_w2"), vec![spec.heads, hd, hd], true);
                    push(p("fuse_b2"), vec![spec.heads, hd], false);
                }
                "attn" => {
                    for w in ["attn_wq", "attn_wk", "attn_wv", "attn_wo"] {
                        push(p(w), vec![dim, dim], true);
                    }
                    for b in ["attn_bq", "attn_bk", "attn_bv", "attn_bo"] {
                        push(p(b), vec![dim], false);
                    }
                }
                other => panic!("unknown mixer kind {other:?}"),
            }
            push(p("ln2_g"), vec![dim], false);
            push(p("ln2_b"), vec![dim], false);
            push(p("ffn_w1"), vec![dim, spec.ffn], true);
            push(p("ffn_b1"), vec![spec.ffn], false);
            push(p("ffn_w2"), vec![spec.ffn, dim], true);
            push(p("ffn_b2"), vec![dim], false);
        }
        push("lnf_g".into(), vec![dim], false);
        push("lnf_b".into(), vec![dim], false);

        let param_count = params.iter().map(|p| p.elems()).sum();
        Manifest {
            preset: "synthetic".to_string(),
            variant: variant.to_string(),
            display_name: variant.to_string(),
            kernels: "native".to_string(),
            dim,
            ctx,
            vocab,
            layers,
            param_count,
            params,
            train: TrainHp {
                batch,
                lr: 0.002,
                weight_decay: 0.01,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                dropout: 0.0,
                epochs: 20,
            },
            dir: PathBuf::from("/tmp/hsm-synthetic"),
        }
    }

    /// Load a manifest given the artifacts root.
    pub fn load_variant(root: &Path, preset: &str, variant: &str) -> Result<Self> {
        if !VARIANTS.contains(&variant) {
            bail!("unknown variant {variant:?}; known: {VARIANTS:?}");
        }
        let dir = Self::dir_for(root, preset, variant);
        if !dir.join("manifest.json").exists() {
            bail!(
                "no artifacts for {preset}/{variant} under {} — run `make artifacts` \
                 (or `python -m compile.aot --preset {preset} --variants {variant}`)",
                root.display()
            );
        }
        Self::load(&dir)
    }
}

/// Resolve the artifacts root: $HSM_ARTIFACTS or ./artifacts.
pub fn artifacts_root() -> PathBuf {
    std::env::var("HSM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "ci", "variant": "hsm_ab", "display_name": "HSM (a,b)",
      "kernels": "pallas",
      "config": {"dim": 64, "ctx": 64, "vocab": 512, "param_count": 270414,
        "layers": [{"kind": "ab", "heads": 1, "shifts": [1], "ffn": 256}]},
      "train": {"batch": 8, "lr": 0.002, "weight_decay": 0.01, "beta1": 0.9,
        "beta2": 0.999, "eps": 1e-08, "dropout": 0.1, "epochs": 20},
      "params": [
        {"name": "tok_emb", "shape": [512, 64], "decay": true},
        {"name": "layer0.mix_a", "shape": [1], "decay": false}
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.variant, "hsm_ab");
        assert_eq!(m.dim, 64);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].elems(), 512 * 64);
        assert!(m.params[0].decay);
        assert!(!m.params[1].decay);
        assert_eq!(m.train.batch, 8);
        assert!((m.train.lr - 0.002).abs() < 1e-12);
        assert_eq!(m.layers[0].kind, "ab");
        assert_eq!(m.artifact("init"), Path::new("/tmp/x/init.hlo.txt"));
    }

    #[test]
    fn rejects_empty_params() {
        let v = json::parse(
            r#"{"preset":"ci","variant":"x","display_name":"x",
                "config":{"dim":1,"ctx":1,"vocab":1,"layers":[]},
                "train":{"batch":1,"lr":0.1},"params":[]}"#,
        )
        .unwrap();
        assert!(Manifest::from_json(&v, Path::new("/tmp")).is_err());
    }

    #[test]
    fn synthetic_manifest_covers_every_mixer_kind() {
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let layers = vec![
                LayerInfo { kind: kind.to_string(), heads: 2, shifts: vec![1, 2], ffn: 32 },
                LayerInfo { kind: kind.to_string(), heads: 2, shifts: vec![2, 4], ffn: 32 },
            ];
            let m = Manifest::synthetic(kind, layers, 16, 32, 64, 4);
            assert_eq!(m.total_elems(), m.param_count, "{kind}");
            assert_eq!(m.layers.len(), 2, "{kind}");
            // Every layer has its LN + FFN block plus kind-specific mixer
            // tensors, all uniquely named.
            let mut names: Vec<&str> = m.params.iter().map(|p| p.name.as_str()).collect();
            let n = names.len();
            names.sort_unstable();
            names.dedup();
            assert_eq!(names.len(), n, "{kind}: duplicate parameter names");
            assert!(m.params.iter().any(|p| p.name == "layer1.ffn_w2"), "{kind}");
        }
    }

    #[test]
    fn to_json_roundtrips_through_from_json() {
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 32 },
            LayerInfo { kind: "attn".into(), heads: 2, shifts: vec![], ffn: 32 },
        ];
        let m = Manifest::synthetic("hybrid", layers, 16, 48, 120, 4);
        let text = m.to_json().to_string();
        let re = Manifest::from_json(&json::parse(&text).unwrap(), Path::new("/elsewhere")).unwrap();
        assert_eq!(re.preset, m.preset);
        assert_eq!(re.variant, m.variant);
        assert_eq!(re.display_name, m.display_name);
        assert_eq!(re.kernels, m.kernels);
        assert_eq!(re.dim, m.dim);
        assert_eq!(re.ctx, m.ctx);
        assert_eq!(re.vocab, m.vocab);
        assert_eq!(re.param_count, m.param_count);
        assert_eq!(re.layers, m.layers);
        assert_eq!(re.params, m.params);
        assert_eq!(re.train, m.train);
        assert_eq!(re.dir, Path::new("/elsewhere"));
    }

    #[test]
    fn registry_consistency() {
        assert_eq!(VARIANTS.len(), 12);
        assert_eq!(TABLE1_VARIANTS.len(), 11);
        for v in TABLE1_VARIANTS {
            assert!(VARIANTS.contains(v));
        }
    }
}
