//! `hsm` — the launcher.
//!
//! Subcommands:
//!
//! * `train`     — train one variant, log metrics, write a checkpoint.
//! * `evaluate`  — validation loss/accuracy of a checkpoint.
//! * `generate`  — sample completions from a (trained) model.
//! * `serve`     — continuous-batching serving: one-shot request batch, or
//!   a streaming HTTP front-end with `--http ADDR`.
//! * `request`   — client for a running `serve --http` server
//!   (`/v1/generate`, or `--stream` for per-token deltas).
//! * `loadgen`   — open-loop load generator: seeded Poisson/Zipf
//!   traffic against a serving front-end, `BENCH_load.json` report.
//! * `report`    — regenerate a paper table/figure (table1|table2|table3|fig7|fig8).
//! * `corpus`    — synthesise the TinyStories-like corpus to a file.
//! * `tokenizer` — train / inspect a BPE tokenizer.
//! * `info`      — print an artifact manifest summary.
//!
//! Run `hsm <subcommand> --help` for flags.

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use hsm::checkpoint::Checkpoint;
use hsm::config::{artifacts_root, Manifest, TABLE1_VARIANTS, VARIANTS};
use hsm::coordinator::{Trainer, TrainerOptions};
use hsm::corpus;
use hsm::generation::{self, SampleCfg, TABLE3_PROMPTS};
use hsm::infer::{DrafterKind, Model, ModelWeights, Precision, SpecCfg, SpecStats};
use hsm::loadgen;
use hsm::report::{self, ExperimentCtx, PjrtFactory, FIG7_VARIANTS};
use hsm::runtime::{PjrtEngine, StepEngine};
use hsm::serve::{FinishReason, QuotaCfg, Request, Scheduler, ServeCfg, StreamScheduler};
use hsm::server::{api::GenerateRequest, client as http_client, HttpServer};
use hsm::tokenizer::{trainer as tok_trainer, Tokenizer};
use hsm::util::cli::Args;

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{}", top_usage());
        return ExitCode::from(2);
    };
    let result = match cmd.as_str() {
        "train" => cmd_train(rest),
        "evaluate" => cmd_evaluate(rest),
        "generate" => cmd_generate(rest),
        "serve" => cmd_serve(rest),
        "request" => cmd_request(rest),
        "loadgen" => cmd_loadgen(rest),
        "report" => cmd_report(rest),
        "corpus" => cmd_corpus(rest),
        "tokenizer" => cmd_tokenizer(rest),
        "info" => cmd_info(rest),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand {other:?}\n\n{}", top_usage())),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn top_usage() -> String {
    "hsm — Hierarchical Shift Mixing (Forchheimer 2026) reproduction\n\
     \n\
     usage: hsm <subcommand> [flags]\n\
     \n\
     subcommands:\n\
       train      train one model variant\n\
       evaluate   evaluate a checkpoint on the validation split\n\
       generate   sample text from a model\n\
       serve      continuous-batching serving (one-shot batch, or --http ADDR front-end)\n\
       request    client for a running `serve --http` server (--stream for per-token deltas)\n\
       loadgen    open-loop load generator against a serving front-end (writes BENCH_load.json)\n\
       report     regenerate a paper table/figure (table1|table2|table3|fig7|fig8)\n\
       corpus     synthesise the TinyStories-like corpus\n\
       tokenizer  train / inspect the byte-level BPE tokenizer\n\
       info       print an artifact manifest summary\n"
        .to_string()
}

// ---------------------------------------------------------------------------

fn experiment_flags(a: Args) -> Args {
    a.flag("preset", "ci", "size preset (paper|desktop|ci)")
        .flag("corpus-bytes", "1048576", "synthetic corpus size in bytes")
        .flag("corpus-seed", "1234", "corpus synthesis seed")
        .optional("corpus", "path to a real TinyStories dump (optional)")
        .flag("epochs", "2", "training epochs")
        .flag("max-steps", "0", "hard cap on optimizer steps (0 = none)")
        .flag("seed", "42", "init/shuffle seed")
        .flag("eval-batches", "8", "validation batches per eval (0 = all)")
        .flag("log-every", "25", "log every N steps (0 = quiet)")
}

fn ctx_from_args(a: &Args) -> Result<ExperimentCtx> {
    let mut ctx = ExperimentCtx::new(&a.str("preset"));
    ctx.corpus_bytes = a.usize("corpus-bytes").map_err(|e| anyhow!(e))?;
    ctx.corpus_seed = a.u64("corpus-seed").map_err(|e| anyhow!(e))?;
    ctx.corpus_path = a.get("corpus").map(PathBuf::from);
    ctx.epochs = a.usize("epochs").map_err(|e| anyhow!(e))?;
    let ms = a.usize("max-steps").map_err(|e| anyhow!(e))?;
    ctx.max_steps = (ms > 0).then_some(ms);
    ctx.train_seed = a.u64("seed").map_err(|e| anyhow!(e))?;
    let eb = a.usize("eval-batches").map_err(|e| anyhow!(e))?;
    ctx.eval_batches = (eb > 0).then_some(eb);
    ctx.log_every = a.usize("log-every").map_err(|e| anyhow!(e))?;
    Ok(ctx)
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = experiment_flags(Args::new("train"))
        .required("variant", "model variant (e.g. hsm_ab, gpt)")
        .optional("checkpoint-out", "write final checkpoint here")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let ctx = ctx_from_args(&a)?;
    let variant = a.str("variant");
    let factory = PjrtFactory::new(&ctx.preset);
    let (engine, outcome) = report::train_variant(&factory, &ctx, &variant)?;
    println!(
        "\n{variant}: final val loss {:.4}, acc {:.4}, {:.1}s/epoch, {} steps",
        outcome.final_val_loss(),
        outcome.epochs.last().map(|e| e.val_acc).unwrap_or(f32::NAN),
        outcome.secs_per_epoch(),
        outcome.total_steps
    );
    if let Some(out) = a.get("checkpoint-out") {
        let m = engine.manifest().clone();
        let params = engine.get_params()?;
        let (mm, vv) = engine.get_state()?;
        // Embeds a manifest snapshot: `generate`/`serve --engine native`
        // run from this checkpoint with no artifact directory.
        let ck = Checkpoint::from_training(&m, outcome.total_steps, params, mm, vv);
        ck.save(&PathBuf::from(&out))?;
        println!("checkpoint written to {out}");
    }
    Ok(())
}

fn load_engine_with_checkpoint(preset: &str, variant: &str, ck_path: Option<String>) -> Result<PjrtEngine> {
    let manifest = Manifest::load_variant(&artifacts_root(), preset, variant)?;
    let mut engine = PjrtEngine::new(manifest)?;
    match ck_path {
        Some(p) => {
            let ck = Checkpoint::load(&PathBuf::from(&p))?;
            if ck.meta_value("variant") != Some(variant) {
                bail!(
                    "checkpoint is for variant {:?}, requested {variant:?}",
                    ck.meta_value("variant")
                );
            }
            engine.set_params(ck.group("param"))?;
            engine.set_state(ck.group("m"), ck.group("v"))?;
        }
        None => engine.init(42)?,
    }
    Ok(engine)
}

fn cmd_evaluate(argv: &[String]) -> Result<()> {
    let a = experiment_flags(Args::new("evaluate"))
        .required("variant", "model variant")
        .optional("checkpoint", "checkpoint to evaluate (default: fresh init)")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let ctx = ctx_from_args(&a)?;
    let mut engine =
        load_engine_with_checkpoint(&ctx.preset, &a.str("variant"), a.get("checkpoint"))?;
    let (_tok, _train, val) = report::build_data(&ctx, engine.manifest())?;
    let mut trainer = Trainer::new(&mut engine, TrainerOptions {
        eval_batches: ctx.eval_batches,
        ..Default::default()
    });
    let m = trainer.validate(&val)?;
    println!("val loss {:.4}  acc {:.4}", m.loss, m.acc);
    Ok(())
}

/// Build the shared native [`Model`] for `--engine native` paths.
///
/// Preference order: a checkpoint's embedded manifest snapshot (fully
/// artifact-free — the ROADMAP's "native checkpoint→generate" item),
/// else the PJRT artifact engine (initialised or checkpoint-restored).
/// Pre-snapshot checkpoints still work whenever artifacts are on disk;
/// without them the error says exactly what is missing.
///
/// `precision` is applied at load: checkpoints always stay f32 on disk;
/// [`Precision::Int8`] / [`Precision::Int4`] quantize the resident
/// model ([`Model::shared_with_precision`]) and drop the f32 copy.
fn native_model(
    preset: &str,
    variant: &str,
    ck_path: Option<String>,
    precision: Precision,
) -> Result<Arc<Model>> {
    let ck = match &ck_path {
        Some(p) => {
            let ck = Checkpoint::load(&PathBuf::from(p))?;
            if ck.meta_value("variant") != Some(variant) {
                bail!(
                    "checkpoint is for variant {:?}, requested {variant:?}",
                    ck.meta_value("variant")
                );
            }
            if let Some(m) = ck.manifest()? {
                let w = ModelWeights::from_checkpoint(&m, &ck)?;
                return Model::shared_with_precision(m, w, precision);
            }
            // Pre-snapshot checkpoint: the artifact manifest below
            // supplies the model shape; the weights come from `ck`.
            Some(ck)
        }
        None => None,
    };
    let manifest = Manifest::load_variant(&artifacts_root(), preset, variant).with_context(|| {
        format!(
            "the native engine needs either a checkpoint with an embedded manifest \
             (written by `hsm train --checkpoint-out` since v0.3) or PJRT artifacts \
             for {preset}/{variant}"
        )
    })?;
    match ck {
        Some(ck) => {
            let weights = ModelWeights::from_checkpoint(&manifest, &ck)?;
            Model::shared_with_precision(manifest, weights, precision)
        }
        None => {
            // Fresh init: only the engine knows the init distribution.
            let mut engine = PjrtEngine::new(manifest)?;
            engine.init(42)?;
            let manifest = engine.manifest().clone();
            let weights = ModelWeights::from_flat(&manifest, &engine.get_params()?)?;
            Model::shared_with_precision(manifest, weights, precision)
        }
    }
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let a = experiment_flags(Args::new("generate"))
        .required("variant", "model variant")
        .optional("checkpoint", "trained checkpoint (default: fresh init)")
        .flag("prompt", "Once upon a time", "prompt text")
        .flag("engine", "native", "decode path: native (incremental, O(1)/token for HSM) | window (full-context artifact)")
        .flag("temperature", "0.8", "sampling temperature (0 = greedy)")
        .flag("top-k", "40", "top-k filter (0 = off)")
        .flag("max-new-tokens", "64", "maximum tokens to generate")
        .flag("samples", "1", "number of samples")
        .flag("speculate", "0", "speculative decoding: draft block length (0 = off; native engine only)")
        .flag("drafter", "ngram", "draft proposer: ngram[:N] | shallow[:K] | shallow-q[:K]")
        .flag("precision", "f32", "weight precision: f32 | int8 | int4 (quantize at load; native engine only)")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let ctx = ctx_from_args(&a)?;
    let samples = a.usize("samples").map_err(|e| anyhow!(e))?;
    let prompt = a.str("prompt");
    let speculation = speculation_from_args(&a)?;
    let precision = Precision::parse(&a.str("precision"))?;
    let cfg = SampleCfg {
        temperature: a.f64("temperature").map_err(|e| anyhow!(e))? as f32,
        top_k: a.usize("top-k").map_err(|e| anyhow!(e))?,
        max_new_tokens: a.usize("max-new-tokens").map_err(|e| anyhow!(e))?,
        seed: ctx.train_seed,
        stop_at_eot: true,
    };
    let gens = match a.str("engine").as_str() {
        "native" => {
            // Serving path: one shared weight set (from the checkpoint's
            // embedded manifest when available — no artifacts needed),
            // `samples` concurrent sessions decoded round-robin.  Each
            // session samples from stream seed ^ i (same as sequential).
            let model =
                native_model(&ctx.preset, &a.str("variant"), a.get("checkpoint"), precision)?;
            let (tok, _, _) = report::build_data(&ctx, &model.manifest)?;
            if speculation.is_some() {
                // Speculative decoding rides the scheduler (same core,
                // byte-identical text); request i uses RNG stream
                // seed ^ i, matching the round-robin path exactly.
                let serve_cfg = ServeCfg {
                    max_active: samples.max(1),
                    threads: 1,
                    quantum: 16,
                    prefix_cache_size: 0,
                    speculation,
                    sample: cfg.clone(),
                    precision,
                    ..Default::default()
                };
                let requests: Vec<Request> =
                    (0..samples).map(|i| Request::new(i as u64, &prompt)).collect();
                let completions = hsm::serve::serve(&model, &tok, requests, &serve_cfg)?;
                for (i, c) in completions.iter().enumerate() {
                    println!("--- sample {i} ({} tokens) ---", c.tokens_generated);
                    println!("{}{}", c.prompt, c.completion);
                }
                print_spec_summary(&completions);
                return Ok(());
            }
            let mut sessions: Vec<_> = (0..samples).map(|_| model.session()).collect();
            let prompts: Vec<&str> = (0..samples).map(|_| prompt.as_str()).collect();
            generation::generate_batch(&mut sessions, &tok, &prompts, &cfg)?
        }
        "window" => {
            if speculation.is_some() {
                bail!(
                    "--speculate needs the native engine (the window baseline \
                     cannot fork session state); drop --speculate or use --engine native"
                );
            }
            if precision != Precision::F32 {
                bail!(
                    "--precision {} needs the native engine (the full-context \
                     window baseline runs f32 only)",
                    precision.label()
                );
            }
            let mut engine =
                load_engine_with_checkpoint(&ctx.preset, &a.str("variant"), a.get("checkpoint"))?;
            let (tok, _, _) = report::build_data(&ctx, engine.manifest())?;
            (0..samples)
                .map(|i| {
                    let cfg_i = SampleCfg { seed: cfg.seed ^ i as u64, ..cfg.clone() };
                    generation::generate_windowed(&mut engine, &tok, &prompt, &cfg_i)
                })
                .collect::<Result<Vec<_>>>()?
        }
        other => bail!("unknown --engine {other:?} (expected native or window)"),
    };
    for (i, g) in gens.iter().enumerate() {
        println!("--- sample {i} ({} tokens) ---", g.tokens_generated);
        println!("{}{}", g.prompt, g.completion);
    }
    Ok(())
}

/// Shared `--speculate N` / `--drafter ngram[:N]|shallow[:K]|shallow-q[:K]`
/// parsing for `serve` and `generate` (the spec grammar itself lives in
/// [`DrafterKind::parse`]).
fn speculation_from_args(a: &Args) -> Result<Option<SpecCfg>> {
    let draft_len = a.usize("speculate").map_err(|e| anyhow!(e))?;
    if draft_len == 0 {
        return Ok(None);
    }
    Ok(Some(SpecCfg {
        drafter: DrafterKind::parse(&a.str("drafter"))?,
        draft_len,
        ..Default::default()
    }))
}

/// One aggregate line of speculative-decoding accounting for a batch.
fn print_spec_summary(completions: &[hsm::serve::Completion]) {
    let mut agg = SpecStats::default();
    for c in completions {
        if let Some(s) = &c.spec {
            agg.add(s);
        }
    }
    if agg.rounds > 0 {
        println!(
            "speculation: {} verify rounds, {:.2} tokens/round, {:.0}% of drafts accepted",
            agg.rounds,
            agg.emitted_per_round(),
            100.0 * agg.acceptance_rate()
        );
        if agg.fused_passes > 0 {
            println!(
                "speculation: {} fused verify passes, {:.2} rows/pass",
                agg.fused_passes,
                agg.rows_per_fused_pass()
            );
        }
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = experiment_flags(Args::new("serve"))
        .required("variant", "model variant")
        .optional("checkpoint", "trained checkpoint (embedded-manifest checkpoints need no artifacts)")
        .optional("http", "serve over HTTP at this address (e.g. 127.0.0.1:8080) until killed, instead of a one-shot batch")
        .flag("requests", "16", "batch mode: number of requests (prompts cycle the Table-3 suite)")
        .flag("max-active", "8", "admission cap: concurrent decode sessions")
        .flag("threads", "4", "worker threads stepping sessions in parallel")
        .flag("quantum", "16", "tokens per scheduling slice")
        .flag("max-queue-wait-ms", "0", "finish requests queued longer than this as timed_out (0 = wait forever)")
        .flag("max-queue-depth", "0", "refuse admissions beyond this many queued jobs: HTTP 429 + Retry-After (0 = unbounded)")
        .flag("quota-requests", "0", "per-user requests per quota window (0 = unlimited)")
        .flag("quota-tokens", "0", "per-user tokens (prompt + budget) per quota window (0 = unlimited)")
        .flag("quota-window-ms", "60000", "per-user quota window length")
        .switch("edf", "earliest-deadline-first queue ordering (per-request deadline_ms, else max-queue-wait-ms)")
        .flag("prefix-cache", "32", "shared prompt-prefix cache entries (0 = disabled)")
        .flag("speculate", "0", "speculative decoding: draft block length (0 = off)")
        .flag("drafter", "ngram", "draft proposer: ngram[:N] (prompt lookup) | shallow[:K] (first K layers) | shallow-q[:K] (first K layers on quantized weights)")
        .flag("temperature", "0.8", "sampling temperature (0 = greedy)")
        .flag("top-k", "40", "top-k filter (0 = off)")
        .flag("max-new-tokens", "48", "maximum tokens per request")
        .flag("precision", "f32", "weight precision: f32 | int8 | int4 (quantize at load; checkpoints stay f32)")
        .optional("log-requests", "append one JSON line per request lifecycle event (admitted/started/first_token/finished) to this file")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let ctx = ctx_from_args(&a)?;
    let precision = Precision::parse(&a.str("precision"))?;
    let model = native_model(&ctx.preset, &a.str("variant"), a.get("checkpoint"), precision)?;
    let (tok, _, _) = report::build_data(&ctx, &model.manifest)?;
    // Startup facts every deployment wants in the log: what the weights
    // cost resident and which kernel tier this build dispatches to.
    let resident = model.resident_weight_bytes();
    let backend = hsm::infer::tensor::kernel_backend();

    let wait_ms = a.u64("max-queue-wait-ms").map_err(|e| anyhow!(e))?;
    // Telemetry is on by default (counters + histograms + stage timing);
    // --log-requests additionally streams the request lifecycle as
    // JSON lines.  Everything lands behind GET /metrics and /healthz.
    let mut obs = hsm::obs::ObsCfg::default();
    if let Some(path) = a.get("log-requests") {
        obs.request_log = Some(
            hsm::obs::RequestLog::to_file(std::path::Path::new(&path))
                .with_context(|| format!("opening request log {path}"))?,
        );
    }
    let cfg = ServeCfg {
        max_active: a.usize("max-active").map_err(|e| anyhow!(e))?,
        threads: a.usize("threads").map_err(|e| anyhow!(e))?,
        quantum: a.usize("quantum").map_err(|e| anyhow!(e))?,
        max_queue_wait: (wait_ms > 0).then(|| std::time::Duration::from_millis(wait_ms)),
        max_queue_depth: a.usize("max-queue-depth").map_err(|e| anyhow!(e))?,
        quota: quota_from_args(&a)?,
        edf: a.bool("edf"),
        prefix_cache_size: a.usize("prefix-cache").map_err(|e| anyhow!(e))?,
        speculation: speculation_from_args(&a)?,
        sample: SampleCfg {
            temperature: a.f64("temperature").map_err(|e| anyhow!(e))? as f32,
            top_k: a.usize("top-k").map_err(|e| anyhow!(e))?,
            max_new_tokens: a.usize("max-new-tokens").map_err(|e| anyhow!(e))?,
            seed: ctx.train_seed,
            stop_at_eot: true,
        },
        precision,
        obs,
    };

    if let Some(addr) = a.get("http") {
        // Long-running front-end: resident scheduler + accept loop, up
        // until the process is killed.
        let sched = Arc::new(StreamScheduler::start(model, tok, cfg)?);
        let server = HttpServer::bind(&addr, sched)?;
        let at = server.local_addr();
        println!(
            "serving {} over http://{at} — {} weights ({resident} resident bytes), \
             {backend} kernels",
            a.str("variant"),
            precision.label()
        );
        println!("\ntry it:");
        println!(
            "  curl -s http://{at}/v1/generate -d '{{\"prompt\": \"Once upon a time\", \
             \"id\": 7, \"max_new_tokens\": 48}}'"
        );
        println!(
            "  curl -sN http://{at}/v1/stream -d '{{\"prompt\": \"Once upon a time\", \
             \"max_new_tokens\": 48}}'"
        );
        println!("  curl -s http://{at}/healthz");
        println!("  curl -s http://{at}/metrics");
        println!("  hsm request --addr {at} --stream --prompt \"Once upon a time\"");
        server.join();
        return Ok(());
    }

    // One-shot batch mode.
    let n = a.usize("requests").map_err(|e| anyhow!(e))?;
    let requests: Vec<Request> = (0..n)
        .map(|i| Request::new(i as u64, TABLE3_PROMPTS[i % TABLE3_PROMPTS.len()]))
        .collect();
    let (max_active, threads) = (cfg.max_active, cfg.threads);
    let sched = Scheduler::new(model, cfg)?;
    println!(
        "serving a {n}-request batch — {} weights ({resident} resident bytes), \
         {backend} kernels",
        precision.label()
    );

    let t0 = Instant::now();
    let completions = sched.serve(&tok, requests)?;
    let secs = t0.elapsed().as_secs_f64();

    let mut tokens = 0usize;
    for c in &completions {
        tokens += c.tokens_generated;
        let head: String = c.completion.replace('\n', " ").chars().take(56).collect();
        let why = match &c.finish {
            FinishReason::Eot => "eot".to_string(),
            FinishReason::MaxTokens => "cap".to_string(),
            FinishReason::CtxFull => "ctx".to_string(),
            FinishReason::TimedOut => "timed out in queue".to_string(),
            FinishReason::Cancelled => "cancelled by consumer".to_string(),
            FinishReason::Rejected(e) => format!("rejected: {e}"),
            FinishReason::Throttled(e) => format!("throttled: {e}"),
        };
        let cached = if c.cached_prefix_len > 0 {
            format!(" ({} prefix tok cached)", c.cached_prefix_len)
        } else {
            String::new()
        };
        println!("#{:<4} {:>3} tok [{why}]{cached} {head}", c.request_id, c.tokens_generated);
    }
    println!(
        "\nserved {} requests / {tokens} tokens in {secs:.2}s — {:.1} tok/s \
         (max_active {max_active}, threads {threads})",
        completions.len(),
        tokens as f64 / secs.max(1e-9),
    );
    print_spec_summary(&completions);
    Ok(())
}

/// Shared `--quota-requests` / `--quota-tokens` / `--quota-window-ms`
/// parsing for `serve` and `loadgen`'s self-hosted target: `None`
/// (quotas off) until at least one cap is set.
fn quota_from_args(a: &Args) -> Result<Option<QuotaCfg>> {
    let requests = a.u64("quota-requests").map_err(|e| anyhow!(e))?;
    let tokens = a.u64("quota-tokens").map_err(|e| anyhow!(e))?;
    if requests == 0 && tokens == 0 {
        return Ok(None);
    }
    Ok(Some(QuotaCfg {
        max_requests: requests,
        max_tokens: tokens,
        window: std::time::Duration::from_millis(a.u64("quota-window-ms").map_err(|e| anyhow!(e))?),
    }))
}

fn cmd_request(argv: &[String]) -> Result<()> {
    let a = Args::new("request")
        .flag("addr", "127.0.0.1:8080", "address of a running `hsm serve --http` server")
        .flag("prompt", "Once upon a time", "prompt text")
        .switch("stream", "use /v1/stream and print per-token deltas as they arrive")
        .optional("id", "request id (fixes the sampling stream; default: server-assigned)")
        .optional("max-new-tokens", "per-request token cap (default: server's)")
        .optional("user", "user identity for per-user quota accounting")
        .optional("deadline-ms", "queue-wait deadline: the server finishes the request timed_out past this")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let addr = a.str("addr");
    let mut req = GenerateRequest::new(&a.str("prompt"));
    if let Some(id) = a.get("id") {
        req.id = Some(id.parse().map_err(|_| anyhow!("--id expects an integer"))?);
    }
    if let Some(m) = a.get("max-new-tokens") {
        req.max_new_tokens =
            Some(m.parse().map_err(|_| anyhow!("--max-new-tokens expects an integer"))?);
    }
    req.user = a.get("user");
    if let Some(d) = a.get("deadline-ms") {
        req.deadline_ms =
            Some(d.parse().map_err(|_| anyhow!("--deadline-ms expects an integer"))?);
    }

    let completion = if a.bool("stream") {
        use std::io::Write as _;
        print!("{}", req.prompt);
        std::io::stdout().flush().ok();
        let c = http_client::stream(&addr, &req, |_, delta| {
            print!("{delta}");
            std::io::stdout().flush().ok();
        })?;
        println!();
        c
    } else {
        match http_client::try_generate(&addr, &req)? {
            http_client::ApiOutcome::Done(c) => {
                println!("{}{}", c.prompt, c.completion);
                c
            }
            http_client::ApiOutcome::Throttled { retry_after, message } => {
                bail!("{message} — retry after {}s", retry_after.as_secs());
            }
        }
    };
    println!(
        "\n#{} — {} tokens, finish: {}{}",
        completion.request_id,
        completion.tokens_generated,
        completion.finish.label(),
        if completion.cached_prefix_len > 0 {
            format!(" ({} prefix tokens served from cache)", completion.cached_prefix_len)
        } else {
            String::new()
        }
    );
    match &completion.finish {
        FinishReason::Rejected(why) => println!("rejected: {why}"),
        FinishReason::Throttled(why) => println!("throttled: {why}"),
        _ => {}
    }
    Ok(())
}

fn cmd_loadgen(argv: &[String]) -> Result<()> {
    let a = Args::new("loadgen")
        .optional("addr", "drive a running `hsm serve --http` server (default: self-hosted loopback on synthetic weights)")
        .flag("seed", "42", "schedule seed — fixes arrivals, prompts, users and token budgets")
        .flag("requests", "24", "requests per scenario")
        .flag("rate", "30", "offered load, requests per second (open loop: a slow server never throttles the generator)")
        .flag("scenario", "all", "short_chat | long_generation | streaming | all")
        .flag("out", "BENCH_load.json", "report path")
        .flag("max-active", "4", "self-host: concurrent decode sessions")
        .flag("threads", "2", "self-host: worker threads")
        .flag("max-queue-depth", "0", "self-host: refuse admissions beyond this many queued jobs (429 + Retry-After; 0 = unbounded)")
        .flag("quota-requests", "0", "self-host: per-user requests per quota window (0 = unlimited)")
        .flag("quota-tokens", "0", "self-host: per-user tokens per quota window (0 = unlimited)")
        .flag("quota-window-ms", "60000", "self-host: per-user quota window length")
        .switch("edf", "self-host: earliest-deadline-first queue ordering")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let seed = a.u64("seed").map_err(|e| anyhow!(e))?;
    let all = loadgen::builtin_scenarios(
        a.usize("requests").map_err(|e| anyhow!(e))?,
        a.f64("rate").map_err(|e| anyhow!(e))?,
    );
    let scenarios: Vec<_> = match a.str("scenario").as_str() {
        "all" => all,
        name => {
            let picked: Vec<_> = all.into_iter().filter(|s| s.name == name).collect();
            if picked.is_empty() {
                bail!("unknown --scenario {name:?} (expected short_chat, long_generation, streaming or all)");
            }
            picked
        }
    };

    // Without --addr, host the target in-process: the same resident
    // scheduler + HTTP front-end `hsm serve --http` runs, on synthetic
    // weights and an OS-assigned loopback port.
    let (hosted, addr) = match a.get("addr") {
        Some(addr) => (None, addr),
        None => {
            let cfg = ServeCfg {
                max_active: a.usize("max-active").map_err(|e| anyhow!(e))?,
                threads: a.usize("threads").map_err(|e| anyhow!(e))?,
                max_queue_depth: a.usize("max-queue-depth").map_err(|e| anyhow!(e))?,
                quota: quota_from_args(&a)?,
                edf: a.bool("edf"),
                sample: SampleCfg { seed, ..SampleCfg::default() },
                ..Default::default()
            };
            let hosted = loadgen::SelfHosted::start(cfg)?;
            let addr = hosted.addr().to_string();
            println!("self-hosted loopback target at http://{addr}");
            (Some(hosted), addr)
        }
    };

    let outcomes = loadgen::run(&addr, &scenarios, seed)?;
    for o in &outcomes {
        println!(
            "{:<16} {:>3} sent: {:>3} ok, {:>2} throttled, {:>2} rejected, {:>2} timed_out, \
             {:>2} errors — ttft p50/p95/p99 {:.1}/{:.1}/{:.1} ms, queue p99 {:.1} ms, \
             {:.1} tok/s (schedule {:016x})",
            o.name,
            o.sent,
            o.completed,
            o.throttled,
            o.rejected,
            o.timed_out,
            o.errors,
            o.ttft_ms[0],
            o.ttft_ms[1],
            o.ttft_ms[2],
            o.queue_wait_ms[2],
            o.tok_per_s,
            o.digest,
        );
    }
    let out = a.str("out");
    std::fs::write(&out, format!("{}\n", loadgen::report_json(seed, &outcomes)))
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {out}");
    if let Some(h) = hosted {
        h.shutdown();
    }
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let a = experiment_flags(Args::new("report <table1|table2|table3|fig7|fig8>"))
        .optional("variants", "comma-separated variant subset")
        .flag("max-new-tokens", "24", "table3: tokens per completion")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let which = a
        .positional
        .first()
        .ok_or_else(|| anyhow!("report needs a target: table1|table2|table3|fig7|fig8"))?
        .clone();
    let ctx = ctx_from_args(&a)?;
    let factory = PjrtFactory::new(&ctx.preset);
    let chosen: Vec<String> = match a.get("variants") {
        Some(list) => list.split(',').map(str::to_string).collect(),
        None => match which.as_str() {
            "fig7" => FIG7_VARIANTS.iter().map(|s| s.to_string()).collect(),
            "table3" | "all" => VARIANTS.iter().map(|s| s.to_string()).collect(),
            _ => TABLE1_VARIANTS.iter().map(|s| s.to_string()).collect(),
        },
    };
    let refs: Vec<&str> = chosen.iter().map(String::as_str).collect();
    match which.as_str() {
        "all" => {
            let md = report::run_all(
                &factory,
                &ctx,
                &refs,
                a.usize("max-new-tokens").map_err(|e| anyhow!(e))?,
            )?;
            println!("\n{md}");
        }
        "table1" => {
            let md = report::run_table1(&factory, &ctx, &refs)?;
            println!("\n{md}");
        }
        "table2" => {
            let md = report::run_table2(&factory, &ctx)?;
            println!("\n{md}");
        }
        "table3" => {
            let md = report::run_table3(
                &factory,
                &ctx,
                &refs,
                a.usize("max-new-tokens").map_err(|e| anyhow!(e))?,
            )?;
            println!("\n{md}");
        }
        "fig7" => {
            let p = report::run_fig7(&factory, &ctx, &refs)?;
            println!("wrote {}", p.display());
        }
        "fig8" => {
            let (p, r) = report::run_fig8(&factory, &ctx, &refs)?;
            println!("wrote {} (pearson(loss, acc) = {r:.4})", p.display());
        }
        other => bail!("unknown report target {other:?}"),
    }
    Ok(())
}

fn cmd_corpus(argv: &[String]) -> Result<()> {
    let a = Args::new("corpus")
        .flag("seed", "1234", "generator seed")
        .flag("stories", "2000", "number of stories")
        .flag("out", "corpus.txt", "output path")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let text = corpus::generate(a.u64("seed").map_err(|e| anyhow!(e))?, a.usize("stories").map_err(|e| anyhow!(e))?);
    std::fs::write(a.str("out"), &text)?;
    println!("wrote {} bytes ({} stories) to {}", text.len(), a.str("stories"), a.str("out"));
    Ok(())
}

fn cmd_tokenizer(argv: &[String]) -> Result<()> {
    let a = Args::new("tokenizer")
        .flag("vocab", "512", "vocabulary size")
        .optional("corpus", "training corpus path (default: synthetic)")
        .flag("out", "tokenizer.json", "output path")
        .optional("encode", "text to encode with --load")
        .optional("load", "load an existing tokenizer")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    if let Some(path) = a.get("load") {
        let tok = Tokenizer::load(&PathBuf::from(path))?;
        println!("vocab size: {}", tok.vocab_size());
        if let Some(text) = a.get("encode") {
            let ids = tok.encode(&text);
            println!("{ids:?}");
            println!("decoded: {:?}", tok.decode(&ids));
        }
        return Ok(());
    }
    let text = match a.get("corpus") {
        Some(p) => std::fs::read_to_string(p)?,
        None => corpus::generate(1234, 2000),
    };
    let tok = tok_trainer::train(&text, a.usize("vocab").map_err(|e| anyhow!(e))?)?;
    tok.save(&PathBuf::from(a.str("out")))?;
    println!("trained {}-token vocab → {}", tok.vocab_size(), a.str("out"));
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let a = Args::new("info")
        .flag("preset", "ci", "size preset")
        .required("variant", "model variant")
        .parse(argv)
        .map_err(|e| anyhow!(e))?;
    let m = Manifest::load_variant(&artifacts_root(), &a.str("preset"), &a.str("variant"))?;
    println!("{} ({}) — preset {}", m.display_name, m.variant, m.preset);
    println!("dim {} ctx {} vocab {} — {} parameters", m.dim, m.ctx, m.vocab, m.param_count);
    println!("kernels: {}", m.kernels);
    for (i, l) in m.layers.iter().enumerate() {
        println!("  layer {i}: {} heads={} shifts={:?} ffn={}", l.kind, l.heads, l.shifts, l.ffn);
    }
    println!("train: batch {} lr {} dropout {}", m.train.batch, m.train.lr, m.train.dropout);
    println!("{} tensors, {} total elements", m.params.len(), m.total_elems());
    Ok(())
}
