//! Byte-level BPE tokenizer, from scratch.
//!
//! The paper tokenizes TinyStories with "a custom-trained byte-level BPE
//! tokenizer" (§6.2).  This module implements the full GPT-2-style
//! pipeline:
//!
//! 1. [`bytes::byte_to_unicode`] — the reversible byte ↔ printable-unicode
//!    table GPT-2 uses so merges operate on visible characters.
//! 2. [`trainer`] — BPE training: iterated most-frequent-pair merging over
//!    a word-frequency table, with GPT-2's regex-like pre-tokenization
//!    (implemented directly, no regex crate needed).
//! 3. [`Tokenizer`] — encoding (greedy lowest-rank merging, linear-time
//!    pair scan) and decoding (merge table → bytes → UTF-8).
//! 4. Vocabulary (de)serialization to a single JSON file.
//!
//! Invariants (property-tested): `decode(encode(s)) == s` for every UTF-8
//! string; token ids are dense in `[0, vocab)`; training is deterministic.

pub mod bytes;
pub mod trainer;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Value};

/// A trained byte-level BPE tokenizer.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// token id → token string (in byte-unicode space).
    pub vocab: Vec<String>,
    /// token string → id.
    pub lookup: HashMap<String, u32>,
    /// merge pair → rank (lower merges first).
    pub merges: HashMap<(String, String), u32>,
    /// id of the end-of-text sentinel appended between documents.
    pub eot: u32,
}

/// The end-of-text sentinel token string.
pub const EOT_TOKEN: &str = "<|endoftext|>";

impl Tokenizer {
    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Encode text to token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 3);
        for word in pre_tokenize(text) {
            self.encode_word(&word, &mut out);
        }
        out
    }

    /// Encode one pre-token by greedy lowest-rank pair merging.
    fn encode_word(&self, word: &str, out: &mut Vec<u32>) {
        // Map to byte-unicode space, one symbol per input byte.
        let mut parts: Vec<String> = word
            .bytes()
            .map(|b| bytes::byte_to_unicode(b).to_string())
            .collect();
        if parts.is_empty() {
            return;
        }
        // Repeatedly apply the lowest-rank applicable merge.
        loop {
            let mut best: Option<(u32, usize)> = None;
            for i in 0..parts.len().saturating_sub(1) {
                if let Some(&rank) = self
                    .merges
                    .get(&(parts[i].clone(), parts[i + 1].clone()))
                {
                    if best.map_or(true, |(r, _)| rank < r) {
                        best = Some((rank, i));
                    }
                }
            }
            match best {
                None => break,
                Some((_, i)) => {
                    let merged = format!("{}{}", parts[i], parts[i + 1]);
                    parts.splice(i..i + 2, [merged]);
                }
            }
        }
        for p in &parts {
            match self.lookup.get(p) {
                Some(&id) => out.push(id),
                // Unreachable for a well-formed vocab (all 256 bytes are
                // base tokens), but degrade gracefully.
                None => out.extend(p.chars().filter_map(|c| {
                    self.lookup.get(&c.to_string()).copied()
                })),
            }
        }
    }

    /// Append one token's raw bytes to `buf` (the EOT sentinel and
    /// unknown ids contribute nothing).  The single source of truth for
    /// token→bytes, shared by [`decode`](Self::decode) and the streaming
    /// [`StreamDecoder`] so the two paths can never drift.
    pub fn token_bytes(&self, id: u32, buf: &mut Vec<u8>) {
        if id == self.eot {
            return;
        }
        if let Some(tok) = self.vocab.get(id as usize) {
            for ch in tok.chars() {
                if let Some(b) = bytes::unicode_to_byte(ch) {
                    buf.push(b);
                }
            }
        }
    }

    /// Decode token ids back to text (lossy only on invalid UTF-8 splices).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut buf: Vec<u8> = Vec::with_capacity(ids.len() * 3);
        for &id in ids {
            self.token_bytes(id, &mut buf);
        }
        String::from_utf8_lossy(&buf).into_owned()
    }

    // -- persistence --------------------------------------------------------

    /// Serialize vocab + merges to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut merges: Vec<(&(String, String), &u32)> = self.merges.iter().collect();
        merges.sort_by_key(|(_, &rank)| rank);
        let doc = json::obj(vec![
            ("version", json::num(1.0)),
            (
                "vocab",
                Value::Arr(self.vocab.iter().map(|t| json::s(t)).collect()),
            ),
            (
                "merges",
                Value::Arr(
                    merges
                        .iter()
                        .map(|((a, b), _)| Value::Arr(vec![json::s(a), json::s(b)]))
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(path, doc.to_string())
            .with_context(|| format!("writing tokenizer to {}", path.display()))
    }

    /// Load a tokenizer saved by [`Tokenizer::save`].
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tokenizer from {}", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let vocab: Vec<String> = doc
            .get("vocab")
            .as_arr()
            .ok_or_else(|| anyhow!("tokenizer json missing 'vocab'"))?
            .iter()
            .map(|v| v.as_str().map(str::to_string).ok_or_else(|| anyhow!("bad vocab entry")))
            .collect::<Result<_>>()?;
        let merges_arr = doc
            .get("merges")
            .as_arr()
            .ok_or_else(|| anyhow!("tokenizer json missing 'merges'"))?;
        let mut merges = HashMap::new();
        for (rank, m) in merges_arr.iter().enumerate() {
            let a = m.at(0).as_str().ok_or_else(|| anyhow!("bad merge"))?;
            let b = m.at(1).as_str().ok_or_else(|| anyhow!("bad merge"))?;
            merges.insert((a.to_string(), b.to_string()), rank as u32);
        }
        Self::from_parts(vocab, merges)
    }

    /// Build the derived lookup structures and validate the vocab.
    pub fn from_parts(
        vocab: Vec<String>,
        merges: HashMap<(String, String), u32>,
    ) -> Result<Self> {
        let lookup: HashMap<String, u32> = vocab
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        if lookup.len() != vocab.len() {
            bail!("duplicate tokens in vocabulary");
        }
        let eot = *lookup
            .get(EOT_TOKEN)
            .ok_or_else(|| anyhow!("vocabulary lacks {EOT_TOKEN}"))?;
        Ok(Tokenizer { vocab, lookup, merges, eot })
    }
}

/// Incremental detokenizer for streaming: feed token ids one at a time
/// and get back exactly the text [`Tokenizer::decode`] would produce for
/// the whole sequence, in byte-identical fragments.
///
/// A BPE token can end in the middle of a multi-byte UTF-8 sequence, so
/// a per-token `decode` of the suffix would emit replacement characters
/// that the full decode would not.  `StreamDecoder` holds such trailing
/// bytes back until the sequence resolves: [`push`](StreamDecoder::push)
/// emits the longest prefix whose interpretation can never change
/// (complete characters, plus one U+FFFD per maximal invalid subpart —
/// the same policy `String::from_utf8_lossy` applies), and
/// [`finish`](StreamDecoder::finish) flushes a still-incomplete tail as
/// the single U+FFFD the full-sequence decode would render it as.
///
/// Invariant (property-tested): for any id sequence,
/// `pushes.concat() + finish() == tok.decode(&ids)`.
#[derive(Debug, Clone, Default)]
pub struct StreamDecoder {
    /// Bytes decoded from tokens but not yet emitted as text (a possibly
    /// incomplete trailing UTF-8 sequence).
    pending: Vec<u8>,
}

impl StreamDecoder {
    pub fn new() -> Self {
        StreamDecoder { pending: Vec::new() }
    }

    /// Feed one token; returns the text it unlocked (possibly empty
    /// while a multi-byte character is still incomplete).
    pub fn push(&mut self, tok: &Tokenizer, id: u32) -> String {
        tok.token_bytes(id, &mut self.pending);
        self.drain(false)
    }

    /// End of sequence: flush any trailing incomplete UTF-8 sequence as
    /// U+FFFD (exactly how the full-sequence lossy decode renders it).
    pub fn finish(&mut self) -> String {
        self.drain(true)
    }

    fn drain(&mut self, flush: bool) -> String {
        let mut out = String::new();
        let mut start = 0usize;
        loop {
            match std::str::from_utf8(&self.pending[start..]) {
                Ok(s) => {
                    out.push_str(s);
                    start = self.pending.len();
                    break;
                }
                Err(e) => {
                    let valid = e.valid_up_to();
                    out.push_str(
                        std::str::from_utf8(&self.pending[start..start + valid])
                            .expect("valid_up_to prefix is valid UTF-8"),
                    );
                    start += valid;
                    match e.error_len() {
                        // Definitely invalid bytes: one replacement char
                        // per maximal invalid subpart, like from_utf8_lossy.
                        Some(n) => {
                            out.push('\u{FFFD}');
                            start += n;
                        }
                        // Incomplete tail: hold it back — the next token
                        // may complete the character.
                        None => {
                            if flush {
                                out.push('\u{FFFD}');
                                start = self.pending.len();
                            }
                            break;
                        }
                    }
                }
            }
        }
        self.pending.drain(..start);
        out
    }
}

/// GPT-2-style pre-tokenization, implemented directly (no regex crate):
/// splits into pieces of the form
/// `contraction | [space]letters | [space]digits | [space]other | whitespace`.
/// A leading space is glued to the following word, as in GPT-2.
pub fn pre_tokenize(text: &str) -> Vec<String> {
    let chars: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    let n = chars.len();

    let is_letter = |c: char| c.is_alphabetic();
    let is_digit = |c: char| c.is_numeric();
    let is_space = |c: char| c.is_whitespace();

    while i < n {
        let start = i;
        // Contractions: 's 't 're 've 'm 'll 'd
        if chars[i] == '\'' && i + 1 < n {
            let rest: String = chars[i + 1..n.min(i + 3)].iter().collect();
            for suf in ["ll", "re", "ve", "s", "t", "m", "d"] {
                if rest.starts_with(suf)
                    && suf
                        .chars()
                        .zip(&chars[i + 1..])
                        .all(|(a, &b)| a == b)
                {
                    // only treat as contraction when preceded by a letter
                    if start > 0 && is_letter(chars[start - 1]) {
                        i += 1 + suf.len();
                        out.push(chars[start..i].iter().collect());
                        break;
                    }
                }
            }
            if i != start {
                continue;
            }
        }
        // Optional single leading space glued to the next run.
        let mut j = i;
        let lead_space = chars[j] == ' '
            && j + 1 < n
            && (is_letter(chars[j + 1]) || is_digit(chars[j + 1]) || !is_space(chars[j + 1]));
        if lead_space {
            j += 1;
        }
        if j < n && is_letter(chars[j]) {
            while j < n && is_letter(chars[j]) && chars[j] != '\'' {
                j += 1;
            }
            // stop before contraction apostrophe
        } else if j < n && is_digit(chars[j]) {
            while j < n && is_digit(chars[j]) {
                j += 1;
            }
        } else if j < n && !is_space(chars[j]) {
            // Punctuation / symbol run.  A leading apostrophe that did not
            // form a contraction is consumed here (j == i guard below
            // guarantees progress on any input).
            if chars[j] == '\'' {
                j += 1;
            }
            while j < n && !is_space(chars[j]) && !is_letter(chars[j]) && !is_digit(chars[j]) && chars[j] != '\'' {
                j += 1;
            }
        } else {
            // whitespace run (no glued space case)
            j = i;
            while j < n && is_space(chars[j]) {
                j += 1;
            }
            // leave the final space to glue onto a following word
            if j < n && j > i && chars[j - 1] == ' ' {
                j -= 1;
            }
        }
        if j <= i {
            j = i + 1; // guaranteed progress on any input
        }
        out.push(chars[i..j].iter().collect());
        i = j;
    }
    out.retain(|s: &String| !s.is_empty());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn tiny_tok() -> Tokenizer {
        // Train on a small corpus; exercises the full pipeline.
        trainer::train(
            "the cat sat on the mat. the cat was happy! once upon a time there was a cat.",
            300,
        )
        .unwrap()
    }

    #[test]
    fn pre_tokenize_glues_spaces() {
        let parts = pre_tokenize("the cat's hat 42!");
        assert_eq!(parts[0], "the");
        assert!(parts.contains(&" cat".to_string()));
        assert!(parts.contains(&"'s".to_string()));
        assert!(parts.contains(&" 42".to_string()));
    }

    #[test]
    fn pre_tokenize_roundtrip_concat() {
        for s in ["hello world", "a  b\n\nc", " leading", "trailing ", "it's x!?"] {
            assert_eq!(pre_tokenize(s).concat(), s, "for {s:?}");
        }
    }

    #[test]
    fn encode_decode_roundtrip_basic() {
        let tok = tiny_tok();
        for s in [
            "the cat sat on the mat.",
            "Once upon a time!",
            "unseen wörds 😀 are fine",
            "",
        ] {
            assert_eq!(tok.decode(&tok.encode(s)), s, "for {s:?}");
        }
    }

    #[test]
    fn encode_decode_roundtrip_property() {
        let tok = tiny_tok();
        prop::check("bpe-roundtrip", |rng| {
            let s = prop::arb_string(rng, 60);
            assert_eq!(tok.decode(&tok.encode(&s)), s, "for {s:?}");
        });
    }

    #[test]
    fn compresses_training_text() {
        let tok = tiny_tok();
        let s = "the cat sat on the mat";
        let ids = tok.encode(s);
        assert!(ids.len() < s.len(), "{} !< {}", ids.len(), s.len());
    }

    #[test]
    fn save_load_roundtrip() {
        let tok = tiny_tok();
        let dir = std::env::temp_dir().join("hsm_tok_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tok.json");
        tok.save(&path).unwrap();
        let tok2 = Tokenizer::load(&path).unwrap();
        assert_eq!(tok.vocab, tok2.vocab);
        let s = "the cat sat";
        assert_eq!(tok.encode(s), tok2.encode(s));
    }

    #[test]
    fn eot_skipped_in_decode() {
        let tok = tiny_tok();
        let mut ids = tok.encode("the cat");
        ids.push(tok.eot);
        assert_eq!(tok.decode(&ids), "the cat");
    }

    /// Concatenated stream deltas must be byte-identical to the one-shot
    /// decode, including across multi-byte characters split over tokens.
    #[test]
    fn stream_decoder_matches_decode_basic() {
        let tok = tiny_tok();
        for s in ["the cat sat", "unseen wörds 😀 are fine", "é中🌍", ""] {
            let ids = tok.encode(s);
            let mut sd = StreamDecoder::new();
            let mut streamed = String::new();
            for &id in &ids {
                streamed.push_str(&sd.push(&tok, id));
            }
            streamed.push_str(&sd.finish());
            assert_eq!(streamed, tok.decode(&ids), "for {s:?}");
        }
    }

    #[test]
    fn stream_decoder_holds_back_incomplete_utf8() {
        let tok = tiny_tok();
        // "😀" is 4 bytes; with a vocab trained on ASCII the emoji comes
        // out as byte-level tokens, so early pushes must emit nothing.
        let ids = tok.encode("😀");
        assert!(ids.len() > 1, "emoji should split into byte tokens");
        let mut sd = StreamDecoder::new();
        let mut deltas: Vec<String> = ids.iter().map(|&id| sd.push(&tok, id)).collect();
        deltas.push(sd.finish());
        for d in &deltas[..deltas.len() - 2] {
            assert!(d.is_empty(), "mid-character delta must be empty, got {d:?}");
        }
        assert_eq!(deltas.concat(), "😀");
    }

    /// Arbitrary id sequences — including the EOT sentinel and ids that
    /// splice invalid UTF-8 — stream to the same text as `decode`.
    #[test]
    fn stream_decoder_matches_decode_property() {
        let tok = tiny_tok();
        let vocab = tok.vocab_size() as u32;
        prop::check("stream-decode-parity", |rng| {
            let ids = prop::arb_tokens(rng, vocab, 40);
            let mut sd = StreamDecoder::new();
            let mut streamed = String::new();
            for &id in &ids {
                streamed.push_str(&sd.push(&tok, id));
            }
            streamed.push_str(&sd.finish());
            assert_eq!(streamed, tok.decode(&ids), "for ids {ids:?}");
        });
    }
}
