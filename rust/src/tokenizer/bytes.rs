//! GPT-2's reversible byte ↔ unicode mapping.
//!
//! BPE merges operate on strings; raw bytes 0x00–0x20, 0x7F–0xA0 and 0xAD
//! are invisible or unprintable, so GPT-2 remaps every byte to a printable
//! unicode codepoint: printable bytes map to themselves, the rest map to
//! 0x100, 0x101, … in order.  The mapping is a bijection, so decoding is
//! exact.

/// Is this byte printable per GPT-2's rule?
fn printable(b: u8) -> bool {
    (0x21..=0x7E).contains(&b) || (0xA1..=0xAC).contains(&b) || (0xAE..=0xFF).contains(&b)
}

/// byte → printable char (bijective).
pub fn byte_to_unicode(b: u8) -> char {
    if printable(b) {
        b as char
    } else {
        // The n-th non-printable byte maps to 0x100 + n.
        let mut n = 0u32;
        for x in 0..b {
            if !printable(x) {
                n += 1;
            }
        }
        char::from_u32(0x100 + n).unwrap()
    }
}

/// printable char → byte (inverse of [`byte_to_unicode`]).
pub fn unicode_to_byte(c: char) -> Option<u8> {
    let cp = c as u32;
    if cp < 0x100 && printable(cp as u8) {
        return Some(cp as u8);
    }
    if (0x100..0x200).contains(&cp) {
        let target = cp - 0x100;
        let mut n = 0u32;
        for b in 0..=255u8 {
            if !printable(b) {
                if n == target {
                    return Some(b);
                }
                n += 1;
            }
        }
    }
    None
}

/// Map a full byte string into byte-unicode space.
pub fn to_unicode(bytes: &[u8]) -> String {
    bytes.iter().map(|&b| byte_to_unicode(b)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn bijective_over_all_bytes() {
        let mut seen = HashSet::new();
        for b in 0..=255u8 {
            let c = byte_to_unicode(b);
            assert!(seen.insert(c), "collision at byte {b}");
            assert_eq!(unicode_to_byte(c), Some(b), "inverse failed at {b}");
        }
    }

    #[test]
    fn printable_bytes_map_to_themselves() {
        assert_eq!(byte_to_unicode(b'a'), 'a');
        assert_eq!(byte_to_unicode(b'!'), '!');
        assert_ne!(byte_to_unicode(b' '), ' '); // space is remapped
    }

    #[test]
    fn unmapped_chars_decode_to_none() {
        assert_eq!(unicode_to_byte('中'), None);
        assert_eq!(unicode_to_byte('\u{300}'), None);
    }

    #[test]
    fn string_roundtrip() {
        let s = "héllo wörld 🌍\n\t".as_bytes();
        let u = to_unicode(s);
        let back: Vec<u8> = u.chars().filter_map(unicode_to_byte).collect();
        assert_eq!(back, s);
    }
}
