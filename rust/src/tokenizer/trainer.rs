//! BPE training: iterated most-frequent-pair merging.
//!
//! The classic algorithm over a word-frequency table, with incremental
//! pair-count maintenance so training a 5 000-token vocabulary over a
//! multi-megabyte corpus stays fast: each merge touches only the words
//! that actually contain the merged pair (tracked in an inverted index)
//! rather than rescanning the corpus.
//!
//! Ties between equal-count pairs break lexicographically so training is
//! fully deterministic — the reproducibility tests depend on it.

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Result};

use super::{bytes, pre_tokenize, Tokenizer, EOT_TOKEN};

/// Train a byte-level BPE tokenizer with `vocab_size` total tokens
/// (256 base bytes + merges + the end-of-text sentinel).
pub fn train(corpus: &str, vocab_size: usize) -> Result<Tokenizer> {
    if vocab_size < 257 {
        bail!("vocab_size must be at least 257 (256 bytes + EOT)");
    }

    // 1. Word frequency table over pre-tokens (in byte-unicode space).
    let mut word_freq: HashMap<String, u64> = HashMap::new();
    for w in pre_tokenize(corpus) {
        *word_freq.entry(bytes::to_unicode(w.as_bytes())).or_insert(0) += 1;
    }

    // Words as mutable symbol sequences.
    let mut words: Vec<(Vec<String>, u64)> = word_freq
        .into_iter()
        .map(|(w, f)| (w.chars().map(|c| c.to_string()).collect(), f))
        .collect();
    // Sort for determinism (HashMap iteration order is randomized).
    words.sort();

    // 2. Initial pair statistics + inverted index pair → words containing it.
    let mut pair_count: HashMap<(String, String), i64> = HashMap::new();
    let mut pair_words: HashMap<(String, String), HashSet<usize>> = HashMap::new();
    for (wi, (syms, freq)) in words.iter().enumerate() {
        for i in 0..syms.len().saturating_sub(1) {
            let p = (syms[i].clone(), syms[i + 1].clone());
            *pair_count.entry(p.clone()).or_insert(0) += *freq as i64;
            pair_words.entry(p).or_default().insert(wi);
        }
    }

    // 3. Merge loop.
    let n_merges = vocab_size.saturating_sub(257);
    let mut merges: Vec<(String, String)> = Vec::with_capacity(n_merges);
    for _ in 0..n_merges {
        // Most frequent pair; lexicographic tie-break for determinism.
        let best = pair_count
            .iter()
            .filter(|(_, &c)| c > 0)
            .max_by(|(pa, ca), (pb, cb)| ca.cmp(cb).then_with(|| pb.cmp(pa)))
            .map(|(p, _)| p.clone());
        let Some(pair) = best else { break };
        let merged = format!("{}{}", pair.0, pair.1);
        merges.push(pair.clone());

        // Update only the words that contain this pair.
        let affected: Vec<usize> = pair_words
            .get(&pair)
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default();
        for wi in affected {
            let (syms, freq) = &mut words[wi];
            let f = *freq as i64;
            // Remove this word's contribution to all its current pairs.
            for i in 0..syms.len().saturating_sub(1) {
                let p = (syms[i].clone(), syms[i + 1].clone());
                *pair_count.get_mut(&p).unwrap() -= f;
                if let Some(ws) = pair_words.get_mut(&p) {
                    ws.remove(&wi);
                }
            }
            // Apply the merge within the word.
            let mut out: Vec<String> = Vec::with_capacity(syms.len());
            let mut i = 0;
            while i < syms.len() {
                if i + 1 < syms.len() && syms[i] == pair.0 && syms[i + 1] == pair.1 {
                    out.push(merged.clone());
                    i += 2;
                } else {
                    out.push(syms[i].clone());
                    i += 1;
                }
            }
            *syms = out;
            // Re-add contributions.
            for i in 0..syms.len().saturating_sub(1) {
                let p = (syms[i].clone(), syms[i + 1].clone());
                *pair_count.entry(p.clone()).or_insert(0) += f;
                pair_words.entry(p).or_default().insert(wi);
            }
        }
        pair_count.remove(&pair);
        pair_words.remove(&pair);
    }

    // 4. Assemble the vocabulary: 256 byte tokens, merged tokens, EOT.
    let mut vocab: Vec<String> = (0..=255u8).map(|b| bytes::byte_to_unicode(b).to_string()).collect();
    for (a, b) in &merges {
        vocab.push(format!("{a}{b}"));
    }
    vocab.push(EOT_TOKEN.to_string());

    let merge_ranks: HashMap<(String, String), u32> = merges
        .into_iter()
        .enumerate()
        .map(|(i, p)| (p, i as u32))
        .collect();

    Tokenizer::from_parts(vocab, merge_ranks)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_training() {
        let corpus = "a banana and an apple and a banana band";
        let t1 = train(corpus, 280).unwrap();
        let t2 = train(corpus, 280).unwrap();
        assert_eq!(t1.vocab, t2.vocab);
    }

    #[test]
    fn respects_vocab_size() {
        let corpus = "the quick brown fox jumps over the lazy dog. \
                      the quick brown fox is quick and brown.";
        let tok = train(corpus, 300).unwrap();
        assert!(tok.vocab_size() <= 300);
        assert!(tok.vocab_size() > 257, "no merges learned");
    }

    #[test]
    fn frequent_word_becomes_single_token() {
        let corpus = &"hello world ".repeat(50);
        let tok = train(corpus, 300).unwrap();
        // " world" (with glued space) should encode to very few tokens.
        let ids = tok.encode(" world");
        assert!(ids.len() <= 2, "got {} tokens", ids.len());
    }

    #[test]
    fn small_vocab_rejected() {
        assert!(train("x", 10).is_err());
    }

    #[test]
    fn merge_count_matches_vocab() {
        let corpus = "aaa bbb aaa bbb aaa";
        let tok = train(corpus, 270).unwrap();
        assert_eq!(tok.vocab_size(), 256 + tok.merges.len() + 1);
    }
}
