//! Execution surface + PJRT runtime (feature `pjrt`).
//!
//! The [`StepEngine`] trait abstracts artifact execution so the
//! coordinator, scheduler, generation and report drivers are testable
//! without artifacts (see `MockEngine` in `coordinator`, and the
//! artifact-free [`crate::infer::WindowEngine`]).  The trait and its
//! metrics type are always compiled; the PJRT-backed implementation
//! below needs the `xla` crate and is gated behind the default `pjrt`
//! feature — `--no-default-features` builds (Mock + native inference)
//! never touch it.
//!
//! ### PJRT state placement — and a load-bearing leak workaround
//!
//! Inputs are passed as **device-resident [`xla::PjRtBuffer`]s via
//! `execute_b`**, never as literals via `execute`: xla_extension 0.5.1's
//! C shim for the literal path leaks the input buffers it creates
//! internally (~280 KB *per step* at ci scale, measured — enough to OOM a
//! 12-variant sweep).  Buffers we create ourselves through
//! `buffer_from_host_literal` are freed by the crate's `Drop`.
//!
//! Results still arrive as a **single tuple buffer** (no output
//! flattening in 0.5.1) and tuple buffers cannot be fed back as inputs,
//! so updated state does one device→host→device round-trip per step
//! (a memcpy on this CPU backend — measured at ≈3 % of step time in
//! EXPERIMENTS.md §Perf).  On a modern PJRT one would lower untupled and
//! donate input buffers; called out as the first TPU-port task in
//! DESIGN.md §8.

use anyhow::Result;

use crate::config::Manifest;
use crate::data::Batch;

/// Loss/accuracy pair returned by train and eval steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepMetrics {
    pub loss: f32,
    pub acc: f32,
}

/// Execution surface the coordinator drives.  Implemented by
/// [`PjrtEngine`] (real PJRT, feature `pjrt`), by `MockEngine` (tests)
/// and by [`crate::infer::WindowEngine`] (native decode-only).
pub trait StepEngine {
    fn manifest(&self) -> &Manifest;

    /// Initialize model parameters from `seed` and zero the optimizer state.
    fn init(&mut self, seed: u32) -> Result<()>;

    /// One AdamW training step on `batch`; `step` is the global step count
    /// (also the dropout seed).  Updates internal state.
    fn train_step(&mut self, step: i32, batch: &Batch) -> Result<StepMetrics>;

    /// Forward-only loss/accuracy on `batch`.
    fn eval_step(&mut self, batch: &Batch) -> Result<StepMetrics>;

    /// Full-context forward on one `[1, ctx]` token row → logits
    /// `[ctx * vocab]`, row-major.
    fn decode(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Download current parameters (manifest order).
    fn get_params(&self) -> Result<Vec<Vec<f32>>>;

    /// Replace parameters (e.g. from a checkpoint).
    fn set_params(&mut self, params: Vec<Vec<f32>>) -> Result<()>;

    /// Download optimizer moments (m, v) for checkpointing.
    fn get_state(&self) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)>;

    /// Restore optimizer moments.
    fn set_state(&mut self, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>) -> Result<()>;
}

#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::path::Path;

    use anyhow::{anyhow, bail, Context, Result};
    use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

    use super::{StepEngine, StepMetrics};
    use crate::config::Manifest;
    use crate::data::Batch;

    /// A device buffer paired with the host literal it was uploaded from.
    ///
    /// `buffer_from_host_literal` copies **asynchronously** on a worker
    /// thread; dropping the source literal before the copy completes is a
    /// use-after-free (observed as a SIGSEGV inside
    /// `AbstractTfrtCpuBuffer::CopyFromLiteral`).  Holding the literal for
    /// the buffer's whole lifetime makes the pair safe without needing a
    /// synchronization point after every upload.
    struct Held {
        /// Keep-alive for the async host→device copy.  Never read back.
        _lit: Literal,
        buf: PjRtBuffer,
    }

    /// Real PJRT-backed engine.
    pub struct PjrtEngine {
        manifest: Manifest,
        client: PjRtClient,
        exe_init: PjRtLoadedExecutable,
        exe_train: Option<PjRtLoadedExecutable>,
        exe_eval: Option<PjRtLoadedExecutable>,
        exe_decode: Option<PjRtLoadedExecutable>,
        /// Device-resident state (+ keep-alive host copies), manifest order.
        params: Vec<Held>,
        m: Vec<Held>,
        v: Vec<Held>,
    }

    fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e}", path.display()))?;
        client
            .compile(&XlaComputation::from_proto(&proto))
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
    }

    impl PjrtEngine {
        /// Compile the artifacts for `manifest` on a fresh CPU client.
        ///
        /// `init` compiles eagerly; `train_step`/`eval_step`/`decode` compile
        /// lazily on first use (decode-only sessions never pay for the
        /// training executable).
        pub fn new(manifest: Manifest) -> Result<Self> {
            let client = PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
            let exe_init = compile(&client, &manifest.artifact("init"))?;
            Ok(PjrtEngine {
                manifest,
                client,
                exe_init,
                exe_train: None,
                exe_eval: None,
                exe_decode: None,
                params: Vec::new(),
                m: Vec::new(),
                v: Vec::new(),
            })
        }

        fn n(&self) -> usize {
            self.manifest.params.len()
        }

        fn check_initialized(&self) -> Result<()> {
            if self.params.len() != self.n() {
                bail!("engine not initialized — call init() or set_params() first");
            }
            Ok(())
        }

        /// Upload one literal as a rust-owned device buffer, keeping the
        /// literal alive for the buffer's lifetime (leak-safe AND
        /// async-copy-safe; see [`Held`] and the module docs).
        fn upload(&self, lit: Literal) -> Result<Held> {
            let buf = self
                .client
                .buffer_from_host_literal(None, &lit)
                .map_err(|e| anyhow!("buffer upload: {e}"))?;
            Ok(Held { _lit: lit, buf })
        }

        fn zeros_like_params(&self) -> Result<Vec<Held>> {
            self.manifest
                .params
                .iter()
                .map(|p| {
                    let lit = Literal::create_from_shape(xla::PrimitiveType::F32, &p.shape);
                    self.upload(lit)
                })
                .collect()
        }

        fn batch_buffers(&self, batch: &Batch) -> Result<(Held, Held)> {
            let dims = [batch.batch as i64, batch.ctx as i64];
            let x = Literal::vec1(&batch.x)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshaping batch x: {e}"))?;
            let y = Literal::vec1(&batch.y)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshaping batch y: {e}"))?;
            Ok((self.upload(x)?, self.upload(y)?))
        }

        /// Execute via the buffer path and decompose the single tuple result
        /// into per-output literals.
        fn run(exe: &PjRtLoadedExecutable, inputs: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
            let out = exe
                .execute_b::<&PjRtBuffer>(inputs)
                .map_err(|e| anyhow!("execute_b: {e}"))?;
            let lit = out[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("download result: {e}"))?;
            lit.to_tuple().map_err(|e| anyhow!("decompose tuple: {e}"))
        }

        /// Re-upload a decomposed result list as device-resident state.
        fn upload_all(&self, lits: Vec<Literal>) -> Result<Vec<Held>> {
            lits.into_iter().map(|l| self.upload(l)).collect()
        }

        fn literal_to_f32s(lit: &Literal) -> Result<Vec<f32>> {
            lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))
        }

        fn buffer_to_f32s(buf: &PjRtBuffer) -> Result<Vec<f32>> {
            let lit = buf
                .to_literal_sync()
                .map_err(|e| anyhow!("buffer download: {e}"))?;
            Self::literal_to_f32s(&lit)
        }

        fn f32s_to_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
            let n: usize = shape.iter().product::<usize>().max(1);
            if data.len() != n {
                bail!("shape {:?} expects {n} elems, got {}", shape, data.len());
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e}"))
        }
    }

    impl StepEngine for PjrtEngine {
        fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        fn init(&mut self, seed: u32) -> Result<()> {
            let seed_buf = self.upload(Literal::scalar(seed))?;
            let params =
                Self::run(&self.exe_init, &[&seed_buf.buf]).context("running init artifact")?;
            if params.len() != self.n() {
                bail!(
                    "init artifact returned {} tensors, manifest says {}",
                    params.len(),
                    self.n()
                );
            }
            self.params = self.upload_all(params)?;
            self.m = self.zeros_like_params()?;
            self.v = self.zeros_like_params()?;
            Ok(())
        }

        fn train_step(&mut self, step: i32, batch: &Batch) -> Result<StepMetrics> {
            self.check_initialized()?;
            if batch.batch != self.manifest.train.batch || batch.ctx != self.manifest.ctx {
                bail!(
                    "batch [{}, {}] does not match artifact [{}, {}]",
                    batch.batch,
                    batch.ctx,
                    self.manifest.train.batch,
                    self.manifest.ctx
                );
            }
            if self.exe_train.is_none() {
                self.exe_train =
                    Some(compile(&self.client, &self.manifest.artifact("train_step"))?);
            }
            let (x, y) = self.batch_buffers(batch)?;
            let step_buf = self.upload(Literal::scalar(step))?;
            let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(3 * self.n() + 3);
            inputs.extend(self.params.iter().map(|h| &h.buf));
            inputs.extend(self.m.iter().map(|h| &h.buf));
            inputs.extend(self.v.iter().map(|h| &h.buf));
            inputs.push(&step_buf.buf);
            inputs.push(&x.buf);
            inputs.push(&y.buf);
            let mut out = Self::run(self.exe_train.as_ref().unwrap(), &inputs)
                .context("running train_step")?;
            let expected = 3 * self.n() + 2;
            if out.len() != expected {
                bail!("train_step returned {} outputs, expected {expected}", out.len());
            }
            let acc = out.pop().unwrap().get_first_element::<f32>()?;
            let loss = out.pop().unwrap().get_first_element::<f32>()?;
            let v = out.split_off(2 * self.n());
            let m = out.split_off(self.n());
            self.params = self.upload_all(out)?;
            self.m = self.upload_all(m)?;
            self.v = self.upload_all(v)?;
            Ok(StepMetrics { loss, acc })
        }

        fn eval_step(&mut self, batch: &Batch) -> Result<StepMetrics> {
            self.check_initialized()?;
            if self.exe_eval.is_none() {
                self.exe_eval = Some(compile(&self.client, &self.manifest.artifact("eval_step"))?);
            }
            let (x, y) = self.batch_buffers(batch)?;
            let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(self.n() + 2);
            inputs.extend(self.params.iter().map(|h| &h.buf));
            inputs.push(&x.buf);
            inputs.push(&y.buf);
            let out =
                Self::run(self.exe_eval.as_ref().unwrap(), &inputs).context("running eval_step")?;
            if out.len() != 2 {
                bail!("eval_step returned {} outputs, expected 2", out.len());
            }
            Ok(StepMetrics {
                loss: out[0].get_first_element::<f32>()?,
                acc: out[1].get_first_element::<f32>()?,
            })
        }

        fn decode(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
            self.check_initialized()?;
            let ctx = self.manifest.ctx;
            if tokens.len() != ctx {
                bail!("decode expects exactly {ctx} tokens, got {}", tokens.len());
            }
            if self.exe_decode.is_none() {
                self.exe_decode = Some(compile(&self.client, &self.manifest.artifact("decode"))?);
            }
            let toks = Literal::vec1(tokens)
                .reshape(&[1, ctx as i64])
                .map_err(|e| anyhow!("reshape tokens: {e}"))?;
            let toks = self.upload(toks)?;
            let mut inputs: Vec<&PjRtBuffer> = Vec::with_capacity(self.n() + 1);
            inputs.extend(self.params.iter().map(|h| &h.buf));
            inputs.push(&toks.buf);
            let out =
                Self::run(self.exe_decode.as_ref().unwrap(), &inputs).context("running decode")?;
            Self::literal_to_f32s(&out[0])
        }

        fn get_params(&self) -> Result<Vec<Vec<f32>>> {
            self.check_initialized()?;
            self.params.iter().map(|h| Self::buffer_to_f32s(&h.buf)).collect()
        }

        fn set_params(&mut self, params: Vec<Vec<f32>>) -> Result<()> {
            if params.len() != self.n() {
                bail!("expected {} tensors, got {}", self.n(), params.len());
            }
            let bufs: Result<Vec<Held>> = params
                .iter()
                .zip(&self.manifest.params)
                .map(|(data, info)| self.upload(Self::f32s_to_literal(data, &info.shape)?))
                .collect();
            self.params = bufs?;
            if self.m.len() != self.n() {
                self.m = self.zeros_like_params()?;
                self.v = self.zeros_like_params()?;
            }
            Ok(())
        }

        fn get_state(&self) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
            self.check_initialized()?;
            let m = self.m.iter().map(|h| Self::buffer_to_f32s(&h.buf)).collect::<Result<_>>()?;
            let v = self.v.iter().map(|h| Self::buffer_to_f32s(&h.buf)).collect::<Result<_>>()?;
            Ok((m, v))
        }

        fn set_state(&mut self, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>) -> Result<()> {
            if m.len() != self.n() || v.len() != self.n() {
                bail!("moment count mismatch");
            }
            fn mk(eng: &PjrtEngine, vecs: &[Vec<f32>]) -> Result<Vec<Held>> {
                vecs.iter()
                    .zip(&eng.manifest.params)
                    .map(|(d, i)| eng.upload(PjrtEngine::f32s_to_literal(d, &i.shape)?))
                    .collect()
            }
            let new_m = mk(self, &m)?;
            let new_v = mk(self, &v)?;
            self.m = new_m;
            self.v = new_v;
            Ok(())
        }
    }
}
