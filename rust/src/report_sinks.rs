//! Metric sinks: CSV series and markdown tables for the report drivers.
//!
//! Figures are emitted as CSV (one series per column) so any plotting tool
//! can render them; tables are emitted as markdown matching the paper's
//! row/column layout.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::TrainOutcome;

/// Escape a CSV field.
fn csv_field(s: &str) -> String {
    if s.contains([',', '"', '\n']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Write rows of fields as CSV.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut out = String::new();
    out.push_str(&header.iter().map(|h| csv_field(h)).collect::<Vec<_>>().join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.iter().map(|f| csv_field(f)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
}

/// Render a markdown table.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&format!("| {} |\n", header.join(" | ")));
    out.push_str(&format!("|{}\n", "---|".repeat(header.len())));
    for row in rows {
        out.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    out
}

/// Figure 7 series: validation loss per epoch, one column per variant.
pub fn fig7_rows(outcomes: &[TrainOutcome]) -> (Vec<String>, Vec<Vec<String>>) {
    let mut header: Vec<String> = vec!["epoch".into()];
    header.extend(outcomes.iter().map(|o| o.variant.clone()));
    let max_epochs = outcomes.iter().map(|o| o.epochs.len()).max().unwrap_or(0);
    let mut rows = Vec::new();
    for e in 0..max_epochs {
        let mut row = vec![e.to_string()];
        for o in outcomes {
            row.push(
                o.epochs
                    .get(e)
                    .map(|r| format!("{:.4}", r.val_loss))
                    .unwrap_or_default(),
            );
        }
        rows.push(row);
    }
    (header, rows)
}

/// Figure 8 point cloud: every (val_acc, val_loss) pair from every epoch of
/// every model.
pub fn fig8_rows(outcomes: &[TrainOutcome]) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for o in outcomes {
        for r in &o.epochs {
            rows.push(vec![
                o.variant.clone(),
                r.epoch.to_string(),
                format!("{:.4}", r.val_loss),
                format!("{:.4}", r.val_acc),
            ]);
        }
    }
    rows
}

/// Pearson correlation between two series (Fig. 8's loss↔accuracy check).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EpochRecord;

    fn outcome(variant: &str, losses: &[f32]) -> TrainOutcome {
        TrainOutcome {
            variant: variant.into(),
            preset: "ci".into(),
            epochs: losses
                .iter()
                .enumerate()
                .map(|(i, &l)| EpochRecord {
                    epoch: i,
                    train_loss: l,
                    val_loss: l,
                    val_acc: 1.0 - l / 10.0,
                    secs: 1.0,
                    steps: 10,
                })
                .collect(),
            step_losses: vec![],
            total_steps: 10 * losses.len(),
            total_secs: losses.len() as f64,
        }
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("q\"q"), "\"q\"\"q\"");
    }

    #[test]
    fn fig7_layout() {
        let outs = vec![outcome("gpt", &[3.0, 2.0]), outcome("hsm_ab", &[3.1, 2.1, 1.9])];
        let (header, rows) = fig7_rows(&outs);
        assert_eq!(header, vec!["epoch", "gpt", "hsm_ab"]);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[2][1], ""); // gpt has no epoch 2
        assert_eq!(rows[2][2], "1.9000");
    }

    #[test]
    fn fig8_collects_all_points() {
        let outs = vec![outcome("gpt", &[3.0, 2.0]), outcome("hsm_ab", &[3.1])];
        assert_eq!(fig8_rows(&outs).len(), 3);
    }

    #[test]
    fn pearson_limits() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let up = [2.0, 4.0, 6.0, 8.0];
        let down = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &up) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &down) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn markdown_shape() {
        let md = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }
}
