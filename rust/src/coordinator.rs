//! The training coordinator: epoch/step loops, validation, metrics and the
//! multi-variant experiment scheduler.
//!
//! This is the L3 "leader" of the stack.  It owns the event loop: it pulls
//! shuffled batches from the [`Dataset`], drives a [`StepEngine`]
//! (PJRT-backed in production, mocked in tests), records per-epoch
//! validation loss/accuracy — the exact series Figures 7 and 8 plot — and
//! wall-clock seconds per epoch — Table 1's timing column.
//!
//! Everything here is engine-agnostic and fully unit-tested against
//! [`MockEngine`]; the runtime_e2e integration tests exercise the same
//! loops against real artifacts.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::data::{Batch, Dataset};
use crate::runtime::{StepEngine, StepMetrics};

/// Options for one training run.
#[derive(Debug, Clone)]
pub struct TrainerOptions {
    /// Stop after this many epochs (paper: 20).
    pub epochs: usize,
    /// Optional hard cap on total optimizer steps (scaled presets).
    pub max_steps: Option<usize>,
    /// Parameter-init / shuffle seed.
    pub seed: u64,
    /// Evaluate on at most this many validation batches (None = all).
    pub eval_batches: Option<usize>,
    /// Print a progress line every N steps (0 = quiet).
    pub log_every: usize,
    /// Record per-step training metrics (for convergence plots).
    pub record_steps: bool,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            epochs: 20,
            max_steps: None,
            seed: 42,
            eval_batches: None,
            log_every: 0,
            record_steps: false,
        }
    }
}

/// Per-epoch record — one point of Figure 7 (loss vs epoch) and one
/// (loss, acc) pair of Figure 8's point cloud.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f32,
    pub val_loss: f32,
    pub val_acc: f32,
    pub secs: f64,
    pub steps: usize,
}

/// Outcome of a full training run.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub variant: String,
    pub preset: String,
    pub epochs: Vec<EpochRecord>,
    pub step_losses: Vec<f32>,
    pub total_steps: usize,
    pub total_secs: f64,
}

impl TrainOutcome {
    /// Final validation loss (Table 1's "Loss" column).
    pub fn final_val_loss(&self) -> f32 {
        self.epochs.last().map(|e| e.val_loss).unwrap_or(f32::NAN)
    }

    /// Best validation loss across epochs.
    pub fn best_val_loss(&self) -> f32 {
        self.epochs
            .iter()
            .map(|e| e.val_loss)
            .fold(f32::INFINITY, f32::min)
    }

    /// Mean wall-clock seconds per epoch (Table 1's timing column).
    pub fn secs_per_epoch(&self) -> f64 {
        if self.epochs.is_empty() {
            return f64::NAN;
        }
        self.epochs.iter().map(|e| e.secs).sum::<f64>() / self.epochs.len() as f64
    }
}

/// The training loop driver.
pub struct Trainer<'a, E: StepEngine + ?Sized> {
    pub engine: &'a mut E,
    pub options: TrainerOptions,
}

impl<'a, E: StepEngine + ?Sized> Trainer<'a, E> {
    pub fn new(engine: &'a mut E, options: TrainerOptions) -> Self {
        Trainer { engine, options }
    }

    /// Run validation over (a prefix of) the validation set.
    pub fn validate(&mut self, val: &Dataset) -> Result<StepMetrics> {
        let batch_size = self.engine.manifest().train.batch;
        let limit = self.options.eval_batches.unwrap_or(usize::MAX);
        let mut loss_sum = 0f64;
        let mut acc_sum = 0f64;
        let mut n = 0usize;
        for batch in val.batches(batch_size).take(limit) {
            let m = self.engine.eval_step(&batch)?;
            loss_sum += m.loss as f64;
            acc_sum += m.acc as f64;
            n += 1;
        }
        if n == 0 {
            bail!(
                "validation set has {} sequences — fewer than one batch of {}",
                val.len(),
                batch_size
            );
        }
        Ok(StepMetrics {
            loss: (loss_sum / n as f64) as f32,
            acc: (acc_sum / n as f64) as f32,
        })
    }

    /// Full training run: init → epochs of shuffled steps → per-epoch
    /// validation.  Returns the metric history.
    pub fn run(&mut self, train: &Dataset, val: &Dataset) -> Result<TrainOutcome> {
        let manifest = self.engine.manifest().clone();
        let batch_size = manifest.train.batch;
        if train.batches_per_epoch(batch_size) == 0 {
            bail!(
                "training set has {} sequences — fewer than one batch of {}",
                train.len(),
                batch_size
            );
        }
        self.engine.init(self.options.seed as u32)?;

        let mut outcome = TrainOutcome {
            variant: manifest.variant.clone(),
            preset: manifest.preset.clone(),
            epochs: Vec::new(),
            step_losses: Vec::new(),
            total_steps: 0,
            total_secs: 0.0,
        };
        let mut step: usize = 0;
        let t_run = Instant::now();

        'outer: for epoch in 0..self.options.epochs {
            let t_epoch = Instant::now();
            let mut train_loss_sum = 0f64;
            let mut n_steps = 0usize;
            for batch in train.epoch(batch_size, self.options.seed ^ (epoch as u64)) {
                let m = self.engine.train_step(step as i32, &batch)?;
                train_loss_sum += m.loss as f64;
                n_steps += 1;
                step += 1;
                if self.options.record_steps {
                    outcome.step_losses.push(m.loss);
                }
                if self.options.log_every > 0 && step % self.options.log_every == 0 {
                    println!(
                        "[{}/{}] epoch {epoch} step {step}: loss {:.4}",
                        manifest.preset, manifest.variant, m.loss
                    );
                }
                if self.options.max_steps.is_some_and(|max| step >= max) {
                    // Final validation still runs below.
                    let secs = t_epoch.elapsed().as_secs_f64();
                    let vm = self.validate(val)?;
                    outcome.epochs.push(EpochRecord {
                        epoch,
                        train_loss: (train_loss_sum / n_steps as f64) as f32,
                        val_loss: vm.loss,
                        val_acc: vm.acc,
                        secs,
                        steps: n_steps,
                    });
                    break 'outer;
                }
            }
            let secs = t_epoch.elapsed().as_secs_f64();
            let vm = self.validate(val)?;
            outcome.epochs.push(EpochRecord {
                epoch,
                train_loss: (train_loss_sum / n_steps as f64) as f32,
                val_loss: vm.loss,
                val_acc: vm.acc,
                secs,
                steps: n_steps,
            });
            if self.options.log_every > 0 {
                println!(
                    "[{}/{}] epoch {epoch}: train {:.4} val {:.4} acc {:.4} ({:.1}s)",
                    manifest.preset,
                    manifest.variant,
                    train_loss_sum / n_steps as f64,
                    vm.loss,
                    vm.acc,
                    secs
                );
            }
        }
        outcome.total_steps = step;
        outcome.total_secs = t_run.elapsed().as_secs_f64();
        Ok(outcome)
    }
}

// ---------------------------------------------------------------------------
// MockEngine — deterministic fake engine for coordinator tests
// ---------------------------------------------------------------------------

/// Deterministic fake [`StepEngine`]: loss decays exponentially toward a
/// per-variant floor, accuracy rises correspondingly.  Lets every
/// coordinator/report/scheduler path run in unit tests without artifacts.
pub struct MockEngine {
    manifest: crate::config::Manifest,
    pub steps_taken: usize,
    pub initialized: bool,
    pub floor: f32,
    pub rate: f32,
    params: Vec<Vec<f32>>,
}

impl MockEngine {
    pub fn new(manifest: crate::config::Manifest, floor: f32, rate: f32) -> Self {
        MockEngine { manifest, steps_taken: 0, initialized: false, floor, rate, params: Vec::new() }
    }

    fn loss_at(&self, step: usize) -> f32 {
        let init = (self.manifest.vocab as f32).ln();
        self.floor + (init - self.floor) * (-self.rate * step as f32).exp()
    }

    fn metrics_at(&self, step: usize) -> StepMetrics {
        let loss = self.loss_at(step);
        // Plausible monotone loss→accuracy mapping (Fig. 8's regression).
        let acc = (1.0 - loss / (self.manifest.vocab as f32).ln()).clamp(0.0, 1.0) * 0.6;
        StepMetrics { loss, acc }
    }
}

impl StepEngine for MockEngine {
    fn manifest(&self) -> &crate::config::Manifest {
        &self.manifest
    }

    fn init(&mut self, _seed: u32) -> Result<()> {
        self.initialized = true;
        self.steps_taken = 0;
        self.params = self
            .manifest
            .params
            .iter()
            .map(|p| vec![0.5f32; p.elems()])
            .collect();
        Ok(())
    }

    fn train_step(&mut self, step: i32, batch: &Batch) -> Result<StepMetrics> {
        if !self.initialized {
            bail!("not initialized");
        }
        if batch.batch != self.manifest.train.batch {
            bail!("batch size mismatch");
        }
        if step as usize != self.steps_taken {
            bail!("step counter out of order: got {step}, expected {}", self.steps_taken);
        }
        self.steps_taken += 1;
        Ok(self.metrics_at(self.steps_taken))
    }

    fn eval_step(&mut self, _batch: &Batch) -> Result<StepMetrics> {
        if !self.initialized {
            bail!("not initialized");
        }
        // Validation slightly above training loss, as in practice.
        let m = self.metrics_at(self.steps_taken);
        Ok(StepMetrics { loss: m.loss * 1.02, acc: m.acc * 0.98 })
    }

    fn decode(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        if tokens.len() != self.manifest.ctx {
            bail!("token length mismatch");
        }
        // Uniform-ish logits favouring (token + 1) — enough for sampler tests.
        let v = self.manifest.vocab;
        let mut logits = vec![0f32; self.manifest.ctx * v];
        for (t, &tok) in tokens.iter().enumerate() {
            let nxt = ((tok as usize) + 1) % v;
            logits[t * v + nxt] = 5.0;
        }
        Ok(logits)
    }

    fn get_params(&self) -> Result<Vec<Vec<f32>>> {
        Ok(self.params.clone())
    }

    fn set_params(&mut self, params: Vec<Vec<f32>>) -> Result<()> {
        self.params = params;
        self.initialized = true;
        Ok(())
    }

    fn get_state(&self) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        Ok((self.params.clone(), self.params.clone()))
    }

    fn set_state(&mut self, _m: Vec<Vec<f32>>, _v: Vec<Vec<f32>>) -> Result<()> {
        Ok(())
    }
}

/// Build a manifest for tests without touching disk: a complete
/// single-layer `hsm_ab` parameter set at dim 8 / ffn 16, so the native
/// inference engine and checkpoint paths exercise every tensor kind.
pub fn test_manifest(variant: &str, batch: usize, ctx: usize, vocab: usize) -> crate::config::Manifest {
    use crate::util::json;
    let doc = format!(
        r#"{{"preset":"ci","variant":"{variant}","display_name":"{variant}",
            "kernels":"pallas",
            "config":{{"dim":8,"ctx":{ctx},"vocab":{vocab},"param_count":100,
              "layers":[{{"kind":"ab","heads":1,"shifts":[1],"ffn":16}}]}},
            "train":{{"batch":{batch},"lr":0.002,"weight_decay":0.01,
              "beta1":0.9,"beta2":0.999,"eps":1e-8,"dropout":0.1,"epochs":20}},
            "params":[
              {{"name":"tok_emb","shape":[{vocab},8],"decay":true}},
              {{"name":"pos_emb","shape":[{ctx},8],"decay":false}},
              {{"name":"layer0.ln1_g","shape":[8],"decay":false}},
              {{"name":"layer0.ln1_b","shape":[8],"decay":false}},
              {{"name":"layer0.mix_a","shape":[1],"decay":false}},
              {{"name":"layer0.mix_b","shape":[1],"decay":false}},
              {{"name":"layer0.ln2_g","shape":[8],"decay":false}},
              {{"name":"layer0.ln2_b","shape":[8],"decay":false}},
              {{"name":"layer0.ffn_w1","shape":[8,16],"decay":true}},
              {{"name":"layer0.ffn_b1","shape":[16],"decay":false}},
              {{"name":"layer0.ffn_w2","shape":[16,8],"decay":true}},
              {{"name":"layer0.ffn_b2","shape":[8],"decay":false}},
              {{"name":"lnf_g","shape":[8],"decay":false}},
              {{"name":"lnf_b","shape":[8],"decay":false}}]}}"#
    );
    crate::config::Manifest::from_json(
        &json::parse(&doc).unwrap(),
        std::path::Path::new("/tmp/hsm-test"),
    )
    .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus;
    use crate::tokenizer::trainer as tok_trainer;

    fn mock_setup() -> (MockEngine, Dataset, Dataset) {
        let text = corpus::generate(3, 80);
        let tok = tok_trainer::train(&text, 300).unwrap();
        let (tr, va, _) = Dataset::build(&text, &tok, 32, 0.9, 7).unwrap();
        let eng = MockEngine::new(test_manifest("hsm_ab", 4, 32, 300), 1.8, 0.01);
        (eng, tr, va)
    }

    #[test]
    fn trains_for_requested_epochs() {
        let (mut eng, tr, va) = mock_setup();
        let mut t = Trainer::new(&mut eng, TrainerOptions { epochs: 3, ..Default::default() });
        let out = t.run(&tr, &va).unwrap();
        assert_eq!(out.epochs.len(), 3);
        assert_eq!(out.total_steps, 3 * tr.batches_per_epoch(4));
        assert_eq!(out.variant, "hsm_ab");
    }

    #[test]
    fn loss_decreases_monotonically_with_mock() {
        let (mut eng, tr, va) = mock_setup();
        let mut t = Trainer::new(&mut eng, TrainerOptions { epochs: 4, ..Default::default() });
        let out = t.run(&tr, &va).unwrap();
        for w in out.epochs.windows(2) {
            assert!(w[1].val_loss < w[0].val_loss);
        }
        assert!(out.final_val_loss() <= out.best_val_loss() + 1e-6);
    }

    #[test]
    fn max_steps_caps_run() {
        let (mut eng, tr, va) = mock_setup();
        let mut t = Trainer::new(
            &mut eng,
            TrainerOptions { epochs: 100, max_steps: Some(5), ..Default::default() },
        );
        let out = t.run(&tr, &va).unwrap();
        assert_eq!(out.total_steps, 5);
        assert_eq!(out.epochs.len(), 1);
    }

    #[test]
    fn step_counter_is_sequential() {
        // MockEngine bails if steps arrive out of order — run() must feed
        // a strictly increasing counter across epochs.
        let (mut eng, tr, va) = mock_setup();
        let mut t = Trainer::new(&mut eng, TrainerOptions { epochs: 2, ..Default::default() });
        t.run(&tr, &va).unwrap();
    }

    #[test]
    fn validation_averages_batches() {
        let (mut eng, _, va) = mock_setup();
        eng.init(0).unwrap();
        let mut t = Trainer::new(&mut eng, TrainerOptions::default());
        let m = t.validate(&va).unwrap();
        assert!(m.loss > 0.0 && m.acc >= 0.0);
    }

    #[test]
    fn record_steps_collects_losses() {
        let (mut eng, tr, va) = mock_setup();
        let mut t = Trainer::new(
            &mut eng,
            TrainerOptions { epochs: 1, record_steps: true, ..Default::default() },
        );
        let out = t.run(&tr, &va).unwrap();
        assert_eq!(out.step_losses.len(), out.total_steps);
    }

    #[test]
    fn errors_if_dataset_smaller_than_batch() {
        let (mut eng, _, _) = mock_setup();
        let tiny = Dataset { sequences: vec![vec![0; 33]; 2], ctx: 32 };
        let mut t = Trainer::new(&mut eng, TrainerOptions::default());
        assert!(t.run(&tiny, &tiny).is_err());
    }
}
