//! Criterion-style micro-benchmark harness (criterion is unavailable
//! offline).  Provides warmup, fixed-duration sampling, and mean / p50 /
//! p95 / throughput reporting.  Every `cargo bench` target builds on this.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_second(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

/// Harness configuration.
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
    results: Vec<Stats>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_samples: 5,
            max_samples: 10_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(500),
            min_samples: 3,
            max_samples: 1_000,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; returns (and records) the statistics.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::new();
        let t0 = Instant::now();
        while (t0.elapsed() < self.measure || samples.len() < self.min_samples)
            && samples.len() < self.max_samples
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed());
        }
        samples.sort();
        let total: Duration = samples.iter().sum();
        let stats = Stats {
            name: name.to_string(),
            samples: samples.len(),
            mean: total / samples.len() as u32,
            p50: samples[samples.len() / 2],
            p95: samples[(samples.len() as f64 * 0.95) as usize..][0],
            min: samples[0],
        };
        println!(
            "{:<40} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} samples)",
            stats.name, stats.mean, stats.p50, stats.p95, stats.samples
        );
        self.results.push(stats.clone());
        stats
    }

    pub fn results(&self) -> &[Stats] {
        &self.results
    }

    /// Markdown table of all recorded results (for EXPERIMENTS.md).
    pub fn markdown(&self, title: &str) -> String {
        let mut out = format!("### {title}\n\n| bench | mean | p50 | p95 | /s |\n|---|---|---|---|---|\n");
        for s in &self.results {
            out.push_str(&format!(
                "| {} | {:.3?} | {:.3?} | {:.3?} | {:.1} |\n",
                s.name,
                s.mean,
                s.p50,
                s.p95,
                s.per_second()
            ));
        }
        out
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_orders_percentiles() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(20),
            min_samples: 5,
            max_samples: 100,
            results: Vec::new(),
        };
        let s = b.run("noop", || {
            black_box(1 + 1);
        });
        assert!(s.samples >= 5);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }
}
