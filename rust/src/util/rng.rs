//! Deterministic, splittable PRNG: xoshiro256** with splitmix64 seeding.
//!
//! Used by the corpus synthesiser, the dataset shuffler, temperature
//! sampling and the property-test harness.  Seeded runs are bit-exact
//! across platforms, which the reproducibility tests rely on.

/// xoshiro256** (Blackman & Vigna) — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (used per-worker / per-story).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift (unbiased enough for
    /// our non-cryptographic uses; exact rejection for small n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
