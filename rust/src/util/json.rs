//! Minimal JSON parser and serializer.
//!
//! Implements the full JSON grammar (RFC 8259): objects, arrays, strings
//! with escapes (including `\uXXXX` surrogate pairs), numbers, booleans,
//! null.  Numbers are stored as `f64`, which is lossless for every value
//! the artifact manifests contain (shapes, hyperparameters).
//!
//! The API mirrors the small subset of `serde_json` this crate needs:
//! [`parse`], [`Value::get`], typed accessors, and [`Value::to_string`].

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field lookup; returns `Value::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Arr(v) => v.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Convenience: `[1, 2, 3]` → `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// Error with byte offset and line/column context.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub msg: String,
    pub pos: usize,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at {}:{}: {}", self.line, self.col, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        ParseError { msg: msg.to_string(), pos: self.pos, line, col }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.keyword("true", Value::Bool(true)),
            Some(b'f') => self.keyword("false", Value::Bool(false)),
            Some(b'n') => self.keyword("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {kw}")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair: expect \uXXXX low surrogate.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences from the raw input.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(self.err("invalid hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---------------------------------------------------------------------------
// Serialization
// ---------------------------------------------------------------------------

/// Escape and quote a string per RFC 8259.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write!(f, "{}", escape(s)),
            Value::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", escape(k), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Builder helpers for emitting reports.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse(r#""a\nb""#).unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(1).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(false));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        // surrogate pair: 😀
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn parses_raw_utf8() {
        assert_eq!(parse("\"héllo 🌍\"").unwrap(), Value::Str("héllo 🌍".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#""\q""#).is_err());
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":true}}"#;
        let v = parse(src).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }
}
