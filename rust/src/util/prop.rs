//! Miniature property-testing framework (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Rng`]; the harness runs it for N
//! deterministic cases and, on failure, reports the failing case seed so it
//! can be replayed exactly.  Generators for the common shapes (vectors,
//! strings, token sequences) live here too.

use super::rng::Rng;

/// Number of cases per property (overridable via `HSM_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("HSM_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` for `cases` deterministic seeds; panic with the seed on failure.
pub fn check_n(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property {name:?} failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default case count.
pub fn check(name: &str, prop: impl FnMut(&mut Rng)) {
    check_n(name, default_cases(), prop);
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// Arbitrary (possibly multi-byte) unicode string, length in `[0, max_len]`.
pub fn arb_string(rng: &mut Rng, max_len: usize) -> String {
    let len = rng.below(max_len + 1);
    (0..len)
        .map(|_| match rng.below(8) {
            0..=4 => (b'a' + rng.below(26) as u8) as char,            // ascii letters
            5 => *rng.pick(&[' ', '.', ',', '!', '?', '\n', '\'']),   // punctuation
            6 => char::from_u32(0xC0 + rng.below(0x100) as u32).unwrap_or('é'),
            _ => *rng.pick(&['é', 'ü', '中', '🌍', 'λ', 'Ж']),
        })
        .collect()
}

/// Vector of u32 tokens below `vocab`.
pub fn arb_tokens(rng: &mut Rng, vocab: u32, max_len: usize) -> Vec<u32> {
    let len = rng.below(max_len + 1);
    (0..len).map(|_| rng.next_u64() as u32 % vocab).collect()
}

/// Vector of f32 in [-scale, scale].
pub fn arb_f32s(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_n("reverse-reverse", 32, |rng| {
            let v = arb_tokens(rng, 100, 50);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports_seed() {
        check_n("always-fails", 4, |rng| {
            assert!(rng.below(10) > 100, "impossible");
        });
    }

    #[test]
    fn arb_string_valid_utf8_and_bounded() {
        check_n("arb-string", 64, |rng| {
            let s = arb_string(rng, 40);
            assert!(s.chars().count() <= 40);
        });
    }
}
