//! From-scratch utility substrates.
//!
//! This sandbox is fully offline and the only third-party crates available
//! are `xla` and `anyhow`, so the usual ecosystem pieces are implemented
//! here from scratch:
//!
//! * [`json`] — a minimal, spec-honest JSON parser/serializer (manifests,
//!   reports).
//! * [`rng`]  — a splittable xoshiro256** PRNG (corpus synthesis, sampling,
//!   property tests).
//! * [`cli`]  — a small declarative flag parser for the `hsm` binary.
//! * [`bench`] — a criterion-style micro-benchmark harness (warmup,
//!   repetitions, mean/p50/p95) used by every `cargo bench` target.
//! * [`prop`] — a miniature property-testing framework (seeded generators,
//!   failure-case reporting) used by the tokenizer/data/coordinator tests.
//! * [`hash`] — FNV-1a folding shared by every content-fingerprint site.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
