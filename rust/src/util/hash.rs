//! FNV-1a folding, shared by every content-fingerprint site (the model
//! fingerprint that keys the serving prefix cache hashes both manifest
//! bytes and weight bits through these — one definition, so the fold
//! can never silently diverge between call sites).

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x100_0000_01b3;

/// Fold one word into the running hash.
#[inline]
pub fn fold(h: &mut u64, word: u64) {
    *h = (*h ^ word).wrapping_mul(FNV_PRIME);
}

/// Fold a byte slice.
pub fn fold_bytes(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        fold(h, b as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinguishes_order_and_content() {
        let hash = |bs: &[u8]| {
            let mut h = FNV_OFFSET;
            fold_bytes(&mut h, bs);
            h
        };
        assert_ne!(hash(b"ab"), hash(b"ba"));
        assert_ne!(hash(b"a"), hash(b"ab"));
        assert_eq!(hash(b"ab"), hash(b"ab"));
        // Reference vector: FNV-1a("a") = 0xaf63dc4c8601ec8c.
        assert_eq!(hash(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
