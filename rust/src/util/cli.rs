//! A small declarative command-line flag parser for the `hsm` binary.
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults and required flags, plus auto-generated `--help`
//! text.  (clap is unavailable offline; this covers everything the
//! launcher needs.)

use std::collections::BTreeMap;

/// Flag specification.
#[derive(Clone)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub required: bool,
    pub boolean: bool,
}

/// Declarative argument parser for one (sub)command.
pub struct Args {
    pub command: String,
    flags: Vec<Flag>,
    values: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn new(command: &str) -> Self {
        Args {
            command: command.to_string(),
            flags: Vec::new(),
            values: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: Some(default), required: false, boolean: false });
        self
    }

    pub fn required(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, required: true, boolean: false });
        self
    }

    pub fn optional(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, required: false, boolean: false });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(Flag { name, help, default: None, required: false, boolean: true });
        self
    }

    /// Parse a token stream (without the program/subcommand names).
    pub fn parse(mut self, argv: &[String]) -> Result<Self, String> {
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?
                    .clone();
                let value = if spec.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .cloned()
                        .ok_or_else(|| format!("--{name} expects a value"))?
                };
                self.values.insert(name, value);
            } else {
                self.positional.push(tok.clone());
            }
            i += 1;
        }
        for f in &self.flags {
            if f.required && !self.values.contains_key(f.name) {
                return Err(format!("missing required flag --{}\n\n{}", f.name, self.usage()));
            }
        }
        Ok(self)
    }

    pub fn usage(&self) -> String {
        let mut out = format!("usage: hsm {} [flags]\n\nflags:\n", self.command);
        for f in &self.flags {
            let extra = match (&f.default, f.required) {
                (Some(d), _) => format!(" (default: {d})"),
                (None, true) => " (required)".to_string(),
                _ => String::new(),
            };
            out.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, extra));
        }
        out
    }

    pub fn get(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.flags
            .iter()
            .find(|f| f.name == name)
            .and_then(|f| f.default.map(str::to_string))
    }

    pub fn str(&self, name: &str) -> String {
        self.get(name).unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, String> {
        self.str(name)
            .parse()
            .map_err(|_| format!("--{name} expects an integer"))
    }

    pub fn u64(&self, name: &str) -> Result<u64, String> {
        self.str(name)
            .parse()
            .map_err(|_| format!("--{name} expects an integer"))
    }

    pub fn f64(&self, name: &str) -> Result<f64, String> {
        self.str(name)
            .parse()
            .map_err(|_| format!("--{name} expects a number"))
    }

    pub fn bool(&self, name: &str) -> bool {
        self.get(name).as_deref() == Some("true")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let a = Args::new("train")
            .flag("preset", "ci", "size preset")
            .required("variant", "model variant")
            .switch("verbose", "chatty")
            .parse(&argv(&["--variant", "gpt", "--verbose"]))
            .unwrap();
        assert_eq!(a.str("preset"), "ci");
        assert_eq!(a.str("variant"), "gpt");
        assert!(a.bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let a = Args::new("x")
            .flag("n", "1", "count")
            .parse(&argv(&["--n=42", "pos1", "pos2"]))
            .unwrap();
        assert_eq!(a.usize("n").unwrap(), 42);
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn missing_required_and_unknown() {
        assert!(Args::new("x").required("v", "v").parse(&argv(&[])).is_err());
        assert!(Args::new("x").parse(&argv(&["--nope", "1"])).is_err());
    }
}
