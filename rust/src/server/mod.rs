//! Cross-process serving: a dependency-free HTTP/1.1 front-end over the
//! resident [`StreamScheduler`].
//!
//! This is the first workload where the model leaves the process: a
//! long-running `hsm serve --http ADDR` exposes
//!
//! * `POST /v1/generate` — JSON in, JSON out; blocks until the
//!   completion is finished.
//! * `POST /v1/stream` — SSE-style per-token events over
//!   `Transfer-Encoding: chunked`, one chunk per [`TokenEvent`], so
//!   time-to-first-token is one prefill + one decode step, not a whole
//!   completion.
//! * `GET /healthz` — model/ctx/vocab liveness probe (JSON).
//! * `GET /metrics` — Prometheus text exposition of the scheduler's
//!   [`crate::obs::MetricsRegistry`]: latency histograms (queue wait,
//!   TTFT, per-token, end-to-end, verify rounds), request/token
//!   counters, prefix-cache and speculation totals, and sampled
//!   per-stage step timings.
//!
//! Concurrency model: one accept-loop thread, one thread per connection
//! (connections are long-lived streams, cheap at the concurrency a
//! loopback/LAN front-end sees; the *decode* concurrency is the
//! scheduler's worker pool, shared by every connection through
//! continuous batching).  The determinism invariant carries across the
//! wire: request `id` fixes the sampled text, so streamed bytes are
//! identical to in-process [`crate::serve::serve`] output —
//! `rust/tests/http_server.rs` pins this over loopback.
//!
//! Submodules:
//! * [`http`] — minimal HTTP/1.1 parsing and (chunked) response writing.
//! * [`api`] — JSON wire types on [`crate::util::json`].
//! * [`client`] — blocking client (used by `hsm request`, tests, and
//!   the `http_streaming` bench).

pub mod api;
pub mod client;
pub mod http;

use std::io::{BufReader, BufWriter, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::serve::{
    AdmissionError, Completion, FinishReason, Request, StreamScheduler, SubmitError, TokenEvent,
};
use crate::util::json;

/// Per-connection socket read timeout: a client that connects and never
/// sends a request cannot pin its handler thread (or shutdown) forever.
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Per-write timeout on responses/chunks, for the same reason.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);
/// Idle budget *between* requests on a kept-alive connection — shorter
/// than the first-request budget so parked keep-alive clients release
/// their handler threads (and never stall shutdown) quickly.
const KEEP_ALIVE_IDLE: Duration = Duration::from_secs(2);
/// Requests served over one kept-alive connection before the server
/// closes it anyway (bounds how long a single client can pin an fd).
const KEEP_ALIVE_MAX_REQUESTS: usize = 1000;

/// The running HTTP front-end.  Bind with [`HttpServer::bind`]; stop
/// with [`shutdown`](HttpServer::shutdown) (graceful: in-flight
/// requests drain first).
pub struct HttpServer {
    inner: Arc<ServerInner>,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
}

struct ServerInner {
    sched: Arc<StreamScheduler>,
    listener: TcpListener,
    addr: SocketAddr,
    stopping: AtomicBool,
    /// Server-assigned request ids start far above anything a client
    /// passing small explicit ids would collide with.
    next_id: AtomicU64,
    conns: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:8080`; port `0` picks a free port —
    /// see [`local_addr`](Self::local_addr)) and start accepting.
    pub fn bind(addr: &str, sched: Arc<StreamScheduler>) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding http server to {addr}"))?;
        let local = listener.local_addr()?;
        let inner = Arc::new(ServerInner {
            sched,
            listener,
            addr: local,
            stopping: AtomicBool::new(false),
            next_id: AtomicU64::new(1 << 32),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || accept_loop(&inner))
        };
        Ok(HttpServer { inner, accept: Mutex::new(Some(accept)) })
    }

    /// The bound address (the actual port when bound with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.inner.addr
    }

    /// Block until the accept loop exits (i.e. until another thread
    /// calls [`shutdown`](Self::shutdown), or the process dies) — what
    /// `hsm serve --http` parks on.
    pub fn join(&self) {
        let handle = self.accept.lock().expect("accept handle lock").take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting, join connection handlers
    /// (each serves one request then closes), then drain the scheduler.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&self) {
        if self.inner.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the blocking accept() with one last loopback connect.
        // A wildcard bind address (0.0.0.0 / ::) is not connectable on
        // every platform, so aim the wake-up at localhost explicitly.
        let mut wake = self.inner.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(if wake.is_ipv4() {
                IpAddr::V4(Ipv4Addr::LOCALHOST)
            } else {
                IpAddr::V6(Ipv6Addr::LOCALHOST)
            });
        }
        let _ = TcpStream::connect_timeout(&wake, Duration::from_secs(1));
        self.join();
        let conns = std::mem::take(&mut *self.inner.conns.lock().expect("conn list lock"));
        for h in conns {
            let _ = h.join();
        }
        self.inner.sched.shutdown();
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(inner: &Arc<ServerInner>) {
    loop {
        let stream = match inner.listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if inner.stopping.load(Ordering::SeqCst) {
                    return;
                }
                // Persistent accept errors (e.g. EMFILE under fd
                // exhaustion) must not busy-spin a core; back off and
                // let in-flight connections release fds.
                std::thread::sleep(Duration::from_millis(50));
                continue;
            }
        };
        if inner.stopping.load(Ordering::SeqCst) {
            return; // the shutdown wake-up connect
        }
        let conn_inner = Arc::clone(inner);
        let handle = std::thread::spawn(move || {
            let _ = handle_connection(&conn_inner, stream);
        });
        let mut conns = inner.conns.lock().expect("conn list lock");
        // Reap finished handlers so a long-lived server's list stays flat.
        conns.retain(|h| !h.is_finished());
        conns.push(handle);
    }
}

/// One connection's request loop.  A request that explicitly asks for
/// `Connection: keep-alive` gets a keep-alive response and another trip
/// around the loop; everything else (errors, streaming, plain requests,
/// server shutdown) serves once and closes — exactly the pre-keep-alive
/// framing, so old clients never see a behavior change.
fn handle_connection(inner: &ServerInner, stream: TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    // Per-token chunks must hit the wire immediately, not sit in Nagle
    // coalescing buffers.
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection stream")?);
    let mut writer = BufWriter::new(stream);
    for served in 0..KEEP_ALIVE_MAX_REQUESTS {
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            // Clean EOF (or idle timeout between keep-alive requests).
            Ok(None) => return Ok(()),
            Err(e) if served == 0 => return respond_error(&mut writer, 400, &format!("{e:#}")),
            // On a reused connection a read error is usually the client
            // going away (or its idle read timing out) — just close.
            Err(_) => return Ok(()),
        };
        // The response's Connection header must tell the truth: on the
        // last allowed request of a capped connection, advertise close
        // (the loop exits right after), never a keep-alive we won't honor.
        let keep_alive = req.wants_keep_alive()
            && !inner.stopping.load(Ordering::SeqCst)
            && served + 1 < KEEP_ALIVE_MAX_REQUESTS;
        let reused = match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/generate") => handle_generate(inner, &mut writer, &req, keep_alive)?,
            ("POST", "/v1/stream") => return handle_stream(inner, &mut writer, &req),
            ("GET", "/healthz") => {
                handle_health(inner, &mut writer, keep_alive)?;
                keep_alive
            }
            ("GET", "/metrics") => {
                handle_metrics(inner, &mut writer, keep_alive)?;
                keep_alive
            }
            (_, "/v1/generate" | "/v1/stream") => {
                return respond_error(&mut writer, 405, "use POST")
            }
            _ => {
                return respond_error(
                    &mut writer,
                    404,
                    "unknown route (have: POST /v1/generate, POST /v1/stream, GET /healthz, GET /metrics)",
                )
            }
        };
        if !reused {
            return Ok(());
        }
        // Between keep-alive requests, idle cheaply: a parked client
        // times out in seconds, not the first-request budget.
        reader.get_ref().set_read_timeout(Some(KEEP_ALIVE_IDLE)).ok();
    }
    Ok(())
}

fn respond_error<W: Write>(w: &mut W, status: u16, msg: &str) -> Result<()> {
    let reason = match status {
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    };
    let body = json::obj(vec![("error", json::s(msg))]).to_string();
    // Errors always close: after a framing problem the read side cannot
    // be trusted to sit at a request boundary.
    http::write_response(w, status, reason, "application/json", body.as_bytes(), false)
}

/// 429 for a request refused by admission control, with the scheduler's
/// backoff hint as both a `Retry-After` header and a machine-readable
/// body field.
fn respond_throttled<W: Write>(w: &mut W, adm: &AdmissionError) -> Result<()> {
    let secs = adm.retry_after().as_secs().max(1);
    let body = json::obj(vec![
        ("error", json::s(&format!("throttled: {adm}"))),
        ("cause", json::s(adm.cause())),
        ("retry_after_seconds", json::num(secs as f64)),
    ])
    .to_string();
    http::write_response_with(
        w,
        429,
        "Too Many Requests",
        "application/json",
        &[("Retry-After", secs.to_string())],
        body.as_bytes(),
        false,
    )
}

/// HTTP disposition of a *finished* completion: client-caused
/// rejections are 400, capacity refusals 429, queue-wait timeouts 503
/// (retryable — the request was valid, the server just couldn't get to
/// it in budget); everything else is a 200 with generated text.
fn completion_status(c: &Completion) -> (u16, &'static str) {
    match &c.finish {
        FinishReason::Rejected(_) => (400, "Bad Request"),
        FinishReason::Throttled(_) => (429, "Too Many Requests"),
        FinishReason::TimedOut => (503, "Service Unavailable"),
        _ => (200, "OK"),
    }
}

/// Parse the JSON body into a scheduler [`Request`], assigning a fresh
/// id when the client did not pick one.
fn parse_generate(inner: &ServerInner, req: &http::HttpRequest) -> Result<Request> {
    let v = json::parse(req.body_str()?).map_err(|e| anyhow::anyhow!("{e}"))?;
    let g = api::GenerateRequest::from_json(&v)?;
    let id = g.id.unwrap_or_else(|| inner.next_id.fetch_add(1, Ordering::Relaxed));
    let mut r = Request::new(id, &g.prompt);
    r.max_new_tokens = g.max_new_tokens;
    r.user = g.user;
    r.deadline_ms = g.deadline_ms;
    Ok(r)
}

/// Serve one `/v1/generate` request; returns whether the connection can
/// be reused (a keep-alive success — every error path closes).
fn handle_generate(
    inner: &ServerInner,
    w: &mut impl Write,
    req: &http::HttpRequest,
    keep_alive: bool,
) -> Result<bool> {
    let request = match parse_generate(inner, req) {
        Ok(r) => r,
        Err(e) => return respond_error(w, 400, &format!("{e:#}")).map(|()| false),
    };
    let stream = match inner.sched.try_submit(request) {
        Ok(s) => s,
        Err(SubmitError::Throttled(adm)) => return respond_throttled(w, &adm).map(|()| false),
        Err(SubmitError::Unavailable(e)) => {
            return respond_error(w, 503, &format!("{e:#}")).map(|()| false)
        }
    };
    match stream.wait(|_| {}) {
        Some(completion) => {
            let (status, reason) = completion_status(&completion);
            // Non-200 dispositions close (mirroring respond_error); the
            // completion body still travels so clients see the detail.
            let reuse = status == 200 && keep_alive;
            let extra: &[(&str, String)] = &if matches!(status, 429 | 503) {
                vec![("Retry-After", "1".to_string())]
            } else {
                Vec::new()
            };
            http::write_response_with(
                w,
                status,
                reason,
                "application/json",
                extra,
                api::completion_to_json(&completion).to_string().as_bytes(),
                reuse,
            )
            .map(|()| reuse)
        }
        None => respond_error(w, 500, "scheduler dropped the request before it finished")
            .map(|()| false),
    }
}

fn handle_stream(inner: &ServerInner, w: &mut impl Write, req: &http::HttpRequest) -> Result<()> {
    let request = match parse_generate(inner, req) {
        Ok(r) => r,
        Err(e) => return respond_error(w, 400, &format!("{e:#}")),
    };
    // Admission errors resolve *before* the stream head: the client
    // gets a real status line (429/503) it can branch on, instead of a
    // 200 whose first event is a failure.
    let stream = match inner.sched.try_submit(request) {
        Ok(s) => s,
        Err(SubmitError::Throttled(adm)) => return respond_throttled(w, &adm),
        Err(SubmitError::Unavailable(e)) => return respond_error(w, 503, &format!("{e:#}")),
    };
    http::write_stream_head(w)?;
    for ev in stream {
        let payload = format!("data: {}\n\n", api::event_to_json(&ev));
        if http::write_chunk(w, payload.as_bytes()).is_err() {
            // Client went away mid-stream.  Dropping the TokenStream
            // marks the sink dead; the scheduler cancels the request at
            // its next sampled token and frees the session
            // ([`crate::serve::FinishReason::Cancelled`]).
            return Ok(());
        }
        if matches!(ev, TokenEvent::Done { .. }) {
            break;
        }
    }
    http::finish_chunks(w)
}

/// Serve `GET /metrics`: Prometheus text exposition (v0.0.4) rendered
/// straight from the scheduler's [`crate::obs::MetricsRegistry`].  A
/// scheduler running with telemetry fully off (`ObsCfg::off`) still
/// answers — with every family present and zero — so scrape configs
/// never see the route flap with server configuration.
fn handle_metrics(inner: &ServerInner, w: &mut impl Write, keep_alive: bool) -> Result<()> {
    let body = match inner.sched.metrics() {
        Some(reg) => reg.render_prometheus(),
        None => crate::obs::MetricsRegistry::default().render_prometheus(),
    };
    http::write_response(
        w,
        200,
        "OK",
        "text/plain; version=0.0.4; charset=utf-8",
        body.as_bytes(),
        keep_alive,
    )
}

fn handle_health(inner: &ServerInner, w: &mut impl Write, keep_alive: bool) -> Result<()> {
    let model = inner.sched.model();
    let m = &model.manifest;
    let mut pairs = vec![
        ("status", json::s("ok")),
        ("variant", json::s(&m.variant)),
        ("ctx", json::num(m.ctx as f64)),
        ("vocab", json::num(m.vocab as f64)),
        // Deployment observability: which kernel tier this build
        // dispatches to, the serving precision, and what the resident
        // weights actually cost in RAM (int8 ≈ 0.27× f32).
        (
            "model",
            json::obj(vec![
                ("precision", json::s(model.precision().label())),
                ("kernel_backend", json::s(crate::infer::tensor::kernel_backend())),
                ("resident_weight_bytes", json::num(model.resident_weight_bytes() as f64)),
            ]),
        ),
    ];
    // Prefix-cache observability: hit rate is the one number that says
    // whether shared-prompt-head traffic is actually being exploited.
    if let Some(cache) = inner.sched.prefix_cache() {
        let s = cache.stats();
        pairs.push((
            "prefix_cache",
            json::obj(vec![
                ("capacity", json::num(s.capacity as f64)),
                ("entries", json::num(s.entries as f64)),
                ("hits", json::num(s.hits as f64)),
                ("misses", json::num(s.misses as f64)),
                ("insertions", json::num(s.insertions as f64)),
                ("evictions", json::num(s.evictions as f64)),
                ("hit_rate", json::num(s.hit_rate())),
                // Quantization-aware storage: at-rest snapshot bytes and
                // how many entries sit compacted at the serving precision.
                ("resident_bytes", json::num(s.resident_bytes as f64)),
                ("quantized_entries", json::num(s.quantized_entries as f64)),
            ]),
        ));
    }
    // SLO observability: the admission-control configuration plus live
    // queue depth and throttle totals, so an operator (or the loadgen
    // harness) can see backpressure without scraping /metrics.
    let cfg = inner.sched.cfg();
    if cfg.max_queue_depth > 0 || cfg.quota.is_some() || cfg.edf {
        let mut slo = vec![
            ("max_queue_depth", json::num(cfg.max_queue_depth as f64)),
            ("edf", json::Value::Bool(cfg.edf)),
        ];
        if let Some(q) = &cfg.quota {
            slo.push((
                "quota",
                json::obj(vec![
                    ("max_requests", json::num(q.max_requests as f64)),
                    ("max_tokens", json::num(q.max_tokens as f64)),
                    ("window_seconds", json::num(q.window.as_secs_f64())),
                ]),
            ));
        }
        if let Some(reg) = inner.sched.metrics() {
            slo.push(("queue_depth", json::num(reg.queue_depth() as f64)));
            slo.push(("throttled_total", json::num(reg.throttled_total() as f64)));
        }
        pairs.push(("slo", json::obj(slo)));
    }
    // Speculative-decoding observability: accepted tokens per verify
    // round is the number that says whether drafting is paying off.
    if let Some(spec) = &inner.sched.cfg().speculation {
        let s = inner.sched.spec_stats();
        pairs.push((
            "speculation",
            json::obj(vec![
                ("drafter", json::s(spec.drafter.label())),
                ("draft_len", json::num(spec.draft_len as f64)),
                ("fused", json::Value::Bool(spec.fused)),
                ("rounds", json::num(s.rounds as f64)),
                ("drafted", json::num(s.drafted as f64)),
                ("accepted", json::num(s.accepted as f64)),
                ("emitted", json::num(s.emitted as f64)),
                ("acceptance_rate", json::num(s.acceptance_rate())),
                ("tokens_per_round", json::num(s.emitted_per_round())),
                ("fused_passes", json::num(s.fused_passes as f64)),
                ("fused_rows", json::num(s.fused_rows as f64)),
                ("rows_per_fused_pass", json::num(s.rows_per_fused_pass())),
            ]),
        ));
    }
    let body = json::obj(pairs).to_string();
    http::write_response(w, 200, "OK", "application/json", body.as_bytes(), keep_alive)
}
