//! JSON wire types for the HTTP API, built on [`crate::util::json`]
//! (serde is unavailable under the vendored-offline constraint).
//!
//! Shapes:
//!
//! * request (`POST /v1/generate`, `POST /v1/stream`):
//!   `{"prompt": "...", "id": 7, "max_new_tokens": 32, "user": "alice",
//!   "deadline_ms": 1500}` — everything but `prompt` optional.  `id`
//!   fixes the sampling RNG stream (`seed ^ id`); omit it and the
//!   server assigns a fresh one.  `user` keys per-user quotas;
//!   `deadline_ms` overrides the server's queue-wait budget and orders
//!   the queue under EDF.
//! * completion: `{"request_id": 7, "prompt": "...", "completion": "...",
//!   "tokens_generated": 32, "cached_prefix_len": 12, "finish": "eot"}`
//!   (+ `"error"` detail when `finish` is `"rejected"` or `"throttled"`;
//!   `cached_prefix_len` counts prompt tokens served from the shared
//!   prefix cache — 0 on a cold prefill; + `"spec": {"rounds": ..,
//!   "drafted": .., "accepted": .., "emitted": .., "fused_passes": ..,
//!   "fused_rows": .., "rows_per_fused_pass": ..}` when the server
//!   decoded the request speculatively — the `fused_*` fields count
//!   batched verify passes and the rows they scored, 0 when the
//!   sequential verify path ran).
//! * stream events (one SSE `data:` payload each):
//!   `{"request_id": 7, "token": 512, "text_delta": "..."}` per token,
//!   then `{"request_id": 7, "done": true, "text_delta": "...",
//!   "completion": {...}}`.

use anyhow::{anyhow, bail, Result};

use crate::infer::speculate::SpecStats;
use crate::serve::{Completion, FinishReason, TokenEvent};
use crate::util::json::{self, Value};

/// Body of `POST /v1/generate` and `POST /v1/stream`.
#[derive(Debug, Clone)]
pub struct GenerateRequest {
    /// Fixes the sampling RNG stream (`seed ^ id`); None = the server
    /// assigns a fresh unique id.
    pub id: Option<u64>,
    pub prompt: String,
    /// Per-request cap on generated tokens (None = server default).
    pub max_new_tokens: Option<usize>,
    /// Quota accounting key (None = anonymous, bypasses per-user
    /// quotas).  Never affects sampled text.
    pub user: Option<String>,
    /// Admission deadline in milliseconds, overriding the server's
    /// `max_queue_wait`; also the EDF ordering key.
    pub deadline_ms: Option<u64>,
}

impl GenerateRequest {
    pub fn new(prompt: &str) -> Self {
        GenerateRequest {
            id: None,
            prompt: prompt.to_string(),
            max_new_tokens: None,
            user: None,
            deadline_ms: None,
        }
    }

    pub fn to_json(&self) -> Value {
        let mut pairs = vec![("prompt", json::s(&self.prompt))];
        if let Some(id) = self.id {
            pairs.push(("id", json::num(id as f64)));
        }
        if let Some(m) = self.max_new_tokens {
            pairs.push(("max_new_tokens", json::num(m as f64)));
        }
        if let Some(u) = &self.user {
            pairs.push(("user", json::s(u)));
        }
        if let Some(d) = self.deadline_ms {
            pairs.push(("deadline_ms", json::num(d as f64)));
        }
        json::obj(pairs)
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let prompt = v
            .get("prompt")
            .as_str()
            .ok_or_else(|| anyhow!("missing or non-string 'prompt'"))?
            .to_string();
        // JSON numbers travel as f64; beyond 2^53 the id would silently
        // round, changing the RNG stream (`seed ^ id`) the client asked
        // for — reject instead of corrupting the determinism contract.
        let id = match v.get("id") {
            Value::Null => None,
            x => {
                let f = x.as_f64().ok_or_else(|| anyhow!("'id' must be a number"))?;
                // ≥ 2^53 already rounded during JSON parsing, so the
                // value here cannot be trusted to be what was sent.
                if f < 0.0 || f.fract() != 0.0 || f >= 9007199254740992.0 {
                    bail!("'id' must be a non-negative integer below 2^53 (got {f})");
                }
                Some(f as u64)
            }
        };
        let max_new_tokens = match v.get("max_new_tokens") {
            Value::Null => None,
            x => Some(
                x.as_usize()
                    .ok_or_else(|| anyhow!("'max_new_tokens' must be a number"))?,
            ),
        };
        let user = match v.get("user") {
            Value::Null => None,
            x => Some(
                x.as_str().ok_or_else(|| anyhow!("'user' must be a string"))?.to_string(),
            ),
        };
        let deadline_ms = match v.get("deadline_ms") {
            Value::Null => None,
            x => {
                let f = x.as_f64().ok_or_else(|| anyhow!("'deadline_ms' must be a number"))?;
                if f < 0.0 || f.fract() != 0.0 {
                    bail!("'deadline_ms' must be a non-negative integer (got {f})");
                }
                Some(f as u64)
            }
        };
        Ok(GenerateRequest { id, prompt, max_new_tokens, user, deadline_ms })
    }
}

/// Parse the stable wire label back into a [`FinishReason`]
/// (the inverse of [`FinishReason::label`]).
pub fn finish_from_label(label: &str, error: Option<&str>) -> Result<FinishReason> {
    Ok(match label {
        "eot" => FinishReason::Eot,
        "max_tokens" => FinishReason::MaxTokens,
        "ctx_full" => FinishReason::CtxFull,
        "timed_out" => FinishReason::TimedOut,
        "cancelled" => FinishReason::Cancelled,
        "rejected" => FinishReason::Rejected(error.unwrap_or("").to_string()),
        "throttled" => FinishReason::Throttled(error.unwrap_or("").to_string()),
        other => bail!("unknown finish reason {other:?}"),
    })
}

pub fn completion_to_json(c: &Completion) -> Value {
    let mut pairs = vec![
        ("request_id", json::num(c.request_id as f64)),
        ("prompt", json::s(&c.prompt)),
        ("completion", json::s(&c.completion)),
        ("tokens_generated", json::num(c.tokens_generated as f64)),
        ("cached_prefix_len", json::num(c.cached_prefix_len as f64)),
        ("finish", json::s(c.finish.label())),
    ];
    if let Some(s) = &c.spec {
        pairs.push((
            "spec",
            json::obj(vec![
                ("rounds", json::num(s.rounds as f64)),
                ("drafted", json::num(s.drafted as f64)),
                ("accepted", json::num(s.accepted as f64)),
                ("emitted", json::num(s.emitted as f64)),
                ("fused_passes", json::num(s.fused_passes as f64)),
                ("fused_rows", json::num(s.fused_rows as f64)),
                ("rows_per_fused_pass", json::num(s.rows_per_fused_pass())),
            ]),
        ));
    }
    match &c.finish {
        FinishReason::Rejected(why) | FinishReason::Throttled(why) => {
            pairs.push(("error", json::s(why)));
        }
        _ => {}
    }
    json::obj(pairs)
}

pub fn completion_from_json(v: &Value) -> Result<Completion> {
    let finish = finish_from_label(
        v.get("finish").as_str().ok_or_else(|| anyhow!("missing 'finish'"))?,
        v.get("error").as_str(),
    )?;
    let spec = match v.get("spec") {
        Value::Null => None,
        s => Some(SpecStats {
            rounds: s.get("rounds").as_usize().unwrap_or(0) as u64,
            drafted: s.get("drafted").as_usize().unwrap_or(0) as u64,
            accepted: s.get("accepted").as_usize().unwrap_or(0) as u64,
            emitted: s.get("emitted").as_usize().unwrap_or(0) as u64,
            fused_passes: s.get("fused_passes").as_usize().unwrap_or(0) as u64,
            fused_rows: s.get("fused_rows").as_usize().unwrap_or(0) as u64,
        }),
    };
    Ok(Completion {
        request_id: v
            .get("request_id")
            .as_f64()
            .ok_or_else(|| anyhow!("missing 'request_id'"))? as u64,
        prompt: v.get("prompt").as_str().unwrap_or("").to_string(),
        completion: v.get("completion").as_str().unwrap_or("").to_string(),
        tokens_generated: v.get("tokens_generated").as_usize().unwrap_or(0),
        cached_prefix_len: v.get("cached_prefix_len").as_usize().unwrap_or(0),
        spec,
        finish,
    })
}

/// Serialize one stream event as an SSE `data:` payload body.
pub fn event_to_json(ev: &TokenEvent) -> Value {
    match ev {
        TokenEvent::Token { request_id, token, text_delta } => json::obj(vec![
            ("request_id", json::num(*request_id as f64)),
            ("token", json::num(*token as f64)),
            ("text_delta", json::s(text_delta)),
        ]),
        TokenEvent::Done { text_delta, completion } => json::obj(vec![
            ("request_id", json::num(completion.request_id as f64)),
            ("done", Value::Bool(true)),
            ("text_delta", json::s(text_delta)),
            ("completion", completion_to_json(completion)),
        ]),
    }
}

pub fn event_from_json(v: &Value) -> Result<TokenEvent> {
    if v.get("done").as_bool() == Some(true) {
        return Ok(TokenEvent::Done {
            text_delta: v.get("text_delta").as_str().unwrap_or("").to_string(),
            completion: completion_from_json(v.get("completion"))?,
        });
    }
    Ok(TokenEvent::Token {
        request_id: v
            .get("request_id")
            .as_f64()
            .ok_or_else(|| anyhow!("missing 'request_id'"))? as u64,
        token: v.get("token").as_f64().ok_or_else(|| anyhow!("missing 'token'"))? as u32,
        text_delta: v.get("text_delta").as_str().unwrap_or("").to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_request_roundtrip() {
        let mut req = GenerateRequest::new("Once upon a time");
        req.id = Some(42);
        req.max_new_tokens = Some(8);
        req.user = Some("alice".into());
        req.deadline_ms = Some(1500);
        let back = GenerateRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.id, Some(42));
        assert_eq!(back.prompt, "Once upon a time");
        assert_eq!(back.max_new_tokens, Some(8));
        assert_eq!(back.user.as_deref(), Some("alice"));
        assert_eq!(back.deadline_ms, Some(1500));

        let bare = GenerateRequest::from_json(&json::parse(r#"{"prompt":"hi"}"#).unwrap()).unwrap();
        assert_eq!(bare.id, None);
        assert_eq!(bare.max_new_tokens, None);
        assert_eq!(bare.user, None);
        assert_eq!(bare.deadline_ms, None);
        assert!(GenerateRequest::from_json(&json::parse(r#"{"id":1}"#).unwrap()).is_err());
        for bad in [r#"{"prompt":"x","user":7}"#, r#"{"prompt":"x","deadline_ms":-5}"#] {
            assert!(
                GenerateRequest::from_json(&json::parse(bad).unwrap()).is_err(),
                "should reject {bad}"
            );
        }

        // Ids that would corrupt through f64 are rejected, not rounded.
        for bad in [r#"{"prompt":"x","id":-1}"#, r#"{"prompt":"x","id":1.5}"#,
                    r#"{"prompt":"x","id":9007199254740993}"#] {
            assert!(
                GenerateRequest::from_json(&json::parse(bad).unwrap()).is_err(),
                "should reject {bad}"
            );
        }
    }

    #[test]
    fn completion_roundtrip_preserves_finish_detail() {
        for finish in [
            FinishReason::Eot,
            FinishReason::MaxTokens,
            FinishReason::CtxFull,
            FinishReason::TimedOut,
            FinishReason::Cancelled,
            FinishReason::Rejected("prompt encodes to zero tokens".into()),
            FinishReason::Throttled("queue full (3 waiting, limit 3)".into()),
        ] {
            let c = Completion {
                request_id: 3,
                prompt: "p".into(),
                completion: "some text\nwith \"quotes\"".into(),
                tokens_generated: 5,
                cached_prefix_len: 4,
                spec: Some(SpecStats {
                    rounds: 2,
                    drafted: 6,
                    accepted: 4,
                    emitted: 6,
                    fused_passes: 2,
                    fused_rows: 8,
                }),
                finish: finish.clone(),
            };
            let text = completion_to_json(&c).to_string();
            let back = completion_from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.finish, finish);
            assert_eq!(back.completion, c.completion);
            assert_eq!(back.request_id, 3);
            assert_eq!(back.cached_prefix_len, 4);
            assert_eq!(back.spec, c.spec, "speculation stats must survive the wire");
        }
        // Absent "spec" (speculation off, or an old server) stays None.
        let bare = completion_from_json(
            &json::parse(r#"{"request_id": 1, "finish": "eot"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(bare.spec, None);
    }

    #[test]
    fn event_roundtrip() {
        let tokev = TokenEvent::Token { request_id: 9, token: 77, text_delta: "é".into() };
        let text = event_to_json(&tokev).to_string();
        match event_from_json(&json::parse(&text).unwrap()).unwrap() {
            TokenEvent::Token { request_id, token, text_delta } => {
                assert_eq!((request_id, token, text_delta.as_str()), (9, 77, "é"));
            }
            _ => panic!("expected Token"),
        }

        let done = TokenEvent::Done {
            text_delta: "\u{FFFD}".into(),
            completion: Completion {
                request_id: 9,
                prompt: "p".into(),
                completion: "full".into(),
                tokens_generated: 2,
                cached_prefix_len: 0,
                spec: None,
                finish: FinishReason::Eot,
            },
        };
        let text = event_to_json(&done).to_string();
        match event_from_json(&json::parse(&text).unwrap()).unwrap() {
            TokenEvent::Done { text_delta, completion } => {
                assert_eq!(text_delta, "\u{FFFD}");
                assert_eq!(completion.completion, "full");
                assert_eq!(completion.finish, FinishReason::Eot);
            }
            _ => panic!("expected Done"),
        }
    }
}
