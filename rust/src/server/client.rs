//! Blocking HTTP client for the front-end, on plain `std::net` — used by
//! the `hsm request` CLI, the loopback integration tests, and the
//! serving benches.  Two shapes:
//!
//! * free functions ([`generate`], [`stream`], [`health`]) — one request
//!   per connection (`Connection: close`), zero state;
//! * [`Client`] — a persistent connection sending
//!   `Connection: keep-alive`, reused across [`generate`](Client::generate)
//!   / [`health`](Client::health) calls, transparently reconnecting when
//!   the server closed it (idle timeout, restart, per-connection request
//!   cap).  This is what repeated short completions want: no
//!   connect/teardown per call.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::api::{self, GenerateRequest};
use super::http;
use crate::serve::{Completion, TokenEvent};
use crate::util::json;

/// Per-read deadline (covers the gap between streamed events too, so it
/// must absorb admission queueing on a loaded server).
const READ_TIMEOUT: Duration = Duration::from_secs(300);
/// Per-write deadline for the request itself.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Status line + headers of a response.
struct ResponseHead {
    status: u16,
    headers: Vec<(String, String)>,
}

impl ResponseHead {
    fn header(&self, name: &str) -> Option<&str> {
        http::header(&self.headers, name)
    }
}

/// Open a connection with the client's standard socket options.
fn connect(addr: &str) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    // Bounded waits: a wedged or half-open server must produce an error,
    // not hang `hsm request` forever.  The read budget is generous —
    // a queued streaming request can legitimately idle for a while
    // before its first token.
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    Ok(stream)
}

/// Write one request head (+ optional JSON body) to `w`.
fn write_request<W: Write>(
    w: &mut W,
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    keep_alive: bool,
) -> Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    match body {
        Some(body) => write!(
            w,
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
            body.len()
        )?,
        None => {
            write!(w, "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: {conn}\r\n\r\n")?
        }
    }
    w.flush()?;
    Ok(())
}

/// Parse an already-read status line.
fn parse_status_line(line: &str) -> Result<u16> {
    line.split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line {:?}", line.trim_end()))
}

/// Read header lines up to the blank separator, leaving `r` at the body.
fn read_headers(r: &mut BufReader<TcpStream>) -> Result<Vec<(String, String)>> {
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("connection closed mid-response-head");
        }
        if line.trim_end_matches(['\r', '\n']).is_empty() {
            break;
        }
        // Lenient on the client side: skip (rather than error on) any
        // header line we cannot parse — we only need a few well-formed ones.
        if let Some(parsed) = http::parse_header_line(&line) {
            headers.push(parsed);
        }
    }
    Ok(headers)
}

/// Parse a response's status line + headers, leaving `r` at the body.
fn read_head(r: &mut BufReader<TcpStream>) -> Result<ResponseHead> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        bail!("server closed the connection without a response");
    }
    Ok(ResponseHead { status: parse_status_line(&line)?, headers: read_headers(r)? })
}

/// Send one request over a fresh connection, returning the parsed
/// response head and the reader positioned at the body.
fn send(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(ResponseHead, BufReader<TcpStream>)> {
    let stream = connect(addr)?;
    let mut w = stream.try_clone().context("cloning client stream")?;
    write_request(&mut w, addr, method, path, body, false)?;
    let mut r = BufReader::new(stream);
    let head = read_head(&mut r)?;
    Ok((head, r))
}

/// Read a fixed-length (or to-EOF) response body.
fn read_body(head: &ResponseHead, r: &mut BufReader<TcpStream>) -> Result<Vec<u8>> {
    match head.header("content-length").and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => {
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)?;
            Ok(body)
        }
        None => {
            // Connection: close framing.
            let mut body = Vec::new();
            r.read_to_end(&mut body)?;
            Ok(body)
        }
    }
}

fn parse_json_body(head: &ResponseHead, r: &mut BufReader<TcpStream>) -> Result<json::Value> {
    let body = read_body(head, r)?;
    let text = std::str::from_utf8(&body).map_err(|_| anyhow!("response body is not UTF-8"))?;
    json::parse(text).map_err(|e| anyhow!("{e}"))
}

fn status_error(status: u16, v: &json::Value) -> anyhow::Error {
    anyhow!("server returned {status}: {}", v.get("error").as_str().unwrap_or("(no detail)"))
}

/// The server's `Retry-After` backoff hint (seconds), defaulting to 1s
/// when the header is missing or unparseable.
fn retry_after_hint(head: &ResponseHead) -> Duration {
    head.header("retry-after")
        .and_then(|v| v.trim().parse::<u64>().ok())
        .map(Duration::from_secs)
        .unwrap_or(Duration::from_secs(1))
}

/// Outcome of [`try_generate`]: a finished completion (whatever its
/// finish reason — the server now grades completions with real HTTP
/// statuses, but the body still travels), or an admission refusal (429)
/// carrying the server's backoff hint.
#[derive(Debug)]
pub enum ApiOutcome {
    Done(Completion),
    /// The server refused the request at admission (queue depth or
    /// per-user quota); retry no sooner than `retry_after`.
    Throttled { retry_after: Duration, message: String },
}

/// Map a parsed response to an [`ApiOutcome`].  Any body carrying
/// `"finish"` is a completion document — 400 (rejected), 429
/// (throttled), and 503 (timed out) completions all still deliver their
/// detail; a 429 *without* a completion is an admission refusal.
fn outcome(head: &ResponseHead, v: &json::Value) -> Result<ApiOutcome> {
    if v.get("finish").as_str().is_some() {
        return api::completion_from_json(v).map(ApiOutcome::Done);
    }
    if head.status == 429 {
        return Ok(ApiOutcome::Throttled {
            retry_after: retry_after_hint(head),
            message: v.get("error").as_str().unwrap_or("throttled").to_string(),
        });
    }
    Err(status_error(head.status, v))
}

/// `POST /v1/generate` with the admission-control surface exposed:
/// backpressure/quota refusals come back as [`ApiOutcome::Throttled`]
/// with the server's `Retry-After`, instead of a stringly error.
pub fn try_generate(addr: &str, req: &GenerateRequest) -> Result<ApiOutcome> {
    let (head, mut r) = send(addr, "POST", "/v1/generate", Some(&req.to_json().to_string()))?;
    let v = parse_json_body(&head, &mut r)?;
    outcome(&head, &v)
}

/// `POST /v1/generate`: block until the whole completion is back.
/// Completions always return `Ok` whatever their finish reason (the
/// body says `"timed_out"`, `"rejected"`, …); a throttled admission
/// surfaces as an error naming the backoff.
pub fn generate(addr: &str, req: &GenerateRequest) -> Result<Completion> {
    match try_generate(addr, req)? {
        ApiOutcome::Done(c) => Ok(c),
        ApiOutcome::Throttled { retry_after, message } => Err(anyhow!(
            "server throttled the request ({message}); retry after {}s",
            retry_after.as_secs()
        )),
    }
}

/// `POST /v1/stream` with the admission-control surface exposed, like
/// [`try_generate`]: a 429 before the stream head comes back as
/// [`ApiOutcome::Throttled`] instead of an error.
pub fn try_stream<F: FnMut(Option<u32>, &str)>(
    addr: &str,
    req: &GenerateRequest,
    mut on_delta: F,
) -> Result<ApiOutcome> {
    let (head, mut r) = send(addr, "POST", "/v1/stream", Some(&req.to_json().to_string()))?;
    if head.status != 200 {
        let v = parse_json_body(&head, &mut r)?;
        if head.status == 429 {
            return Ok(ApiOutcome::Throttled {
                retry_after: retry_after_hint(&head),
                message: v.get("error").as_str().unwrap_or("throttled").to_string(),
            });
        }
        return Err(status_error(head.status, &v));
    }

    let mut done: Option<Completion> = None;
    // SSE events are "data: <json>\n\n"; the server sends one per chunk,
    // but reassemble across chunk boundaries anyway.
    let mut buf: Vec<u8> = Vec::new();
    http::read_chunks(&mut r, |chunk| {
        buf.extend_from_slice(chunk);
        while let Some(pos) = buf.windows(2).position(|w| w == b"\n\n") {
            let event: Vec<u8> = buf.drain(..pos + 2).collect();
            let text = std::str::from_utf8(&event[..pos])
                .map_err(|_| anyhow!("stream event is not UTF-8"))?;
            for line in text.lines() {
                let Some(data) = line.strip_prefix("data: ") else { continue };
                let v = json::parse(data).map_err(|e| anyhow!("{e}"))?;
                match api::event_from_json(&v)? {
                    TokenEvent::Token { token, text_delta, .. } => {
                        on_delta(Some(token), &text_delta);
                    }
                    TokenEvent::Done { text_delta, completion } => {
                        on_delta(None, &text_delta);
                        done = Some(completion);
                    }
                }
            }
        }
        Ok(())
    })?;
    done.map(ApiOutcome::Done)
        .ok_or_else(|| anyhow!("stream ended without a done event (server failure mid-request?)"))
}

/// `POST /v1/stream`: invoke `on_delta(token, text)` for every event as
/// it arrives (`token` is `None` for the final mid-character flush), and
/// return the finished [`Completion`].  Concatenating every `text`
/// argument reconstructs the completion byte-for-byte.  A throttled
/// admission surfaces as an error naming the backoff.
pub fn stream<F: FnMut(Option<u32>, &str)>(
    addr: &str,
    req: &GenerateRequest,
    on_delta: F,
) -> Result<Completion> {
    match try_stream(addr, req, on_delta)? {
        ApiOutcome::Done(c) => Ok(c),
        ApiOutcome::Throttled { retry_after, message } => Err(anyhow!(
            "server throttled the request ({message}); retry after {}s",
            retry_after.as_secs()
        )),
    }
}

/// `GET /metrics` — the raw Prometheus text exposition.  The load
/// generator differences two of these around a run to extract latency
/// quantiles and token throughput.
pub fn metrics_text(addr: &str) -> Result<String> {
    let (head, mut r) = send(addr, "GET", "/metrics", None)?;
    let body = read_body(&head, &mut r)?;
    if head.status != 200 {
        bail!("server returned {} for /metrics", head.status);
    }
    String::from_utf8(body).map_err(|_| anyhow!("metrics body is not UTF-8"))
}

/// `GET /healthz` — returns the parsed health document.
pub fn health(addr: &str) -> Result<json::Value> {
    let (head, mut r) = send(addr, "GET", "/healthz", None)?;
    let v = parse_json_body(&head, &mut r)?;
    if head.status != 200 {
        return Err(status_error(head.status, &v));
    }
    Ok(v)
}

/// A persistent keep-alive connection to one server.
///
/// Requests go out with `Connection: keep-alive`; as long as the server
/// honors it (this crate's does, for `/v1/generate` and `/healthz`),
/// every call after the first skips the TCP connect.  When the reused
/// socket turns out dead — server idle-closed it, restarted, or hit its
/// per-connection request cap — the call transparently retries once on
/// a fresh connection, so callers never see the reconnect.
pub struct Client {
    addr: String,
    conn: Option<BufReader<TcpStream>>,
}

impl Client {
    /// A client for `addr` (e.g. `127.0.0.1:8080`).  Connects lazily on
    /// the first request.
    pub fn new(addr: &str) -> Self {
        Client { addr: addr.to_string(), conn: None }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One attempt over the current connection.  `Err((retryable, e))`:
    /// retryable is true **only** when the failure proves the server
    /// closed the idle connection before reading our request (write
    /// error, or EOF/reset before a single response byte) — re-sending
    /// is then safe even for the non-idempotent generate POST.  Once any
    /// response byte has arrived, or on a read timeout (the request may
    /// be queued or decoding server-side), the failure is final: a blind
    /// retry could silently submit the request twice.
    fn attempt(
        r: &mut BufReader<TcpStream>,
        addr: &str,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> std::result::Result<(ResponseHead, Vec<u8>), (bool, anyhow::Error)> {
        let mut w = r
            .get_ref()
            .try_clone()
            .map_err(|e| (true, anyhow::Error::from(e).context("cloning client stream")))?;
        write_request(&mut w, addr, method, path, body, true).map_err(|e| (true, e))?;
        let mut line = String::new();
        match r.read_line(&mut line) {
            // Clean EOF before any response byte: the server closed the
            // idle connection (it always answers requests it accepts).
            Ok(0) => return Err((true, anyhow!("server closed the idle connection"))),
            Ok(_) => {}
            Err(e) => {
                let stale = matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::BrokenPipe
                );
                return Err((stale, e.into()));
            }
        }
        let fatal = |e: anyhow::Error| (false, e);
        let head = ResponseHead {
            status: parse_status_line(&line).map_err(fatal)?,
            headers: read_headers(r).map_err(fatal)?,
        };
        let body = read_body(&head, r).map_err(fatal)?;
        Ok((head, body))
    }

    /// One request-response over the kept-alive connection, reconnecting
    /// (at most once per call) when the reused socket turns out to have
    /// been closed before the request was sent.
    fn roundtrip(&mut self, method: &str, path: &str, body: Option<&str>) -> Result<json::Value> {
        for _ in 0..2 {
            let reused = self.conn.is_some();
            if self.conn.is_none() {
                self.conn = Some(BufReader::new(connect(&self.addr)?));
            }
            let r = self.conn.as_mut().expect("connection just ensured");
            match Self::attempt(r, &self.addr, method, path, body) {
                Ok((head, bytes)) => {
                    // The server may have answered `Connection: close`
                    // (error path, shutdown, per-connection request
                    // cap): drop the socket so the next call reconnects
                    // instead of failing.
                    let keep = head
                        .header("connection")
                        .is_some_and(|v| v.eq_ignore_ascii_case("keep-alive"));
                    if !keep {
                        self.conn = None;
                    }
                    let text = std::str::from_utf8(&bytes)
                        .map_err(|_| anyhow!("response body is not UTF-8"))?;
                    let v = json::parse(text).map_err(|e| anyhow!("{e}"))?;
                    // Completion documents keep flowing whatever their
                    // status (the server grades rejected/timed-out/
                    // throttled completions with real codes now);
                    // everything else non-200 is an error.
                    if head.status != 200 && v.get("finish").as_str().is_none() {
                        return Err(status_error(head.status, &v));
                    }
                    return Ok(v);
                }
                Err((retryable, e)) => {
                    self.conn = None;
                    if !reused || !retryable {
                        // Fresh-connection failures are real errors, and
                        // a reused connection that died mid-exchange must
                        // not be retried (the request may have reached
                        // the scheduler).
                        return Err(e);
                    }
                    // The reused socket was already closed when we sent:
                    // loop once more on a fresh connection.
                }
            }
        }
        unreachable!("second attempt always returns");
    }

    /// `POST /v1/generate` over the kept-alive connection.
    pub fn generate(&mut self, req: &GenerateRequest) -> Result<Completion> {
        let v = self.roundtrip("POST", "/v1/generate", Some(&req.to_json().to_string()))?;
        api::completion_from_json(&v)
    }

    /// `GET /healthz` over the kept-alive connection.
    pub fn health(&mut self) -> Result<json::Value> {
        self.roundtrip("GET", "/healthz", None)
    }
}
