//! Blocking HTTP client for the front-end, on plain `std::net` — used by
//! the `hsm request` CLI, the loopback integration tests, and the
//! `http_streaming` bench.  One request per connection (the server
//! always answers `Connection: close`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::api::{self, GenerateRequest};
use super::http;
use crate::serve::{Completion, TokenEvent};
use crate::util::json;

/// Per-read deadline (covers the gap between streamed events too, so it
/// must absorb admission queueing on a loaded server).
const READ_TIMEOUT: Duration = Duration::from_secs(300);
/// Per-write deadline for the request itself.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Status line + headers of a response.
struct ResponseHead {
    status: u16,
    headers: Vec<(String, String)>,
}

impl ResponseHead {
    fn header(&self, name: &str) -> Option<&str> {
        http::header(&self.headers, name)
    }
}

/// Send one request, returning the parsed response head and the reader
/// positioned at the body.
fn send(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(ResponseHead, BufReader<TcpStream>)> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    // Bounded waits: a wedged or half-open server must produce an error,
    // not hang `hsm request` forever.  The read budget is generous —
    // a queued streaming request can legitimately idle for a while
    // before its first token.
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    stream.set_write_timeout(Some(WRITE_TIMEOUT)).ok();
    let mut w = stream.try_clone().context("cloning client stream")?;
    match body {
        Some(body) => write!(
            w,
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )?,
        None => write!(w, "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?,
    }
    w.flush()?;

    let mut r = BufReader::new(stream);
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        bail!("server closed the connection without a response");
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("malformed status line {:?}", line.trim_end()))?;
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("connection closed mid-response-head");
        }
        if line.trim_end_matches(['\r', '\n']).is_empty() {
            break;
        }
        // Lenient on the client side: skip (rather than error on) any
        // header line we cannot parse — we only need a few well-formed ones.
        if let Some(parsed) = http::parse_header_line(&line) {
            headers.push(parsed);
        }
    }
    Ok((ResponseHead { status, headers }, r))
}

/// Read a fixed-length (or to-EOF) response body.
fn read_body(head: &ResponseHead, r: &mut BufReader<TcpStream>) -> Result<Vec<u8>> {
    match head.header("content-length").and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => {
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)?;
            Ok(body)
        }
        None => {
            // Connection: close framing.
            let mut body = Vec::new();
            r.read_to_end(&mut body)?;
            Ok(body)
        }
    }
}

fn parse_json_body(head: &ResponseHead, r: &mut BufReader<TcpStream>) -> Result<json::Value> {
    let body = read_body(head, r)?;
    let text = std::str::from_utf8(&body).map_err(|_| anyhow!("response body is not UTF-8"))?;
    json::parse(text).map_err(|e| anyhow!("{e}"))
}

fn status_error(status: u16, v: &json::Value) -> anyhow::Error {
    anyhow!("server returned {status}: {}", v.get("error").as_str().unwrap_or("(no detail)"))
}

/// `POST /v1/generate`: block until the whole completion is back.
pub fn generate(addr: &str, req: &GenerateRequest) -> Result<Completion> {
    let (head, mut r) = send(addr, "POST", "/v1/generate", Some(&req.to_json().to_string()))?;
    let v = parse_json_body(&head, &mut r)?;
    if head.status != 200 {
        return Err(status_error(head.status, &v));
    }
    api::completion_from_json(&v)
}

/// `POST /v1/stream`: invoke `on_delta(token, text)` for every event as
/// it arrives (`token` is `None` for the final mid-character flush), and
/// return the finished [`Completion`].  Concatenating every `text`
/// argument reconstructs the completion byte-for-byte.
pub fn stream<F: FnMut(Option<u32>, &str)>(
    addr: &str,
    req: &GenerateRequest,
    mut on_delta: F,
) -> Result<Completion> {
    let (head, mut r) = send(addr, "POST", "/v1/stream", Some(&req.to_json().to_string()))?;
    if head.status != 200 {
        let v = parse_json_body(&head, &mut r)?;
        return Err(status_error(head.status, &v));
    }

    let mut done: Option<Completion> = None;
    // SSE events are "data: <json>\n\n"; the server sends one per chunk,
    // but reassemble across chunk boundaries anyway.
    let mut buf: Vec<u8> = Vec::new();
    http::read_chunks(&mut r, |chunk| {
        buf.extend_from_slice(chunk);
        while let Some(pos) = buf.windows(2).position(|w| w == b"\n\n") {
            let event: Vec<u8> = buf.drain(..pos + 2).collect();
            let text = std::str::from_utf8(&event[..pos])
                .map_err(|_| anyhow!("stream event is not UTF-8"))?;
            for line in text.lines() {
                let Some(data) = line.strip_prefix("data: ") else { continue };
                let v = json::parse(data).map_err(|e| anyhow!("{e}"))?;
                match api::event_from_json(&v)? {
                    TokenEvent::Token { token, text_delta, .. } => {
                        on_delta(Some(token), &text_delta);
                    }
                    TokenEvent::Done { text_delta, completion } => {
                        on_delta(None, &text_delta);
                        done = Some(completion);
                    }
                }
            }
        }
        Ok(())
    })?;
    done.ok_or_else(|| anyhow!("stream ended without a done event (server failure mid-request?)"))
}

/// `GET /healthz` — returns the parsed health document.
pub fn health(addr: &str) -> Result<json::Value> {
    let (head, mut r) = send(addr, "GET", "/healthz", None)?;
    let v = parse_json_body(&head, &mut r)?;
    if head.status != 200 {
        return Err(status_error(head.status, &v));
    }
    Ok(v)
}
