//! Minimal HTTP/1.1 wire layer on plain `std::io`, shared by the server
//! and the blocking client (hyper/tokio are unavailable under the
//! vendored-offline constraint, and this front-end needs only a sliver
//! of the protocol: `Content-Length` bodies in, fixed or chunked bodies
//! out, opt-in keep-alive).
//!
//! Fixed-length responses are `Content-Length`-framed and may carry
//! `Connection: keep-alive` when the client asked for it (explicitly —
//! clients that never send the header keep the old close-per-request
//! framing), so `hsm request` and the bench client can reuse one
//! connection across calls.  Streaming responses use
//! `Transfer-Encoding: chunked` with **one chunk per event**, so a
//! client sees each token the moment the server samples it; they always
//! close the connection afterwards.

use std::io::{BufRead, Read, Write};

use anyhow::{anyhow, bail, Result};

/// Cap on the request line + headers (a loopback API front-end, not a
/// general proxy — anything bigger is a broken or hostile client).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Cap on request and chunk bodies.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header names lowercased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        header(&self.headers, name)
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| anyhow!("request body is not valid UTF-8"))
    }

    /// Did the client explicitly ask to keep the connection open?
    /// Conservative on purpose: absent header means close (the HTTP/1.1
    /// default would be keep-alive, but every pre-keep-alive client of
    /// this server frames responses by connection close).
    pub fn wants_keep_alive(&self) -> bool {
        self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("keep-alive"))
    }
}

/// Parse one `Name: value` header line into `(lowercased name, value)`.
/// Shared by the server's request parser and the client's response
/// parser so the two sides of the wire can never drift.
pub fn parse_header_line(line: &str) -> Option<(String, String)> {
    let line = line.trim_end_matches(['\r', '\n']);
    let (name, value) = line.split_once(':')?;
    Some((name.trim().to_ascii_lowercase(), value.trim().to_string()))
}

/// Case-insensitive lookup over headers parsed by [`parse_header_line`].
pub fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    let name = name.to_ascii_lowercase();
    headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
}

/// One head line from the size-capped reader; errors when the cap (not
/// the peer) ended the stream.
fn head_line<T: BufRead>(head: &mut std::io::Take<T>) -> Result<String> {
    let mut line = String::new();
    let n = head.read_line(&mut line)?;
    if n == 0 && head.limit() == 0 {
        bail!("request head exceeds {MAX_HEAD_BYTES} bytes");
    }
    Ok(line)
}

/// Read and parse one request.  `Ok(None)` means the peer closed the
/// connection before sending anything (a clean EOF, not an error).
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Option<HttpRequest>> {
    // `take` caps the head *as it is read*: a single giant line can never
    // buffer more than the budget before the error fires.
    let mut head = (&mut *r).take(MAX_HEAD_BYTES as u64);

    let line = head_line(&mut head)?;
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        bail!("malformed request line {:?}", line.trim_end());
    }

    let mut headers = Vec::new();
    loop {
        let line = head_line(&mut head)?;
        if line.trim_end_matches(['\r', '\n']).is_empty() {
            break;
        }
        headers.push(
            parse_header_line(&line)
                .ok_or_else(|| anyhow!("malformed header line {:?}", line.trim_end()))?,
        );
    }
    drop(head);

    let req = HttpRequest { method, path, headers, body: Vec::new() };
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| anyhow!("bad Content-Length {v:?}"))?,
    };
    if len > MAX_BODY_BYTES {
        bail!("request body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Some(HttpRequest { body, ..req }))
}

/// Write a complete fixed-length response.  `keep_alive` controls the
/// `Connection` header; the `Content-Length` framing makes reuse safe.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> Result<()> {
    write_response_with(w, status, reason, content_type, &[], body, keep_alive)
}

/// [`write_response`] plus extra headers (e.g. `Retry-After` on a 429).
/// Each `(name, value)` pair is emitted verbatim between the standard
/// headers and the blank line.
pub fn write_response_with<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
    keep_alive: bool,
) -> Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {}\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

/// Start a streaming (SSE-over-chunked) response; follow with
/// [`write_chunk`] per event and [`finish_chunks`] at the end.
pub fn write_stream_head<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
    )?;
    w.flush()?;
    Ok(())
}

/// One chunk, flushed immediately — per-token latency is the whole point
/// of the streaming endpoint.
pub fn write_chunk<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    write!(w, "{:x}\r\n", payload.len())?;
    w.write_all(payload)?;
    w.write_all(b"\r\n")?;
    w.flush()?;
    Ok(())
}

/// Terminate a chunked body.
pub fn finish_chunks<W: Write>(w: &mut W) -> Result<()> {
    w.write_all(b"0\r\n\r\n")?;
    w.flush()?;
    Ok(())
}

/// Decode a chunked body incrementally, invoking `on_chunk` per chunk as
/// it arrives (the client side of [`write_chunk`]).
pub fn read_chunks<R: BufRead, F: FnMut(&[u8]) -> Result<()>>(
    r: &mut R,
    mut on_chunk: F,
) -> Result<()> {
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("connection closed mid-chunk-stream");
        }
        let size_field = line.trim().split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_field, 16)
            .map_err(|_| anyhow!("bad chunk size line {:?}", line.trim_end()))?;
        if size == 0 {
            // Final chunk; we never send trailers, so just the blank line.
            let mut end = String::new();
            let _ = r.read_line(&mut end);
            return Ok(());
        }
        if size > MAX_BODY_BYTES {
            bail!("chunk of {size} bytes exceeds the {MAX_BODY_BYTES}-byte cap");
        }
        let mut buf = vec![0u8; size + 2];
        r.read_exact(&mut buf)?;
        if &buf[size..] != b"\r\n" {
            bail!("chunk missing CRLF terminator");
        }
        on_chunk(&buf[..size])?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
                    Content-Length: 14\r\n\r\n{\"prompt\":\"a\"}";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("content-TYPE"), Some("application/json"));
        assert_eq!(req.body_str().unwrap(), "{\"prompt\":\"a\"}");
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(read_request(&mut Cursor::new(&b""[..])).unwrap().is_none());
    }

    #[test]
    fn rejects_garbage_and_oversize() {
        assert!(read_request(&mut Cursor::new(&b"nonsense\r\n\r\n"[..])).is_err());
        let huge = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "a".repeat(MAX_HEAD_BYTES));
        assert!(read_request(&mut Cursor::new(huge.as_bytes())).is_err());
        let big_body = b"POST / HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&big_body[..])).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "OK", "application/json", b"{}", false).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));

        let mut buf = Vec::new();
        write_response(&mut buf, 200, "OK", "application/json", b"{}", true).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"));
    }

    #[test]
    fn extra_headers_land_before_the_blank_line() {
        let mut buf = Vec::new();
        write_response_with(
            &mut buf,
            429,
            "Too Many Requests",
            "application/json",
            &[("Retry-After", "7".to_string())],
            b"{}",
            false,
        )
        .unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        let head_end = text.find("\r\n\r\n").unwrap();
        assert!(text[..head_end].ends_with("Retry-After: 7"), "header inside the head: {text}");
        assert!(text[..head_end].contains("Connection: close"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }

    #[test]
    fn keep_alive_detection_is_explicit_and_case_insensitive() {
        let parse = |conn: &str| {
            let raw = format!("GET / HTTP/1.1\r\n{conn}\r\n\r\n");
            read_request(&mut Cursor::new(raw.as_bytes())).unwrap().unwrap()
        };
        assert!(parse("Connection: keep-alive").wants_keep_alive());
        assert!(parse("CONNECTION: Keep-Alive").wants_keep_alive());
        assert!(!parse("Connection: close").wants_keep_alive());
        assert!(!parse("Host: x").wants_keep_alive(), "absent header means close");
    }

    #[test]
    fn chunk_roundtrip() {
        let mut wire = Vec::new();
        write_stream_head(&mut wire).unwrap();
        write_chunk(&mut wire, b"data: one\n\n").unwrap();
        write_chunk(&mut wire, b"data: two\n\n").unwrap();
        finish_chunks(&mut wire).unwrap();

        // Skip the head, then decode the chunks back.
        let head_end = wire.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        let mut r = Cursor::new(&wire[head_end..]);
        let mut got: Vec<Vec<u8>> = Vec::new();
        read_chunks(&mut r, |c| {
            got.push(c.to_vec());
            Ok(())
        })
        .unwrap();
        assert_eq!(got, vec![b"data: one\n\n".to_vec(), b"data: two\n\n".to_vec()]);
    }
}
