//! Continuous-batching serve subsystem: a threaded scheduler over
//! shared-weight decode sessions.
//!
//! The paper's O(1)-state incremental step (ring buffers instead of a
//! growing KV scan) makes per-token work cheap enough that serving
//! throughput is decided by *scheduling*, not math.  This module replaces
//! the fixed-membership round-robin loop that
//! [`crate::generation::generate_batch`] used to be with a real serving
//! core:
//!
//! * [`Request`] / [`Completion`] — the admission/finish lifecycle of one
//!   prompt, with a [`FinishReason`] (EOT, token cap, context eviction,
//!   or admission rejection).
//! * [`ServeCfg`] — admission control: at most `max_active` concurrent
//!   [`crate::infer::DecodeSession`]s, `threads` workers stepping them,
//!   `quantum`-token time slices.
//! * [`Scheduler`] — continuous batching over one `Arc<`[`Model`]`>`:
//!   the moment a sequence finishes, its session is recycled and the next
//!   pending request is admitted — **no barrier at batch end**.  With
//!   `threads > 1` a worker pool steps *disjoint* sessions in parallel
//!   (the model is immutable and `Send + Sync`; every mutable byte of a
//!   sequence lives in its own session).
//!
//! **Determinism invariant:** sequence `id` samples from an RNG stream
//! seeded `cfg.sample.seed ^ id`, and no per-sequence state is shared, so
//! completions are byte-identical whatever the admission order, quantum,
//! `max_active`, or thread count — and identical to decoding each request
//! alone in a fresh session.  `rust/tests/serve_parity.rs` pins this for
//! every mixer kind.
//!
//! [`generate`](crate::generation::generate) (single-session) and
//! [`generate_batch`](crate::generation::generate_batch)
//! (fixed-membership) are thin wrappers over the same core
//! ([`run_local`]), so the pre-scheduler parity tests keep pinning the
//! decode semantics.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::generation::{encode_prompt, sample_logits, SampleCfg};
use crate::infer::{Decoder, Model, NativeDecoder};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

/// One generation request, submitted to a [`Scheduler`].
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-chosen id; the sequence's RNG stream is seeded
    /// `cfg.sample.seed ^ id`, so ids (not scheduling order) determine
    /// sampled text.  Duplicate ids get duplicate streams.
    pub id: u64,
    pub prompt: String,
    /// Per-request cap on generated tokens (None = `cfg.sample`'s cap).
    pub max_new_tokens: Option<usize>,
}

impl Request {
    pub fn new(id: u64, prompt: &str) -> Self {
        Request { id, prompt: prompt.to_string(), max_new_tokens: None }
    }
}

/// Why a sequence left the active set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FinishReason {
    /// Sampled the end-of-text sentinel.
    Eot,
    /// Hit the request's new-token cap.
    MaxTokens,
    /// Evicted: the context window filled before any other stop.
    CtxFull,
    /// Never admitted — the prompt failed validation (empty encoding,
    /// vocab mismatch, or longer than the context window).
    Rejected(String),
}

/// The finished lifecycle of one [`Request`].
#[derive(Debug, Clone)]
pub struct Completion {
    pub request_id: u64,
    pub prompt: String,
    pub completion: String,
    pub tokens_generated: usize,
    pub finish: FinishReason,
}

impl Completion {
    /// Compatibility accessor matching
    /// [`crate::generation::Generation::stopped_at_eot`].
    pub fn stopped_at_eot(&self) -> bool {
        self.finish == FinishReason::Eot
    }
}

/// Scheduler configuration: admission control + worker pool shape.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Concurrent-session cap: at most this many sequences hold decode
    /// state at once; the rest queue for admission.
    pub max_active: usize,
    /// Worker threads stepping sessions (1 = current thread, no spawn).
    pub threads: usize,
    /// Tokens a worker decodes on one sequence before rotating to the
    /// next ready one (0 = run each admitted sequence to completion).
    /// Pure scheduling knob — never changes sampled text.
    pub quantum: usize,
    /// Sampling parameters shared by every request.
    pub sample: SampleCfg,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { max_active: 8, threads: 4, quantum: 16, sample: SampleCfg::default() }
    }
}

/// Continuous-batching scheduler bound to one shared-weight [`Model`].
///
/// Holding a `Scheduler` is the multi-user serving shape: construct it
/// once and call [`serve`](Scheduler::serve) per request batch; sessions
/// are created lazily per call (weights are never copied — they live in
/// the `Arc`).
pub struct Scheduler {
    model: Arc<Model>,
    cfg: ServeCfg,
}

impl Scheduler {
    pub fn new(model: Arc<Model>, cfg: ServeCfg) -> Self {
        Scheduler { model, cfg }
    }

    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }

    pub fn cfg(&self) -> &ServeCfg {
        &self.cfg
    }

    /// Serve a batch of requests to completion; results come back in
    /// request order.  Invalid prompts are rejected per-request
    /// ([`FinishReason::Rejected`]) without failing the batch; engine
    /// errors (a model/session fault) abort the whole call.
    pub fn serve(&self, tok: &Tokenizer, requests: Vec<Request>) -> Result<Vec<Completion>> {
        serve(&self.model, tok, requests, &self.cfg)
    }
}

/// One-shot convenience for [`Scheduler::serve`].
pub fn serve(
    model: &Arc<Model>,
    tok: &Tokenizer,
    requests: Vec<Request>,
    cfg: &ServeCfg,
) -> Result<Vec<Completion>> {
    if cfg.max_active == 0 {
        bail!("serve: max_active must be at least 1");
    }
    if cfg.threads == 0 {
        bail!("serve: threads must be at least 1");
    }

    // Validate at admission: a bad prompt becomes a Rejected completion
    // (one user's malformed request must not fail everyone else's).
    let mut out: Vec<Option<Completion>> = vec![None; requests.len()];
    let mut jobs: Vec<Job> = Vec::with_capacity(requests.len());
    for (ix, req) in requests.into_iter().enumerate() {
        match encode_prompt(&model.manifest, tok, &req.prompt) {
            Ok(ids) => jobs.push(Job {
                ix,
                id: req.id,
                budget: req.max_new_tokens.unwrap_or(cfg.sample.max_new_tokens),
                prompt: req.prompt,
                ids,
            }),
            Err(e) => {
                out[ix] = Some(Completion {
                    request_id: req.id,
                    prompt: req.prompt,
                    completion: String::new(),
                    tokens_generated: 0,
                    finish: FinishReason::Rejected(format!("{e:#}")),
                });
            }
        }
    }

    if !jobs.is_empty() {
        let n_sessions = cfg.max_active.min(jobs.len());
        if cfg.threads == 1 {
            let mut sessions: Vec<NativeDecoder> =
                (0..n_sessions).map(|_| model.session()).collect();
            run_local(&mut sessions, tok, jobs, &cfg.sample, cfg.quantum, &mut out)?;
        } else {
            run_parallel(model, tok, jobs, cfg, n_sessions, &mut out)?;
        }
    }

    Ok(out
        .into_iter()
        .map(|c| c.expect("scheduler drained every request"))
        .collect())
}

// ---------------------------------------------------------------------------
// Core: per-sequence state machine, shared by the local and threaded drivers
// ---------------------------------------------------------------------------

/// An admitted-but-not-started request: slot index, validated prompt ids
/// and the per-request token budget.
pub(crate) struct Job {
    /// Output slot (input order).
    pub(crate) ix: usize,
    pub(crate) id: u64,
    pub(crate) budget: usize,
    pub(crate) prompt: String,
    pub(crate) ids: Vec<u32>,
}

/// One in-flight sequence.  Everything mutable is per-request (decoder
/// state, token buffer, RNG stream), which is the whole determinism
/// argument: any interleaving of disjoint `Active`s produces identical
/// text.
struct Active<D> {
    dec: D,
    ix: usize,
    id: u64,
    prompt: String,
    ids: Vec<u32>,
    prompt_len: usize,
    last: u32,
    rng: Rng,
    budget: usize,
}

/// Bind a decoder to a job: reset, prefill all but the last prompt token
/// (its logits come from the first `step`), seed the sequence RNG.
fn admit<D: Decoder>(mut dec: D, job: Job, cfg: &SampleCfg) -> Result<Active<D>> {
    let prompt_len = job.ids.len();
    dec.reset();
    dec.prefill(&job.ids[..prompt_len - 1])?;
    Ok(Active {
        last: job.ids[prompt_len - 1],
        dec,
        ix: job.ix,
        id: job.id,
        prompt: job.prompt,
        ids: job.ids,
        prompt_len,
        rng: Rng::new(cfg.seed ^ job.id),
        budget: job.budget,
    })
}

/// Decode up to `quantum` tokens (0 = until finished).  Returns
/// `Some(reason)` when the sequence is done, `None` when its time slice
/// expired.  The stop conditions and sampling order mirror the original
/// `generate` loop exactly, so wrappers stay byte-compatible.
fn advance<D: Decoder>(
    seq: &mut Active<D>,
    tok: &Tokenizer,
    cfg: &SampleCfg,
    quantum: usize,
) -> Result<Option<FinishReason>> {
    let ctx = seq.dec.manifest().ctx;
    let mut sliced = 0usize;
    loop {
        if seq.ids.len() >= ctx {
            return Ok(Some(FinishReason::CtxFull));
        }
        if seq.ids.len() - seq.prompt_len >= seq.budget {
            return Ok(Some(FinishReason::MaxTokens));
        }
        let logits = seq.dec.step(seq.last)?;
        let next = sample_logits(logits, cfg, &mut seq.rng);
        if cfg.stop_at_eot && next == tok.eot {
            return Ok(Some(FinishReason::Eot));
        }
        seq.ids.push(next);
        seq.last = next;
        sliced += 1;
        if quantum > 0 && sliced >= quantum {
            return Ok(None);
        }
    }
}

/// Tear a finished sequence down into its completion, recovering the
/// decoder for the free pool.
fn complete<D>(seq: Active<D>, tok: &Tokenizer, finish: FinishReason) -> (D, usize, Completion) {
    let Active { dec, ix, id, prompt, ids, prompt_len, .. } = seq;
    let completion = Completion {
        request_id: id,
        prompt,
        completion: tok.decode(&ids[prompt_len..]),
        tokens_generated: ids.len() - prompt_len,
        finish,
    };
    (dec, ix, completion)
}

// ---------------------------------------------------------------------------
// Single-threaded driver (also the generate / generate_batch wrapper core)
// ---------------------------------------------------------------------------

/// Continuous batching on the current thread: breadth-first over the
/// active set in `quantum`-token slices; a finishing sequence's decoder
/// immediately admits the next pending job.  `decoders.len()` is the
/// effective `max_active`.
pub(crate) fn run_local<D: Decoder>(
    decoders: &mut [D],
    tok: &Tokenizer,
    jobs: Vec<Job>,
    cfg: &SampleCfg,
    quantum: usize,
    out: &mut [Option<Completion>],
) -> Result<()> {
    if decoders.is_empty() && !jobs.is_empty() {
        bail!("serve: {} requests but no decode sessions", jobs.len());
    }
    let mut free: VecDeque<&mut D> = decoders.iter_mut().collect();
    let mut pending: VecDeque<Job> = jobs.into();
    let mut ready: VecDeque<Active<&mut D>> = VecDeque::new();
    loop {
        // Admission: fill every free session before stepping (job order
        // meets decoder order, so fixed-membership callers get the same
        // decoder↔prompt pairing the old round-robin loop had).
        while !pending.is_empty() {
            let Some(dec) = free.pop_front() else { break };
            let job = pending.pop_front().unwrap();
            ready.push_back(admit(dec, job, cfg)?);
        }
        let Some(mut seq) = ready.pop_front() else { break };
        match advance(&mut seq, tok, cfg, quantum)? {
            Some(finish) => {
                let (dec, ix, completion) = complete(seq, tok, finish);
                out[ix] = Some(completion);
                free.push_back(dec);
            }
            None => ready.push_back(seq),
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Threaded driver: worker pool over disjoint sessions
// ---------------------------------------------------------------------------

/// State behind the scheduler mutex.  Workers hold the lock only to move
/// sequences between queues — prefill and decode run outside it.
struct Shared {
    pending: VecDeque<Job>,
    free: Vec<NativeDecoder>,
    ready: VecDeque<Active<NativeDecoder>>,
    done: Vec<(usize, Completion)>,
    /// Admitted but unfinished sequences (in `ready` or claimed by a
    /// worker).  `inflight == 0 && pending.is_empty()` is the drain
    /// condition.
    inflight: usize,
    failed: Option<anyhow::Error>,
}

fn run_parallel(
    model: &Arc<Model>,
    tok: &Tokenizer,
    jobs: Vec<Job>,
    cfg: &ServeCfg,
    n_sessions: usize,
    out: &mut [Option<Completion>],
) -> Result<()> {
    let workers = cfg.threads.min(jobs.len()).max(1);
    let shared = Mutex::new(Shared {
        pending: jobs.into(),
        free: (0..n_sessions).map(|_| model.session()).collect(),
        ready: VecDeque::new(),
        done: Vec::new(),
        inflight: 0,
        failed: None,
    });
    let wake = Condvar::new();

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| worker(&shared, &wake, tok, cfg));
        }
    });

    // A worker panic would have re-raised when the scope closed above,
    // so the lock cannot be poisoned here.
    let shared = shared.into_inner().expect("workers joined without panicking");
    if let Some(e) = shared.failed {
        return Err(e);
    }
    for (ix, completion) in shared.done {
        out[ix] = Some(completion);
    }
    Ok(())
}

/// What a worker claimed under the lock.
enum Work {
    Admit(Job, NativeDecoder),
    Step(Active<NativeDecoder>),
}

/// Unwind guard: a worker that panics **outside** the lock (decoder or
/// tensor code) would otherwise strand its claimed sequence's `inflight`
/// count and leave the siblings waiting forever.  On a panicking unwind
/// this flags `failed` and wakes everyone, so the siblings exit, the
/// scope joins, and `std::thread::scope` re-raises the panic instead of
/// hanging.  (A panic taken *while holding* the lock poisons it, which
/// already crashes the siblings on their `expect` — also not a hang.)
struct PanicGuard<'a> {
    shared: &'a Mutex<Shared>,
    wake: &'a Condvar,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            if let Ok(mut g) = self.shared.lock() {
                if g.failed.is_none() {
                    g.failed = Some(anyhow!("serve: a worker thread panicked"));
                }
            }
            self.wake.notify_all();
        }
    }
}

fn worker(shared: &Mutex<Shared>, wake: &Condvar, tok: &Tokenizer, cfg: &ServeCfg) {
    let _guard = PanicGuard { shared, wake };
    loop {
        let work = {
            let mut g = shared.lock().expect("scheduler lock poisoned");
            loop {
                if g.failed.is_some() {
                    return;
                }
                if let Some(seq) = g.ready.pop_front() {
                    break Work::Step(seq);
                }
                // Continuous admission: any free session + pending job
                // pairs up immediately — no end-of-batch barrier.
                if !g.pending.is_empty() && !g.free.is_empty() {
                    let job = g.pending.pop_front().unwrap();
                    let dec = g.free.pop().unwrap();
                    g.inflight += 1;
                    break Work::Admit(job, dec);
                }
                if g.inflight == 0 && g.pending.is_empty() {
                    return; // drained
                }
                g = wake.wait(g).expect("scheduler lock poisoned");
            }
        };

        // Heavy work (prefill / quantum of decode steps) off the lock.
        let stepped = match work {
            Work::Admit(job, dec) => admit(dec, job, &cfg.sample).and_then(|mut seq| {
                advance(&mut seq, tok, &cfg.sample, cfg.quantum).map(|f| (seq, f))
            }),
            Work::Step(mut seq) => {
                advance(&mut seq, tok, &cfg.sample, cfg.quantum).map(|f| (seq, f))
            }
        };

        match stepped {
            Ok((seq, None)) => {
                let mut g = shared.lock().expect("scheduler lock poisoned");
                g.ready.push_back(seq);
                drop(g);
                wake.notify_one();
            }
            Ok((seq, Some(finish))) => {
                let (dec, ix, completion) = complete(seq, tok, finish);
                let mut g = shared.lock().expect("scheduler lock poisoned");
                g.done.push((ix, completion));
                g.free.push(dec);
                g.inflight -= 1;
                drop(g);
                // A session freed AND possibly the last sequence: wake
                // everyone so admitters and the drain check both run.
                wake.notify_all();
            }
            Err(e) => {
                let mut g = shared.lock().expect("scheduler lock poisoned");
                g.inflight -= 1;
                if g.failed.is_none() {
                    g.failed = Some(e);
                }
                drop(g);
                wake.notify_all();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LayerInfo;
    use crate::config::Manifest;
    use crate::infer::{weights, ModelWeights};
    use crate::tokenizer::trainer as tok_trainer;

    fn model(vocab: usize, ctx: usize) -> Arc<Model> {
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
        ];
        let m = Manifest::synthetic("hsm_ab", layers, 8, ctx, vocab, 1);
        let flat = weights::seeded_flat(&m, 21);
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
    }

    fn tok() -> Tokenizer {
        let text = crate::corpus::generate(11, 60);
        tok_trainer::train(&text, 280).unwrap()
    }

    #[test]
    fn scheduler_and_convenience_fn_agree() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = ServeCfg {
            max_active: 2,
            threads: 1,
            quantum: 3,
            sample: SampleCfg { max_new_tokens: 6, seed: 4, ..Default::default() },
        };
        let reqs = |s: u64| {
            vec![Request::new(s, "Once upon a time"), Request::new(s + 1, "Lily likes cats")]
        };
        let a = serve(&model, &tok, reqs(0), &cfg).unwrap();
        let b = Scheduler::new(Arc::clone(&model), cfg).serve(&tok, reqs(0)).unwrap();
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.completion, y.completion);
            assert_eq!(x.request_id, y.request_id);
        }
    }

    #[test]
    fn rejected_request_does_not_fail_the_batch() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let cfg = ServeCfg {
            threads: 1,
            sample: SampleCfg { max_new_tokens: 4, ..Default::default() },
            ..Default::default()
        };
        let reqs = vec![Request::new(0, "Once upon a time"), Request::new(1, "")];
        let comps = serve(&model, &tok, reqs, &cfg).unwrap();
        assert_eq!(comps.len(), 2);
        assert!(comps[0].tokens_generated > 0 || comps[0].finish == FinishReason::Eot);
        assert!(matches!(comps[1].finish, FinishReason::Rejected(_)));
        assert_eq!(comps[1].tokens_generated, 0);
    }

    #[test]
    fn zero_capacity_or_threads_is_an_error() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let bad = |max_active, threads| ServeCfg {
            max_active,
            threads,
            ..Default::default()
        };
        let req = vec![Request::new(0, "hi there")];
        assert!(serve(&model, &tok, req.clone(), &bad(0, 1)).is_err());
        assert!(serve(&model, &tok, req, &bad(1, 0)).is_err());
    }

    #[test]
    fn empty_request_batch_is_empty() {
        let tok = tok();
        let model = model(tok.vocab_size(), 48);
        let comps = serve(&model, &tok, Vec::new(), &ServeCfg::default()).unwrap();
        assert!(comps.is_empty());
    }
}
