//! Typed weight views for the native inference engine.
//!
//! The manifest gives the flat parameter order; this module indexes that
//! flat list into named per-layer weight structs so `engine.rs` reads
//! like the math in the paper.  Weights can come from a live
//! [`crate::runtime::StepEngine`] (`get_params`) or a saved
//! [`crate::checkpoint::Checkpoint`].
//!
//! [`QuantWeights`] is the int8 companion representation: every weight
//! *matrix* is quantized to one `i8` row + one `f32` scale per output
//! ([`QuantMatrix`], built by [`crate::infer::tensor::quantize_row`]),
//! stored **out-major** so the tier-4 kernels only ever walk contiguous
//! rows; every weight *vector* (biases, LayerNorm gains, mixing taps)
//! stays f32 — they are O(D) against the matrices' O(D²) and their
//! precision is free.  Checkpoints stay f32: quantization happens once
//! at model-load time ([`crate::infer::Model`]).
//!
//! [`Quant4Weights`] is the int4 group-wise companion: same layout
//! decisions, but each matrix row packs two values per byte with one
//! `f32` scale per [`crate::infer::tensor::Q4_GROUP`] (= 32) input taps
//! ([`QuantMatrix4`], built by
//! [`crate::infer::tensor::quantize_row_q4`]) — ~0.16× the f32 resident
//! bytes against int8's ~0.27×.  Both representations share one
//! generic skeleton ([`QWeights`] over the [`QuantStore`] trait), so
//! layer/mixer field names and quantization *orientation* are identical
//! by construction.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::checkpoint::Checkpoint;
use crate::config::Manifest;
use crate::infer::tensor::{
    q4_get, q4_row_bytes, q4_row_groups, quantize_row, quantize_row_q4,
};

/// One layer's mixer weights (variant-dependent subset populated).
#[derive(Debug, Clone, Default)]
pub struct MixerWeights {
    // ab / vec (per-head scalars or per-channel vectors)
    pub mix_a: Vec<f32>,
    pub mix_b: Vec<f32>,
    // mat
    pub mix_mat_a: Vec<f32>, // [D, D]
    pub mix_mat_b: Vec<f32>, // [D, D]
    pub mix_bias: Vec<f32>,  // [D]
    // gate1 (two-layer MLP) / gate2 (per-head linear)
    pub gate_w1: Vec<f32>,
    pub gate_b1: Vec<f32>,
    pub gate_w2: Vec<f32>,
    pub gate_b2: Vec<f32>,
    pub gate_w: Vec<f32>, // [H, 2hd, hd]
    pub gate_b: Vec<f32>, // [H, hd]
    // fusion
    pub fuse_w1: Vec<f32>,
    pub fuse_b1: Vec<f32>,
    pub fuse_w2: Vec<f32>,
    pub fuse_b2: Vec<f32>,
    // attention
    pub wq: Vec<f32>,
    pub bq: Vec<f32>,
    pub wk: Vec<f32>,
    pub bk: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
}

/// One transformer block's weights.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub ffn_w1: Vec<f32>, // [D, F]
    pub ffn_b1: Vec<f32>, // [F]
    pub ffn_w2: Vec<f32>, // [F, D]
    pub ffn_b2: Vec<f32>, // [D]
    pub mixer: MixerWeights,
}

/// The full decoder's weights, shaped per the manifest.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub tok_emb: Vec<f32>, // [V, D]
    pub pos_emb: Vec<f32>, // [C, D]
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    /// Build from the flat parameter list (manifest order).
    pub fn from_flat(manifest: &Manifest, flat: &[Vec<f32>]) -> Result<Self> {
        if flat.len() != manifest.params.len() {
            bail!(
                "expected {} tensors, got {}",
                manifest.params.len(),
                flat.len()
            );
        }
        let by_name: HashMap<&str, &Vec<f32>> = manifest
            .params
            .iter()
            .zip(flat)
            .map(|(p, d)| (p.name.as_str(), d))
            .collect();
        let get = |name: &str| -> Result<Vec<f32>> {
            by_name
                .get(name)
                .map(|v| (*v).clone())
                .ok_or_else(|| anyhow!("missing parameter {name}"))
        };
        let opt = |name: &str| -> Vec<f32> {
            by_name.get(name).map(|v| (*v).clone()).unwrap_or_default()
        };

        let mut layers = Vec::with_capacity(manifest.layers.len());
        for l in 0..manifest.layers.len() {
            let p = |s: &str| format!("layer{l}.{s}");
            layers.push(LayerWeights {
                ln1_g: get(&p("ln1_g"))?,
                ln1_b: get(&p("ln1_b"))?,
                ln2_g: get(&p("ln2_g"))?,
                ln2_b: get(&p("ln2_b"))?,
                ffn_w1: get(&p("ffn_w1"))?,
                ffn_b1: get(&p("ffn_b1"))?,
                ffn_w2: get(&p("ffn_w2"))?,
                ffn_b2: get(&p("ffn_b2"))?,
                mixer: MixerWeights {
                    mix_a: opt(&p("mix_a")),
                    mix_b: opt(&p("mix_b")),
                    mix_mat_a: opt(&p("mix_A")),
                    mix_mat_b: opt(&p("mix_B")),
                    mix_bias: opt(&p("mix_bias")),
                    gate_w1: opt(&p("gate_w1")),
                    gate_b1: opt(&p("gate_b1")),
                    gate_w2: opt(&p("gate_w2")),
                    gate_b2: opt(&p("gate_b2")),
                    gate_w: opt(&p("gate_w")),
                    gate_b: opt(&p("gate_b")),
                    fuse_w1: opt(&p("fuse_w1")),
                    fuse_b1: opt(&p("fuse_b1")),
                    fuse_w2: opt(&p("fuse_w2")),
                    fuse_b2: opt(&p("fuse_b2")),
                    wq: opt(&p("attn_wq")),
                    bq: opt(&p("attn_bq")),
                    wk: opt(&p("attn_wk")),
                    bk: opt(&p("attn_bk")),
                    wv: opt(&p("attn_wv")),
                    bv: opt(&p("attn_bv")),
                    wo: opt(&p("attn_wo")),
                    bo: opt(&p("attn_bo")),
                },
            });
        }
        Ok(ModelWeights {
            tok_emb: get("tok_emb")?,
            pos_emb: get("pos_emb")?,
            lnf_g: get("lnf_g")?,
            lnf_b: get("lnf_b")?,
            layers,
        })
    }

    /// Build from a training checkpoint (`param/` group, manifest order).
    pub fn from_checkpoint(manifest: &Manifest, ck: &Checkpoint) -> Result<Self> {
        let params = ck.group("param");
        if params.is_empty() {
            bail!("checkpoint has no param/ tensors");
        }
        Self::from_flat(manifest, &params)
    }

    /// FNV-1a over every tensor's f32 bit patterns, in a fixed traversal
    /// order with per-tensor separators (so `[a,b]+[c]` never collides
    /// with `[a]+[b,c]`).  Combined with the manifest hash this is the
    /// model fingerprint that keys the serving stack's prefix cache:
    /// any weight-bit difference yields a different key, so a snapshot
    /// can never be decoded against the wrong weights.
    pub fn content_hash(&self) -> u64 {
        use crate::util::hash;
        let mut h = hash::FNV_OFFSET;
        let tensor = |h: &mut u64, t: &[f32]| {
            for &x in t {
                hash::fold(h, x.to_bits() as u64);
            }
            hash::fold(h, 0xff); // separator
        };
        tensor(&mut h, &self.tok_emb);
        tensor(&mut h, &self.pos_emb);
        tensor(&mut h, &self.lnf_g);
        tensor(&mut h, &self.lnf_b);
        for lw in &self.layers {
            let mw = &lw.mixer;
            for t in [
                &lw.ln1_g, &lw.ln1_b, &lw.ln2_g, &lw.ln2_b, &lw.ffn_w1, &lw.ffn_b1,
                &lw.ffn_w2, &lw.ffn_b2, &mw.mix_a, &mw.mix_b, &mw.mix_mat_a, &mw.mix_mat_b,
                &mw.mix_bias, &mw.gate_w1, &mw.gate_b1, &mw.gate_w2, &mw.gate_b2, &mw.gate_w,
                &mw.gate_b, &mw.fuse_w1, &mw.fuse_b1, &mw.fuse_w2, &mw.fuse_b2, &mw.wq,
                &mw.bq, &mw.wk, &mw.bk, &mw.wv, &mw.bv, &mw.wo, &mw.bo,
            ] {
                tensor(&mut h, t);
            }
        }
        h
    }

    /// Bytes of weight data resident in memory (f32: 4 bytes/element,
    /// same fixed traversal as [`Self::content_hash`]).
    pub fn resident_bytes(&self) -> usize {
        let mut elems =
            self.tok_emb.len() + self.pos_emb.len() + self.lnf_g.len() + self.lnf_b.len();
        for lw in &self.layers {
            let mw = &lw.mixer;
            for t in [
                &lw.ln1_g, &lw.ln1_b, &lw.ln2_g, &lw.ln2_b, &lw.ffn_w1, &lw.ffn_b1,
                &lw.ffn_w2, &lw.ffn_b2, &mw.mix_a, &mw.mix_b, &mw.mix_mat_a, &mw.mix_mat_b,
                &mw.mix_bias, &mw.gate_w1, &mw.gate_b1, &mw.gate_w2, &mw.gate_b2, &mw.gate_w,
                &mw.gate_b, &mw.fuse_w1, &mw.fuse_b1, &mw.fuse_w2, &mw.fuse_b2, &mw.wq,
                &mw.bq, &mw.wk, &mw.bk, &mw.wv, &mw.bv, &mw.wo, &mw.bo,
            ] {
                elems += t.len();
            }
        }
        elems * 4
    }
}

// ---------------------------------------------------------------------------
// Int8 per-row-scale quantized representation
// ---------------------------------------------------------------------------

/// Numeric precision of the resident weights on the native decode path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// Full-precision f32 weights (the checkpoint representation).
    #[default]
    F32,
    /// Int8 per-row-scale quantized weights ([`QuantWeights`]).
    Int8,
    /// Int4 group-wise quantized weights ([`Quant4Weights`]).
    Int4,
}

impl Precision {
    /// Stable label for logs, `/healthz` and bench JSON.
    pub fn label(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }

    /// Parse a CLI spec (`f32` | `int8` | `int4`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Precision::F32),
            "int8" | "i8" => Ok(Precision::Int8),
            "int4" | "i4" => Ok(Precision::Int4),
            other => bail!("unknown precision {other:?} (expected f32, int8 or int4)"),
        }
    }

    /// True for the quantized-weight modes (int8 / int4) — the modes
    /// whose decode path quantizes activations and whose ring state
    /// carries an int8 image ([`crate::infer::engine::Ring`]).
    pub fn is_quantized(&self) -> bool {
        !matches!(self, Precision::F32)
    }
}

/// One int8-quantized weight matrix, stored **out-major** (`[rows,
/// cols]`: row r holds every input tap of output r) with one f32 scale
/// per row.  An absent f32 tensor (mixer kinds leave unused slots
/// empty) quantizes to the empty default.
#[derive(Debug, Clone, Default)]
pub struct QuantMatrix {
    /// Input (reduction) dimension of each row.
    pub cols: usize,
    /// `[rows, cols]` int8 values, row-major; values lie in ±127.
    pub q: Vec<i8>,
    /// Per-row dequantization scales (`w ≈ q · scale`), len = rows.
    pub scale: Vec<f32>,
}

impl QuantMatrix {
    pub fn rows(&self) -> usize {
        self.scale.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Quantize an f32 matrix that is **already out-major** (`[rows,
    /// cols]` — e.g. `tok_emb: [V, D]`), row by row.
    pub fn from_rows(w: &[f32], cols: usize) -> Self {
        if w.is_empty() {
            return QuantMatrix::default();
        }
        debug_assert!(cols > 0 && w.len() % cols == 0, "quant shape mismatch");
        let rows = w.len() / cols;
        let mut q = vec![0i8; w.len()];
        let mut scale = vec![0.0f32; rows];
        for r in 0..rows {
            scale[r] = quantize_row(&w[r * cols..(r + 1) * cols], &mut q[r * cols..(r + 1) * cols]);
        }
        QuantMatrix { cols, q, scale }
    }

    /// Quantize an **in-major** `[k, n]` f32 matrix (the `matvec`
    /// orientation) transposed into out-major `[n, k]` rows, so the
    /// tier-4 kernels walk contiguous int8 rows.
    pub fn from_cols(w: &[f32], n: usize) -> Self {
        if w.is_empty() {
            return QuantMatrix::default();
        }
        debug_assert!(n > 0 && w.len() % n == 0, "quant shape mismatch");
        let k = w.len() / n;
        let mut row = vec![0.0f32; k];
        let mut q = vec![0i8; w.len()];
        let mut scale = vec![0.0f32; n];
        for j in 0..n {
            for i in 0..k {
                row[i] = w[i * n + j];
            }
            scale[j] = quantize_row(&row, &mut q[j * k..(j + 1) * k]);
        }
        QuantMatrix { cols: k, q, scale }
    }

    /// Quantize `blocks` stacked in-major `[k, n]` matrices (per-head
    /// weights like `gate_w: [H, 2hd, hd]`), each transposed, stacked
    /// out-major — block b owns rows `b*n..(b+1)*n`.
    pub fn from_col_blocks(w: &[f32], blocks: usize, k: usize, n: usize) -> Self {
        if w.is_empty() {
            return QuantMatrix::default();
        }
        debug_assert_eq!(w.len(), blocks * k * n, "quant block shape mismatch");
        let mut out =
            QuantMatrix { cols: k, q: vec![0i8; w.len()], scale: vec![0.0f32; blocks * n] };
        let mut row = vec![0.0f32; k];
        for b in 0..blocks {
            let src = &w[b * k * n..(b + 1) * k * n];
            for j in 0..n {
                for i in 0..k {
                    row[i] = src[i * n + j];
                }
                let r = b * n + j;
                out.scale[r] = quantize_row(&row, &mut out.q[r * k..(r + 1) * k]);
            }
        }
        out
    }

    /// Borrow rows `r0..r1` (a per-head block) as a sub-view.
    pub fn rows_slice(&self, r0: usize, r1: usize) -> (&[i8], &[f32]) {
        (&self.q[r0 * self.cols..r1 * self.cols], &self.scale[r0..r1])
    }

    /// Dequantize row r into `out` (`out[i] = q[r,i] · scale[r]`) — the
    /// embedding-lookup path.
    pub fn dequant_row(&self, r: usize, out: &mut [f32]) {
        let s = self.scale[r];
        let row = &self.q[r * self.cols..(r + 1) * self.cols];
        for (o, &qv) in out.iter_mut().zip(row) {
            *o = qv as f32 * s;
        }
    }

    /// Dequantize row r and add it into `out` (the position-embedding
    /// add).
    pub fn dequant_row_add(&self, r: usize, out: &mut [f32]) {
        let s = self.scale[r];
        let row = &self.q[r * self.cols..(r + 1) * self.cols];
        for (o, &qv) in out.iter_mut().zip(row) {
            *o += qv as f32 * s;
        }
    }

    /// Bytes resident: one byte per int8 element + 4 per row scale.
    pub fn resident_bytes(&self) -> usize {
        self.q.len() + self.scale.len() * 4
    }
}

/// One int4 group-wise quantized weight matrix, stored **out-major**
/// like [`QuantMatrix`] but with two values packed per byte (even
/// element in the low nibble) and one f32 scale per
/// [`crate::infer::tensor::Q4_GROUP`] (= 32) input taps of each row.
/// Rows are byte-aligned (`⌈cols/2⌉` bytes each), so per-head row
/// blocks slice cleanly.  An absent f32 tensor quantizes to the empty
/// default.
#[derive(Debug, Clone, Default)]
pub struct QuantMatrix4 {
    /// Input (reduction) dimension of each row.
    pub cols: usize,
    /// Output rows.
    pub rows: usize,
    /// `[rows, ⌈cols/2⌉]` packed int4 values; nibbles lie in ±7.
    pub q: Vec<u8>,
    /// `[rows, ⌈cols/32⌉]` per-group dequantization scales.
    pub scale: Vec<f32>,
}

impl QuantMatrix4 {
    /// Packed bytes per row.
    pub fn row_bytes(&self) -> usize {
        q4_row_bytes(self.cols)
    }

    /// Scale groups per row.
    pub fn row_groups(&self) -> usize {
        q4_row_groups(self.cols)
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Quantize an f32 matrix that is **already out-major**, row by row
    /// (see [`QuantMatrix::from_rows`]).
    pub fn from_rows(w: &[f32], cols: usize) -> Self {
        if w.is_empty() {
            return QuantMatrix4::default();
        }
        debug_assert!(cols > 0 && w.len() % cols == 0, "quant4 shape mismatch");
        let rows = w.len() / cols;
        let kb = q4_row_bytes(cols);
        let groups = q4_row_groups(cols);
        let mut q = vec![0u8; rows * kb];
        let mut scale = vec![0.0f32; rows * groups];
        for r in 0..rows {
            quantize_row_q4(
                &w[r * cols..(r + 1) * cols],
                &mut q[r * kb..(r + 1) * kb],
                &mut scale[r * groups..(r + 1) * groups],
            );
        }
        QuantMatrix4 { cols, rows, q, scale }
    }

    /// Quantize an **in-major** `[k, n]` f32 matrix transposed into
    /// out-major packed rows (see [`QuantMatrix::from_cols`]).
    pub fn from_cols(w: &[f32], n: usize) -> Self {
        if w.is_empty() {
            return QuantMatrix4::default();
        }
        debug_assert!(n > 0 && w.len() % n == 0, "quant4 shape mismatch");
        let k = w.len() / n;
        let mut t = vec![0.0f32; w.len()];
        for i in 0..k {
            for j in 0..n {
                t[j * k + i] = w[i * n + j];
            }
        }
        Self::from_rows(&t, k)
    }

    /// Quantize `blocks` stacked in-major `[k, n]` matrices, each
    /// transposed, stacked out-major (see
    /// [`QuantMatrix::from_col_blocks`]).
    pub fn from_col_blocks(w: &[f32], blocks: usize, k: usize, n: usize) -> Self {
        if w.is_empty() {
            return QuantMatrix4::default();
        }
        debug_assert_eq!(w.len(), blocks * k * n, "quant4 block shape mismatch");
        let mut t = vec![0.0f32; w.len()];
        for b in 0..blocks {
            let src = &w[b * k * n..(b + 1) * k * n];
            let dst = &mut t[b * n * k..(b + 1) * n * k];
            for i in 0..k {
                for j in 0..n {
                    dst[j * k + i] = src[i * n + j];
                }
            }
        }
        Self::from_rows(&t, k)
    }

    /// Borrow rows `r0..r1` (a per-head block) as a sub-view of packed
    /// bytes and group scales.
    pub fn rows_slice(&self, r0: usize, r1: usize) -> (&[u8], &[f32]) {
        let kb = self.row_bytes();
        let groups = self.row_groups();
        (&self.q[r0 * kb..r1 * kb], &self.scale[r0 * groups..r1 * groups])
    }

    /// Dequantize row r into `out` (`out[i] = q4[r,i] · scale[r, i/32]`).
    pub fn dequant_row(&self, r: usize, out: &mut [f32]) {
        let kb = self.row_bytes();
        let groups = self.row_groups();
        let row = &self.q[r * kb..(r + 1) * kb];
        let srow = &self.scale[r * groups..(r + 1) * groups];
        for (i, o) in out.iter_mut().enumerate() {
            *o = q4_get(row, i) as f32 * srow[i / crate::infer::tensor::Q4_GROUP];
        }
    }

    /// Dequantize row r and add it into `out`.
    pub fn dequant_row_add(&self, r: usize, out: &mut [f32]) {
        let kb = self.row_bytes();
        let groups = self.row_groups();
        let row = &self.q[r * kb..(r + 1) * kb];
        let srow = &self.scale[r * groups..(r + 1) * groups];
        for (i, o) in out.iter_mut().enumerate() {
            *o += q4_get(row, i) as f32 * srow[i / crate::infer::tensor::Q4_GROUP];
        }
    }

    /// Bytes resident: one packed byte per element pair + 4 per group
    /// scale — ~0.156× the f32 bytes at 32-wide groups (0.5 B/element
    /// + 0.125 B/element of scales vs 4 B/element).
    pub fn resident_bytes(&self) -> usize {
        self.q.len() + self.scale.len() * 4
    }
}

/// The storage contract a quantized weight *matrix* representation
/// fulfils, so [`QWeights`] can assemble a whole model generically:
/// the three quantization orientations used at load time (already
/// out-major rows; in-major transposed; per-head stacked blocks), the
/// embedding dequantization hooks, and resident-byte accounting.
pub trait QuantStore: Clone + std::fmt::Debug + Default {
    fn from_rows(w: &[f32], cols: usize) -> Self;
    fn from_cols(w: &[f32], n: usize) -> Self;
    fn from_col_blocks(w: &[f32], blocks: usize, k: usize, n: usize) -> Self;
    fn dequant_row(&self, r: usize, out: &mut [f32]);
    fn dequant_row_add(&self, r: usize, out: &mut [f32]);
    fn resident_bytes(&self) -> usize;
    /// Fold this matrix's quantized bytes and scale bits into an FNV-1a
    /// accumulator (the injected-weights fingerprint path).
    fn fold_content(&self, h: &mut u64);
}

impl QuantStore for QuantMatrix {
    fn from_rows(w: &[f32], cols: usize) -> Self {
        QuantMatrix::from_rows(w, cols)
    }
    fn from_cols(w: &[f32], n: usize) -> Self {
        QuantMatrix::from_cols(w, n)
    }
    fn from_col_blocks(w: &[f32], blocks: usize, k: usize, n: usize) -> Self {
        QuantMatrix::from_col_blocks(w, blocks, k, n)
    }
    fn dequant_row(&self, r: usize, out: &mut [f32]) {
        QuantMatrix::dequant_row(self, r, out)
    }
    fn dequant_row_add(&self, r: usize, out: &mut [f32]) {
        QuantMatrix::dequant_row_add(self, r, out)
    }
    fn resident_bytes(&self) -> usize {
        QuantMatrix::resident_bytes(self)
    }
    fn fold_content(&self, h: &mut u64) {
        use crate::util::hash;
        for &q in &self.q {
            hash::fold(h, q as u8 as u64);
        }
        for &s in &self.scale {
            hash::fold(h, s.to_bits() as u64);
        }
        hash::fold(h, 0xff); // separator
    }
}

impl QuantStore for QuantMatrix4 {
    fn from_rows(w: &[f32], cols: usize) -> Self {
        QuantMatrix4::from_rows(w, cols)
    }
    fn from_cols(w: &[f32], n: usize) -> Self {
        QuantMatrix4::from_cols(w, n)
    }
    fn from_col_blocks(w: &[f32], blocks: usize, k: usize, n: usize) -> Self {
        QuantMatrix4::from_col_blocks(w, blocks, k, n)
    }
    fn dequant_row(&self, r: usize, out: &mut [f32]) {
        QuantMatrix4::dequant_row(self, r, out)
    }
    fn dequant_row_add(&self, r: usize, out: &mut [f32]) {
        QuantMatrix4::dequant_row_add(self, r, out)
    }
    fn resident_bytes(&self) -> usize {
        QuantMatrix4::resident_bytes(self)
    }
    fn fold_content(&self, h: &mut u64) {
        use crate::util::hash;
        hash::fold_bytes(h, &self.q);
        for &s in &self.scale {
            hash::fold(h, s.to_bits() as u64);
        }
        hash::fold(h, 0xff); // separator
    }
}

/// One layer's quantized mixer weights, generic over the matrix store
/// (matrices quantized, vectors f32).
#[derive(Debug, Clone, Default)]
pub struct QMixerWeights<M> {
    pub mix_a: Vec<f32>,
    pub mix_b: Vec<f32>,
    pub mix_mat_a: M,
    pub mix_mat_b: M,
    pub mix_bias: Vec<f32>,
    pub gate_w1: M,
    pub gate_b1: Vec<f32>,
    pub gate_w2: M,
    pub gate_b2: Vec<f32>,
    pub gate_w: M, // per-head blocks: head h owns rows h*hd..(h+1)*hd
    pub gate_b: Vec<f32>,
    pub fuse_w1: M,
    pub fuse_b1: Vec<f32>,
    pub fuse_w2: M,
    pub fuse_b2: Vec<f32>,
    pub wq: M,
    pub bq: Vec<f32>,
    pub wk: M,
    pub bk: Vec<f32>,
    pub wv: M,
    pub bv: Vec<f32>,
    pub wo: M,
    pub bo: Vec<f32>,
}

/// One transformer block's quantized weights.
#[derive(Debug, Clone)]
pub struct QLayerWeights<M> {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub ffn_w1: M, // out-major [F, D]
    pub ffn_b1: Vec<f32>,
    pub ffn_w2: M, // out-major [D, F]
    pub ffn_b2: Vec<f32>,
    pub mixer: QMixerWeights<M>,
}

/// The full decoder's quantized representation: weight matrices in the
/// store `M`, weight vectors carried in f32.  Built once from
/// [`ModelWeights`] at model-load time; checkpoints are untouched.
#[derive(Debug, Clone)]
pub struct QWeights<M> {
    pub tok_emb: M, // [V, D], already out-major: logits AND embedding lookup
    pub pos_emb: M, // [C, D] per-position rows (dequantized on lookup)
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub layers: Vec<QLayerWeights<M>>,
}

/// The int8 per-row-scale model representation.
pub type QuantMixerWeights = QMixerWeights<QuantMatrix>;
/// The int8 per-row-scale model representation.
pub type QuantLayerWeights = QLayerWeights<QuantMatrix>;
/// The int8 per-row-scale model representation.
pub type QuantWeights = QWeights<QuantMatrix>;
/// The int4 group-wise model representation.
pub type Quant4MixerWeights = QMixerWeights<QuantMatrix4>;
/// The int4 group-wise model representation.
pub type Quant4LayerWeights = QLayerWeights<QuantMatrix4>;
/// The int4 group-wise model representation.
pub type Quant4Weights = QWeights<QuantMatrix4>;

impl<M: QuantStore> QWeights<M> {
    /// Quantize a full f32 weight set.  Orientation per matrix follows
    /// its use in `engine.rs`: `matvec`-direction matrices (`[k, n]`)
    /// are transposed at quantization time, per-head tensors are
    /// quantized block-per-head, and `tok_emb`/`pos_emb` are quantized
    /// per vocabulary/position row.
    pub fn from_weights(manifest: &Manifest, w: &ModelWeights) -> Self {
        let d = manifest.dim;
        let mut layers = Vec::with_capacity(w.layers.len());
        for (lw, spec) in w.layers.iter().zip(&manifest.layers) {
            let mw = &lw.mixer;
            let heads = spec.heads.max(1);
            let hd = d / heads;
            let f = spec.ffn.max(1);
            layers.push(QLayerWeights {
                ln1_g: lw.ln1_g.clone(),
                ln1_b: lw.ln1_b.clone(),
                ln2_g: lw.ln2_g.clone(),
                ln2_b: lw.ln2_b.clone(),
                ffn_w1: M::from_cols(&lw.ffn_w1, f),
                ffn_b1: lw.ffn_b1.clone(),
                ffn_w2: M::from_cols(&lw.ffn_w2, d),
                ffn_b2: lw.ffn_b2.clone(),
                mixer: QMixerWeights {
                    mix_a: mw.mix_a.clone(),
                    mix_b: mw.mix_b.clone(),
                    mix_mat_a: M::from_cols(&mw.mix_mat_a, d),
                    mix_mat_b: M::from_cols(&mw.mix_mat_b, d),
                    mix_bias: mw.mix_bias.clone(),
                    gate_w1: M::from_cols(&mw.gate_w1, gate1_hidden(&mw.gate_w1, d)),
                    gate_b1: mw.gate_b1.clone(),
                    gate_w2: M::from_cols(&mw.gate_w2, d),
                    gate_b2: mw.gate_b2.clone(),
                    gate_w: M::from_col_blocks(&mw.gate_w, heads, 2 * hd, hd),
                    gate_b: mw.gate_b.clone(),
                    fuse_w1: M::from_col_blocks(
                        &mw.fuse_w1,
                        heads,
                        2 * hd,
                        fuse_hidden(&mw.fuse_w1, heads, hd),
                    ),
                    fuse_b1: mw.fuse_b1.clone(),
                    fuse_w2: M::from_col_blocks(
                        &mw.fuse_w2,
                        heads,
                        fuse_hidden(&mw.fuse_w1, heads, hd),
                        hd,
                    ),
                    fuse_b2: mw.fuse_b2.clone(),
                    wq: M::from_cols(&mw.wq, d),
                    bq: mw.bq.clone(),
                    wk: M::from_cols(&mw.wk, d),
                    bk: mw.bk.clone(),
                    wv: M::from_cols(&mw.wv, d),
                    bv: mw.bv.clone(),
                    wo: M::from_cols(&mw.wo, d),
                    bo: mw.bo.clone(),
                },
            });
        }
        QWeights {
            tok_emb: M::from_rows(&w.tok_emb, d),
            pos_emb: M::from_rows(&w.pos_emb, d),
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
            layers,
        }
    }

    /// FNV-1a over the quantized representation itself (packed bytes,
    /// scales, f32 vectors) in a fixed traversal order — the
    /// fingerprint source for weight sets **injected** pre-quantized
    /// ([`crate::infer::Model::from_quant4`]), where no f32 checkpoint
    /// exists to hash.  Any quantized-bit difference (a corrupted group
    /// scale included) yields a different hash.
    pub fn content_hash(&self) -> u64 {
        use crate::util::hash;
        let mut h = hash::FNV_OFFSET;
        let vector = |h: &mut u64, t: &[f32]| {
            for &x in t {
                hash::fold(h, x.to_bits() as u64);
            }
            hash::fold(h, 0xff); // separator
        };
        self.tok_emb.fold_content(&mut h);
        self.pos_emb.fold_content(&mut h);
        vector(&mut h, &self.lnf_g);
        vector(&mut h, &self.lnf_b);
        for lw in &self.layers {
            let mw = &lw.mixer;
            for m in [
                &lw.ffn_w1, &lw.ffn_w2, &mw.mix_mat_a, &mw.mix_mat_b, &mw.gate_w1, &mw.gate_w2,
                &mw.gate_w, &mw.fuse_w1, &mw.fuse_w2, &mw.wq, &mw.wk, &mw.wv, &mw.wo,
            ] {
                m.fold_content(&mut h);
            }
            for v in [
                &lw.ln1_g, &lw.ln1_b, &lw.ln2_g, &lw.ln2_b, &lw.ffn_b1, &lw.ffn_b2, &mw.mix_a,
                &mw.mix_b, &mw.mix_bias, &mw.gate_b1, &mw.gate_b2, &mw.gate_b, &mw.fuse_b1,
                &mw.fuse_b2, &mw.bq, &mw.bk, &mw.bv, &mw.bo,
            ] {
                vector(&mut h, v);
            }
        }
        h
    }

    /// Bytes of weight data resident in memory: quantized matrices (+
    /// their f32 scales) and the f32 vectors.
    pub fn resident_bytes(&self) -> usize {
        let mut bytes = self.tok_emb.resident_bytes()
            + self.pos_emb.resident_bytes()
            + (self.lnf_g.len() + self.lnf_b.len()) * 4;
        for lw in &self.layers {
            let mw = &lw.mixer;
            for m in [
                &lw.ffn_w1, &lw.ffn_w2, &mw.mix_mat_a, &mw.mix_mat_b, &mw.gate_w1, &mw.gate_w2,
                &mw.gate_w, &mw.fuse_w1, &mw.fuse_w2, &mw.wq, &mw.wk, &mw.wv, &mw.wo,
            ] {
                bytes += m.resident_bytes();
            }
            for v in [
                &lw.ln1_g, &lw.ln1_b, &lw.ln2_g, &lw.ln2_b, &lw.ffn_b1, &lw.ffn_b2, &mw.mix_a,
                &mw.mix_b, &mw.mix_bias, &mw.gate_b1, &mw.gate_b2, &mw.gate_b, &mw.fuse_b1,
                &mw.fuse_b2, &mw.bq, &mw.bk, &mw.bv, &mw.bo,
            ] {
                bytes += v.len() * 4;
            }
        }
        bytes
    }
}

/// Hidden width of the `gate1` MLP: `gate_w1` is `[D, G]` in-major, so
/// G = len / D (0 for kinds without it).
fn gate1_hidden(gate_w1: &[f32], d: usize) -> usize {
    if gate_w1.is_empty() || d == 0 {
        0
    } else {
        gate_w1.len() / d
    }
}

/// Hidden width of the per-head fusion MLP: `fuse_w1` is
/// `[H, 2hd, Fh]` in-major, so Fh = len / (H · 2hd).
fn fuse_hidden(fuse_w1: &[f32], heads: usize, hd: usize) -> usize {
    let denom = heads * 2 * hd;
    if fuse_w1.is_empty() || denom == 0 {
        0
    } else {
        fuse_w1.len() / denom
    }
}

/// Deterministic plausible-init flat parameters for a manifest: LayerNorm
/// gains near 1, biases near 0, everything else small Gaussian noise.
/// Used by benches, examples and parity tests to build runnable models
/// without artifacts or training.
pub fn seeded_flat(manifest: &Manifest, seed: u64) -> Vec<Vec<f32>> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    manifest
        .params
        .iter()
        .map(|p| {
            let mut r = rng.split(p.name.len() as u64);
            let n = p.elems();
            if p.name.ends_with("_g") {
                // LayerNorm gains: near 1 so activations keep unit scale.
                (0..n).map(|_| 1.0 + 0.05 * r.normal() as f32).collect()
            } else if p.name.ends_with("mix_a") || p.name.ends_with("mix_b") {
                // Mixing taps: near the paper's learned magnitudes.
                (0..n).map(|_| 0.6 + 0.2 * r.normal() as f32).collect()
            } else {
                (0..n).map(|_| 0.12 * r.normal() as f32).collect()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{test_manifest, MockEngine};
    use crate::runtime::StepEngine;

    #[test]
    fn builds_from_mock_engine_flat_params() {
        let m = test_manifest("hsm_ab", 2, 16, 300);
        let mut eng = MockEngine::new(m.clone(), 1.8, 0.01);
        eng.init(0).unwrap();
        let w = ModelWeights::from_flat(&m, &eng.get_params().unwrap()).unwrap();
        assert_eq!(w.tok_emb.len(), 300 * 8); // test manifest: [vocab, 8]
        assert_eq!(w.layers.len(), 1);
        assert_eq!(w.layers[0].mixer.mix_a.len(), 1);
        assert_eq!(w.layers[0].ffn_w1.len(), 8 * 16);
    }

    #[test]
    fn rejects_wrong_tensor_count() {
        let m = test_manifest("hsm_ab", 2, 16, 300);
        assert!(ModelWeights::from_flat(&m, &[vec![0.0]]).is_err());
    }

    #[test]
    fn precision_labels_and_parsing() {
        assert_eq!(Precision::default(), Precision::F32);
        assert_eq!(Precision::F32.label(), "f32");
        assert_eq!(Precision::Int8.label(), "int8");
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("int8").unwrap(), Precision::Int8);
        assert_eq!(Precision::parse("i8").unwrap(), Precision::Int8);
        assert!(Precision::parse("fp16").is_err());
    }

    #[test]
    fn quant_from_cols_matches_transposed_from_rows() {
        let (k, n) = (12, 5);
        let w: Vec<f32> = (0..k * n).map(|i| 0.3 * (i as f32) - 7.0).collect(); // in-major [k, n]
        let mut t = vec![0.0f32; k * n]; // out-major [n, k]
        for i in 0..k {
            for j in 0..n {
                t[j * k + i] = w[i * n + j];
            }
        }
        let a = QuantMatrix::from_cols(&w, n);
        let b = QuantMatrix::from_rows(&t, k);
        assert_eq!(a.cols, k);
        assert_eq!(a.rows(), n);
        assert_eq!(a.q, b.q);
        let bits = |s: &[f32]| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.scale), bits(&b.scale));
        assert!(QuantMatrix::from_cols(&[], 0).is_empty());
    }

    #[test]
    fn quant_col_blocks_match_per_block_from_cols() {
        let (blocks, k, n) = (3, 8, 4);
        let w: Vec<f32> =
            (0..blocks * k * n).map(|i| (((i * 13) % 29) as f32) * 0.21 - 2.0).collect();
        let all = QuantMatrix::from_col_blocks(&w, blocks, k, n);
        assert_eq!(all.rows(), blocks * n);
        assert_eq!(all.cols, k);
        for b in 0..blocks {
            let one = QuantMatrix::from_cols(&w[b * k * n..(b + 1) * k * n], n);
            let (q, s) = all.rows_slice(b * n, (b + 1) * n);
            assert_eq!(q, &one.q[..], "block {b} int8 rows diverged");
            assert_eq!(s, &one.scale[..], "block {b} scales diverged");
        }
    }

    #[test]
    fn dequant_row_round_trips_within_half_scale() {
        let d = 16;
        let w: Vec<f32> = (0..3 * d).map(|i| 0.17 * (i as f32) - 4.0).collect();
        let qm = QuantMatrix::from_rows(&w, d);
        let mut out = vec![0.0f32; d];
        for r in 0..3 {
            qm.dequant_row(r, &mut out);
            for (o, &x) in out.iter().zip(&w[r * d..(r + 1) * d]) {
                assert!((o - x).abs() <= 0.5 * qm.scale[r] + 1e-6, "row {r}: {o} vs {x}");
            }
            let before = out.clone();
            qm.dequant_row_add(r, &mut out);
            for (a, b) in out.iter().zip(&before) {
                assert_eq!(*a, 2.0 * b); // x + x is exact in f32
            }
        }
    }

    #[test]
    fn quantized_resident_bytes_are_at_most_30_percent_of_f32() {
        use crate::config::LayerInfo;
        // dim 64: one int8 row of a [·, 64]-col matrix costs 64 + 4
        // bytes against 256 f32 bytes, so matrices land at ~0.27x and
        // the f32-kept vectors stay a rounding error.
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 128 },
            LayerInfo { kind: "attn".into(), heads: 4, shifts: vec![1], ffn: 128 },
            LayerInfo { kind: "fusion".into(), heads: 4, shifts: vec![2], ffn: 128 },
        ];
        let m = Manifest::synthetic("hsm_ab", layers, 64, 64, 300, 1);
        let w = ModelWeights::from_flat(&m, &seeded_flat(&m, 11)).unwrap();
        let q = QuantWeights::from_weights(&m, &w);
        let (fb, qb) = (w.resident_bytes(), q.resident_bytes());
        assert!(qb * 10 <= fb * 3, "int8 resident {qb} bytes vs f32 {fb} — above 0.30x");
        assert_eq!(q.layers.len(), 3);
        assert_eq!(q.tok_emb.rows(), 300);
        assert_eq!(q.tok_emb.cols, 64);
        assert_eq!(q.layers[1].mixer.wq.rows(), 64);
        // fusion per-head blocks: H heads of hd outputs each.
        assert_eq!(q.layers[2].mixer.fuse_w1.rows(), 64);
        assert_eq!(q.layers[2].mixer.fuse_w1.cols, 32);
        assert_eq!(q.layers[2].mixer.fuse_w2.cols, 16);
    }

    #[test]
    fn int4_precision_labels_and_parsing() {
        assert_eq!(Precision::Int4.label(), "int4");
        assert_eq!(Precision::parse("int4").unwrap(), Precision::Int4);
        assert_eq!(Precision::parse("i4").unwrap(), Precision::Int4);
        assert!(!Precision::F32.is_quantized());
        assert!(Precision::Int8.is_quantized());
        assert!(Precision::Int4.is_quantized());
    }

    #[test]
    fn quant4_from_cols_matches_transposed_from_rows() {
        let (k, n) = (45, 5); // k%32 != 0 and k%2 != 0: tail group + tail nibble
        let w: Vec<f32> = (0..k * n).map(|i| 0.3 * (i as f32) - 7.0).collect(); // in-major [k, n]
        let mut t = vec![0.0f32; k * n]; // out-major [n, k]
        for i in 0..k {
            for j in 0..n {
                t[j * k + i] = w[i * n + j];
            }
        }
        let a = QuantMatrix4::from_cols(&w, n);
        let b = QuantMatrix4::from_rows(&t, k);
        assert_eq!(a.cols, k);
        assert_eq!(a.rows, n);
        assert_eq!(a.row_bytes(), 23);
        assert_eq!(a.row_groups(), 2);
        assert_eq!(a.q, b.q);
        let bits = |s: &[f32]| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.scale), bits(&b.scale));
        assert!(QuantMatrix4::from_cols(&[], 0).is_empty());
    }

    #[test]
    fn quant4_col_blocks_match_per_block_from_cols() {
        let (blocks, k, n) = (3, 40, 4);
        let w: Vec<f32> =
            (0..blocks * k * n).map(|i| (((i * 13) % 29) as f32) * 0.21 - 2.0).collect();
        let all = QuantMatrix4::from_col_blocks(&w, blocks, k, n);
        assert_eq!(all.rows, blocks * n);
        assert_eq!(all.cols, k);
        for b in 0..blocks {
            let one = QuantMatrix4::from_cols(&w[b * k * n..(b + 1) * k * n], n);
            let (q, s) = all.rows_slice(b * n, (b + 1) * n);
            assert_eq!(q, &one.q[..], "block {b} int4 rows diverged");
            assert_eq!(s, &one.scale[..], "block {b} group scales diverged");
        }
    }

    #[test]
    fn quant4_dequant_row_round_trips_within_half_group_scale() {
        let d = 48; // one full group + one half group per row
        let w: Vec<f32> = (0..3 * d).map(|i| 0.17 * (i as f32) - 4.0).collect();
        let qm = QuantMatrix4::from_rows(&w, d);
        let mut out = vec![0.0f32; d];
        for r in 0..3 {
            qm.dequant_row(r, &mut out);
            for (i, (o, &x)) in out.iter().zip(&w[r * d..(r + 1) * d]).enumerate() {
                let s = qm.scale[r * qm.row_groups() + i / crate::infer::tensor::Q4_GROUP];
                assert!((o - x).abs() <= 0.5 * s + 1e-6, "row {r} tap {i}: {o} vs {x}");
            }
            let before = out.clone();
            qm.dequant_row_add(r, &mut out);
            for (a, b) in out.iter().zip(&before) {
                assert_eq!(*a, 2.0 * b); // x + x is exact in f32
            }
        }
    }

    #[test]
    fn int4_resident_bytes_are_at_most_20_percent_of_f32() {
        use crate::config::LayerInfo;
        // Packed nibbles cost 0.5 B/element + 4 B per 32-wide group
        // (0.125 B/element of scales): matrices land at ~0.156x and the
        // f32-kept vectors stay a rounding error.
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 128 },
            LayerInfo { kind: "attn".into(), heads: 4, shifts: vec![1], ffn: 128 },
            LayerInfo { kind: "fusion".into(), heads: 4, shifts: vec![2], ffn: 128 },
        ];
        let m = Manifest::synthetic("hsm_ab", layers, 64, 64, 300, 1);
        let w = ModelWeights::from_flat(&m, &seeded_flat(&m, 11)).unwrap();
        let q = Quant4Weights::from_weights(&m, &w);
        let q8 = QuantWeights::from_weights(&m, &w);
        let (fb, qb) = (w.resident_bytes(), q.resident_bytes());
        assert!(qb * 5 <= fb, "int4 resident {qb} bytes vs f32 {fb} — above 0.20x");
        let q8b = q8.resident_bytes();
        assert!(qb * 3 <= q8b * 2, "int4 resident {qb} bytes vs int8 {q8b} — above 0.67x");
        assert_eq!(q.layers.len(), 3);
        assert_eq!(q.tok_emb.rows, 300);
        assert_eq!(q.tok_emb.cols, 64);
        // Same per-head blocking as the int8 representation.
        assert_eq!(q.layers[2].mixer.fuse_w1.rows, 64);
        assert_eq!(q.layers[2].mixer.fuse_w1.cols, 32);
        assert_eq!(q.layers[2].mixer.fuse_w2.cols, 16);
    }
}
