//! Typed weight views for the native inference engine.
//!
//! The manifest gives the flat parameter order; this module indexes that
//! flat list into named per-layer weight structs so `engine.rs` reads
//! like the math in the paper.  Weights can come from a live
//! [`crate::runtime::StepEngine`] (`get_params`) or a saved
//! [`crate::checkpoint::Checkpoint`].

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::checkpoint::Checkpoint;
use crate::config::Manifest;

/// One layer's mixer weights (variant-dependent subset populated).
#[derive(Debug, Clone, Default)]
pub struct MixerWeights {
    // ab / vec (per-head scalars or per-channel vectors)
    pub mix_a: Vec<f32>,
    pub mix_b: Vec<f32>,
    // mat
    pub mix_mat_a: Vec<f32>, // [D, D]
    pub mix_mat_b: Vec<f32>, // [D, D]
    pub mix_bias: Vec<f32>,  // [D]
    // gate1 (two-layer MLP) / gate2 (per-head linear)
    pub gate_w1: Vec<f32>,
    pub gate_b1: Vec<f32>,
    pub gate_w2: Vec<f32>,
    pub gate_b2: Vec<f32>,
    pub gate_w: Vec<f32>, // [H, 2hd, hd]
    pub gate_b: Vec<f32>, // [H, hd]
    // fusion
    pub fuse_w1: Vec<f32>,
    pub fuse_b1: Vec<f32>,
    pub fuse_w2: Vec<f32>,
    pub fuse_b2: Vec<f32>,
    // attention
    pub wq: Vec<f32>,
    pub bq: Vec<f32>,
    pub wk: Vec<f32>,
    pub bk: Vec<f32>,
    pub wv: Vec<f32>,
    pub bv: Vec<f32>,
    pub wo: Vec<f32>,
    pub bo: Vec<f32>,
}

/// One transformer block's weights.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub ffn_w1: Vec<f32>, // [D, F]
    pub ffn_b1: Vec<f32>, // [F]
    pub ffn_w2: Vec<f32>, // [F, D]
    pub ffn_b2: Vec<f32>, // [D]
    pub mixer: MixerWeights,
}

/// The full decoder's weights, shaped per the manifest.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub tok_emb: Vec<f32>, // [V, D]
    pub pos_emb: Vec<f32>, // [C, D]
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    /// Build from the flat parameter list (manifest order).
    pub fn from_flat(manifest: &Manifest, flat: &[Vec<f32>]) -> Result<Self> {
        if flat.len() != manifest.params.len() {
            bail!(
                "expected {} tensors, got {}",
                manifest.params.len(),
                flat.len()
            );
        }
        let by_name: HashMap<&str, &Vec<f32>> = manifest
            .params
            .iter()
            .zip(flat)
            .map(|(p, d)| (p.name.as_str(), d))
            .collect();
        let get = |name: &str| -> Result<Vec<f32>> {
            by_name
                .get(name)
                .map(|v| (*v).clone())
                .ok_or_else(|| anyhow!("missing parameter {name}"))
        };
        let opt = |name: &str| -> Vec<f32> {
            by_name.get(name).map(|v| (*v).clone()).unwrap_or_default()
        };

        let mut layers = Vec::with_capacity(manifest.layers.len());
        for l in 0..manifest.layers.len() {
            let p = |s: &str| format!("layer{l}.{s}");
            layers.push(LayerWeights {
                ln1_g: get(&p("ln1_g"))?,
                ln1_b: get(&p("ln1_b"))?,
                ln2_g: get(&p("ln2_g"))?,
                ln2_b: get(&p("ln2_b"))?,
                ffn_w1: get(&p("ffn_w1"))?,
                ffn_b1: get(&p("ffn_b1"))?,
                ffn_w2: get(&p("ffn_w2"))?,
                ffn_b2: get(&p("ffn_b2"))?,
                mixer: MixerWeights {
                    mix_a: opt(&p("mix_a")),
                    mix_b: opt(&p("mix_b")),
                    mix_mat_a: opt(&p("mix_A")),
                    mix_mat_b: opt(&p("mix_B")),
                    mix_bias: opt(&p("mix_bias")),
                    gate_w1: opt(&p("gate_w1")),
                    gate_b1: opt(&p("gate_b1")),
                    gate_w2: opt(&p("gate_w2")),
                    gate_b2: opt(&p("gate_b2")),
                    gate_w: opt(&p("gate_w")),
                    gate_b: opt(&p("gate_b")),
                    fuse_w1: opt(&p("fuse_w1")),
                    fuse_b1: opt(&p("fuse_b1")),
                    fuse_w2: opt(&p("fuse_w2")),
                    fuse_b2: opt(&p("fuse_b2")),
                    wq: opt(&p("attn_wq")),
                    bq: opt(&p("attn_bq")),
                    wk: opt(&p("attn_wk")),
                    bk: opt(&p("attn_bk")),
                    wv: opt(&p("attn_wv")),
                    bv: opt(&p("attn_bv")),
                    wo: opt(&p("attn_wo")),
                    bo: opt(&p("attn_bo")),
                },
            });
        }
        Ok(ModelWeights {
            tok_emb: get("tok_emb")?,
            pos_emb: get("pos_emb")?,
            lnf_g: get("lnf_g")?,
            lnf_b: get("lnf_b")?,
            layers,
        })
    }

    /// Build from a training checkpoint (`param/` group, manifest order).
    pub fn from_checkpoint(manifest: &Manifest, ck: &Checkpoint) -> Result<Self> {
        let params = ck.group("param");
        if params.is_empty() {
            bail!("checkpoint has no param/ tensors");
        }
        Self::from_flat(manifest, &params)
    }

    /// FNV-1a over every tensor's f32 bit patterns, in a fixed traversal
    /// order with per-tensor separators (so `[a,b]+[c]` never collides
    /// with `[a]+[b,c]`).  Combined with the manifest hash this is the
    /// model fingerprint that keys the serving stack's prefix cache:
    /// any weight-bit difference yields a different key, so a snapshot
    /// can never be decoded against the wrong weights.
    pub fn content_hash(&self) -> u64 {
        use crate::util::hash;
        let mut h = hash::FNV_OFFSET;
        let tensor = |h: &mut u64, t: &[f32]| {
            for &x in t {
                hash::fold(h, x.to_bits() as u64);
            }
            hash::fold(h, 0xff); // separator
        };
        tensor(&mut h, &self.tok_emb);
        tensor(&mut h, &self.pos_emb);
        tensor(&mut h, &self.lnf_g);
        tensor(&mut h, &self.lnf_b);
        for lw in &self.layers {
            let mw = &lw.mixer;
            for t in [
                &lw.ln1_g, &lw.ln1_b, &lw.ln2_g, &lw.ln2_b, &lw.ffn_w1, &lw.ffn_b1,
                &lw.ffn_w2, &lw.ffn_b2, &mw.mix_a, &mw.mix_b, &mw.mix_mat_a, &mw.mix_mat_b,
                &mw.mix_bias, &mw.gate_w1, &mw.gate_b1, &mw.gate_w2, &mw.gate_b2, &mw.gate_w,
                &mw.gate_b, &mw.fuse_w1, &mw.fuse_b1, &mw.fuse_w2, &mw.fuse_b2, &mw.wq,
                &mw.bq, &mw.wk, &mw.bk, &mw.wv, &mw.bv, &mw.wo, &mw.bo,
            ] {
                tensor(&mut h, t);
            }
        }
        h
    }
}

/// Deterministic plausible-init flat parameters for a manifest: LayerNorm
/// gains near 1, biases near 0, everything else small Gaussian noise.
/// Used by benches, examples and parity tests to build runnable models
/// without artifacts or training.
pub fn seeded_flat(manifest: &Manifest, seed: u64) -> Vec<Vec<f32>> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed);
    manifest
        .params
        .iter()
        .map(|p| {
            let mut r = rng.split(p.name.len() as u64);
            let n = p.elems();
            if p.name.ends_with("_g") {
                // LayerNorm gains: near 1 so activations keep unit scale.
                (0..n).map(|_| 1.0 + 0.05 * r.normal() as f32).collect()
            } else if p.name.ends_with("mix_a") || p.name.ends_with("mix_b") {
                // Mixing taps: near the paper's learned magnitudes.
                (0..n).map(|_| 0.6 + 0.2 * r.normal() as f32).collect()
            } else {
                (0..n).map(|_| 0.12 * r.normal() as f32).collect()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{test_manifest, MockEngine};
    use crate::runtime::StepEngine;

    #[test]
    fn builds_from_mock_engine_flat_params() {
        let m = test_manifest("hsm_ab", 2, 16, 300);
        let mut eng = MockEngine::new(m.clone(), 1.8, 0.01);
        eng.init(0).unwrap();
        let w = ModelWeights::from_flat(&m, &eng.get_params().unwrap()).unwrap();
        assert_eq!(w.tok_emb.len(), 300 * 8); // test manifest: [vocab, 8]
        assert_eq!(w.layers.len(), 1);
        assert_eq!(w.layers[0].mixer.mix_a.len(), 1);
        assert_eq!(w.layers[0].ffn_w1.len(), 8 * 16);
    }

    #[test]
    fn rejects_wrong_tensor_count() {
        let m = test_manifest("hsm_ab", 2, 16, 300);
        assert!(ModelWeights::from_flat(&m, &[vec![0.0]]).is_err());
    }
}
