//! Speculative decoding on forked sessions: drafters and their config.
//!
//! The paper's inference-side claim is that HSM makes per-token decode
//! state tiny and **forkable** ([`SessionState`]), which is exactly what
//! speculative decoding needs: a cheap drafter proposes a block of
//! tokens, the full model scores the whole block on a forked session,
//! and an accept/reject pass keeps the longest draft prefix that the
//! full model agrees with — emitting several tokens per verify round
//! when the drafter is right, one when it is wrong.
//!
//! **Exactness.**  Every drafter here is deterministic (a point-mass
//! proposal), so exact rejection sampling degenerates to: sample from
//! the full model's distribution at each scored position — with the
//! request's own RNG stream, in the same order plain decoding would —
//! and accept the draft token iff it equals that sample.  The emitted
//! token is *always* the full-model sample, so the output distribution
//! is untouched, and because the per-request RNG stream
//! (`seed ^ request_id`, PR 2) is consumed identically, the emitted
//! **bytes** are identical to plain decoding (greedy is trivially so).
//! `rust/tests/spec_parity.rs` pins this for every mixer kind, both
//! drafters, and both sampling modes.
//!
//! The drafters:
//!
//! * [`ShallowDrafter`] — self-drafting through the first K layers of
//!   the *same* `Arc<`[`Model`]`>` (no second model, no extra weights).
//!   Natural for HSM: pairwise interactions accumulate across layers,
//!   so a shallow prefix of the stack is a coherent cheap approximation
//!   of the full model.  Resync after a verify round is free — the
//!   first K layers of a full-model [`SessionState`] snapshot *are* the
//!   shallow state (layer l sees only layers below it), so restoring
//!   the main session's snapshot is a complete catch-up.
//! * `shallow-q` ([`ShallowDrafter::quantized`]) — the same shallow
//!   self-draft, stepped through the model's int8 shadow weights
//!   ([`Model::quant`]): the drafter pays quantized (memory-light)
//!   matmuls while the verify pass keeps scoring at the model's own
//!   precision.  Quantization error can only change *which tokens get
//!   proposed* — acceptance may dip, bytes cannot change.
//! * [`NGramDrafter`] — model-free prompt-lookup: propose the
//!   continuation of the most recent earlier occurrence of the current
//!   suffix n-gram in the request's own token history.  Free to run,
//!   and strong on repetitive or copy-heavy contexts.
//!
//! The verify loop itself lives in the serve scheduler
//! (`crate::serve`), where it threads through both scheduler shapes and
//! the streaming surface; this module owns the drafter abstraction, the
//! configuration ([`SpecCfg`], [`DrafterKind`]) and the acceptance
//! accounting ([`SpecStats`]; schedulers aggregate across requests via
//! [`crate::obs::MetricsRegistry`]).

use std::sync::Arc;

use anyhow::{bail, Result};

use super::engine::{DecodeSession, Model, SessionState};
use super::weights::Precision;
use crate::generation::argmax;

/// Speculative-decoding configuration (per scheduler, off by default:
/// `ServeCfg::speculation` is `None`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecCfg {
    /// Which drafter proposes blocks.
    pub drafter: DrafterKind,
    /// Draft block length: tokens proposed (and scored by the full
    /// model) per verify round.  Must be ≥ 1 — "speculation with a
    /// zero-length draft" is plain decoding; disable with `None`
    /// instead.
    pub draft_len: usize,
    /// Score each verify round's block with one fused
    /// [`Decoder::step_batch`](crate::infer::Decoder::step_batch) pass
    /// (default) instead of draft_len + 1 sequential steps with a
    /// snapshot per position.  Byte-identical output either way; only
    /// honoured when the decoder supports batched stepping (others fall
    /// back to the sequential path automatically).  `false` exists for
    /// before/after benching.
    pub fused: bool,
}

impl Default for SpecCfg {
    /// N-gram drafting (max n-gram 3), draft blocks of 4, fused verify.
    fn default() -> Self {
        SpecCfg { drafter: DrafterKind::NGram { max_ngram: 3 }, draft_len: 4, fused: true }
    }
}

impl SpecCfg {
    /// Construction-time validation (run by `ServeCfg::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.draft_len == 0 {
            bail!(
                "speculation: draft_len must be ≥ 1 \
                 (disable speculation by leaving it unset instead)"
            );
        }
        if let DrafterKind::NGram { max_ngram } = self.drafter {
            if max_ngram == 0 {
                bail!("speculation: ngram drafter needs max_ngram ≥ 1");
            }
        }
        Ok(())
    }
}

/// Which draft proposer to run per request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrafterKind {
    /// Self-draft through the first `layers` layers of the serving
    /// model (0 = half the stack).  Needs a decoder that can fork
    /// shared-weight sessions (the native engine).
    Shallow { layers: usize },
    /// [`Self::Shallow`], stepped on the model's int8 shadow weights
    /// ([`Model::quant`]) while verification stays at the model's own
    /// precision — served bytes are identical, only acceptance moves.
    ShallowQuant { layers: usize },
    /// Prompt-lookup n-gram matching over the request's own history,
    /// trying suffix lengths `max_ngram` down to 1.  Model-free.
    NGram { max_ngram: usize },
}

impl DrafterKind {
    /// Stable wire/CLI label.
    pub fn label(&self) -> &'static str {
        match self {
            DrafterKind::Shallow { .. } => "shallow",
            DrafterKind::ShallowQuant { .. } => "shallow-q",
            DrafterKind::NGram { .. } => "ngram",
        }
    }

    /// Parse a CLI/HTTP drafter spec — the **single** place drafter
    /// specs are validated (`--drafter`, `ServeCfg`, tests all route
    /// here): `ngram`, `ngram:N`, `shallow`, `shallow:K`,
    /// `shallow-q`, `shallow-q:K` (N = max n-gram length, default 3;
    /// K = drafter layers, default 0 = half the stack).
    pub fn parse(s: &str) -> Result<DrafterKind> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let num = |p: Option<&str>, default: usize| -> Result<usize> {
            match p {
                None => Ok(default),
                Some(v) => v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("drafter parameter {v:?} is not an integer")),
            }
        };
        match name {
            "ngram" => {
                let max_ngram = num(param, 3)?;
                if max_ngram == 0 {
                    bail!("ngram drafter needs max_ngram ≥ 1");
                }
                Ok(DrafterKind::NGram { max_ngram })
            }
            "shallow" => Ok(DrafterKind::Shallow { layers: num(param, 0)? }),
            "shallow-q" => Ok(DrafterKind::ShallowQuant { layers: num(param, 0)? }),
            other => bail!(
                "unknown drafter {other:?} (expected ngram[:N], shallow[:K] or shallow-q[:K])"
            ),
        }
    }
}

/// Everything a drafter may condition a proposal on.
pub struct DraftCtx<'a> {
    /// The request's full token history — prompt plus every emitted
    /// token, *including* the pending last token (not yet consumed by
    /// the main decoder).  Never empty.
    pub ids: &'a [u32],
    /// The main decoder's state before consuming the pending token
    /// (`state.position() == ids.len() - 1`), supplied only to drafters
    /// that ask for it ([`Drafter::wants_state`]).  Self-drafting
    /// restores from it; model-free drafters never see (or pay for) it.
    pub state: Option<&'a SessionState>,
    /// The end-of-text sentinel when the request stops at it (`None`
    /// when `stop_at_eot` is off).  Draft tokens at or past an EOT can
    /// never be accepted, so drafters truncate there.
    pub eot: Option<u32>,
}

/// A draft-block proposer for speculative decoding.  Implementations
/// must be deterministic: the verify loop's byte-exactness argument
/// needs the proposal to depend only on the (deterministic) context,
/// never on shared mutable state or randomness.
pub trait Drafter: Send {
    /// Stable label for stats and debugging.
    fn label(&self) -> &'static str;

    /// Does [`propose`](Self::propose) read [`DraftCtx::state`]?  The
    /// verify loop snapshots the main session once per round *only*
    /// for drafters that say so (default `false`) — a model-free
    /// drafter never pays the state-clone cost.
    fn wants_state(&self) -> bool {
        false
    }

    /// Append up to `max` proposed continuation tokens to `out`
    /// (fewer — including zero — is always acceptable and simply
    /// shortens the verified block).  The caller guarantees `ids` is
    /// non-empty and that `max` keeps the scored block inside the
    /// model's context window.
    fn propose(&mut self, ctx: &DraftCtx, max: usize, out: &mut Vec<u32>) -> Result<()>;
}

/// Model-free prompt-lookup drafter: find the most recent earlier
/// occurrence of the longest current suffix n-gram (n = `max_ngram`
/// down to 1) in the request's own history and propose the tokens that
/// followed it.  O(history · n) per proposal, no weights touched.
pub struct NGramDrafter {
    max_ngram: usize,
}

impl NGramDrafter {
    pub fn new(max_ngram: usize) -> Self {
        NGramDrafter { max_ngram: max_ngram.max(1) }
    }
}

impl Drafter for NGramDrafter {
    fn label(&self) -> &'static str {
        "ngram"
    }

    fn propose(&mut self, ctx: &DraftCtx, max: usize, out: &mut Vec<u32>) -> Result<()> {
        if max == 0 {
            return Ok(());
        }
        let ids = ctx.ids;
        let len = ids.len();
        // Longest suffix first; a strictly earlier occurrence guarantees
        // at least one continuation token to copy.
        for n in (1..=self.max_ngram.min(len.saturating_sub(1))).rev() {
            let suffix = &ids[len - n..];
            for start in (0..len - n).rev() {
                if &ids[start..start + n] == suffix {
                    for &t in &ids[start + n..(start + n + max).min(len)] {
                        if ctx.eot == Some(t) {
                            break; // at/past EOT a draft can never be accepted
                        }
                        out.push(t);
                    }
                    return Ok(());
                }
            }
        }
        Ok(())
    }
}

/// Self-drafting through the first K layers of the serving model: the
/// drafter forks a [`DecodeSession`] over the *same* `Arc<Model>` and
/// steps only the shallow prefix of the stack
/// ([`DecodeSession::step_shallow`]), drafting greedily.
///
/// Resync is free: because layer l's state depends only on layers
/// below it, the first K layers of the main session's full snapshot
/// are bit-identical to what shallow decoding over the same tokens
/// would have produced — so every proposal starts by restoring the
/// main state, and the drafter can never drift from the verified
/// context (rejected draft tokens never contaminate the next round).
pub struct ShallowDrafter {
    model: Arc<Model>,
    session: DecodeSession,
    layers: usize,
    /// The precision drafting steps run at.  [`Self::new`] inherits the
    /// model's own; [`Self::quantized`] forces [`Precision::Int8`]
    /// (`shallow-q`), stepping through [`Model::quant`] while the
    /// verify side keeps the model's precision.
    precision: Precision,
}

impl ShallowDrafter {
    /// `layers` = 0 picks half the stack (at least 1).
    pub fn new(model: Arc<Model>, layers: usize) -> Self {
        let precision = model.precision();
        Self::at_precision(model, layers, precision)
    }

    /// The `shallow-q` drafter: same shallow self-draft, stepped on
    /// quantized weights (built once, lazily, for f32 models).
    /// Proposals may differ from f32 shallow drafting — acceptance can
    /// move, served bytes cannot.  Int4 models draft on their own int4
    /// weights (they hold no f32 copy to build an int8 shadow from);
    /// everything else drafts on the int8 shadow.
    pub fn quantized(model: Arc<Model>, layers: usize) -> Self {
        let precision = match model.precision() {
            Precision::Int4 => Precision::Int4,
            _ => Precision::Int8,
        };
        Self::at_precision(model, layers, precision)
    }

    fn at_precision(model: Arc<Model>, layers: usize, precision: Precision) -> Self {
        let depth = model.manifest.layers.len().max(1);
        let layers = match layers {
            0 => depth.div_ceil(2),
            n => n.min(depth),
        };
        let session = DecodeSession::new(&model.manifest, None)
            .expect("fresh session state is always valid for its own manifest");
        ShallowDrafter { model, session, layers, precision }
    }

    /// How many layers of the stack this drafter runs.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// The precision drafting steps run at.
    pub fn precision(&self) -> Precision {
        self.precision
    }
}

impl Drafter for ShallowDrafter {
    fn label(&self) -> &'static str {
        match self.precision {
            Precision::F32 => "shallow",
            Precision::Int8 | Precision::Int4 => "shallow-q",
        }
    }

    fn wants_state(&self) -> bool {
        true
    }

    fn propose(&mut self, ctx: &DraftCtx, max: usize, out: &mut Vec<u32>) -> Result<()> {
        if max == 0 {
            return Ok(());
        }
        let m = &self.model.manifest;
        let state = ctx
            .state
            .ok_or_else(|| anyhow::anyhow!("shallow drafting needs the main session state"))?;
        // Complete resync from the verified context (see type docs).
        self.session.restore(m, state)?;
        let mut last = *ctx.ids.last().expect("draft context is never empty");
        // Defensive context clamp; the caller's `max` is already sized
        // to the scored block.
        let cap = m.ctx.saturating_sub(self.session.position());
        for _ in 0..max.min(cap) {
            let logits =
                self.session.step_shallow_at(&self.model, last, self.layers, self.precision)?;
            let next = argmax(logits);
            if ctx.eot == Some(next) {
                break;
            }
            out.push(next);
            last = next;
        }
        Ok(())
    }
}

/// Per-request speculative-decoding accounting; also the aggregate
/// shape reported by `GET /healthz` via
/// [`crate::obs::SpecCounterGroup`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Verify rounds run (each scores one drafted block with the full
    /// model).
    pub rounds: u64,
    /// Draft tokens proposed across all rounds.
    pub drafted: u64,
    /// Draft tokens accepted (the full-model sample matched the draft).
    pub accepted: u64,
    /// Tokens emitted across all rounds — accepted drafts plus the one
    /// corrective/bonus full-model sample each round ends with.
    pub emitted: u64,
    /// Verify rounds scored with one fused multi-row `step_batch` pass
    /// (≤ `rounds`; the rest used the sequential per-position path).
    pub fused_passes: u64,
    /// Positions scored across all fused passes — `fused_rows /
    /// fused_passes` is the mean batch height the fused kernels ran at.
    pub fused_rows: u64,
}

impl SpecStats {
    /// Accepted over drafted (0.0 before any draft).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Tokens emitted per verify round — the headline speculative
    /// metric (1.0 = no better than plain decoding).
    pub fn emitted_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.emitted as f64 / self.rounds as f64
        }
    }

    /// Mean positions scored per fused verify pass (0.0 when every
    /// round used the sequential path) — the observable batch height of
    /// the fused-verify optimisation, surfaced per request and on
    /// `/healthz`.
    pub fn rows_per_fused_pass(&self) -> f64 {
        if self.fused_passes == 0 {
            0.0
        } else {
            self.fused_rows as f64 / self.fused_passes as f64
        }
    }

    /// Accumulate another request's stats.
    pub fn add(&mut self, other: &SpecStats) {
        self.rounds += other.rounds;
        self.drafted += other.drafted;
        self.accepted += other.accepted;
        self.emitted += other.emitted;
        self.fused_passes += other.fused_passes;
        self.fused_rows += other.fused_rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LayerInfo, Manifest};
    use crate::infer::{weights, Decoder, ModelWeights};

    fn model() -> Arc<Model> {
        let layers = vec![
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![1, 2], ffn: 16 },
            LayerInfo { kind: "ab".into(), heads: 2, shifts: vec![2, 4], ffn: 16 },
        ];
        let m = Manifest::synthetic("hsm_ab", layers, 8, 64, 300, 1);
        let flat = weights::seeded_flat(&m, 77);
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
    }

    /// Context + main-session snapshot after consuming all but the
    /// last of `ids`.
    fn ctx_for(model: &Arc<Model>, ids: &[u32]) -> SessionState {
        let mut s = model.session();
        s.prefill(&ids[..ids.len() - 1]).unwrap();
        s.snapshot().unwrap()
    }

    #[test]
    fn ngram_proposes_the_continuation_of_the_latest_match() {
        let md = model();
        let mut d = NGramDrafter::new(3);
        // History: [1 2 3 9 | 1 2 3 4 5 | 1 2 3] — suffix [1,2,3] last
        // occurred at position 4, followed by [4, 5].
        let ids = [1u32, 2, 3, 9, 1, 2, 3, 4, 5, 1, 2, 3];
        let state = ctx_for(&md, &ids);
        let mut out = Vec::new();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 4, &mut out).unwrap();
        assert_eq!(out, vec![4, 5, 1, 2], "longest suffix wins, most recent occurrence");

        // EOT truncation: the copied continuation stops before EOT.
        out.clear();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: Some(5) }, 4, &mut out).unwrap();
        assert_eq!(out, vec![4]);

        // No match anywhere: empty proposal, not an error.
        out.clear();
        let lonely = [7u32, 8];
        let st = ctx_for(&md, &lonely);
        d.propose(&DraftCtx { ids: &lonely, state: Some(&st), eot: None }, 4, &mut out).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn ngram_is_deterministic_and_respects_max() {
        let md = model();
        let mut d = NGramDrafter::new(2);
        let ids = [5u32, 6, 5, 6, 5, 6];
        let state = ctx_for(&md, &ids);
        let mut a = Vec::new();
        let mut b = Vec::new();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 3, &mut a).unwrap();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 3, &mut b).unwrap();
        assert_eq!(a, b);
        assert!(a.len() <= 3);
        assert!(!a.is_empty(), "periodic history must match");
    }

    #[test]
    fn shallow_drafter_is_deterministic_and_never_drifts() {
        let md = model();
        let mut d = ShallowDrafter::new(Arc::clone(&md), 1);
        assert_eq!(d.layers(), 1);
        let ids = [5u32, 9, 3, 7];
        let state = ctx_for(&md, &ids);
        let mut a = Vec::new();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 4, &mut a).unwrap();
        assert_eq!(a.len(), 4, "shallow drafting always fills the block (no EOT stop here)");

        // A second proposal from the same context is identical even
        // though the first one mutated the drafter's internal session —
        // the restore-based resync erases any drift.
        let mut b = Vec::new();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 4, &mut b).unwrap();
        assert_eq!(a, b);

        // Full-depth shallow drafting (layers = L) greedily matches the
        // full model: draft_i = argmax of the real next-token logits.
        let mut full = ShallowDrafter::new(Arc::clone(&md), 99);
        assert_eq!(full.layers(), 2);
        let mut c = Vec::new();
        full.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 3, &mut c).unwrap();
        let mut sess = md.session();
        sess.prefill(&ids[..ids.len() - 1]).unwrap();
        let mut last = *ids.last().unwrap();
        for (i, &want) in c.iter().enumerate() {
            let got = argmax(sess.step(last).unwrap());
            assert_eq!(got, want, "full-depth draft diverged at {i}");
            last = got;
        }
    }

    #[test]
    fn drafter_kind_parses_cli_specs() {
        assert_eq!(DrafterKind::parse("ngram").unwrap(), DrafterKind::NGram { max_ngram: 3 });
        assert_eq!(DrafterKind::parse("ngram:5").unwrap(), DrafterKind::NGram { max_ngram: 5 });
        assert_eq!(DrafterKind::parse("shallow").unwrap(), DrafterKind::Shallow { layers: 0 });
        assert_eq!(
            DrafterKind::parse("shallow:2").unwrap(),
            DrafterKind::Shallow { layers: 2 }
        );
        assert_eq!(
            DrafterKind::parse("shallow-q").unwrap(),
            DrafterKind::ShallowQuant { layers: 0 }
        );
        assert_eq!(
            DrafterKind::parse("shallow-q:3").unwrap(),
            DrafterKind::ShallowQuant { layers: 3 }
        );
        assert_eq!(DrafterKind::ShallowQuant { layers: 0 }.label(), "shallow-q");
        assert!(DrafterKind::parse("ngram:0").is_err());
        assert!(DrafterKind::parse("ngram:x").is_err());
        assert!(DrafterKind::parse("shallow-q:x").is_err());
        assert!(DrafterKind::parse("magic").is_err());
    }

    /// `shallow-q` proposes by stepping the model's int8 shadow: a
    /// full-depth quantized proposal equals greedy decoding on the same
    /// checkpoint loaded as an int8 model, and re-proposing from the
    /// same context is drift-free, exactly like the f32 drafter.
    #[test]
    fn shallow_q_drafter_drafts_on_the_int8_weights() {
        let md = model();
        let mut d = ShallowDrafter::quantized(Arc::clone(&md), 99);
        assert_eq!(d.label(), "shallow-q");
        assert_eq!(d.precision(), Precision::Int8);
        assert_eq!(d.layers(), 2);
        let ids = [5u32, 9, 3, 7];
        let state = ctx_for(&md, &ids);
        let mut a = Vec::new();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 4, &mut a).unwrap();
        assert_eq!(a.len(), 4);
        let mut b = Vec::new();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 4, &mut b).unwrap();
        assert_eq!(a, b, "shallow-q must be deterministic across proposals");

        // The proposal must track the int8 model's greedy continuation
        // over the same restored context.
        let flat = weights::seeded_flat(&md.manifest, 77);
        let q = Model::shared_with_precision(
            md.manifest.clone(),
            ModelWeights::from_flat(&md.manifest, &flat).unwrap(),
            Precision::Int8,
        )
        .unwrap();
        let mut sess = DecodeSession::new(&q.manifest, None).unwrap();
        sess.restore(&q.manifest, &state).unwrap();
        let mut last = *ids.last().unwrap();
        for (i, &want) in a.iter().enumerate() {
            let got = argmax(sess.step(&q, last).unwrap());
            assert_eq!(got, want, "shallow-q draft diverged from the int8 model at {i}");
            last = got;
        }
    }

    /// On an int4 model the quantized drafter must draft at int4 (it
    /// holds no f32 weights, so an int8 shadow cannot be built) and
    /// its proposals must track the model's own greedy continuation.
    #[test]
    fn shallow_q_drafter_on_an_int4_model_drafts_at_int4() {
        let md = model();
        let flat = weights::seeded_flat(&md.manifest, 77);
        let q4 = Model::shared_with_precision(
            md.manifest.clone(),
            ModelWeights::from_flat(&md.manifest, &flat).unwrap(),
            Precision::Int4,
        )
        .unwrap();
        let mut d = ShallowDrafter::quantized(Arc::clone(&q4), 99);
        assert_eq!(d.label(), "shallow-q");
        assert_eq!(d.precision(), Precision::Int4);
        assert_eq!(d.layers(), 2);
        let ids = [5u32, 9, 3, 7];
        let state = ctx_for(&q4, &ids);
        let mut a = Vec::new();
        d.propose(&DraftCtx { ids: &ids, state: Some(&state), eot: None }, 4, &mut a).unwrap();
        assert_eq!(a.len(), 4);

        // Full-depth int4 drafting == greedy decoding on the int4 model.
        let mut sess = DecodeSession::new(&q4.manifest, None).unwrap();
        sess.restore(&q4.manifest, &state).unwrap();
        let mut last = *ids.last().unwrap();
        for (i, &want) in a.iter().enumerate() {
            let got = argmax(sess.step(&q4, last).unwrap());
            assert_eq!(got, want, "int4 shallow-q draft diverged from the int4 model at {i}");
            last = got;
        }
    }

    #[test]
    fn spec_cfg_validates() {
        let ok = SpecCfg::default();
        assert!(ok.fused, "fused verify is the default");
        assert!(ok.validate().is_ok());
        let zero = SpecCfg { draft_len: 0, ..Default::default() };
        assert!(zero.validate().is_err());
        let bad = SpecCfg { drafter: DrafterKind::NGram { max_ngram: 0 }, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn stats_and_counters_aggregate() {
        let a = SpecStats {
            rounds: 2,
            drafted: 8,
            accepted: 6,
            emitted: 8,
            fused_passes: 2,
            fused_rows: 9,
        };
        let mut b =
            SpecStats { rounds: 1, drafted: 4, accepted: 0, emitted: 1, ..Default::default() };
        b.add(&a);
        assert_eq!(
            b,
            SpecStats {
                rounds: 3,
                drafted: 12,
                accepted: 6,
                emitted: 9,
                fused_passes: 2,
                fused_rows: 9,
            }
        );
        assert!((a.acceptance_rate() - 0.75).abs() < 1e-12);
        assert!((a.emitted_per_round() - 4.0).abs() < 1e-12);
        assert!((a.rows_per_fused_pass() - 4.5).abs() < 1e-12);
        assert_eq!(SpecStats::default().acceptance_rate(), 0.0);
        assert_eq!(SpecStats::default().emitted_per_round(), 0.0);
        assert_eq!(SpecStats::default().rows_per_fused_pass(), 0.0);

        let c = crate::obs::SpecCounterGroup::default();
        c.add(&a);
        c.add(&b);
        let snap = c.snapshot();
        assert_eq!(snap.rounds, 5);
        assert_eq!(snap.emitted, 17);
        assert_eq!(snap.fused_passes, 4);
        assert_eq!(snap.fused_rows, 18);
    }
}
