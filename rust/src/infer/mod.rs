//! Native incremental inference engine.
//!
//! The PJRT `decode` artifact recomputes the **full context** for every
//! generated token — O(T) work per token for HSM, O(T²) for attention.
//! But HSM's defining property (paper §3) is that each layer needs only
//! *one* past activation at a fixed shift, so autoregressive decoding
//! admits **O(1) work and state per layer per token** (a ring buffer of
//! post-LN activations), something dense attention fundamentally cannot
//! match (its KV cache grows with T and each step scans all of it).
//!
//! This module realises that advantage as a from-scratch Rust forward
//! pass: checkpoint weights in, one token at a time in, next-token logits
//! out.  It supports **every** mixer variant (HSM ring buffers; a KV
//! cache for attention/hybrid layers) and is validated for logits parity
//! against the PJRT decode artifact in `rust/tests/runtime_e2e.rs`.
//!
//! Submodules:
//! * [`tensor`] — the minimal dense-math substrate (matvec, layernorm,
//!   softmax) used by the engine.
//! * [`weights`] — typed per-layer weight views over a flat checkpoint.
//! * [`engine`] — the incremental decoder itself + sampling loop.

pub mod engine;
pub mod tensor;
pub mod weights;

pub use engine::{InferenceEngine, LayerState};
pub use weights::ModelWeights;
