//! Native inference: the incremental decoder and the serving surface.
//!
//! The PJRT `decode` artifact recomputes the **full context** for every
//! generated token — O(T) work per token for HSM, O(T²) for attention.
//! But HSM's defining property (paper §3) is that each layer needs only
//! *one* past activation at a fixed shift, so autoregressive decoding
//! admits **O(1) work and state per layer per token** (a ring buffer of
//! post-LN activations), something dense attention fundamentally cannot
//! match (its KV cache grows with T and each step scans all of it).
//!
//! This module realises that advantage as a from-scratch Rust forward
//! pass and shapes it for serving:
//!
//! * [`Model`] — manifest + [`ModelWeights`] behind an `Arc`: **one**
//!   weight set shared by any number of concurrent decode sessions.
//! * [`DecodeSession`] — the per-sequence half: layer state (rings / KV
//!   cache) plus all scratch buffers, so the step path allocates nothing.
//! * [`NativeDecoder`] — `Arc<Model>` + `DecodeSession`, implementing
//!   [`Decoder`].
//! * [`WindowEngine`] — an artifact-free full-context reference forward
//!   (independent O(T²) code path) used for parity checking and as the
//!   windowed-decode baseline in benches.
//!
//! Submodules:
//! * [`tensor`] — the minimal dense-math substrate (matvec, layernorm,
//!   softmax) used by both forward passes.
//! * [`weights`] — typed per-layer weight views over a flat checkpoint,
//!   plus quantization of the resident model: int8 per-row-scale
//!   ([`QuantWeights`]) and int4 group-wise ([`Quant4Weights`], group
//!   32), selected by [`Precision`].
//! * [`engine`] — the incremental decoder itself.
//! * [`window`] — the full-sequence reference forward.
//! * [`speculate`] — drafters and configuration for speculative
//!   decoding on forked sessions (the verify loop lives in
//!   [`crate::serve`]).

pub mod engine;
pub mod speculate;
pub mod tensor;
pub mod weights;
pub mod window;

pub use engine::{DecodeSession, LayerState, Model, NativeDecoder, SessionState};
pub use speculate::{
    DraftCtx, Drafter, DrafterKind, NGramDrafter, ShallowDrafter, SpecCfg, SpecStats,
};
pub use weights::{
    ModelWeights, Precision, Quant4Weights, QuantMatrix, QuantMatrix4, QuantWeights,
};
pub use window::WindowEngine;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Manifest;

/// The incremental-generation surface every generation consumer drives.
///
/// A decoder owns the position cursor of one sequence.  `prefill` pushes
/// prompt tokens without needing their logits, `step` consumes one token
/// and returns next-token logits (borrow valid until the next call),
/// `reset` rewinds to an empty sequence.
///
/// Implementations:
/// * [`NativeDecoder`] — O(1)-state incremental engine (rings/KV cache).
/// * [`crate::generation::WindowDecoder`] — re-runs a full-context
///   [`crate::runtime::StepEngine::decode`] pass per token (the PJRT
///   artifact path, and the parity baseline).
pub trait Decoder {
    /// Static model description (ctx, vocab, layer specs).
    fn manifest(&self) -> &Manifest;

    /// Consume prompt tokens without sampling.  Implementations may skip
    /// logit computation entirely (the native decoder does).
    fn prefill(&mut self, tokens: &[u32]) -> Result<()> {
        for &t in tokens {
            self.step(t)?;
        }
        Ok(())
    }

    /// Consume one token, return next-token logits (borrow valid until
    /// the next call on this decoder).
    fn step(&mut self, token: u32) -> Result<&[f32]>;

    /// Score a block of tokens in one fused multi-row pass, returning
    /// their logits row-major as `[tokens.len() * vocab]` (chunk by
    /// `vocab`; borrow valid until the next call).  Bit-identical per
    /// row to stepping the block sequentially, but each weight matrix
    /// streams through cache once for the whole block — the speculative
    /// verify pass.  Afterwards the state is as if every token was
    /// stepped; [`rewind_batch`](Self::rewind_batch) keeps only an
    /// accepted prefix.  The default errors — probe with
    /// [`supports_step_batch`](Self::supports_step_batch) first.
    fn step_batch(&mut self, tokens: &[u32]) -> Result<&[f32]> {
        let _ = tokens;
        bail!("this decoder does not support fused batch stepping")
    }

    /// Roll back the most recent [`step_batch`](Self::step_batch) so
    /// that only its first `keep` tokens remain stepped, byte-exactly.
    fn rewind_batch(&mut self, keep: usize) -> Result<()> {
        let _ = keep;
        bail!("this decoder does not support fused batch stepping")
    }

    /// Cheap capability probe for
    /// [`step_batch`](Self::step_batch)/[`rewind_batch`](Self::rewind_batch)
    /// (the serve scheduler's fused-verify gate).
    fn supports_step_batch(&self) -> bool {
        false
    }

    /// Clear all sequence state (start a new sequence).
    fn reset(&mut self);

    /// Tokens consumed so far.
    fn position(&self) -> usize;

    /// Snapshot this decoder's sequence state, if the implementation
    /// supports forking (`None` otherwise — the default).  A snapshot
    /// restored into a compatible decoder continues decoding
    /// byte-identically to the original.
    fn snapshot(&self) -> Option<SessionState> {
        None
    }

    /// Cheap capability probe: would [`snapshot`](Self::snapshot)
    /// return `Some`?  The default derives the answer by actually
    /// snapshotting (and discarding) — implementations with snapshot
    /// support should override this with a constant so capability
    /// checks (the serve scheduler's speculation gate) never pay a
    /// state clone.
    fn supports_snapshot(&self) -> bool {
        self.snapshot().is_some()
    }

    /// Restore a snapshot taken from a compatible decoder, replacing any
    /// current sequence state.  The default errors: a decoder that
    /// cannot fork (e.g. the full-context window baseline) simply opts
    /// out, and callers (the serve scheduler's prefix cache) fall back
    /// to a cold prefill.
    fn restore(&mut self, _state: &SessionState) -> Result<()> {
        bail!("this decoder does not support state restore")
    }

    /// Stable fingerprint of the model this decoder runs (0 when the
    /// implementation does not provide one).  Prefix-cache snapshots
    /// are keyed by it so state never crosses model boundaries.
    fn fingerprint(&self) -> u64 {
        0
    }

    /// Build a [`Drafter`] of the requested kind for speculative
    /// decoding, or `None` when this implementation cannot supply it.
    /// The model-free n-gram drafter works for any decoder; shallow
    /// self-drafting needs shared-weight session forking, so only
    /// [`NativeDecoder`] provides it.  (Speculation additionally needs
    /// [`snapshot`](Self::snapshot)/[`restore`](Self::restore) support
    /// in the verify loop, so the serve scheduler falls back to plain
    /// decoding on decoders without it.)
    fn drafter(&self, kind: &DrafterKind) -> Option<Box<dyn Drafter>> {
        match *kind {
            DrafterKind::NGram { max_ngram } => Some(Box::new(NGramDrafter::new(max_ngram))),
            DrafterKind::Shallow { .. } | DrafterKind::ShallowQuant { .. } => None,
        }
    }

    /// Weight precision this decoder runs at — a telemetry label
    /// ([`crate::obs`] stage timings, request logs).  The default claims
    /// f32; implementations that can quantize report their actual mode.
    fn precision(&self) -> Precision {
        Precision::F32
    }

    /// Install per-stage timing ([`crate::obs::StageObs`]) on this
    /// decoder's step path, sampling one step in `sample_every`.  The
    /// default is a no-op: a decoder without stage hooks simply
    /// contributes no `hsm_stage_seconds_total` samples, and every other
    /// metric still works.
    fn attach_stage_obs(&mut self, registry: &Arc<crate::obs::MetricsRegistry>, sample_every: usize) {
        let _ = (registry, sample_every);
    }
}

/// Forwarding impl: a `&mut D` decodes through the borrowed decoder, so
/// the serve scheduler's fixed-membership wrappers
/// ([`crate::generation::generate`] / `generate_batch`) can run caller-
/// owned decoders through the same core that owns sessions outright.
impl<D: Decoder + ?Sized> Decoder for &mut D {
    fn manifest(&self) -> &Manifest {
        (**self).manifest()
    }

    fn prefill(&mut self, tokens: &[u32]) -> Result<()> {
        (**self).prefill(tokens)
    }

    fn step(&mut self, token: u32) -> Result<&[f32]> {
        (**self).step(token)
    }

    fn step_batch(&mut self, tokens: &[u32]) -> Result<&[f32]> {
        (**self).step_batch(tokens)
    }

    fn rewind_batch(&mut self, keep: usize) -> Result<()> {
        (**self).rewind_batch(keep)
    }

    fn supports_step_batch(&self) -> bool {
        (**self).supports_step_batch()
    }

    fn reset(&mut self) {
        (**self).reset()
    }

    fn position(&self) -> usize {
        (**self).position()
    }

    fn snapshot(&self) -> Option<SessionState> {
        (**self).snapshot()
    }

    fn supports_snapshot(&self) -> bool {
        (**self).supports_snapshot()
    }

    fn restore(&mut self, state: &SessionState) -> Result<()> {
        (**self).restore(state)
    }

    fn fingerprint(&self) -> u64 {
        (**self).fingerprint()
    }

    fn drafter(&self, kind: &DrafterKind) -> Option<Box<dyn Drafter>> {
        (**self).drafter(kind)
    }

    fn precision(&self) -> Precision {
        (**self).precision()
    }

    fn attach_stage_obs(&mut self, registry: &Arc<crate::obs::MetricsRegistry>, sample_every: usize) {
        (**self).attach_stage_obs(registry, sample_every)
    }
}
