//! The incremental decoder.
//!
//! One call to [`InferenceEngine::step`] consumes one token and returns
//! next-token logits, maintaining per-layer state:
//!
//! * **HSM layers** — a ring buffer of post-LN1 activations with capacity
//!   `max_shift` — **O(1) state and work per token**, the paper's
//!   linear-time claim realised (dense attention cannot do this).
//! * **Attention layers** — a growing K/V cache, O(p) work at position p
//!   (this is exactly why hybrids lose the linear-time property, paper §5).
//!
//! Numerics mirror `python/compile/model.py` op for op (pre-LN blocks,
//! tied embedding, ReLU FFN); parity with the PJRT `decode` artifact is
//! asserted to ~1e-3 in `rust/tests/runtime_e2e.rs`.

use anyhow::{bail, Result};

use super::tensor::{add_assign, layer_norm, matvec, matvec_t, relu_inplace, softmax_inplace, tanh_inplace};
use super::weights::{LayerWeights, ModelWeights};
use crate::config::{LayerInfo, Manifest};

/// Ring buffer of the last `capacity` activation vectors.
#[derive(Debug, Clone)]
pub struct Ring {
    buf: Vec<Vec<f32>>,
    capacity: usize,
    next: usize,
    filled: usize,
}

impl Ring {
    fn new(capacity: usize, dim: usize) -> Self {
        Ring {
            buf: vec![vec![0.0; dim]; capacity.max(1)],
            capacity: capacity.max(1),
            next: 0,
            filled: 0,
        }
    }

    fn push(&mut self, v: &[f32]) {
        self.buf[self.next].copy_from_slice(v);
        self.next = (self.next + 1) % self.capacity;
        self.filled = (self.filled + 1).min(self.capacity);
    }

    /// The vector pushed `age` steps ago (age ≥ 1); None if not yet seen.
    fn back(&self, age: usize) -> Option<&[f32]> {
        if age == 0 || age > self.filled || age > self.capacity {
            return None;
        }
        let idx = (self.next + self.capacity - age) % self.capacity;
        Some(&self.buf[idx])
    }
}

/// Per-layer decoding state.
pub enum LayerState {
    /// HSM mixers: ring of post-LN1 activations (capacity = max shift).
    Hsm(Ring),
    /// Attention: cached K and V per past position, per head-concatenated
    /// `[D]` rows.
    Attn { k: Vec<Vec<f32>>, v: Vec<Vec<f32>> },
}

/// The native incremental inference engine.
pub struct InferenceEngine {
    pub manifest: Manifest,
    w: ModelWeights,
    state: Vec<LayerState>,
    /// Current position (tokens consumed so far).
    pos: usize,
    // scratch buffers (no allocation on the step path)
    h: Vec<f32>,
    y: Vec<f32>,
    f1: Vec<f32>,
    f2: Vec<f32>,
    logits: Vec<f32>,
}

impl InferenceEngine {
    pub fn new(manifest: Manifest, weights: ModelWeights) -> Result<Self> {
        if weights.layers.len() != manifest.layers.len() {
            bail!("weights/manifest layer count mismatch");
        }
        let d = manifest.dim;
        let max_ffn = manifest.layers.iter().map(|l| l.ffn).max().unwrap_or(d);
        let state = manifest
            .layers
            .iter()
            .map(|l| {
                if l.kind == "attn" {
                    LayerState::Attn { k: Vec::new(), v: Vec::new() }
                } else {
                    let max_shift = l.shifts.iter().copied().max().unwrap_or(1);
                    LayerState::Hsm(Ring::new(max_shift, d))
                }
            })
            .collect();
        let vocab = manifest.vocab;
        Ok(InferenceEngine {
            manifest,
            w: weights,
            state,
            pos: 0,
            h: vec![0.0; d],
            y: vec![0.0; d],
            f1: vec![0.0; max_ffn],
            f2: vec![0.0; d],
            logits: vec![0.0; vocab],
        })
    }

    pub fn position(&self) -> usize {
        self.pos
    }

    /// Clear all decoding state (start a new sequence).
    pub fn reset(&mut self) {
        let d = self.manifest.dim;
        for (st, l) in self.state.iter_mut().zip(&self.manifest.layers) {
            *st = if l.kind == "attn" {
                LayerState::Attn { k: Vec::new(), v: Vec::new() }
            } else {
                LayerState::Hsm(Ring::new(l.shifts.iter().copied().max().unwrap_or(1), d))
            };
        }
        self.pos = 0;
    }

    /// Consume one token, return next-token logits (borrow valid until the
    /// next call).
    pub fn step(&mut self, token: u32) -> Result<&[f32]> {
        let d = self.manifest.dim;
        let vocab = self.manifest.vocab;
        if (token as usize) >= vocab {
            bail!("token {token} out of vocab {vocab}");
        }
        if self.pos >= self.manifest.ctx {
            bail!("context window ({}) exhausted — call reset()", self.manifest.ctx);
        }

        // Embedding + learned position.
        let mut x = vec![0.0f32; d];
        let te = &self.w.tok_emb[token as usize * d..(token as usize + 1) * d];
        let pe = &self.w.pos_emb[self.pos * d..(self.pos + 1) * d];
        for i in 0..d {
            x[i] = te[i] + pe[i];
        }

        let n_layers = self.manifest.layers.len();
        for l in 0..n_layers {
            // Split borrows: clone the spec (cheap) and take state by index.
            let spec = self.manifest.layers[l].clone();
            let lw = &self.w.layers[l];

            // h = LN1(x)
            layer_norm(&x, &lw.ln1_g, &lw.ln1_b, &mut self.h);
            // y = mixer(h, state)
            mixer_step(&spec, lw, &self.h, &mut self.state[l], &mut self.y, d);
            add_assign(&mut x, &self.y);

            // FFN
            layer_norm(&x, &lw.ln2_g, &lw.ln2_b, &mut self.f2);
            let f = spec.ffn;
            let f1 = &mut self.f1[..f];
            matvec(&self.f2, &lw.ffn_w1, f, f1);
            add_assign(f1, &lw.ffn_b1);
            relu_inplace(f1);
            matvec(f1, &lw.ffn_w2, d, &mut self.f2);
            add_assign(&mut self.f2, &lw.ffn_b2);
            add_assign(&mut x, &self.f2);
        }

        // Final LN + tied-embedding projection.
        layer_norm(&x, &self.w.lnf_g, &self.w.lnf_b, &mut self.h);
        matvec_t(&self.h, &self.w.tok_emb, vocab, &mut self.logits);
        self.pos += 1;
        Ok(&self.logits)
    }
}

/// One mixer application at the current position.
fn mixer_step(
    spec: &LayerInfo,
    lw: &LayerWeights,
    h: &[f32],
    state: &mut LayerState,
    y: &mut [f32],
    d: usize,
) {
    let mw = &lw.mixer;
    let heads = spec.heads;
    let hd = d / heads;
    match state {
        LayerState::Hsm(ring) => {
            let zeros = vec![0.0f32; d];
            match spec.kind.as_str() {
                "ab" => {
                    for hix in 0..heads {
                        let s = spec.shifts[hix.min(spec.shifts.len() - 1)];
                        // history age s == activation at position p - s; the
                        // push below happens AFTER reads, so age s-1 relative
                        // to the pre-push ring == p - s. We push first instead
                        // to keep ages 1-based; see ordering note below.
                        let prev = ring.back(s).unwrap_or(&zeros);
                        let (a, b) = (mw.mix_a[hix], mw.mix_b[hix]);
                        for c in hix * hd..(hix + 1) * hd {
                            y[c] = a * h[c] + b * prev[c];
                        }
                    }
                }
                "vec" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(&zeros);
                    for c in 0..d {
                        y[c] = mw.mix_a[c] * h[c] + mw.mix_b[c] * prev[c];
                    }
                }
                "mat" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(&zeros);
                    let mut tmp = vec![0.0f32; d];
                    matvec(h, &mw.mix_mat_a, d, y);
                    matvec(prev, &mw.mix_mat_b, d, &mut tmp);
                    add_assign(y, &tmp);
                    add_assign(y, &mw.mix_bias);
                }
                "gate1" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(&zeros);
                    let mut g1 = vec![0.0f32; d];
                    let mut gate = vec![0.0f32; d];
                    matvec(h, &mw.gate_w1, d, &mut g1);
                    add_assign(&mut g1, &mw.gate_b1);
                    relu_inplace(&mut g1);
                    matvec(&g1, &mw.gate_w2, d, &mut gate);
                    add_assign(&mut gate, &mw.gate_b2);
                    tanh_inplace(&mut gate);
                    for c in 0..d {
                        y[c] = gate[c] * h[c] + (1.0 - gate[c]) * prev[c];
                    }
                }
                "gate2" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(&zeros);
                    let mut cat = vec![0.0f32; 2 * hd];
                    let mut gate = vec![0.0f32; hd];
                    for hix in 0..heads {
                        cat[..hd].copy_from_slice(&h[hix * hd..(hix + 1) * hd]);
                        cat[hd..].copy_from_slice(&prev[hix * hd..(hix + 1) * hd]);
                        let w = &mw.gate_w[hix * 2 * hd * hd..(hix + 1) * 2 * hd * hd];
                        matvec(&cat, w, hd, &mut gate);
                        add_assign(&mut gate, &mw.gate_b[hix * hd..(hix + 1) * hd]);
                        tanh_inplace(&mut gate);
                        for c in 0..hd {
                            let gc = hix * hd + c;
                            y[gc] = gate[c] * h[gc] + (1.0 - gate[c]) * prev[gc];
                        }
                    }
                }
                "fusion" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(&zeros);
                    let mut cat = vec![0.0f32; 2 * hd];
                    let mut mid = vec![0.0f32; hd];
                    let mut out = vec![0.0f32; hd];
                    for hix in 0..heads {
                        cat[..hd].copy_from_slice(&h[hix * hd..(hix + 1) * hd]);
                        cat[hd..].copy_from_slice(&prev[hix * hd..(hix + 1) * hd]);
                        let w1 = &mw.fuse_w1[hix * 2 * hd * hd..(hix + 1) * 2 * hd * hd];
                        matvec(&cat, w1, hd, &mut mid);
                        add_assign(&mut mid, &mw.fuse_b1[hix * hd..(hix + 1) * hd]);
                        relu_inplace(&mut mid);
                        let w2 = &mw.fuse_w2[hix * hd * hd..(hix + 1) * hd * hd];
                        matvec(&mid, w2, hd, &mut out);
                        add_assign(&mut out, &mw.fuse_b2[hix * hd..(hix + 1) * hd]);
                        y[hix * hd..(hix + 1) * hd].copy_from_slice(&out);
                    }
                }
                other => panic!("unknown HSM mixer kind {other}"),
            }
            // NOTE ordering: reads used ages relative to the ring BEFORE this
            // push, so back(s) was the activation at position p − s. Push now.
            ring.push(h);
        }
        LayerState::Attn { k, v } => {
            // Project q, k, v for this position.
            let mut q = vec![0.0f32; d];
            let mut kk = vec![0.0f32; d];
            let mut vv = vec![0.0f32; d];
            matvec(h, &mw.wq, d, &mut q);
            add_assign(&mut q, &mw.bq);
            matvec(h, &mw.wk, d, &mut kk);
            add_assign(&mut kk, &mw.bk);
            matvec(h, &mw.wv, d, &mut vv);
            add_assign(&mut vv, &mw.bv);
            k.push(kk);
            v.push(vv);
            let t = k.len();
            let scale = 1.0 / (hd as f32).sqrt();
            let mut o = vec![0.0f32; d];
            let mut scores = vec![0.0f32; t];
            for hix in 0..heads {
                let r = hix * hd..(hix + 1) * hd;
                for (j, kj) in k.iter().enumerate() {
                    let mut dot = 0.0;
                    for c in r.clone() {
                        dot += q[c] * kj[c];
                    }
                    scores[j] = dot * scale;
                }
                softmax_inplace(&mut scores[..t]);
                for (j, vj) in v.iter().enumerate() {
                    let p = scores[j];
                    for c in r.clone() {
                        o[c] += p * vj[c];
                    }
                }
            }
            matvec(&o, &mw.wo, d, y);
            add_assign(y, &mw.bo);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{test_manifest, MockEngine};
    use crate::infer::weights::ModelWeights;
    use crate::runtime::StepEngine;

    fn engine() -> InferenceEngine {
        let m = test_manifest("hsm_ab", 2, 16, 300);
        let mut mock = MockEngine::new(m.clone(), 1.8, 0.01);
        mock.init(0).unwrap();
        // MockEngine weights are constant; perturb them deterministically so
        // tokens/positions are distinguishable.
        let mut params = mock.get_params().unwrap();
        for (ti, t) in params.iter_mut().enumerate() {
            for (i, x) in t.iter_mut().enumerate() {
                *x += 0.05 * (((i * 31 + ti * 7) % 17) as f32 - 8.0) / 8.0;
            }
        }
        let w = ModelWeights::from_flat(&m, &params).unwrap();
        InferenceEngine::new(m, w).unwrap()
    }

    #[test]
    fn ring_buffer_ages() {
        let mut r = Ring::new(3, 2);
        assert!(r.back(1).is_none());
        r.push(&[1.0, 1.0]);
        r.push(&[2.0, 2.0]);
        assert_eq!(r.back(1).unwrap(), &[2.0, 2.0]);
        assert_eq!(r.back(2).unwrap(), &[1.0, 1.0]);
        assert!(r.back(3).is_none());
        r.push(&[3.0, 3.0]);
        r.push(&[4.0, 4.0]); // evicts 1.0
        assert_eq!(r.back(3).unwrap(), &[2.0, 2.0]);
        assert!(r.back(4).is_none());
    }

    #[test]
    fn step_produces_finite_logits_and_advances() {
        let mut e = engine();
        let l1 = e.step(5).unwrap().to_vec();
        assert_eq!(l1.len(), 300);
        assert!(l1.iter().all(|x| x.is_finite()));
        assert_eq!(e.position(), 1);
        let l2 = e.step(6).unwrap().to_vec();
        assert_ne!(l1, l2, "different context, different logits");
    }

    #[test]
    fn reset_restores_determinism() {
        let mut e = engine();
        let a1 = e.step(5).unwrap().to_vec();
        let a2 = e.step(9).unwrap().to_vec();
        e.reset();
        assert_eq!(e.step(5).unwrap().to_vec(), a1);
        assert_eq!(e.step(9).unwrap().to_vec(), a2);
    }

    #[test]
    fn rejects_out_of_vocab_and_overflow() {
        let mut e = engine();
        assert!(e.step(9999).is_err());
        for t in 0..16 {
            e.step(t % 7).unwrap();
        }
        assert!(e.step(0).is_err(), "ctx exhausted must error");
    }

    #[test]
    fn hsm_state_is_constant_size() {
        let mut e = engine();
        for t in 0..10 {
            e.step(t).unwrap();
        }
        match &e.state[0] {
            LayerState::Hsm(r) => assert_eq!(r.buf.len(), 1), // max shift = 1
            _ => panic!("expected HSM state"),
        }
    }
}
