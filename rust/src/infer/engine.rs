//! The incremental decoder, structured for multi-session serving.
//!
//! [`Model`] is the immutable half (manifest + weights) and lives behind
//! an `Arc`: N concurrent [`DecodeSession`]s share one weight set, which
//! is what multi-user serving needs — weights are by far the largest
//! allocation, per-sequence state is tiny:
//!
//! * **HSM layers** — a ring buffer of post-LN1 activations with capacity
//!   `max_shift` — **O(1) state and work per token**, the paper's
//!   linear-time claim realised (dense attention cannot do this).
//! * **Attention layers** — a growing K/V cache, O(p) work at position p
//!   (this is exactly why hybrids lose the linear-time property, paper §5).
//!
//! Every scratch buffer the forward pass needs (including the per-mixer
//! temporaries) lives in the session, so the step path performs **zero
//! allocations** (the KV cache grows amortised).
//!
//! Numerics mirror `python/compile/model.py` op for op (pre-LN blocks,
//! tied embedding, ReLU FFN); parity with the PJRT `decode` artifact is
//! asserted to ~1e-3 in `rust/tests/runtime_e2e.rs`, and with the
//! independent full-sequence forward ([`crate::infer::WindowEngine`])
//! token-for-token in `rust/tests/decode_parity.rs`.

use std::sync::{Arc, OnceLock};
use std::time::Instant;

use anyhow::{bail, Result};

use super::speculate::{Drafter, DrafterKind, NGramDrafter, ShallowDrafter};
use super::tensor::{
    add_assign, layer_norm, matmul, matmul_q, matmul_q4, matmul_t, matmul_t_q, matmul_t_q4,
    matvec, matvec_q, matvec_q4, matvec_t, matvec_t_q, matvec_t_q4, quantize_row, relu_inplace,
    softmax_inplace, tanh_inplace,
};
use super::weights::{
    LayerWeights, ModelWeights, Precision, Quant4LayerWeights, Quant4Weights, QuantLayerWeights,
    QuantMatrix, QuantMatrix4, QuantWeights,
};
use super::Decoder;
use crate::config::{LayerInfo, Manifest};
use crate::obs::{MetricsRegistry, Phase, StageObs};

/// Ring buffer of the last `capacity` activation vectors.
///
/// Quantized stepping additionally stores each row's int8 image
/// ([`Self::push_q`]): the f32 row is then **defined as** the
/// dequantization `q·s` of that image, so downstream quantized matvecs
/// can reuse `(q, s)` directly ([`Self::back_q`]) and a snapshot can
/// drop the f32 rows entirely ([`Self::compact`]) and rebuild them
/// byte-exactly ([`Self::hydrate`]) — the prefix cache's at-rest form.
/// F32 stepping never touches the quantized side, so its rings (and
/// their bytes) are exactly as before.
#[derive(Debug, Clone)]
pub struct Ring {
    buf: Vec<Vec<f32>>,
    capacity: usize,
    next: usize,
    filled: usize,
    /// Per-slot int8 activation rows; empty until the first
    /// [`Self::push_q`] (f32 stepping allocates nothing).
    qrow: Vec<Vec<i8>>,
    /// Per-slot activation scales (pairs with `qrow`).
    qscale: Vec<f32>,
    /// Per-slot validity: a plain [`Self::push`] invalidates the slot's
    /// quantized image instead of recomputing it.
    qok: Vec<bool>,
}

impl Ring {
    fn new(capacity: usize, dim: usize) -> Self {
        Ring {
            buf: vec![vec![0.0; dim]; capacity.max(1)],
            capacity: capacity.max(1),
            next: 0,
            filled: 0,
            qrow: Vec::new(),
            qscale: Vec::new(),
            qok: Vec::new(),
        }
    }

    fn push(&mut self, v: &[f32]) {
        self.buf[self.next].copy_from_slice(v);
        if !self.qok.is_empty() {
            self.qok[self.next] = false;
        }
        self.next = (self.next + 1) % self.capacity;
        self.filled = (self.filled + 1).min(self.capacity);
    }

    fn alloc_q(&mut self, dim: usize) {
        self.qrow = vec![vec![0i8; dim]; self.capacity];
        self.qscale = vec![0.0; self.capacity];
        self.qok = vec![false; self.capacity];
    }

    /// Push a row given as its int8 quantization: the slot's f32
    /// content becomes the dequantization `q·s` (NOT the pre-quantized
    /// row), which makes the stored image canonical — compacting and
    /// rehydrating reproduces the f32 bytes exactly.
    fn push_q(&mut self, q: &[i8], s: f32) {
        if self.qrow.is_empty() {
            self.alloc_q(q.len());
        }
        for (o, &qi) in self.buf[self.next].iter_mut().zip(q) {
            *o = qi as f32 * s;
        }
        self.qrow[self.next].copy_from_slice(q);
        self.qscale[self.next] = s;
        self.qok[self.next] = true;
        self.next = (self.next + 1) % self.capacity;
        self.filled = (self.filled + 1).min(self.capacity);
    }

    /// The vector pushed `age` steps ago (age ≥ 1); None if not yet seen.
    fn back(&self, age: usize) -> Option<&[f32]> {
        if age == 0 || age > self.filled || age > self.capacity {
            return None;
        }
        let idx = (self.next + self.capacity - age) % self.capacity;
        Some(&self.buf[idx])
    }

    /// The int8 image of the row pushed `age` steps ago, when that row
    /// arrived via [`Self::push_q`].
    fn back_q(&self, age: usize) -> Option<(&[i8], f32)> {
        if age == 0 || age > self.filled || age > self.capacity || self.qok.is_empty() {
            return None;
        }
        let idx = (self.next + self.capacity - age) % self.capacity;
        if !self.qok[idx] {
            return None;
        }
        Some((&self.qrow[idx], self.qscale[idx]))
    }

    /// Forget everything (stale contents become unreadable).
    fn clear(&mut self) {
        self.next = 0;
        self.filled = 0;
        self.qok.fill(false);
    }

    /// True when [`Self::compact`] dropped the f32 rows.
    fn is_compacted(&self) -> bool {
        !self.qrow.is_empty() && self.buf.iter().any(Vec::is_empty)
    }

    /// Drop the f32 rows when every *readable* slot (ages `1..=filled`)
    /// carries a quantized image — roughly quarters a cached snapshot's
    /// ring bytes.  No-op otherwise (f32 stepping, partial images), so
    /// callers can invoke it unconditionally.
    fn compact(&mut self) {
        if self.qrow.is_empty() || self.is_compacted() || self.qrow.first().map_or(0, Vec::len) == 0
        {
            return;
        }
        for age in 1..=self.filled.min(self.capacity) {
            let idx = (self.next + self.capacity - age) % self.capacity;
            if !self.qok[idx] {
                return;
            }
        }
        for row in &mut self.buf {
            *row = Vec::new();
        }
    }

    /// Rebuild the f32 rows of a compacted ring from the int8 images —
    /// the exact bytes [`Self::push_q`] wrote (same `q·s` expression),
    /// so a hydrate-after-compact round trip is lossless.  Unreadable
    /// slots rehydrate to zeros, matching a fresh ring.
    fn hydrate(&mut self) {
        if !self.is_compacted() {
            return;
        }
        let dim = self.qrow.first().map_or(0, Vec::len);
        for (((row, q), &s), &ok) in
            self.buf.iter_mut().zip(&self.qrow).zip(&self.qscale).zip(&self.qok)
        {
            row.clear();
            if ok {
                row.extend(q.iter().map(|&qi| qi as f32 * s));
            } else {
                row.resize(dim, 0.0);
            }
        }
    }

    /// Copy another ring's contents into this one without reallocating
    /// (the derived `Clone::clone_from` would rebuild the row vecs).
    /// Both rings must share capacity and dim — always true for rings
    /// of the same session layer — and neither side is compacted
    /// (session rings always carry their f32 rows).
    fn copy_from(&mut self, other: &Ring) {
        debug_assert_eq!(self.capacity, other.capacity);
        for (dst, src) in self.buf.iter_mut().zip(&other.buf) {
            dst.copy_from_slice(src);
        }
        if other.qrow.is_empty() {
            self.qok.fill(false);
        } else {
            if self.qrow.is_empty() {
                self.alloc_q(other.qrow.first().map_or(0, Vec::len));
            }
            for (dst, src) in self.qrow.iter_mut().zip(&other.qrow) {
                dst.copy_from_slice(src);
            }
            self.qscale.copy_from_slice(&other.qscale);
            self.qok.copy_from_slice(&other.qok);
        }
        self.next = other.next;
        self.filled = other.filled;
    }
}

/// Per-layer decoding state.
#[derive(Debug, Clone)]
pub enum LayerState {
    /// HSM mixers: ring of post-LN1 activations (capacity = max shift).
    Hsm(Ring),
    /// Attention: flat K and V caches, one `[D]` row per past position
    /// (head-concatenated), stride `D`.
    Attn { k: Vec<f32>, v: Vec<f32> },
}

impl LayerState {
    fn new(spec: &LayerInfo, d: usize) -> Self {
        if spec.kind == "attn" {
            LayerState::Attn { k: Vec::new(), v: Vec::new() }
        } else {
            let max_shift = spec.shifts.iter().copied().max().unwrap_or(1);
            LayerState::Hsm(Ring::new(max_shift, d))
        }
    }

    fn clear(&mut self) {
        match self {
            LayerState::Hsm(ring) => ring.clear(),
            LayerState::Attn { k, v } => {
                k.clear();
                v.clear();
            }
        }
    }
}

/// The complete decoding state of one sequence after consuming some
/// token prefix: per-layer state plus the position cursor, detached
/// from any session.  Cloneable, so it is the snapshot/fork currency of
/// the serving stack — prefix caching today ([`crate::serve::PrefixCache`]),
/// speculative decoding and session migration later.
///
/// HSM layers make snapshots unusually cheap: a ring of `max_shift`
/// activation rows is **O(max_shift · D) regardless of how many tokens
/// were consumed** — unlike a KV cache, which grows with the prefix
/// (attention layers in hybrids still carry their O(pos · D) caches,
/// exactly the asymmetry of the paper's linear-time claim).
///
/// Restoring a snapshot is bit-exact: decoding from a restored state is
/// byte-identical to cold-prefilling the same prefix
/// (`rust/tests/fork_parity.rs` pins this for every mixer kind).
#[derive(Debug, Clone)]
pub struct SessionState {
    layers: Vec<LayerState>,
    pos: usize,
    /// Fingerprint of the model this state was captured under
    /// (0 = unstamped — accepted by any structurally matching model).
    /// [`NativeDecoder`] stamps snapshots and refuses to restore a
    /// stamp from different weights, so structurally identical models
    /// can never silently swap state.
    fingerprint: u64,
}

impl SessionState {
    /// Fresh (position-zero) state for a manifest.
    fn fresh(m: &Manifest) -> Self {
        SessionState {
            layers: m.layers.iter().map(|l| LayerState::new(l, m.dim)).collect(),
            pos: 0,
            fingerprint: 0,
        }
    }

    /// Tokens consumed by the sequence this state was captured from.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Fingerprint of the model this state was captured under (0 when
    /// unstamped).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Heap footprint in f32 elements (prefix-cache accounting).
    pub fn elems(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                LayerState::Hsm(r) => r.buf.len() * r.buf.first().map_or(0, Vec::len),
                LayerState::Attn { k, v } => k.len() + v.len(),
            })
            .sum()
    }

    /// Approximate heap bytes this state holds: f32 ring rows + int8
    /// ring images + KV caches.  The prefix cache's byte accounting —
    /// a [`Self::compact`]ed quantized snapshot reports roughly a
    /// quarter of its hydrated self.
    pub fn resident_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                LayerState::Hsm(r) => {
                    r.buf.iter().map(|b| b.len() * 4).sum::<usize>()
                        + r.qrow.iter().map(Vec::len).sum::<usize>()
                        + r.qscale.len() * 4
                        + r.qok.len()
                }
                LayerState::Attn { k, v } => (k.len() + v.len()) * 4,
            })
            .sum()
    }

    /// True when at least one ring dropped its f32 rows in favour of
    /// the int8 images — how the prefix cache classifies an entry's
    /// at-rest precision.
    pub fn is_compacted(&self) -> bool {
        self.layers.iter().any(|l| matches!(l, LayerState::Hsm(r) if r.is_compacted()))
    }

    /// Drop the f32 ring rows wherever a complete int8 image exists
    /// (quantized-precision decoding records one per pushed row).  A
    /// no-op for f32-decoded state, so the prefix cache calls it
    /// unconditionally before storing a snapshot.  A compacted state
    /// fails [`Self::validate`] — [`Self::hydrate`] before use.
    pub fn compact(&mut self) {
        for l in &mut self.layers {
            if let LayerState::Hsm(r) = l {
                r.compact();
            }
        }
    }

    /// Rebuild the f32 ring rows of a compacted state — byte-exact, as
    /// dequantization is the same `q·s` every [`Ring::push_q`] wrote.
    pub fn hydrate(&mut self) {
        for l in &mut self.layers {
            if let LayerState::Hsm(r) = l {
                r.hydrate();
            }
        }
    }

    /// Structural compatibility with a manifest: layer count, kinds and
    /// dimensions must match, and internal invariants (ring fill, KV
    /// row count vs position) must hold.  Structure alone cannot tell
    /// two same-shaped models apart — [`NativeDecoder`] additionally
    /// checks the fingerprint stamp when restoring.
    pub fn validate(&self, m: &Manifest) -> Result<()> {
        if self.layers.len() != m.layers.len() {
            bail!(
                "session state has {} layers, manifest {}",
                self.layers.len(),
                m.layers.len()
            );
        }
        if self.pos > m.ctx {
            bail!("session state position {} exceeds ctx {}", self.pos, m.ctx);
        }
        for (l, (st, spec)) in self.layers.iter().zip(&m.layers).enumerate() {
            match st {
                LayerState::Hsm(ring) => {
                    if spec.kind == "attn" {
                        bail!("layer {l}: state is HSM but spec is attention");
                    }
                    let cap = spec.shifts.iter().copied().max().unwrap_or(1).max(1);
                    let dim = ring.buf.first().map_or(0, Vec::len);
                    if ring.capacity != cap || dim != m.dim {
                        bail!(
                            "layer {l}: ring shape {}x{dim} does not match spec {cap}x{}",
                            ring.capacity,
                            m.dim
                        );
                    }
                    if ring.filled != self.pos.min(ring.capacity) {
                        bail!(
                            "layer {l}: ring fill {} inconsistent with position {}",
                            ring.filled,
                            self.pos
                        );
                    }
                }
                LayerState::Attn { k, v } => {
                    if spec.kind != "attn" {
                        bail!("layer {l}: state is attention but spec is {:?}", spec.kind);
                    }
                    if k.len() != self.pos * m.dim || v.len() != self.pos * m.dim {
                        bail!(
                            "layer {l}: KV cache of {}/{} elems inconsistent with \
                             position {} (dim {})",
                            k.len(),
                            v.len(),
                            self.pos,
                            m.dim
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

/// The immutable half of a decoder: manifest + weights, shared across
/// any number of [`DecodeSession`]s via `Arc`.
///
/// Weights are resident at one [`Precision`], chosen at construction:
/// * [`Precision::F32`] — the checkpoint representation, byte-exact
///   decoding.  A quantized shadow ([`QuantWeights`] or
///   [`Quant4Weights`]) is built lazily the first time something asks
///   for it (the `shallow-q` drafter).
/// * [`Precision::Int8`] — weights are quantized once at load time and
///   the f32 copy is **dropped**, so the resident footprint really is
///   the quantized one (≈0.27x at dim 64); decoding dispatches to the
///   int8 kernel tier.
/// * [`Precision::Int4`] — as int8, but group-wise 4-bit (group 32,
///   ≈0.16x resident); decoding dispatches to the int4 kernel tier.
pub struct Model {
    pub manifest: Manifest,
    /// F32 weights; `None` for pure-quantized models (dropped after
    /// quantization so the memory saving is real).
    weights: Option<ModelWeights>,
    /// Int8 shadow: pre-built for int8 models, lazily built from the
    /// f32 weights otherwise (the quantized drafter's weight set).
    quant: OnceLock<QuantWeights>,
    /// Int4 shadow: pre-built for int4 models, lazily built from the
    /// f32 weights otherwise.
    quant4: OnceLock<Quant4Weights>,
    precision: Precision,
    /// Lazily computed content fingerprint (manifest shape + weight
    /// bits + precision); keys the serving stack's prefix cache and
    /// guards snapshot restores so state can never cross into a
    /// different model — or the same weights at a different precision,
    /// whose activations diverge.
    fingerprint: OnceLock<u64>,
}

impl Model {
    /// Validate weight/manifest consistency (f32 precision).
    pub fn new(manifest: Manifest, weights: ModelWeights) -> Result<Self> {
        Self::with_precision(manifest, weights, Precision::F32)
    }

    /// Validate weight/manifest consistency; for [`Precision::Int8`] /
    /// [`Precision::Int4`], quantize at load time and drop the f32 copy
    /// (checkpoints on disk are untouched — quantization is a load-time
    /// representation).
    pub fn with_precision(
        manifest: Manifest,
        weights: ModelWeights,
        precision: Precision,
    ) -> Result<Self> {
        if weights.layers.len() != manifest.layers.len() {
            bail!(
                "weights have {} layers, manifest {}",
                weights.layers.len(),
                manifest.layers.len()
            );
        }
        let d = manifest.dim;
        if weights.tok_emb.len() != manifest.vocab * d {
            bail!(
                "tok_emb has {} elems, expected vocab*dim = {}",
                weights.tok_emb.len(),
                manifest.vocab * d
            );
        }
        if weights.pos_emb.len() != manifest.ctx * d {
            bail!(
                "pos_emb has {} elems, expected ctx*dim = {}",
                weights.pos_emb.len(),
                manifest.ctx * d
            );
        }
        for (l, spec) in manifest.layers.iter().enumerate() {
            if spec.heads == 0 || d % spec.heads != 0 {
                bail!("layer {l}: heads {} must divide dim {d}", spec.heads);
            }
        }
        let quant = OnceLock::new();
        let quant4 = OnceLock::new();
        let fingerprint = OnceLock::new();
        let weights = match precision {
            Precision::F32 => Some(weights),
            Precision::Int8 => {
                // The fingerprint folds the f32 weight bits, so stamp it
                // eagerly while they still exist, then let them go.
                fingerprint
                    .set(Self::fingerprint_of(&manifest, &weights, precision))
                    .expect("fresh OnceLock");
                quant
                    .set(QuantWeights::from_weights(&manifest, &weights))
                    .expect("fresh OnceLock");
                None
            }
            Precision::Int4 => {
                fingerprint
                    .set(Self::fingerprint_of(&manifest, &weights, precision))
                    .expect("fresh OnceLock");
                quant4
                    .set(Quant4Weights::from_weights(&manifest, &weights))
                    .expect("fresh OnceLock");
                None
            }
        };
        Ok(Model { manifest, weights, quant, quant4, precision, fingerprint })
    }

    /// `new`, wrapped for sharing.
    pub fn shared(manifest: Manifest, weights: ModelWeights) -> Result<Arc<Self>> {
        Ok(Arc::new(Self::new(manifest, weights)?))
    }

    /// `with_precision`, wrapped for sharing.
    pub fn shared_with_precision(
        manifest: Manifest,
        weights: ModelWeights,
        precision: Precision,
    ) -> Result<Arc<Self>> {
        Ok(Arc::new(Self::with_precision(manifest, weights, precision)?))
    }

    /// Wrap a **pre-built** int4 weight set directly — no f32 weights
    /// are ever resident.  The tolerance harness's injection path: it
    /// corrupts group scales *after* quantization to prove its pins
    /// trip on exactly the failure class a broken quantizer produces.
    /// The fingerprint folds the quantized bytes themselves
    /// ([`Quant4Weights::content_hash`]), so two injected models differ
    /// whenever any packed nibble or group scale does.
    pub fn from_quant4(manifest: Manifest, q4: Quant4Weights) -> Result<Arc<Self>> {
        if q4.layers.len() != manifest.layers.len() {
            bail!(
                "int4 weights have {} layers, manifest {}",
                q4.layers.len(),
                manifest.layers.len()
            );
        }
        let fingerprint = OnceLock::new();
        {
            use crate::util::hash;
            let mut h = hash::FNV_OFFSET;
            hash::fold_bytes(&mut h, manifest.to_json().to_string().as_bytes());
            hash::fold(&mut h, q4.content_hash());
            hash::fold_bytes(&mut h, Precision::Int4.label().as_bytes());
            fingerprint.set(h).expect("fresh OnceLock");
        }
        let quant4 = OnceLock::new();
        quant4.set(q4).expect("fresh OnceLock");
        Ok(Arc::new(Model {
            manifest,
            weights: None,
            quant: OnceLock::new(),
            quant4,
            precision: Precision::Int4,
            fingerprint,
        }))
    }

    fn fingerprint_of(manifest: &Manifest, weights: &ModelWeights, precision: Precision) -> u64 {
        use crate::util::hash;
        // Two models share a fingerprint only when shape, every weight
        // bit AND the resident precision agree — int8 decoding of the
        // same checkpoint produces different activations, so its
        // session state must never restore into the f32 model.
        let mut h = hash::FNV_OFFSET;
        hash::fold_bytes(&mut h, manifest.to_json().to_string().as_bytes());
        hash::fold(&mut h, weights.content_hash());
        hash::fold_bytes(&mut h, precision.label().as_bytes());
        h
    }

    /// Stable content fingerprint of (manifest, weights, precision) —
    /// the prefix cache's model key, and the snapshot-compatibility
    /// check in [`NativeDecoder::restore`](crate::infer::Decoder::restore).
    ///
    /// Computed lazily on first use for f32 models (an FNV-1a pass over
    /// the manifest's canonical JSON and every weight bit is
    /// O(parameters) — paths that never snapshot never pay it), then
    /// cached for the model's lifetime.  Quantized models stamp it
    /// eagerly at load time, before the f32 weights are dropped.
    pub fn fingerprint(&self) -> u64 {
        *self.fingerprint.get_or_init(|| {
            let w =
                self.weights.as_ref().expect("quantized models stamp their fingerprint at load");
            Self::fingerprint_of(&self.manifest, w, self.precision)
        })
    }

    /// The precision the resident weights decode at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The f32 weight set, when resident (`None` for pure-int8 models).
    pub fn weights(&self) -> Option<&ModelWeights> {
        self.weights.as_ref()
    }

    /// The int8 weight set: resident for int8 models, built (once) from
    /// the f32 weights on first use otherwise — the `shallow-q`
    /// drafter's path, which drafts on int8 while verify stays f32.
    /// Panics for int4 models (no f32 weights to quantize from).
    pub fn quant(&self) -> &QuantWeights {
        self.quant.get_or_init(|| {
            let w = self
                .weights
                .as_ref()
                .expect("an int8 shadow needs resident f32 or pre-built int8 weights");
            QuantWeights::from_weights(&self.manifest, w)
        })
    }

    /// The int4 weight set: resident for int4 models, built (once) from
    /// the f32 weights on first use otherwise.  Panics for int8 models
    /// (no f32 weights to quantize from).
    pub fn quant4(&self) -> &Quant4Weights {
        self.quant4.get_or_init(|| {
            let w = self
                .weights
                .as_ref()
                .expect("an int4 shadow needs resident f32 or pre-built int4 weights");
            Quant4Weights::from_weights(&self.manifest, w)
        })
    }

    /// Bytes of weight data resident in memory at [`Self::precision`]
    /// (reported on `/healthz` and the serve startup line).
    pub fn resident_weight_bytes(&self) -> usize {
        match self.precision {
            Precision::F32 => self.weights.as_ref().map_or(0, ModelWeights::resident_bytes),
            Precision::Int8 => self.quant().resident_bytes(),
            Precision::Int4 => self.quant4().resident_bytes(),
        }
    }

    /// The weight view decoding at `p` dispatches through.
    fn weights_ref_at(&self, p: Precision) -> WeightsRef<'_> {
        match p {
            Precision::F32 => WeightsRef::F32(
                self.weights.as_ref().expect("f32 stepping needs resident f32 weights"),
            ),
            Precision::Int8 => WeightsRef::I8(self.quant()),
            Precision::Int4 => WeightsRef::I4(self.quant4()),
        }
    }

    /// Open a new decode session against this (shared) weight set.
    pub fn session(self: &Arc<Self>) -> NativeDecoder {
        NativeDecoder::new(Arc::clone(self))
    }

    /// Open a session primed with a [`SessionState`] snapshot (e.g. a
    /// prefix-cache hit): decoding continues from `state.position()`.
    pub fn session_from(self: &Arc<Self>, state: SessionState) -> Result<NativeDecoder> {
        NativeDecoder::with_state(Arc::clone(self), state)
    }
}

// ---------------------------------------------------------------------------
// Precision-dispatched weight views
// ---------------------------------------------------------------------------
//
// The forward pass is written once against these views: every weight
// matrix is a `MatRef` (f32 slice or int8 rows + scales) and every
// linear op goes through `lin`/`lin_t` (single row) or
// `lin_batch`/`lin_t_batch` (fused verify rows), which quantize the
// activation on the fly and dispatch to the int8 kernel tier when the
// weight side is int8.  Weight *vectors* (LN gains, biases, per-head
// mix scalars) are f32 in both representations, so everything outside
// the matmuls is untouched.

/// One weight matrix at any precision.  Orientation is the call
/// site's contract, as with the raw slices before: `lin` expects the
/// f32 form in-major (`[k, n]`, the [`matvec`] layout) and `lin_t`
/// out-major (`[n, k]`); the quantized forms are always out-major
/// (int4 rows packed two nibbles per byte, one scale per 32-group).
#[derive(Clone, Copy)]
enum MatRef<'a> {
    F32(&'a [f32]),
    I8 { q: &'a [i8], scale: &'a [f32] },
    I4 { q: &'a [u8], scale: &'a [f32] },
}

impl<'a> MatRef<'a> {
    fn i8(m: &'a QuantMatrix) -> Self {
        MatRef::I8 { q: &m.q, scale: &m.scale }
    }

    fn i4(m: &'a QuantMatrix4) -> Self {
        MatRef::I4 { q: &m.q, scale: &m.scale }
    }

    /// Sub-view of per-head block `hix` when heads are stacked along
    /// the weight tensor (`[H, k, n]` f32 in-major / `[H·n, k]`
    /// quantized rows): the gate2/fusion per-head matmuls.  Int4 rows
    /// are byte-aligned (`⌈k/2⌉` bytes, `⌈k/32⌉` scales per row), so
    /// the block boundaries stay clean for any k.
    fn head(self, hix: usize, k: usize, n: usize) -> MatRef<'a> {
        match self {
            MatRef::F32(w) => MatRef::F32(&w[hix * k * n..(hix + 1) * k * n]),
            MatRef::I8 { q, scale } => MatRef::I8 {
                q: &q[hix * n * k..(hix + 1) * n * k],
                scale: &scale[hix * n..(hix + 1) * n],
            },
            MatRef::I4 { q, scale } => {
                let kb = super::tensor::q4_row_bytes(k);
                let groups = super::tensor::q4_row_groups(k);
                MatRef::I4 {
                    q: &q[hix * n * kb..(hix + 1) * n * kb],
                    scale: &scale[hix * n * groups..(hix + 1) * n * groups],
                }
            }
        }
    }
}

/// One layer's weights at either precision (vectors always f32).
struct LayerRef<'a> {
    ln1_g: &'a [f32],
    ln1_b: &'a [f32],
    ln2_g: &'a [f32],
    ln2_b: &'a [f32],
    ffn_w1: MatRef<'a>,
    ffn_b1: &'a [f32],
    ffn_w2: MatRef<'a>,
    ffn_b2: &'a [f32],
    mix_a: &'a [f32],
    mix_b: &'a [f32],
    mix_mat_a: MatRef<'a>,
    mix_mat_b: MatRef<'a>,
    mix_bias: &'a [f32],
    gate_w1: MatRef<'a>,
    gate_b1: &'a [f32],
    gate_w2: MatRef<'a>,
    gate_b2: &'a [f32],
    gate_w: MatRef<'a>,
    gate_b: &'a [f32],
    fuse_w1: MatRef<'a>,
    fuse_b1: &'a [f32],
    fuse_w2: MatRef<'a>,
    fuse_b2: &'a [f32],
    wq: MatRef<'a>,
    bq: &'a [f32],
    wk: MatRef<'a>,
    bk: &'a [f32],
    wv: MatRef<'a>,
    bv: &'a [f32],
    wo: MatRef<'a>,
    bo: &'a [f32],
}

impl<'a> LayerRef<'a> {
    fn f32(lw: &'a LayerWeights) -> Self {
        let mw = &lw.mixer;
        LayerRef {
            ln1_g: &lw.ln1_g,
            ln1_b: &lw.ln1_b,
            ln2_g: &lw.ln2_g,
            ln2_b: &lw.ln2_b,
            ffn_w1: MatRef::F32(&lw.ffn_w1),
            ffn_b1: &lw.ffn_b1,
            ffn_w2: MatRef::F32(&lw.ffn_w2),
            ffn_b2: &lw.ffn_b2,
            mix_a: &mw.mix_a,
            mix_b: &mw.mix_b,
            mix_mat_a: MatRef::F32(&mw.mix_mat_a),
            mix_mat_b: MatRef::F32(&mw.mix_mat_b),
            mix_bias: &mw.mix_bias,
            gate_w1: MatRef::F32(&mw.gate_w1),
            gate_b1: &mw.gate_b1,
            gate_w2: MatRef::F32(&mw.gate_w2),
            gate_b2: &mw.gate_b2,
            gate_w: MatRef::F32(&mw.gate_w),
            gate_b: &mw.gate_b,
            fuse_w1: MatRef::F32(&mw.fuse_w1),
            fuse_b1: &mw.fuse_b1,
            fuse_w2: MatRef::F32(&mw.fuse_w2),
            fuse_b2: &mw.fuse_b2,
            wq: MatRef::F32(&mw.wq),
            bq: &mw.bq,
            wk: MatRef::F32(&mw.wk),
            bk: &mw.bk,
            wv: MatRef::F32(&mw.wv),
            bv: &mw.bv,
            wo: MatRef::F32(&mw.wo),
            bo: &mw.bo,
        }
    }

    fn i8(lw: &'a QuantLayerWeights) -> Self {
        let mw = &lw.mixer;
        LayerRef {
            ln1_g: &lw.ln1_g,
            ln1_b: &lw.ln1_b,
            ln2_g: &lw.ln2_g,
            ln2_b: &lw.ln2_b,
            ffn_w1: MatRef::i8(&lw.ffn_w1),
            ffn_b1: &lw.ffn_b1,
            ffn_w2: MatRef::i8(&lw.ffn_w2),
            ffn_b2: &lw.ffn_b2,
            mix_a: &mw.mix_a,
            mix_b: &mw.mix_b,
            mix_mat_a: MatRef::i8(&mw.mix_mat_a),
            mix_mat_b: MatRef::i8(&mw.mix_mat_b),
            mix_bias: &mw.mix_bias,
            gate_w1: MatRef::i8(&mw.gate_w1),
            gate_b1: &mw.gate_b1,
            gate_w2: MatRef::i8(&mw.gate_w2),
            gate_b2: &mw.gate_b2,
            gate_w: MatRef::i8(&mw.gate_w),
            gate_b: &mw.gate_b,
            fuse_w1: MatRef::i8(&mw.fuse_w1),
            fuse_b1: &mw.fuse_b1,
            fuse_w2: MatRef::i8(&mw.fuse_w2),
            fuse_b2: &mw.fuse_b2,
            wq: MatRef::i8(&mw.wq),
            bq: &mw.bq,
            wk: MatRef::i8(&mw.wk),
            bk: &mw.bk,
            wv: MatRef::i8(&mw.wv),
            bv: &mw.bv,
            wo: MatRef::i8(&mw.wo),
            bo: &mw.bo,
        }
    }

    fn i4(lw: &'a Quant4LayerWeights) -> Self {
        let mw = &lw.mixer;
        LayerRef {
            ln1_g: &lw.ln1_g,
            ln1_b: &lw.ln1_b,
            ln2_g: &lw.ln2_g,
            ln2_b: &lw.ln2_b,
            ffn_w1: MatRef::i4(&lw.ffn_w1),
            ffn_b1: &lw.ffn_b1,
            ffn_w2: MatRef::i4(&lw.ffn_w2),
            ffn_b2: &lw.ffn_b2,
            mix_a: &mw.mix_a,
            mix_b: &mw.mix_b,
            mix_mat_a: MatRef::i4(&mw.mix_mat_a),
            mix_mat_b: MatRef::i4(&mw.mix_mat_b),
            mix_bias: &mw.mix_bias,
            gate_w1: MatRef::i4(&mw.gate_w1),
            gate_b1: &mw.gate_b1,
            gate_w2: MatRef::i4(&mw.gate_w2),
            gate_b2: &mw.gate_b2,
            gate_w: MatRef::i4(&mw.gate_w),
            gate_b: &mw.gate_b,
            fuse_w1: MatRef::i4(&mw.fuse_w1),
            fuse_b1: &mw.fuse_b1,
            fuse_w2: MatRef::i4(&mw.fuse_w2),
            fuse_b2: &mw.fuse_b2,
            wq: MatRef::i4(&mw.wq),
            bq: &mw.bq,
            wk: MatRef::i4(&mw.wk),
            bk: &mw.bk,
            wv: MatRef::i4(&mw.wv),
            bv: &mw.bv,
            wo: MatRef::i4(&mw.wo),
            bo: &mw.bo,
        }
    }
}

/// The full weight set at the precision a step decodes at.
#[derive(Clone, Copy)]
enum WeightsRef<'a> {
    F32(&'a ModelWeights),
    I8(&'a QuantWeights),
    I4(&'a Quant4Weights),
}

impl<'a> WeightsRef<'a> {
    fn layer(&self, l: usize) -> LayerRef<'a> {
        match *self {
            WeightsRef::F32(w) => LayerRef::f32(&w.layers[l]),
            WeightsRef::I8(w) => LayerRef::i8(&w.layers[l]),
            WeightsRef::I4(w) => LayerRef::i4(&w.layers[l]),
        }
    }

    fn lnf(&self) -> (&'a [f32], &'a [f32]) {
        match *self {
            WeightsRef::F32(w) => (&w.lnf_g, &w.lnf_b),
            WeightsRef::I8(w) => (&w.lnf_g, &w.lnf_b),
            WeightsRef::I4(w) => (&w.lnf_g, &w.lnf_b),
        }
    }

    /// The `[V, D]` tied embedding as seen by the logit projection
    /// (out-major in every representation — pair with `lin_t`).
    fn tok_emb(&self) -> MatRef<'a> {
        match *self {
            WeightsRef::F32(w) => MatRef::F32(&w.tok_emb),
            WeightsRef::I8(w) => MatRef::i8(&w.tok_emb),
            WeightsRef::I4(w) => MatRef::i4(&w.tok_emb),
        }
    }

    /// `x = tok_emb[token] + pos_emb[pos]` (quantized rows dequantize
    /// on the fly — two rows per token, a rounding error next to the
    /// matmuls).
    fn embed(&self, token: usize, pos: usize, d: usize, x: &mut [f32]) {
        match *self {
            WeightsRef::F32(w) => {
                let te = &w.tok_emb[token * d..(token + 1) * d];
                let pe = &w.pos_emb[pos * d..(pos + 1) * d];
                for i in 0..d {
                    x[i] = te[i] + pe[i];
                }
            }
            WeightsRef::I8(w) => {
                w.tok_emb.dequant_row(token, x);
                w.pos_emb.dequant_row_add(pos, x);
            }
            WeightsRef::I4(w) => {
                w.tok_emb.dequant_row(token, x);
                w.pos_emb.dequant_row_add(pos, x);
            }
        }
    }
}

/// `y = W·x` in the [`matvec`] orientation; the quantized sides
/// quantize `x` into `qx` scratch first (activations are int8 at both
/// weight precisions).
fn lin(x: &[f32], w: MatRef, n: usize, qx: &mut [i8], y: &mut [f32]) {
    match w {
        MatRef::F32(w) => matvec(x, w, n, y),
        MatRef::I8 { q, scale } => {
            let qx = &mut qx[..x.len()];
            let sx = quantize_row(x, qx);
            matvec_q(qx, sx, q, scale, &mut y[..n]);
        }
        MatRef::I4 { q, scale } => {
            let qx = &mut qx[..x.len()];
            let sx = quantize_row(x, qx);
            matvec_q4(qx, sx, q, scale, &mut y[..n]);
        }
    }
}

/// [`lin`] with the activation **already quantized** — the hoisted
/// path, and the ring-image path (`prev` rows whose int8 image is
/// stored alongside).  Only ever called with quantized weights.
fn lin_q(qx: &[i8], sx: f32, w: MatRef, n: usize, y: &mut [f32]) {
    match w {
        MatRef::F32(_) => unreachable!("pre-quantized activations never pair with f32 weights"),
        MatRef::I8 { q, scale } => matvec_q(qx, sx, q, scale, &mut y[..n]),
        MatRef::I4 { q, scale } => matvec_q4(qx, sx, q, scale, &mut y[..n]),
    }
}

/// [`lin`] that reuses a hoisted activation quantization when one is
/// available: `hq` is the post-LN1 row `h` quantized **once** per layer
/// ([`DecodeSession`] slab), shared by every quantized matvec whose
/// input is `h`.  Bit-identical to quantizing per call —
/// [`quantize_row`] is deterministic, so the per-call path would
/// produce the same `(q, s)` bits — pinned by
/// `hoisted_activation_quantization_is_bit_identical_per_call` below.
fn lin_hoisted(
    x: &[f32],
    hq: Option<(&[i8], f32)>,
    w: MatRef,
    n: usize,
    qx: &mut [i8],
    y: &mut [f32],
) {
    match (hq, w) {
        (Some((q, s)), MatRef::I8 { .. } | MatRef::I4 { .. }) => lin_q(q, s, w, n, y),
        _ => lin(x, w, n, qx, y),
    }
}

/// `y = Wᵀ·x` in the [`matvec_t`] orientation (out-major `[n, k]`
/// weights — the logit projection over the tied embedding).
fn lin_t(x: &[f32], w: MatRef, n: usize, qx: &mut [i8], y: &mut [f32]) {
    match w {
        MatRef::F32(w) => matvec_t(x, w, n, y),
        MatRef::I8 { q, scale } => {
            let qx = &mut qx[..x.len()];
            let sx = quantize_row(x, qx);
            matvec_t_q(qx, sx, q, scale, &mut y[..n]);
        }
        MatRef::I4 { q, scale } => {
            let qx = &mut qx[..x.len()];
            let sx = quantize_row(x, qx);
            matvec_t_q4(qx, sx, q, scale, &mut y[..n]);
        }
    }
}

/// Batched [`lin`] over `m` rows (the fused verify pass): one weight
/// stream for the whole block at either precision.
fn lin_batch(
    xs: &[f32],
    m: usize,
    w: MatRef,
    n: usize,
    qxs: &mut [i8],
    sxs: &mut [f32],
    ys: &mut [f32],
) {
    match w {
        MatRef::F32(w) => matmul(xs, m, w, n, ys),
        MatRef::I8 { q, scale } => {
            let k = if m == 0 { 0 } else { xs.len() / m };
            for r in 0..m {
                sxs[r] = quantize_row(&xs[r * k..(r + 1) * k], &mut qxs[r * k..(r + 1) * k]);
            }
            matmul_q(&qxs[..m * k], m, &sxs[..m], q, scale, &mut ys[..m * n]);
        }
        MatRef::I4 { q, scale } => {
            let k = if m == 0 { 0 } else { xs.len() / m };
            for r in 0..m {
                sxs[r] = quantize_row(&xs[r * k..(r + 1) * k], &mut qxs[r * k..(r + 1) * k]);
            }
            matmul_q4(&qxs[..m * k], m, &sxs[..m], q, scale, &mut ys[..m * n]);
        }
    }
}

/// Batched [`lin_t`] over `m` rows (the fused logit projection).
fn lin_t_batch(
    xs: &[f32],
    m: usize,
    w: MatRef,
    n: usize,
    qxs: &mut [i8],
    sxs: &mut [f32],
    ys: &mut [f32],
) {
    match w {
        MatRef::F32(w) => matmul_t(xs, m, w, n, ys),
        MatRef::I8 { q, scale } => {
            let k = if m == 0 { 0 } else { xs.len() / m };
            for r in 0..m {
                sxs[r] = quantize_row(&xs[r * k..(r + 1) * k], &mut qxs[r * k..(r + 1) * k]);
            }
            matmul_t_q(&qxs[..m * k], m, &sxs[..m], q, scale, &mut ys[..m * n]);
        }
        MatRef::I4 { q, scale } => {
            let k = if m == 0 { 0 } else { xs.len() / m };
            for r in 0..m {
                sxs[r] = quantize_row(&xs[r * k..(r + 1) * k], &mut qxs[r * k..(r + 1) * k]);
            }
            matmul_t_q4(&qxs[..m * k], m, &sxs[..m], q, scale, &mut ys[..m * n]);
        }
    }
}

/// Mixer scratch: every temporary any mixer variant needs, hoisted out
/// of the step path.  Field roles rotate by mixer kind (documented at
/// the use sites); `zeros` is the before-history activation and is never
/// written.
struct MixScratch {
    zeros: Vec<f32>,
    /// mat: B·prev | gate1: hidden | attn: q
    tmp: Vec<f32>,
    /// gate1: gate | attn: k row
    gate: Vec<f32>,
    /// attn: v row
    aux: Vec<f32>,
    /// attn: per-head weighted-value accumulator
    acc: Vec<f32>,
    /// gate2/fusion: per-head `[h; prev]` concat (first `2·hd` used)
    cat: Vec<f32>,
    /// gate2: per-head gate | fusion: per-head hidden (first `hd` used)
    mid: Vec<f32>,
    /// fusion: per-head output (first `hd` used)
    head_out: Vec<f32>,
    /// attn: one score per cached position (grows with the KV cache)
    scores: Vec<f32>,
    /// int8 stepping: the quantized activation row, sized for the
    /// widest linear input (`2·d` covers the gate2/fusion concat at
    /// heads = 1; `max_ffn` covers the FFN down-projection).
    qx: Vec<i8>,
}

impl MixScratch {
    fn new(d: usize, max_ffn: usize) -> Self {
        MixScratch {
            zeros: vec![0.0; d],
            tmp: vec![0.0; d],
            gate: vec![0.0; d],
            aux: vec![0.0; d],
            acc: vec![0.0; d],
            cat: vec![0.0; 2 * d],
            mid: vec![0.0; d],
            head_out: vec![0.0; d],
            scores: Vec::new(),
            qx: vec![0; (2 * d).max(max_ffn)],
        }
    }
}

/// Reusable arena for [`DecodeSession::step_batch`]: every `[m, ·]`
/// row-major buffer the fused multi-token pass needs, plus the rewind
/// book-keeping ([`DecodeSession::rewind_batch`]).  Allocated lazily on
/// the first batched call and **reused across verify rounds** — buffers
/// only ever grow (`resize` keeps capacity), and the saved ring images
/// are refreshed in place via [`Ring::copy_from`], so steady-state
/// speculative decoding allocates nothing per round.
#[derive(Default)]
struct BatchScratch {
    /// Rows scored by the pending batch (0 = none / already rewound).
    rows: usize,
    /// Session position before the pending batch.
    pre_pos: usize,
    /// `[m, d]` residual stream rows.
    xs: Vec<f32>,
    /// `[m, d]` per-layer post-LN1 rows (mixer input), then the final
    /// post-LN rows feeding the logit projection.
    hs: Vec<f32>,
    /// `[m, d]` mixer outputs.
    ys: Vec<f32>,
    /// `[m, max_ffn]` FFN hidden rows.
    f1s: Vec<f32>,
    /// `[m, d]` FFN LN/output rows.
    f2s: Vec<f32>,
    /// `[m, vocab]` logits, row per scored token.
    logits: Vec<f32>,
    /// Per layer: the HSM ring image from before the batch (`None` for
    /// attention layers, whose append-only KV caches rewind by
    /// truncation).
    saved: Vec<Option<Ring>>,
    /// Per layer: the batch's post-LN1 rows, replayed into the restored
    /// ring by [`DecodeSession::rewind_batch`].
    h_hist: Vec<Vec<f32>>,
    /// int8 stepping: `[m, ·]` quantized activation rows for the fused
    /// projections (sized for the widest linear input).
    qxs: Vec<i8>,
    /// int8 stepping: one activation scale per row.
    sxs: Vec<f32>,
}

impl BatchScratch {
    fn prepare(
        &mut self,
        rows: usize,
        pre_pos: usize,
        depth: usize,
        d: usize,
        max_ffn: usize,
        vocab: usize,
    ) {
        self.rows = rows;
        self.pre_pos = pre_pos;
        self.xs.resize(rows * d, 0.0);
        self.hs.resize(rows * d, 0.0);
        self.ys.resize(rows * d, 0.0);
        self.f1s.resize(rows * max_ffn, 0.0);
        self.f2s.resize(rows * d, 0.0);
        self.logits.resize(rows * vocab, 0.0);
        if self.saved.len() != depth {
            self.saved = vec![None; depth];
        }
        if self.h_hist.len() != depth {
            self.h_hist = vec![Vec::new(); depth];
        }
        for hh in &mut self.h_hist {
            hh.resize(rows * d, 0.0);
        }
        self.qxs.resize(rows * d.max(max_ffn), 0);
        self.sxs.resize(rows, 0.0);
    }
}

/// The mutable, per-sequence half of a decoder: a [`SessionState`]
/// (layer state + position cursor) plus scratch.  Cheap relative to
/// weights — allocate one per concurrent user and share the [`Model`].
pub struct DecodeSession {
    state: SessionState,
    // scratch buffers (no allocation on the step path)
    x: Vec<f32>,
    h: Vec<f32>,
    y: Vec<f32>,
    f1: Vec<f32>,
    f2: Vec<f32>,
    logits: Vec<f32>,
    mix: MixScratch,
    /// Hoisted activation quantization: the post-LN1 row `h` quantized
    /// once per layer, fed to every quantized matvec that consumes `h`
    /// (attention q/k/v, the `mat`/`gate1` first projections) and to
    /// the ring push — instead of re-running [`quantize_row`] per call.
    qh: Vec<i8>,
    /// Hoist toggle (default on).  Off forces per-call re-quantization
    /// — bit-identical by construction, kept for the A/B bench and the
    /// parity tests that pin it.
    hoist: bool,
    /// Fused-batch arena; `None` until the first [`Self::step_batch`].
    batch: Option<Box<BatchScratch>>,
    /// Per-stage timing handle (telemetry); `None` — the default — adds
    /// a single branch per step, and even when attached only every
    /// `sample_every`th step reads the clock.
    obs: Option<Box<StageObs>>,
}

impl DecodeSession {
    /// A session starting fresh, or — when `start` is given — continuing
    /// from a [`SessionState`] snapshot (validated against `m`).
    pub fn new(m: &Manifest, start: Option<SessionState>) -> Result<Self> {
        let state = match start {
            Some(s) => {
                s.validate(m)?;
                s
            }
            None => SessionState::fresh(m),
        };
        let d = m.dim;
        let max_ffn = m.layers.iter().map(|l| l.ffn).max().unwrap_or(d);
        Ok(DecodeSession {
            state,
            x: vec![0.0; d],
            h: vec![0.0; d],
            y: vec![0.0; d],
            f1: vec![0.0; max_ffn],
            f2: vec![0.0; d],
            logits: vec![0.0; m.vocab],
            mix: MixScratch::new(d, max_ffn),
            qh: vec![0; d],
            hoist: true,
            batch: None,
            obs: None,
        })
    }

    /// Toggle the hoisted activation quantization (on by default).
    /// Both settings produce bit-identical logits — per-call
    /// quantization just redoes identical [`quantize_row`] work — so
    /// this exists for the hoisted-vs-per-call A/B bench, not as a
    /// numerics knob.
    pub fn set_quant_hoist(&mut self, on: bool) {
        self.hoist = on;
    }

    /// Install (or remove) the per-stage timing handle.  Schedulers
    /// attach one at admission when stage sampling is enabled; a plain
    /// session never pays more than the `None` branch.
    pub fn set_stage_obs(&mut self, obs: Option<Box<StageObs>>) {
        self.obs = obs;
    }

    pub fn position(&self) -> usize {
        self.state.pos
    }

    /// Clone the sequence state out of this session.  The session keeps
    /// decoding; the snapshot is fully independent.
    pub fn snapshot(&self) -> SessionState {
        self.state.clone()
    }

    /// Replace this session's sequence state with a snapshot (validated
    /// against `m`).  Scratch buffers are untouched, so restoring costs
    /// only the state copy itself.
    pub fn restore(&mut self, m: &Manifest, state: &SessionState) -> Result<()> {
        state.validate(m)?;
        self.state.clone_from(state);
        if let Some(bs) = &mut self.batch {
            bs.rows = 0; // any pending batch no longer matches the state
        }
        Ok(())
    }

    /// A new session continuing from this one's exact current state;
    /// decoding either session never affects the other.
    pub fn fork(&self, m: &Manifest) -> Result<Self> {
        Self::new(m, Some(self.state.clone()))
    }

    /// Clear all decoding state (start a new sequence).
    pub fn reset(&mut self) {
        for st in &mut self.state.layers {
            st.clear();
        }
        self.state.pos = 0;
        if let Some(bs) = &mut self.batch {
            bs.rows = 0;
        }
    }

    /// Consume one token, return next-token logits (borrow valid until
    /// the next call with this session).
    pub fn step(&mut self, model: &Model, token: u32) -> Result<&[f32]> {
        let depth = model.manifest.layers.len();
        self.step_inner(model, token, true, depth, model.precision())?;
        Ok(&self.logits)
    }

    /// One forward step through only the first `layers` layers (0 or
    /// anything past the stack depth runs the full stack), followed by
    /// the final LN + logit projection — the self-drafting path of
    /// speculative decoding
    /// ([`crate::infer::speculate::ShallowDrafter`]).  Deeper layers'
    /// state is left untouched, so a shallow-stepped session is no
    /// longer a valid full-model session; resync with
    /// [`restore`](Self::restore) before full-model use.
    pub fn step_shallow(&mut self, model: &Model, token: u32, layers: usize) -> Result<&[f32]> {
        self.step_shallow_at(model, token, layers, model.precision())
    }

    /// [`step_shallow`](Self::step_shallow) at an explicit precision —
    /// the `shallow-q` drafter path, which drafts through the model's
    /// int8 shadow weights ([`Model::quant`]) while the verify side
    /// keeps decoding f32.  Draft tokens only ever *propose*; the f32
    /// verify pass decides, so served bytes are untouched.
    pub fn step_shallow_at(
        &mut self,
        model: &Model,
        token: u32,
        layers: usize,
        precision: Precision,
    ) -> Result<&[f32]> {
        let depth = model.manifest.layers.len();
        let n = if layers == 0 { depth } else { layers.min(depth) };
        self.step_inner(model, token, true, n, precision)?;
        Ok(&self.logits)
    }

    /// One forward step over the first `layers` layers; the final LN +
    /// `[D, V]` logit projection (the most expensive single op at small
    /// D) is skipped during prefill.
    fn step_inner(
        &mut self,
        model: &Model,
        token: u32,
        want_logits: bool,
        layers: usize,
        precision: Precision,
    ) -> Result<()> {
        let m = &model.manifest;
        let w = model.weights_ref_at(precision);
        let d = m.dim;
        let vocab = m.vocab;
        if (token as usize) >= vocab {
            bail!("token {token} out of vocab {vocab}");
        }
        if self.state.pos >= m.ctx {
            bail!("context window ({}) exhausted — call reset()", m.ctx);
        }

        // Stage timing: the sampling countdown decides once per step;
        // unsampled steps (and sessions without a handle) never read
        // the clock.  Prefill steps skip logits, so the phase split
        // keys off `want_logits`.
        let timed = self.obs.as_mut().is_some_and(|o| o.tick());
        let phase = if want_logits { Phase::Step } else { Phase::Prefill };

        // Embedding + learned position.
        w.embed(token as usize, self.state.pos, d, &mut self.x);

        for (l, spec) in m.layers.iter().enumerate().take(layers) {
            let lw = w.layer(l);

            let mut t0 = timed.then(Instant::now);
            // h = LN1(x); y = mixer(h, state); x += y.  Quantized
            // stepping quantizes h once, here — the mixer and the ring
            // push reuse the same (q, s).
            layer_norm(&self.x, lw.ln1_g, lw.ln1_b, &mut self.h);
            let hq = if precision.is_quantized() {
                let sh = quantize_row(&self.h, &mut self.qh[..d]);
                Some((&self.qh[..d], sh))
            } else {
                None
            };
            mixer_step(
                spec,
                &lw,
                &self.h,
                hq,
                self.hoist,
                &mut self.state.layers[l],
                &mut self.y,
                d,
                &mut self.mix,
            );
            add_assign(&mut self.x, &self.y);
            if let (Some(t), Some(o)) = (t0, &self.obs) {
                let now = Instant::now();
                o.cells(phase).mixer[l].record(now.duration_since(t).as_nanos() as u64);
                t0 = Some(now);
            }

            // FFN
            layer_norm(&self.x, lw.ln2_g, lw.ln2_b, &mut self.f2);
            let f = spec.ffn;
            let f1 = &mut self.f1[..f];
            lin(&self.f2, lw.ffn_w1, f, &mut self.mix.qx, f1);
            add_assign(f1, lw.ffn_b1);
            relu_inplace(f1);
            lin(f1, lw.ffn_w2, d, &mut self.mix.qx, &mut self.f2);
            add_assign(&mut self.f2, lw.ffn_b2);
            add_assign(&mut self.x, &self.f2);
            if let (Some(t), Some(o)) = (t0, &self.obs) {
                o.cells(phase).ffn[l].record(t.elapsed().as_nanos() as u64);
            }
        }

        if want_logits {
            let t0 = timed.then(Instant::now);
            // Final LN + tied-embedding projection.
            let (lnf_g, lnf_b) = w.lnf();
            layer_norm(&self.x, lnf_g, lnf_b, &mut self.h);
            lin_t(&self.h, w.tok_emb(), vocab, &mut self.mix.qx, &mut self.logits);
            if let (Some(t), Some(o)) = (t0, &self.obs) {
                o.cells(phase).logits.record(t.elapsed().as_nanos() as u64);
            }
        }
        self.state.pos += 1;
        Ok(())
    }

    /// Score a block of tokens in **one fused pass per layer** instead
    /// of `tokens.len()` sequential [`step`](Self::step)s — the
    /// speculative verify pass, where the block is draft length + 1.
    ///
    /// Per layer, LN and the mixer run row by row (each row's ring/KV
    /// push lands before the next row reads, so every row sees exactly
    /// the history a sequential step would), while the two FFN
    /// projections run as batched [`matmul`]s and the final logit
    /// projection as one batched [`matmul_t`] — each weight matrix
    /// streams through cache **once** for all rows instead of once per
    /// row.  Every logit row is bit-identical to the sequential loop's.
    ///
    /// Returns the logits row-major as `[tokens.len() * vocab]` (chunk
    /// by `vocab`; borrow valid until the next call).  Afterwards the
    /// session state is as if every token was stepped; use
    /// [`rewind_batch`](Self::rewind_batch) to keep only an accepted
    /// prefix.  Scratch lives in a lazily-allocated arena
    /// ([`BatchScratch`]) reused across rounds, so steady-state verify
    /// rounds allocate nothing.
    pub fn step_batch(&mut self, model: &Model, tokens: &[u32]) -> Result<&[f32]> {
        let m = &model.manifest;
        let w = model.weights_ref_at(model.precision());
        let d = m.dim;
        let vocab = m.vocab;
        let rows = tokens.len();
        if rows == 0 {
            bail!("step_batch needs at least one token");
        }
        for &t in tokens {
            if (t as usize) >= vocab {
                bail!("token {t} out of vocab {vocab}");
            }
        }
        if self.state.pos + rows > m.ctx {
            bail!("context window ({}) exhausted — call reset()", m.ctx);
        }
        let depth = m.layers.len();
        let max_ffn = m.layers.iter().map(|l| l.ffn).max().unwrap_or(d);
        let quantized = model.precision().is_quantized();
        let pre_pos = self.state.pos;
        // One sampling decision per fused pass (it scores `rows`
        // positions, so sampling is per-pass, like one verify round).
        let timed = self.obs.as_mut().is_some_and(|o| o.tick());
        let bs = self.batch.get_or_insert_with(Box::default);
        bs.prepare(rows, pre_pos, depth, d, max_ffn, vocab);

        // Embedding + learned position, one row per token.
        for (r, &t) in tokens.iter().enumerate() {
            w.embed(t as usize, pre_pos + r, d, &mut bs.xs[r * d..(r + 1) * d]);
        }

        for (l, spec) in m.layers.iter().enumerate() {
            let lw = w.layer(l);

            // Save the pre-batch ring image for rewind (attention
            // layers rewind by KV truncation — nothing to save).
            match &self.state.layers[l] {
                LayerState::Hsm(ring) => match &mut bs.saved[l] {
                    Some(s) => s.copy_from(ring),
                    slot => *slot = Some(ring.clone()),
                },
                LayerState::Attn { .. } => bs.saved[l] = None,
            }

            let mut t0 = timed.then(Instant::now);
            // h = LN1(x); y = mixer(h, state); x += y.
            for r in 0..rows {
                layer_norm(
                    &bs.xs[r * d..(r + 1) * d],
                    lw.ln1_g,
                    lw.ln1_b,
                    &mut bs.hs[r * d..(r + 1) * d],
                );
            }
            for r in 0..rows {
                let h = &bs.hs[r * d..(r + 1) * d];
                // Same hoist as the sequential step: one quantize_row
                // per row per layer, shared by the mixer and its ring
                // push — so batched rows stay bit-identical to
                // sequential steps.
                let hq = if quantized {
                    let sh = quantize_row(h, &mut self.qh[..d]);
                    Some((&self.qh[..d], sh))
                } else {
                    None
                };
                mixer_step(
                    spec,
                    &lw,
                    h,
                    hq,
                    self.hoist,
                    &mut self.state.layers[l],
                    &mut bs.ys[r * d..(r + 1) * d],
                    d,
                    &mut self.mix,
                );
            }
            bs.h_hist[l].copy_from_slice(&bs.hs[..rows * d]);
            for r in 0..rows {
                add_assign(&mut bs.xs[r * d..(r + 1) * d], &bs.ys[r * d..(r + 1) * d]);
            }
            if let (Some(t), Some(o)) = (t0, &self.obs) {
                let now = Instant::now();
                o.cells(Phase::VerifyFused).mixer[l]
                    .record(now.duration_since(t).as_nanos() as u64);
                t0 = Some(now);
            }

            // FFN: LN row-wise, both projections fused across rows.
            let f = spec.ffn;
            for r in 0..rows {
                layer_norm(
                    &bs.xs[r * d..(r + 1) * d],
                    lw.ln2_g,
                    lw.ln2_b,
                    &mut bs.f2s[r * d..(r + 1) * d],
                );
            }
            lin_batch(
                &bs.f2s[..rows * d],
                rows,
                lw.ffn_w1,
                f,
                &mut bs.qxs,
                &mut bs.sxs,
                &mut bs.f1s[..rows * f],
            );
            for r in 0..rows {
                let f1 = &mut bs.f1s[r * f..(r + 1) * f];
                add_assign(f1, lw.ffn_b1);
                relu_inplace(f1);
            }
            lin_batch(
                &bs.f1s[..rows * f],
                rows,
                lw.ffn_w2,
                d,
                &mut bs.qxs,
                &mut bs.sxs,
                &mut bs.f2s[..rows * d],
            );
            for r in 0..rows {
                add_assign(&mut bs.f2s[r * d..(r + 1) * d], lw.ffn_b2);
            }
            for r in 0..rows {
                add_assign(&mut bs.xs[r * d..(r + 1) * d], &bs.f2s[r * d..(r + 1) * d]);
            }
            if let (Some(t), Some(o)) = (t0, &self.obs) {
                o.cells(Phase::VerifyFused).ffn[l].record(t.elapsed().as_nanos() as u64);
            }
        }

        // Final LN + tied-embedding projection, fused across rows.
        let t0 = timed.then(Instant::now);
        let (lnf_g, lnf_b) = w.lnf();
        for r in 0..rows {
            layer_norm(&bs.xs[r * d..(r + 1) * d], lnf_g, lnf_b, &mut bs.hs[r * d..(r + 1) * d]);
        }
        lin_t_batch(
            &bs.hs[..rows * d],
            rows,
            w.tok_emb(),
            vocab,
            &mut bs.qxs,
            &mut bs.sxs,
            &mut bs.logits[..rows * vocab],
        );
        if let (Some(t), Some(o)) = (t0, &self.obs) {
            o.cells(Phase::VerifyFused).logits.record(t.elapsed().as_nanos() as u64);
        }
        self.state.pos += rows;
        Ok(&bs.logits[..rows * vocab])
    }

    /// Roll the session back to `pre_batch_position + keep` after a
    /// [`step_batch`](Self::step_batch): each HSM ring is restored to
    /// its saved pre-batch image and the first `keep` rows' pushes are
    /// **replayed** (byte-identical to having only ever stepped those
    /// rows, because a ring's content is a pure function of its push
    /// sequence); attention KV caches, being append-only, rewind by
    /// truncation.  Errors if no batch is pending or the session moved
    /// since the batch.
    pub fn rewind_batch(&mut self, model: &Model, keep: usize) -> Result<()> {
        let d = model.manifest.dim;
        let bs = match &mut self.batch {
            Some(bs) if bs.rows > 0 => bs,
            _ => bail!("rewind_batch without a pending step_batch"),
        };
        if keep > bs.rows {
            bail!("cannot keep {keep} of {} batched rows", bs.rows);
        }
        if self.state.pos != bs.pre_pos + bs.rows {
            bail!(
                "session moved since step_batch (position {}, batch ended at {})",
                self.state.pos,
                bs.pre_pos + bs.rows
            );
        }
        let quantized = model.precision().is_quantized();
        for (l, st) in self.state.layers.iter_mut().enumerate() {
            match st {
                LayerState::Hsm(ring) => {
                    let saved = bs.saved[l].as_ref().expect("HSM layer saved its ring");
                    ring.copy_from(saved);
                    for r in 0..keep {
                        let row = &bs.h_hist[l][r * d..(r + 1) * d];
                        if quantized {
                            // Replay the quantized push: quantize_row is
                            // deterministic, so this reproduces the exact
                            // (q, s) — and the exact dequantized f32 row —
                            // the batch pushed.
                            let sh = quantize_row(row, &mut self.qh[..d]);
                            ring.push_q(&self.qh[..d], sh);
                        } else {
                            ring.push(row);
                        }
                    }
                }
                LayerState::Attn { k, v } => {
                    k.truncate((bs.pre_pos + keep) * d);
                    v.truncate((bs.pre_pos + keep) * d);
                }
            }
        }
        self.state.pos = bs.pre_pos + keep;
        bs.rows = 0;
        Ok(())
    }
}

/// The native incremental decoder: shared [`Model`] + own [`DecodeSession`].
pub struct NativeDecoder {
    model: Arc<Model>,
    session: DecodeSession,
}

impl NativeDecoder {
    /// Open a session against a shared model.
    pub fn new(model: Arc<Model>) -> Self {
        let session = DecodeSession::new(&model.manifest, None)
            .expect("fresh session state is always valid for its own manifest");
        NativeDecoder { model, session }
    }

    /// Snapshots stamped by a different model's weights must never
    /// decode here — structural validation alone cannot tell two
    /// same-shaped models apart.
    fn check_state_origin(model: &Model, state: &SessionState) -> Result<()> {
        if state.fingerprint != 0 && state.fingerprint != model.fingerprint() {
            bail!(
                "session state was captured under a different model \
                 (fingerprint {:#018x}, this model {:#018x})",
                state.fingerprint,
                model.fingerprint()
            );
        }
        Ok(())
    }

    /// Open a session primed with a [`SessionState`] snapshot.
    pub fn with_state(model: Arc<Model>, state: SessionState) -> Result<Self> {
        Self::check_state_origin(&model, &state)?;
        let session = DecodeSession::new(&model.manifest, Some(state))?;
        Ok(NativeDecoder { model, session })
    }

    /// Convenience: validate and wrap an owned (manifest, weights) pair.
    pub fn from_parts(manifest: Manifest, weights: ModelWeights) -> Result<Self> {
        Ok(Self::new(Model::shared(manifest, weights)?))
    }

    /// The shared model (clone the `Arc` to open more sessions).
    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }

    /// Toggle hoisted activation quantization on the underlying session
    /// (on by default; see [`DecodeSession::set_quant_hoist`]).  Output
    /// bytes are identical either way — this exists so benches can A/B
    /// the hoist and tests can pin that parity.
    pub fn set_quant_hoist(&mut self, on: bool) {
        self.session.set_quant_hoist(on);
    }

    /// Fork: a new decoder over the same shared weights, continuing
    /// from this one's exact sequence state.  Byte-identical decoding
    /// on both sides, zero interference.
    pub fn fork(&self) -> Self {
        let session = self.session.fork(&self.model.manifest).expect("own state is always valid");
        NativeDecoder { model: Arc::clone(&self.model), session }
    }
}

impl Decoder for NativeDecoder {
    fn manifest(&self) -> &Manifest {
        &self.model.manifest
    }

    fn prefill(&mut self, tokens: &[u32]) -> Result<()> {
        let depth = self.model.manifest.layers.len();
        let precision = self.model.precision();
        for &t in tokens {
            self.session.step_inner(&self.model, t, false, depth, precision)?;
        }
        Ok(())
    }

    fn step(&mut self, token: u32) -> Result<&[f32]> {
        self.session.step(&self.model, token)
    }

    fn supports_step_batch(&self) -> bool {
        true
    }

    fn step_batch(&mut self, tokens: &[u32]) -> Result<&[f32]> {
        self.session.step_batch(&self.model, tokens)
    }

    fn rewind_batch(&mut self, keep: usize) -> Result<()> {
        self.session.rewind_batch(&self.model, keep)
    }

    fn reset(&mut self) {
        self.session.reset();
    }

    fn position(&self) -> usize {
        self.session.position()
    }

    fn snapshot(&self) -> Option<SessionState> {
        let mut state = self.session.snapshot();
        state.fingerprint = self.model.fingerprint();
        Some(state)
    }

    fn supports_snapshot(&self) -> bool {
        true
    }

    fn restore(&mut self, state: &SessionState) -> Result<()> {
        Self::check_state_origin(&self.model, state)?;
        self.session.restore(&self.model.manifest, state)
    }

    fn fingerprint(&self) -> u64 {
        self.model.fingerprint()
    }

    fn precision(&self) -> Precision {
        self.model.precision()
    }

    /// Resolve stage cells for this model's layer stack and install
    /// them on the session; every subsequent step/prefill/fused-verify
    /// pass samples its mixer/FFN/logits split into `registry`.
    fn attach_stage_obs(&mut self, registry: &Arc<MetricsRegistry>, sample_every: usize) {
        if sample_every == 0 {
            self.session.set_stage_obs(None);
            return;
        }
        self.session.set_stage_obs(Some(StageObs::attach(
            registry,
            &self.model.manifest,
            self.model.precision().label(),
            sample_every,
        )));
    }

    /// The native engine supports every drafter: the model-free n-gram
    /// lookup, shallow self-drafting over the same shared weights, and
    /// its int8-quantized variant (`shallow-q`), which drafts through
    /// [`Model::quant`] while the verify pass stays at the model's own
    /// precision — served bytes are untouched.
    fn drafter(&self, kind: &DrafterKind) -> Option<Box<dyn Drafter>> {
        match *kind {
            DrafterKind::NGram { max_ngram } => Some(Box::new(NGramDrafter::new(max_ngram))),
            DrafterKind::Shallow { layers } => {
                Some(Box::new(ShallowDrafter::new(Arc::clone(&self.model), layers)))
            }
            DrafterKind::ShallowQuant { layers } => {
                Some(Box::new(ShallowDrafter::quantized(Arc::clone(&self.model), layers)))
            }
        }
    }
}

/// One mixer application at the current position.  Weights arrive as a
/// [`LayerRef`], so every matmul dispatches to the f32, int8 or int4
/// kernel tier through [`lin`] — one body serves every precision.
///
/// `hq` is the hoisted int8 quantization of `h` (always present for
/// quantized stepping): consumers of `h` reuse it when `hoist` is on,
/// and the ring push always records it, making the stored image — and
/// therefore a compacted snapshot — canonical.  The previous-row reads
/// likewise reuse the ring's stored image ([`Ring::back_q`]) instead of
/// re-quantizing the dequantized row, which both saves the work and
/// keeps hoist-on/off bit-identical (`quantize_row` over a dequantized
/// row is *not* guaranteed to reproduce the stored scale).
#[allow(clippy::too_many_arguments)]
fn mixer_step(
    spec: &LayerInfo,
    lw: &LayerRef,
    h: &[f32],
    hq: Option<(&[i8], f32)>,
    hoist: bool,
    state: &mut LayerState,
    y: &mut [f32],
    d: usize,
    mix: &mut MixScratch,
) {
    let hq_lin = if hoist { hq } else { None };
    let heads = spec.heads;
    let hd = d / heads;
    let MixScratch { zeros, tmp, gate, aux, acc, cat, mid, head_out, scores, qx } = mix;
    match state {
        LayerState::Hsm(ring) => {
            let zeros = &zeros[..];
            match spec.kind.as_str() {
                "ab" => {
                    for hix in 0..heads {
                        let s = spec.shifts[hix.min(spec.shifts.len() - 1)];
                        // back(s) is the activation at position p − s (the
                        // push below happens AFTER all reads).
                        let prev = ring.back(s).unwrap_or(zeros);
                        let (a, b) = (lw.mix_a[hix], lw.mix_b[hix]);
                        for c in hix * hd..(hix + 1) * hd {
                            y[c] = a * h[c] + b * prev[c];
                        }
                    }
                }
                "vec" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(zeros);
                    for c in 0..d {
                        y[c] = lw.mix_a[c] * h[c] + lw.mix_b[c] * prev[c];
                    }
                }
                "mat" => {
                    let s = spec.shifts[0];
                    lin_hoisted(h, hq_lin, lw.mix_mat_a, d, qx, y);
                    // Reuse the ring's stored int8 image for the shifted
                    // row when present: re-quantizing the dequantized row
                    // is redundant work and not guaranteed bit-stable.
                    match ring.back_q(s) {
                        Some((pq, ps)) => lin_q(pq, ps, lw.mix_mat_b, d, tmp),
                        None => lin(ring.back(s).unwrap_or(zeros), lw.mix_mat_b, d, qx, tmp),
                    }
                    add_assign(y, tmp);
                    add_assign(y, lw.mix_bias);
                }
                "gate1" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(zeros);
                    lin_hoisted(h, hq_lin, lw.gate_w1, d, qx, tmp);
                    add_assign(tmp, lw.gate_b1);
                    relu_inplace(tmp);
                    lin(tmp, lw.gate_w2, d, qx, gate);
                    add_assign(gate, lw.gate_b2);
                    tanh_inplace(gate);
                    for c in 0..d {
                        y[c] = gate[c] * h[c] + (1.0 - gate[c]) * prev[c];
                    }
                }
                "gate2" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(zeros);
                    let cat = &mut cat[..2 * hd];
                    let g = &mut mid[..hd];
                    for hix in 0..heads {
                        cat[..hd].copy_from_slice(&h[hix * hd..(hix + 1) * hd]);
                        cat[hd..].copy_from_slice(&prev[hix * hd..(hix + 1) * hd]);
                        lin(cat, lw.gate_w.head(hix, 2 * hd, hd), hd, qx, g);
                        add_assign(g, &lw.gate_b[hix * hd..(hix + 1) * hd]);
                        tanh_inplace(g);
                        for c in 0..hd {
                            let gc = hix * hd + c;
                            y[gc] = g[c] * h[gc] + (1.0 - g[c]) * prev[gc];
                        }
                    }
                }
                "fusion" => {
                    let s = spec.shifts[0];
                    let prev = ring.back(s).unwrap_or(zeros);
                    let cat = &mut cat[..2 * hd];
                    let m1 = &mut mid[..hd];
                    let out = &mut head_out[..hd];
                    for hix in 0..heads {
                        cat[..hd].copy_from_slice(&h[hix * hd..(hix + 1) * hd]);
                        cat[hd..].copy_from_slice(&prev[hix * hd..(hix + 1) * hd]);
                        lin(cat, lw.fuse_w1.head(hix, 2 * hd, hd), hd, qx, m1);
                        add_assign(m1, &lw.fuse_b1[hix * hd..(hix + 1) * hd]);
                        relu_inplace(m1);
                        lin(m1, lw.fuse_w2.head(hix, hd, hd), hd, qx, out);
                        add_assign(out, &lw.fuse_b2[hix * hd..(hix + 1) * hd]);
                        y[hix * hd..(hix + 1) * hd].copy_from_slice(out);
                    }
                }
                other => panic!("unknown HSM mixer kind {other}"),
            }
            // NOTE ordering: reads used ages relative to the ring BEFORE this
            // push, so back(s) was the activation at position p − s. Push now.
            // Under a quantized precision the push also records the int8
            // image, so later back_q reads and compacted cache snapshots
            // see exactly the bytes this step computed with.
            match hq {
                Some((q, s)) => ring.push_q(q, s),
                None => ring.push(h),
            }
        }
        LayerState::Attn { k, v } => {
            // Project q (tmp), k-row (gate), v-row (aux) for this position.
            lin_hoisted(h, hq_lin, lw.wq, d, qx, tmp);
            add_assign(tmp, lw.bq);
            lin_hoisted(h, hq_lin, lw.wk, d, qx, gate);
            add_assign(gate, lw.bk);
            lin_hoisted(h, hq_lin, lw.wv, d, qx, aux);
            add_assign(aux, lw.bv);
            k.extend_from_slice(gate);
            v.extend_from_slice(aux);
            let t = k.len() / d;
            let scale = 1.0 / (hd as f32).sqrt();
            acc.fill(0.0);
            scores.resize(t, 0.0);
            for hix in 0..heads {
                let r = hix * hd..(hix + 1) * hd;
                for j in 0..t {
                    let kj = &k[j * d..(j + 1) * d];
                    let mut dot = 0.0;
                    for c in r.clone() {
                        dot += tmp[c] * kj[c];
                    }
                    scores[j] = dot * scale;
                }
                softmax_inplace(&mut scores[..t]);
                for j in 0..t {
                    let vj = &v[j * d..(j + 1) * d];
                    let p = scores[j];
                    for c in r.clone() {
                        acc[c] += p * vj[c];
                    }
                }
            }
            lin(acc, lw.wo, d, qx, y);
            add_assign(y, lw.bo);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{test_manifest, MockEngine};
    use crate::infer::weights::ModelWeights;
    use crate::runtime::StepEngine;

    fn model() -> Arc<Model> {
        let m = test_manifest("hsm_ab", 2, 16, 300);
        let mut mock = MockEngine::new(m.clone(), 1.8, 0.01);
        mock.init(0).unwrap();
        // MockEngine weights are constant; perturb them deterministically so
        // tokens/positions are distinguishable.
        let mut params = mock.get_params().unwrap();
        for (ti, t) in params.iter_mut().enumerate() {
            for (i, x) in t.iter_mut().enumerate() {
                *x += 0.05 * (((i * 31 + ti * 7) % 17) as f32 - 8.0) / 8.0;
            }
        }
        let w = ModelWeights::from_flat(&m, &params).unwrap();
        Model::shared(m, w).unwrap()
    }

    fn engine() -> NativeDecoder {
        model().session()
    }

    #[test]
    fn ring_buffer_ages() {
        let mut r = Ring::new(3, 2);
        assert!(r.back(1).is_none());
        r.push(&[1.0, 1.0]);
        r.push(&[2.0, 2.0]);
        assert_eq!(r.back(1).unwrap(), &[2.0, 2.0]);
        assert_eq!(r.back(2).unwrap(), &[1.0, 1.0]);
        assert!(r.back(3).is_none());
        r.push(&[3.0, 3.0]);
        r.push(&[4.0, 4.0]); // evicts 1.0
        assert_eq!(r.back(3).unwrap(), &[2.0, 2.0]);
        assert!(r.back(4).is_none());
        r.clear();
        assert!(r.back(1).is_none());
    }

    #[test]
    fn step_produces_finite_logits_and_advances() {
        let mut e = engine();
        let l1 = e.step(5).unwrap().to_vec();
        assert_eq!(l1.len(), 300);
        assert!(l1.iter().all(|x| x.is_finite()));
        assert_eq!(e.position(), 1);
        let l2 = e.step(6).unwrap().to_vec();
        assert_ne!(l1, l2, "different context, different logits");
    }

    #[test]
    fn reset_restores_determinism() {
        let mut e = engine();
        let a1 = e.step(5).unwrap().to_vec();
        let a2 = e.step(9).unwrap().to_vec();
        e.reset();
        assert_eq!(e.step(5).unwrap().to_vec(), a1);
        assert_eq!(e.step(9).unwrap().to_vec(), a2);
    }

    #[test]
    fn rejects_out_of_vocab_and_overflow() {
        let mut e = engine();
        assert!(e.step(9999).is_err());
        for t in 0..16 {
            e.step(t % 7).unwrap();
        }
        assert!(e.step(0).is_err(), "ctx exhausted must error");
    }

    #[test]
    fn hsm_state_is_constant_size() {
        let mut e = engine();
        for t in 0..10 {
            e.step(t).unwrap();
        }
        match &e.session.state.layers[0] {
            LayerState::Hsm(r) => assert_eq!(r.buf.len(), 1), // max shift = 1
            _ => panic!("expected HSM state"),
        }
    }

    #[test]
    fn snapshot_restore_is_bit_exact() {
        let md = model();
        let mut a = md.session();
        a.prefill(&[5, 9, 3]).unwrap();
        let snap = a.snapshot().unwrap();
        assert_eq!(snap.position(), 3);
        let want = a.step(2).unwrap().to_vec();

        // Restore into a session that decoded something else entirely.
        let mut b = md.session();
        b.prefill(&[1, 1, 1, 1]).unwrap();
        b.restore(&snap).unwrap();
        assert_eq!(b.position(), 3);
        let got = b.step(2).unwrap().to_vec();
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "restored decode must be bit-exact"
        );
    }

    #[test]
    fn fork_decodes_independently() {
        let md = model();
        let mut a = md.session();
        a.prefill(&[5, 9]).unwrap();
        let mut b = a.fork();
        // Diverge the fork; the original must be unaffected.
        b.step(6).unwrap();
        b.step(6).unwrap();
        let solo = {
            let mut s = md.session();
            s.prefill(&[5, 9]).unwrap();
            s.step(3).unwrap().to_vec()
        };
        assert_eq!(a.step(3).unwrap().to_vec(), solo, "fork perturbed the original");
    }

    #[test]
    fn restore_rejects_incompatible_state() {
        let md = model();
        let mut a = md.session();
        a.prefill(&[5, 9]).unwrap();
        let snap = a.snapshot().unwrap();

        // A structurally different model (larger shift ring) rejects it.
        let other = {
            let layers =
                vec![LayerInfo { kind: "ab".into(), heads: 1, shifts: vec![4], ffn: 16 }];
            let m = Manifest::synthetic("hsm_ab", layers, 8, 16, 300, 1);
            let flat = super::super::weights::seeded_flat(&m, 7);
            Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
        };
        let mut b = other.session();
        assert!(b.restore(&snap).is_err(), "cross-shape restore must fail");
        assert_ne!(md.fingerprint(), other.fingerprint(), "fingerprints must differ");

        // Same shape, different weight bits: structural validation
        // passes, so only the fingerprint stamp stands between the
        // snapshot and silently-wrong logits.
        let twin = {
            let m = test_manifest("hsm_ab", 2, 16, 300);
            let mut mock = MockEngine::new(m.clone(), 1.8, 0.01);
            mock.init(0).unwrap();
            let mut params = mock.get_params().unwrap();
            for t in params.iter_mut() {
                for x in t.iter_mut() {
                    *x += 0.125;
                }
            }
            let w = ModelWeights::from_flat(&m, &params).unwrap();
            Model::shared(m, w).unwrap()
        };
        let mut t = twin.session();
        assert!(
            t.restore(&snap).is_err(),
            "same-shape different-weights restore must fail on the fingerprint"
        );
        assert!(twin.session_from(snap).is_err(), "session_from must check the stamp too");
    }

    /// The shallow drafter's resync argument: layer l's state depends
    /// only on layers below it, so after restoring a *full-model*
    /// snapshot, shallow-stepping the first K layers produces exactly
    /// the logits a session that only ever stepped K layers would —
    /// and with K = L, `step_shallow` is bit-identical to `step`.
    #[test]
    fn shallow_steps_agree_with_a_shallow_only_session() {
        let md = model();
        let k = 1usize; // first of 2 layers

        // Session A: full-model prefill, then shallow steps.
        let mut a = md.session();
        a.prefill(&[5, 9, 3]).unwrap();
        let mut a_sess = DecodeSession::new(&md.manifest, None).unwrap();
        a_sess.restore(&md.manifest, &a.snapshot().unwrap()).unwrap();

        // Session B: shallow-only from scratch over the same tokens.
        let mut b_sess = DecodeSession::new(&md.manifest, None).unwrap();
        for t in [5u32, 9, 3] {
            b_sess.step_shallow(&md, t, k).unwrap();
        }

        let la = a_sess.step_shallow(&md, 2, k).unwrap().to_vec();
        let lb = b_sess.step_shallow(&md, 2, k).unwrap().to_vec();
        assert_eq!(
            la.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            lb.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "shallow state must be the prefix of full state"
        );

        // Full-depth shallow == the ordinary step.
        let mut c = md.session();
        c.prefill(&[5, 9, 3]).unwrap();
        let want = c.step(2).unwrap().to_vec();
        let mut d_sess = DecodeSession::new(&md.manifest, None).unwrap();
        d_sess.restore(&md.manifest, &{
            let mut s = md.session();
            s.prefill(&[5, 9, 3]).unwrap();
            s.snapshot().unwrap()
        })
        .unwrap();
        let got = d_sess.step_shallow(&md, 2, 99).unwrap().to_vec();
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    fn model_of_kind(kind: &str) -> Arc<Model> {
        let layers = match kind {
            "ab" => vec![
                LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![1, 2, 4, 8], ffn: 24 },
                LayerInfo { kind: "ab".into(), heads: 4, shifts: vec![2, 4, 8, 16], ffn: 24 },
            ],
            _ => vec![
                LayerInfo { kind: kind.into(), heads: 2, shifts: vec![1], ffn: 24 },
                LayerInfo { kind: kind.into(), heads: 2, shifts: vec![3], ffn: 24 },
            ],
        };
        let m = Manifest::synthetic(kind, layers, 16, 64, 300, 1);
        let flat = super::super::weights::seeded_flat(&m, 31);
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &flat).unwrap()).unwrap()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// The fused verify pass is a pure re-grouping: for every mixer
    /// kind, `step_batch` over a block is bit-identical per row to
    /// stepping the block sequentially, and `rewind_batch(keep)`
    /// reproduces — byte for byte — the state of a sequential session
    /// that stopped after `keep` of those tokens (shift rings larger
    /// and smaller than the block both covered via the layer shifts).
    #[test]
    fn step_batch_matches_sequential_steps_for_every_mixer_kind() {
        let prompt = [5u32, 9, 3, 7];
        let block = [2u32, 11, 6, 4, 8];
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let md = model_of_kind(kind);

            let mut seq = md.session();
            seq.prefill(&prompt).unwrap();
            let want: Vec<Vec<f32>> =
                block.iter().map(|&t| seq.step(t).unwrap().to_vec()).collect();

            let mut fused = md.session();
            fused.prefill(&prompt).unwrap();
            let logits = fused.step_batch(&block).unwrap();
            assert_eq!(logits.len(), block.len() * 300);
            for (r, row) in want.iter().enumerate() {
                assert_eq!(
                    bits(&logits[r * 300..(r + 1) * 300]),
                    bits(row),
                    "{kind}: fused logits row {r} diverged from sequential"
                );
            }
            assert_eq!(fused.position(), prompt.len() + block.len());

            for keep in [0usize, 2, block.len()] {
                let mut fused = md.session();
                fused.prefill(&prompt).unwrap();
                fused.step_batch(&block).unwrap();
                fused.rewind_batch(keep).unwrap();
                assert_eq!(fused.position(), prompt.len() + keep);

                let mut r = md.session();
                r.prefill(&prompt).unwrap();
                for &t in &block[..keep] {
                    r.step(t).unwrap();
                }
                assert_eq!(
                    bits(fused.step(1).unwrap()),
                    bits(r.step(1).unwrap()),
                    "{kind}: decode after rewind({keep}) diverged"
                );
            }
        }
    }

    /// Back-to-back verify rounds reuse the same arena; interleaving
    /// fused blocks with ordinary steps stays bit-exact.
    #[test]
    fn repeated_fused_rounds_stay_bit_exact() {
        let md = model_of_kind("ab");
        let mut seq = md.session();
        let mut fused = md.session();
        seq.prefill(&[5, 9]).unwrap();
        fused.prefill(&[5, 9]).unwrap();
        let script: &[(&[u32], usize)] = &[(&[3, 7, 2], 1), (&[4, 4, 8, 1], 3), (&[6], 0)];
        for &(block, keep) in script {
            fused.step_batch(block).unwrap();
            fused.rewind_batch(keep).unwrap();
            for &t in &block[..keep] {
                seq.step(t).unwrap();
            }
            assert_eq!(bits(fused.step(2).unwrap()), bits(seq.step(2).unwrap()));
        }
    }

    #[test]
    fn step_batch_guards() {
        let mut e = engine();
        assert!(e.rewind_batch(0).is_err(), "no pending batch");
        e.prefill(&[1, 2]).unwrap();
        assert!(e.step_batch(&[]).is_err(), "empty batch");
        assert!(e.step_batch(&[9999]).is_err(), "out-of-vocab token");
        assert!(e.step_batch(&[0; 15]).is_err(), "batch past ctx (16)");
        e.step_batch(&[3, 4]).unwrap();
        assert!(e.rewind_batch(3).is_err(), "keep > rows");
        e.rewind_batch(1).unwrap();
        assert!(e.rewind_batch(1).is_err(), "batch already consumed");
        // Restoring a snapshot invalidates any pending batch.
        let snap = e.snapshot().unwrap();
        e.step_batch(&[5]).unwrap();
        e.restore(&snap).unwrap();
        assert!(e.rewind_batch(0).is_err(), "restore must void the batch");
    }

    #[test]
    fn prefill_matches_step_by_step() {
        let md = model();
        let mut a = md.session();
        a.step(5).unwrap();
        a.step(9).unwrap();
        let want = a.step(3).unwrap().to_vec();

        let mut b = md.session();
        b.prefill(&[5, 9]).unwrap();
        assert_eq!(b.position(), 2);
        assert_eq!(b.step(3).unwrap().to_vec(), want);
    }

    fn quant_model_of_kind(kind: &str) -> Arc<Model> {
        let md = model_of_kind(kind);
        let flat = super::super::weights::seeded_flat(&md.manifest, 31);
        let w = ModelWeights::from_flat(&md.manifest, &flat).unwrap();
        Model::shared_with_precision(md.manifest.clone(), w, Precision::Int8).unwrap()
    }

    #[test]
    fn int8_model_drops_f32_weights_and_shrinks_residency() {
        let f = model_of_kind("ab");
        let q = quant_model_of_kind("ab");
        assert_eq!(f.precision(), Precision::F32);
        assert_eq!(q.precision(), Precision::Int8);
        assert!(f.weights().is_some());
        assert!(q.weights().is_none(), "int8 models must not keep the f32 copy");
        assert!(
            q.resident_weight_bytes() < f.resident_weight_bytes() / 2,
            "int8 residency {} vs f32 {}",
            q.resident_weight_bytes(),
            f.resident_weight_bytes()
        );
        // Same checkpoint, different precision: activations diverge, so
        // the fingerprints must too (snapshots must never cross over).
        assert_ne!(f.fingerprint(), q.fingerprint());
    }

    #[test]
    fn int8_decoding_is_deterministic_and_close_to_f32() {
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let q = quant_model_of_kind(kind);
            let mut a = q.session();
            let mut b = q.session();
            for t in [5u32, 9, 3, 7, 2] {
                let la = a.step(t).unwrap().to_vec();
                let lb = b.step(t).unwrap().to_vec();
                assert!(la.iter().all(|x| x.is_finite()), "{kind}: non-finite int8 logits");
                assert_eq!(bits(&la), bits(&lb), "{kind}: int8 decode must be deterministic");
            }
        }
    }

    /// The `shallow-q` drafter contract: full-depth shallow stepping at
    /// `Precision::Int8` on an f32 model (through its lazily built
    /// [`Model::quant`] shadow) is bit-identical to decoding the same
    /// checkpoint loaded as an int8 model — the drafter really runs on
    /// the int8 weights.
    #[test]
    fn quantized_shallow_steps_match_the_int8_model() {
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let f = model_of_kind(kind);
            let q = quant_model_of_kind(kind);
            let mut a = DecodeSession::new(&f.manifest, None).unwrap();
            let mut b = q.session();
            for t in [5u32, 9, 3, 7] {
                let la = a.step_shallow_at(&f, t, 0, Precision::Int8).unwrap().to_vec();
                let lb = b.step(t).unwrap().to_vec();
                assert_eq!(bits(&la), bits(&lb), "{kind}: shallow-q diverged from int8 model");
            }
        }
    }

    /// The fused verify pass stays a pure re-grouping at int8: batched
    /// rows are bit-identical to sequential int8 steps for every mixer
    /// kind (activation rows quantize identically either way, and the
    /// int8 kernel tiers are bit-exact against each other).
    #[test]
    fn int8_step_batch_matches_sequential_int8_steps() {
        let prompt = [5u32, 9, 3, 7];
        let block = [2u32, 11, 6, 4, 8];
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let md = quant_model_of_kind(kind);
            let mut seq = md.session();
            seq.prefill(&prompt).unwrap();
            let want: Vec<Vec<f32>> =
                block.iter().map(|&t| seq.step(t).unwrap().to_vec()).collect();

            let mut fused = md.session();
            fused.prefill(&prompt).unwrap();
            let logits = fused.step_batch(&block).unwrap();
            for (r, row) in want.iter().enumerate() {
                assert_eq!(
                    bits(&logits[r * 300..(r + 1) * 300]),
                    bits(row),
                    "{kind}: int8 fused logits row {r} diverged from sequential"
                );
            }
            fused.rewind_batch(2).unwrap();
            let mut r2 = md.session();
            r2.prefill(&prompt).unwrap();
            r2.step(block[0]).unwrap();
            r2.step(block[1]).unwrap();
            assert_eq!(
                bits(fused.step(1).unwrap()),
                bits(r2.step(1).unwrap()),
                "{kind}: int8 decode after rewind diverged"
            );
        }
    }

    fn quant4_model_of_kind(kind: &str) -> Arc<Model> {
        let md = model_of_kind(kind);
        let flat = super::super::weights::seeded_flat(&md.manifest, 31);
        let w = ModelWeights::from_flat(&md.manifest, &flat).unwrap();
        Model::shared_with_precision(md.manifest.clone(), w, Precision::Int4).unwrap()
    }

    #[test]
    fn int4_model_drops_f32_weights_and_shrinks_residency() {
        let f = model_of_kind("ab");
        let q8 = quant_model_of_kind("ab");
        let q4 = quant4_model_of_kind("ab");
        assert_eq!(q4.precision(), Precision::Int4);
        assert!(q4.weights().is_none(), "int4 models must not keep the f32 copy");
        assert!(
            q4.resident_weight_bytes() < q8.resident_weight_bytes(),
            "int4 residency {} vs int8 {}",
            q4.resident_weight_bytes(),
            q8.resident_weight_bytes()
        );
        assert!(
            q4.resident_weight_bytes() * 3 < f.resident_weight_bytes(),
            "int4 residency {} vs f32 {}",
            q4.resident_weight_bytes(),
            f.resident_weight_bytes()
        );
        // Same checkpoint at three precisions: three distinct
        // fingerprints (snapshots must never cross over).
        assert_ne!(q4.fingerprint(), f.fingerprint());
        assert_ne!(q4.fingerprint(), q8.fingerprint());
    }

    #[test]
    fn int4_decoding_is_deterministic_and_finite() {
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let q = quant4_model_of_kind(kind);
            let mut a = q.session();
            let mut b = q.session();
            for t in [5u32, 9, 3, 7, 2] {
                let la = a.step(t).unwrap().to_vec();
                let lb = b.step(t).unwrap().to_vec();
                assert!(la.iter().all(|x| x.is_finite()), "{kind}: non-finite int4 logits");
                assert_eq!(bits(&la), bits(&lb), "{kind}: int4 decode must be deterministic");
            }
        }
    }

    /// Full-depth shallow stepping at `Precision::Int4` on an f32 model
    /// (through its lazily built [`Model::quant4`] shadow) is
    /// bit-identical to decoding the same checkpoint loaded as an int4
    /// model — the int4 drafter path really runs on the int4 weights.
    #[test]
    fn quantized_shallow_int4_steps_match_the_int4_model() {
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let f = model_of_kind(kind);
            let q = quant4_model_of_kind(kind);
            let mut a = DecodeSession::new(&f.manifest, None).unwrap();
            let mut b = q.session();
            for t in [5u32, 9, 3, 7] {
                let la = a.step_shallow_at(&f, t, 0, Precision::Int4).unwrap().to_vec();
                let lb = b.step(t).unwrap().to_vec();
                assert_eq!(bits(&la), bits(&lb), "{kind}: shallow-int4 diverged from int4 model");
            }
        }
    }

    /// The fused verify pass stays a pure re-grouping at int4: batched
    /// rows are bit-identical to sequential int4 steps for every mixer
    /// kind.
    #[test]
    fn int4_step_batch_matches_sequential_int4_steps() {
        let prompt = [5u32, 9, 3, 7];
        let block = [2u32, 11, 6, 4, 8];
        for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
            let md = quant4_model_of_kind(kind);
            let mut seq = md.session();
            seq.prefill(&prompt).unwrap();
            let want: Vec<Vec<f32>> =
                block.iter().map(|&t| seq.step(t).unwrap().to_vec()).collect();

            let mut fused = md.session();
            fused.prefill(&prompt).unwrap();
            let logits = fused.step_batch(&block).unwrap();
            for (r, row) in want.iter().enumerate() {
                assert_eq!(
                    bits(&logits[r * 300..(r + 1) * 300]),
                    bits(row),
                    "{kind}: int4 fused logits row {r} diverged from sequential"
                );
            }
            fused.rewind_batch(2).unwrap();
            let mut r2 = md.session();
            r2.prefill(&prompt).unwrap();
            r2.step(block[0]).unwrap();
            r2.step(block[1]).unwrap();
            assert_eq!(
                bits(fused.step(1).unwrap()),
                bits(r2.step(1).unwrap()),
                "{kind}: int4 decode after rewind diverged"
            );
        }
    }

    /// The contract [`lin_hoisted`]'s doc points at: hoisting the
    /// activation quantization (quantize `h` once per layer, reuse the
    /// image everywhere) is a pure work saving — logits are bit-equal
    /// to per-call quantization, sequential and fused, at int8 and
    /// int4, for every mixer kind.
    #[test]
    fn hoisted_activation_quantization_is_bit_identical_per_call() {
        for precision in [Precision::Int8, Precision::Int4] {
            for kind in ["ab", "vec", "mat", "gate1", "gate2", "fusion", "attn"] {
                let md = match precision {
                    Precision::Int8 => quant_model_of_kind(kind),
                    _ => quant4_model_of_kind(kind),
                };
                let mut on = md.session();
                let mut off = md.session();
                off.set_quant_hoist(false);
                for t in [5u32, 9, 3, 7, 2] {
                    assert_eq!(
                        bits(on.step(t).unwrap()),
                        bits(off.step(t).unwrap()),
                        "{kind}@{precision:?}: hoist changed sequential step bytes"
                    );
                }
                let a = on.step_batch(&[4, 8, 1]).unwrap().to_vec();
                let b = off.step_batch(&[4, 8, 1]).unwrap();
                assert_eq!(
                    bits(&a),
                    bits(b),
                    "{kind}@{precision:?}: hoist changed fused-batch bytes"
                );
            }
        }
    }

    /// [`Ring`] quantized-image bookkeeping: `push_q` stores a
    /// reusable `(q, s)` whose dequantization IS the f32 row, a plain
    /// `push` invalidates the slot's image, and `copy_from` carries
    /// images across rings (allocating or invalidating as needed).
    #[test]
    fn ring_quantized_images_track_pushes() {
        let mut r = Ring::new(3, 4);
        assert!(r.back_q(1).is_none(), "fresh ring has no images");
        let q1 = [1i8, -2, 3, -4];
        r.push_q(&q1, 0.5);
        let (q, s) = r.back_q(1).unwrap();
        assert_eq!(q, &q1);
        assert_eq!(s, 0.5);
        assert_eq!(r.back(1).unwrap(), &[0.5, -1.0, 1.5, -2.0]);

        r.push(&[1.0; 4]);
        assert!(r.back_q(1).is_none(), "plain push must invalidate the image");
        assert!(r.back_q(2).is_some(), "older image survives");

        r.clear();
        assert!(r.back_q(1).is_none(), "clear must drop all images");

        r.push_q(&q1, 2.0);
        let mut dst = Ring::new(3, 4);
        dst.copy_from(&r);
        assert_eq!(dst.back_q(1).unwrap(), (&q1[..], 2.0));
        assert_eq!(bits(dst.back(1).unwrap()), bits(r.back(1).unwrap()));

        let mut plain = Ring::new(3, 4);
        plain.push(&[9.0; 4]);
        dst.copy_from(&plain);
        assert!(dst.back_q(1).is_none(), "copying an unquantized ring must invalidate");
        assert_eq!(dst.back(1).unwrap(), &[9.0; 4]);
    }

    /// Compact → hydrate is lossless for quantized snapshots (the f32
    /// rows are *defined as* `q·s`), shrinks resident bytes while
    /// compacted, and is a no-op for f32 state.  A compacted state must
    /// not validate — the cache hydrates before handing it out.
    #[test]
    fn compact_hydrate_round_trips_quantized_snapshots() {
        let md = quant4_model_of_kind("mat");
        let mut s = md.session();
        s.prefill(&[5, 9, 3, 7]).unwrap();
        let full = s.snapshot().unwrap();
        let mut packed = full.clone();
        packed.compact();
        assert!(packed.is_compacted());
        assert!(!full.is_compacted());
        assert!(
            packed.resident_bytes() < full.resident_bytes(),
            "compacted {} vs full {}",
            packed.resident_bytes(),
            full.resident_bytes()
        );
        assert!(packed.validate(&md.manifest).is_err(), "compacted state must not validate");
        packed.hydrate();
        assert!(!packed.is_compacted());
        assert_eq!(packed.resident_bytes(), full.resident_bytes());
        let mut a = md.session_from(full).unwrap();
        let mut b = md.session_from(packed).unwrap();
        assert_eq!(
            bits(a.step(2).unwrap()),
            bits(b.step(2).unwrap()),
            "decode after compact+hydrate diverged"
        );

        // F32 decoding records no images, so compact must refuse.
        let fd = model_of_kind("mat");
        let mut fs = fd.session();
        fs.prefill(&[5, 9]).unwrap();
        let mut snap = fs.snapshot().unwrap();
        let rb = snap.resident_bytes();
        snap.compact();
        assert!(!snap.is_compacted(), "f32 state must not compact");
        assert_eq!(snap.resident_bytes(), rb);
        assert!(snap.validate(&fd.manifest).is_ok());
    }

    #[test]
    fn concurrent_sessions_share_weights_without_crosstalk() {
        let md = model();
        let mut solo = md.session();
        let s1: Vec<Vec<f32>> =
            [5u32, 9, 3].iter().map(|&t| solo.step(t).unwrap().to_vec()).collect();

        // Two interleaved sessions over the same Arc<Model>: one replays the
        // solo stream, the other runs a different stream in between.
        let mut a = md.session();
        let mut b = md.session();
        for (i, &t) in [5u32, 9, 3].iter().enumerate() {
            b.step((t + 1) % 7).unwrap();
            let got = a.step(t).unwrap().to_vec();
            assert_eq!(got, s1[i], "session crosstalk at step {i}");
        }
        assert_eq!(std::sync::Arc::strong_count(&md), 4); // md + solo + a + b
    }
}
