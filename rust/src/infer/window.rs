//! Full-sequence reference forward: an artifact-free `decode` pass.
//!
//! [`WindowEngine`] implements [`StepEngine::decode`] over a shared
//! [`Model`] by materialising the whole `[T, D]` activation matrix and
//! mixing with explicit `t − s` indexing — a code path **independent of
//! the ring-buffer/KV-cache incremental engine** (`engine.rs`).  That
//! independence is the point: `tests/decode_parity.rs` drives greedy
//! generation through both and requires token-for-token agreement, which
//! pins the incremental state machinery (ring ages, push ordering, KV
//! growth) against the plain math.  It is also the "windowed decode"
//! baseline in `benches/decode_latency.rs` — O(ctx) work per generated
//! token versus the incremental engine's O(1) (pure HSM).
//!
//! Training entry points intentionally bail: this engine exists to
//! decode.  Op order matches `engine.rs` exactly, so agreement is
//! bit-level, not just within tolerance.

use anyhow::{bail, Result};
use std::sync::Arc;

use super::engine::Model;
use super::tensor::{add_assign, layer_norm, matvec, matvec_t, relu_inplace, softmax_inplace, tanh_inplace};
use crate::config::Manifest;
use crate::data::Batch;
use crate::runtime::{StepEngine, StepMetrics};

/// Decode-only [`StepEngine`] over native weights (no artifacts, no PJRT).
pub struct WindowEngine {
    model: Arc<Model>,
}

impl WindowEngine {
    pub fn new(model: Arc<Model>) -> Self {
        WindowEngine { model }
    }

    pub fn model(&self) -> &Arc<Model> {
        &self.model
    }
}

impl StepEngine for WindowEngine {
    fn manifest(&self) -> &Manifest {
        &self.model.manifest
    }

    /// Weights are fixed at construction; init is a no-op for interface
    /// compatibility (the generate path calls it unconditionally).
    fn init(&mut self, _seed: u32) -> Result<()> {
        Ok(())
    }

    fn train_step(&mut self, _step: i32, _batch: &Batch) -> Result<StepMetrics> {
        bail!("WindowEngine is decode-only (no training artifacts)")
    }

    fn eval_step(&mut self, _batch: &Batch) -> Result<StepMetrics> {
        bail!("WindowEngine is decode-only (no training artifacts)")
    }

    fn decode(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let ctx = self.model.manifest.ctx;
        if tokens.len() != ctx {
            bail!("decode expects exactly {ctx} tokens, got {}", tokens.len());
        }
        forward_full(&self.model, tokens)
    }

    fn get_params(&self) -> Result<Vec<Vec<f32>>> {
        bail!("WindowEngine does not expose flat parameters")
    }

    fn set_params(&mut self, _params: Vec<Vec<f32>>) -> Result<()> {
        bail!("WindowEngine weights are fixed at construction")
    }

    fn get_state(&self) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        bail!("WindowEngine has no optimizer state")
    }

    fn set_state(&mut self, _m: Vec<Vec<f32>>, _v: Vec<Vec<f32>>) -> Result<()> {
        bail!("WindowEngine has no optimizer state")
    }
}

/// Full-context forward over `tokens` (length ≤ ctx): logits for every
/// position, row-major `[tokens.len() * vocab]`.
pub fn forward_full(model: &Model, tokens: &[i32]) -> Result<Vec<f32>> {
    let m = &model.manifest;
    // The reference forward is deliberately f32-only: it exists to pin
    // the incremental engine's numerics against an independent code
    // path, and the incremental int8 path is pinned against f32 by the
    // quant tolerance harness instead.
    let Some(w) = model.weights() else {
        bail!(
            "the full-context reference forward needs resident f32 weights (model is {})",
            model.precision().label()
        );
    };
    let d = m.dim;
    let vocab = m.vocab;
    let n = tokens.len();
    if n == 0 || n > m.ctx {
        bail!("window length {n} must be in 1..={}", m.ctx);
    }
    for &t in tokens {
        if t < 0 || t as usize >= vocab {
            bail!("token {t} out of vocab {vocab}");
        }
    }

    // Embedding + learned position.
    let mut x = vec![0.0f32; n * d];
    for (p, &tok) in tokens.iter().enumerate() {
        let te = &w.tok_emb[tok as usize * d..(tok as usize + 1) * d];
        let pe = &w.pos_emb[p * d..(p + 1) * d];
        for i in 0..d {
            x[p * d + i] = te[i] + pe[i];
        }
    }

    let mut h = vec![0.0f32; n * d];
    let mut y = vec![0.0f32; n * d];
    let zeros = vec![0.0f32; d];

    for (l, spec) in m.layers.iter().enumerate() {
        let lw = &w.layers[l];
        let heads = spec.heads;
        let hd = d / heads;

        // H = LN1(X), row-wise.
        for p in 0..n {
            layer_norm(&x[p * d..(p + 1) * d], &lw.ln1_g, &lw.ln1_b, &mut h[p * d..(p + 1) * d]);
        }

        // Y = mixer(H) with explicit t − s / causal-attention indexing.
        let mw = &lw.mixer;
        match spec.kind.as_str() {
            "ab" => {
                for p in 0..n {
                    for hix in 0..heads {
                        let s = spec.shifts[hix.min(spec.shifts.len() - 1)];
                        let prev = if p >= s { &h[(p - s) * d..(p - s + 1) * d] } else { &zeros[..] };
                        let (a, b) = (mw.mix_a[hix], mw.mix_b[hix]);
                        for c in hix * hd..(hix + 1) * hd {
                            y[p * d + c] = a * h[p * d + c] + b * prev[c];
                        }
                    }
                }
            }
            "vec" => {
                let s = spec.shifts[0];
                for p in 0..n {
                    let prev = if p >= s { &h[(p - s) * d..(p - s + 1) * d] } else { &zeros[..] };
                    for c in 0..d {
                        y[p * d + c] = mw.mix_a[c] * h[p * d + c] + mw.mix_b[c] * prev[c];
                    }
                }
            }
            "mat" => {
                let s = spec.shifts[0];
                let mut tmp = vec![0.0f32; d];
                for p in 0..n {
                    let (hp, yp) = (&h[p * d..(p + 1) * d], &mut y[p * d..(p + 1) * d]);
                    let prev = if p >= s { &h[(p - s) * d..(p - s + 1) * d] } else { &zeros[..] };
                    matvec(hp, &mw.mix_mat_a, d, yp);
                    matvec(prev, &mw.mix_mat_b, d, &mut tmp);
                    add_assign(yp, &tmp);
                    add_assign(yp, &mw.mix_bias);
                }
            }
            "gate1" => {
                let s = spec.shifts[0];
                let mut g1 = vec![0.0f32; d];
                let mut gate = vec![0.0f32; d];
                for p in 0..n {
                    let hp = &h[p * d..(p + 1) * d];
                    let prev = if p >= s { &h[(p - s) * d..(p - s + 1) * d] } else { &zeros[..] };
                    matvec(hp, &mw.gate_w1, d, &mut g1);
                    add_assign(&mut g1, &mw.gate_b1);
                    relu_inplace(&mut g1);
                    matvec(&g1, &mw.gate_w2, d, &mut gate);
                    add_assign(&mut gate, &mw.gate_b2);
                    tanh_inplace(&mut gate);
                    for c in 0..d {
                        y[p * d + c] = gate[c] * hp[c] + (1.0 - gate[c]) * prev[c];
                    }
                }
            }
            "gate2" => {
                let s = spec.shifts[0];
                let mut cat = vec![0.0f32; 2 * hd];
                let mut gate = vec![0.0f32; hd];
                for p in 0..n {
                    let hp = &h[p * d..(p + 1) * d];
                    let prev = if p >= s { &h[(p - s) * d..(p - s + 1) * d] } else { &zeros[..] };
                    for hix in 0..heads {
                        cat[..hd].copy_from_slice(&hp[hix * hd..(hix + 1) * hd]);
                        cat[hd..].copy_from_slice(&prev[hix * hd..(hix + 1) * hd]);
                        let wg = &mw.gate_w[hix * 2 * hd * hd..(hix + 1) * 2 * hd * hd];
                        matvec(&cat, wg, hd, &mut gate);
                        add_assign(&mut gate, &mw.gate_b[hix * hd..(hix + 1) * hd]);
                        tanh_inplace(&mut gate);
                        for c in 0..hd {
                            let gc = hix * hd + c;
                            y[p * d + gc] = gate[c] * hp[gc] + (1.0 - gate[c]) * prev[gc];
                        }
                    }
                }
            }
            "fusion" => {
                let s = spec.shifts[0];
                let mut cat = vec![0.0f32; 2 * hd];
                let mut mid = vec![0.0f32; hd];
                let mut out = vec![0.0f32; hd];
                for p in 0..n {
                    let hp = &h[p * d..(p + 1) * d];
                    let prev = if p >= s { &h[(p - s) * d..(p - s + 1) * d] } else { &zeros[..] };
                    for hix in 0..heads {
                        cat[..hd].copy_from_slice(&hp[hix * hd..(hix + 1) * hd]);
                        cat[hd..].copy_from_slice(&prev[hix * hd..(hix + 1) * hd]);
                        let w1 = &mw.fuse_w1[hix * 2 * hd * hd..(hix + 1) * 2 * hd * hd];
                        matvec(&cat, w1, hd, &mut mid);
                        add_assign(&mut mid, &mw.fuse_b1[hix * hd..(hix + 1) * hd]);
                        relu_inplace(&mut mid);
                        let w2 = &mw.fuse_w2[hix * hd * hd..(hix + 1) * hd * hd];
                        matvec(&mid, w2, hd, &mut out);
                        add_assign(&mut out, &mw.fuse_b2[hix * hd..(hix + 1) * hd]);
                        y[p * d + hix * hd..p * d + (hix + 1) * hd].copy_from_slice(&out);
                    }
                }
            }
            "attn" => {
                // Project q/k/v for every position, then causal softmax
                // attention per head (op order matches engine.rs exactly).
                let mut q = vec![0.0f32; n * d];
                let mut kk = vec![0.0f32; n * d];
                let mut vv = vec![0.0f32; n * d];
                for p in 0..n {
                    let hp = &h[p * d..(p + 1) * d];
                    let qp = &mut q[p * d..(p + 1) * d];
                    matvec(hp, &mw.wq, d, qp);
                    add_assign(qp, &mw.bq);
                    let kp = &mut kk[p * d..(p + 1) * d];
                    matvec(hp, &mw.wk, d, kp);
                    add_assign(kp, &mw.bk);
                    let vp = &mut vv[p * d..(p + 1) * d];
                    matvec(hp, &mw.wv, d, vp);
                    add_assign(vp, &mw.bv);
                }
                let scale = 1.0 / (hd as f32).sqrt();
                let mut o = vec![0.0f32; d];
                let mut scores = vec![0.0f32; n];
                for p in 0..n {
                    let t = p + 1; // causal: attend to positions 0..=p
                    o.fill(0.0);
                    for hix in 0..heads {
                        let r = hix * hd..(hix + 1) * hd;
                        for j in 0..t {
                            let kj = &kk[j * d..(j + 1) * d];
                            let mut dot = 0.0;
                            for c in r.clone() {
                                dot += q[p * d + c] * kj[c];
                            }
                            scores[j] = dot * scale;
                        }
                        softmax_inplace(&mut scores[..t]);
                        for j in 0..t {
                            let vj = &vv[j * d..(j + 1) * d];
                            let pj = scores[j];
                            for c in r.clone() {
                                o[c] += pj * vj[c];
                            }
                        }
                    }
                    let yp = &mut y[p * d..(p + 1) * d];
                    matvec(&o, &mw.wo, d, yp);
                    add_assign(yp, &mw.bo);
                }
            }
            other => bail!("layer {l}: unknown mixer kind {other:?}"),
        }

        // X += Y, then the FFN block row-wise.
        let mut f2 = vec![0.0f32; d];
        let mut f1 = vec![0.0f32; spec.ffn];
        for p in 0..n {
            let xp = &mut x[p * d..(p + 1) * d];
            add_assign(xp, &y[p * d..(p + 1) * d]);
            layer_norm(xp, &lw.ln2_g, &lw.ln2_b, &mut f2);
            matvec(&f2, &lw.ffn_w1, spec.ffn, &mut f1);
            add_assign(&mut f1, &lw.ffn_b1);
            relu_inplace(&mut f1);
            matvec(&f1, &lw.ffn_w2, d, &mut f2);
            add_assign(&mut f2, &lw.ffn_b2);
            add_assign(xp, &f2);
        }
    }

    // Final LN + tied-embedding projection per row.
    let mut logits = vec![0.0f32; n * vocab];
    let mut hf = vec![0.0f32; d];
    for p in 0..n {
        layer_norm(&x[p * d..(p + 1) * d], &w.lnf_g, &w.lnf_b, &mut hf);
        matvec_t(&hf, &w.tok_emb, vocab, &mut logits[p * vocab..(p + 1) * vocab]);
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{test_manifest, MockEngine};
    use crate::infer::{Decoder, ModelWeights};

    fn model() -> Arc<Model> {
        let m = test_manifest("hsm_ab", 2, 16, 300);
        let mut mock = MockEngine::new(m.clone(), 1.8, 0.01);
        mock.init(0).unwrap();
        let mut params = mock.get_params().unwrap();
        for (ti, t) in params.iter_mut().enumerate() {
            for (i, x) in t.iter_mut().enumerate() {
                *x += 0.04 * (((i * 13 + ti * 5) % 23) as f32 - 11.0) / 11.0;
            }
        }
        Model::shared(m.clone(), ModelWeights::from_flat(&m, &params).unwrap()).unwrap()
    }

    #[test]
    fn full_forward_matches_incremental_bitwise() {
        let md = model();
        let toks = [3i32, 7, 1, 9, 2, 5];
        let full = forward_full(&md, &toks).unwrap();
        let mut session = md.session();
        let vocab = md.manifest.vocab;
        for (p, &t) in toks.iter().enumerate() {
            let inc = session.step(t as u32).unwrap();
            assert_eq!(
                inc,
                &full[p * vocab..(p + 1) * vocab],
                "row {p} differs between full and incremental forward"
            );
        }
    }

    #[test]
    fn decode_enforces_the_artifact_contract() {
        let md = model();
        let mut eng = WindowEngine::new(md);
        assert!(eng.decode(&[1, 2, 3]).is_err(), "must require exactly ctx tokens");
        let ok: Vec<i32> = (0..16).collect();
        assert_eq!(eng.decode(&ok).unwrap().len(), 16 * 300);
        let bad: Vec<i32> = vec![900; 16];
        assert!(eng.decode(&bad).is_err(), "out-of-vocab token must fail");
        assert!(eng.train_step(0, &Batch { x: vec![], y: vec![], batch: 0, ctx: 0 }).is_err());
    }
}
