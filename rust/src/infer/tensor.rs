//! Minimal dense-math substrate for the native inference engine.
//!
//! Row-major `f32` throughout, shaped to the decoder's needs: vector ×
//! matrix products (the hot path — one token at a time), LayerNorm, ReLU,
//! tanh, and a numerically-stable softmax.  No external BLAS: the matvec
//! is written as an axpy-accumulation over matrix rows so the inner loop
//! is contiguous in memory and auto-vectorizes.

/// y = x @ W where `x: [k]`, `w: [k, n]` row-major → `y: [n]`.
///
/// Iterating over rows of `w` keeps both `w`'s row and `y` contiguous
/// (axpy form), which the compiler vectorizes; the naive column-dot form
/// would stride by `n` and run ~4× slower.
pub fn matvec(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), k * n, "matvec shape mismatch");
    debug_assert_eq!(y.len(), n);
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for (yj, &wij) in y.iter_mut().zip(row) {
            *yj += xi * wij;
        }
    }
    let _ = k;
}

/// y = x @ Wᵀ where `x: [k]`, `w: [n, k]` row-major → `y: [n]`.
/// (Used for the tied-embedding logit projection `h @ Eᵀ`.)
pub fn matvec_t(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), n * k, "matvec_t shape mismatch");
    for j in 0..n {
        let row = &w[j * k..(j + 1) * k];
        let mut acc = 0.0f32;
        for (xi, wji) in x.iter().zip(row) {
            acc += xi * wji;
        }
        y[j] = acc;
    }
}

/// In-place y += x.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += b;
    }
}

/// LayerNorm with learned gain/bias (eps matches the L2 model's 1e-5).
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}

pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn tanh_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Numerically-stable in-place softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_small() {
        // x: [2], W: [2, 3] = [[1,2,3],[4,5,6]] → y = [1*1+2*4, 1*2+2*5, 1*3+2*6]
        let x = [1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut y = [0.0; 3];
        matvec(&x, &w, 3, &mut y);
        assert_eq!(y, [9.0, 12.0, 15.0]);
    }

    #[test]
    fn matvec_t_is_transpose_of_matvec() {
        let x = [0.5, -1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3] as n=2, k=3 for matvec_t
        let mut yt = [0.0; 2];
        matvec_t(&x, &w, 2, &mut yt);
        // row0 · x = 1*0.5 + 2*-1 + 3*2 = 4.5 ; row1 · x = 4*0.5 + 5*-1 + 6*2 = 9
        assert_eq!(yt, [4.5, 9.0]);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let g = [1.0; 4];
        let b = [0.0; 4];
        let mut out = [0.0; 4];
        layer_norm(&x, &g, &b, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = [1000.0, 1001.0, 999.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn relu_and_tanh() {
        let mut x = [-1.0, 0.5];
        relu_inplace(&mut x);
        assert_eq!(x, [0.0, 0.5]);
        let mut y = [0.0f32, 100.0];
        tanh_inplace(&mut y);
        assert!((y[0]).abs() < 1e-7 && (y[1] - 1.0).abs() < 1e-5);
    }
}
