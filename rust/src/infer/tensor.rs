//! Minimal dense-math substrate for the native inference engine.
//!
//! Row-major `f32` throughout, shaped to the decoder's needs: vector ×
//! matrix products (the hot path — one token at a time), LayerNorm, ReLU,
//! tanh, and a numerically-stable softmax.  No external BLAS: the matvecs
//! are cache-tiled over **four matrix rows per pass** on top of the
//! contiguous axpy/dot forms the compiler already vectorizes — `y` (for
//! [`matvec`]) or `x` (for [`matvec_t`]) is streamed once per four rows
//! instead of once per row, and the four independent accumulator chains
//! give the superscalar units something to overlap.  The per-element op
//! sequence is **exactly** the naive forms' (row 0 first, same zero
//! skips), so results are bit-identical to [`matvec_naive`] /
//! [`matvec_t_naive`] in every case — non-finite weights and the sign
//! of zero included — which keeps the decode parity suite exact.  The
//! naive forms stay as the reference implementation and the
//! before/after baseline in `benches/serve_throughput.rs`.

/// y = x @ W where `x: [k]`, `w: [k, n]` row-major → `y: [n]`.
///
/// Blocked axpy: when all four of a block's `x` taps are nonzero (the
/// common dense case — layernormed activations), four rows of `w`
/// accumulate into `y` per pass, so each `y[j]` is loaded/stored once
/// per four input elements.  Blocks with any zero tap (ReLU outputs on
/// the FFN path are ~half zeros) fall back to the naive row-at-a-time
/// form with its per-row zero skip — so the op sequence per `y[j]` is
/// **exactly** [`matvec_naive`]'s in every case, including non-finite
/// weights and the sign of zero.
pub fn matvec(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), k * n, "matvec shape mismatch");
    debug_assert_eq!(y.len(), n);
    y.fill(0.0);
    let y = &mut y[..n];
    let blocks = k / 4 * 4;
    let mut i = 0;
    while i < blocks {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
            let r0 = &w[i * n..(i + 1) * n];
            let r1 = &w[(i + 1) * n..(i + 2) * n];
            let r2 = &w[(i + 2) * n..(i + 3) * n];
            let r3 = &w[(i + 3) * n..(i + 4) * n];
            for j in 0..n {
                // Left-to-right adds match the naive row-at-a-time order.
                y[j] = y[j] + x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
            }
        } else {
            for ii in i..i + 4 {
                let xi = x[ii];
                if xi == 0.0 {
                    continue;
                }
                let row = &w[ii * n..(ii + 1) * n];
                for (yj, &wij) in y.iter_mut().zip(row) {
                    *yj += xi * wij;
                }
            }
        }
        i += 4;
    }
    for i in blocks..k {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for (yj, &wij) in y.iter_mut().zip(row) {
            *yj += xi * wij;
        }
    }
}

/// Reference (unblocked) [`matvec`]: one row of `w` per pass.
pub fn matvec_naive(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), k * n, "matvec shape mismatch");
    debug_assert_eq!(y.len(), n);
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for (yj, &wij) in y.iter_mut().zip(row) {
            *yj += xi * wij;
        }
    }
    let _ = k;
}

/// y = x @ Wᵀ where `x: [k]`, `w: [n, k]` row-major → `y: [n]`.
/// (Used for the tied-embedding logit projection `h @ Eᵀ` — at small D
/// the single most expensive op per generated token.)
///
/// Blocked dots: four output rows share one streaming pass over `x`,
/// with four independent accumulators (each summed in the same order as
/// [`matvec_t_naive`], so outputs are bit-identical).
pub fn matvec_t(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), n * k, "matvec_t shape mismatch");
    debug_assert_eq!(y.len(), n);
    let blocks = n / 4 * 4;
    let mut j = 0;
    while j < blocks {
        let r0 = &w[j * k..(j + 1) * k];
        let r1 = &w[(j + 1) * k..(j + 2) * k];
        let r2 = &w[(j + 2) * k..(j + 3) * k];
        let r3 = &w[(j + 3) * k..(j + 4) * k];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (i, &xi) in x.iter().enumerate() {
            a0 += xi * r0[i];
            a1 += xi * r1[i];
            a2 += xi * r2[i];
            a3 += xi * r3[i];
        }
        y[j] = a0;
        y[j + 1] = a1;
        y[j + 2] = a2;
        y[j + 3] = a3;
        j += 4;
    }
    for j in blocks..n {
        let row = &w[j * k..(j + 1) * k];
        let mut acc = 0.0f32;
        for (xi, wji) in x.iter().zip(row) {
            acc += xi * wji;
        }
        y[j] = acc;
    }
}

/// Reference (unblocked) [`matvec_t`]: one dot product per output row.
pub fn matvec_t_naive(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), n * k, "matvec_t shape mismatch");
    for j in 0..n {
        let row = &w[j * k..(j + 1) * k];
        let mut acc = 0.0f32;
        for (xi, wji) in x.iter().zip(row) {
            acc += xi * wji;
        }
        y[j] = acc;
    }
}

/// In-place y += x.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += b;
    }
}

/// LayerNorm with learned gain/bias (eps matches the L2 model's 1e-5).
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}

pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn tanh_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Numerically-stable in-place softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_small() {
        // x: [2], W: [2, 3] = [[1,2,3],[4,5,6]] → y = [1*1+2*4, 1*2+2*5, 1*3+2*6]
        let x = [1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut y = [0.0; 3];
        matvec(&x, &w, 3, &mut y);
        assert_eq!(y, [9.0, 12.0, 15.0]);
    }

    #[test]
    fn blocked_matches_naive_bit_for_bit() {
        // Odd k and n exercise both the 4-wide blocks and the remainders;
        // a sprinkled zero exercises the sparsity skip.
        let (k, n) = (13, 11);
        let x: Vec<f32> = (0..k)
            .map(|i| if i % 5 == 2 { 0.0 } else { 0.37 * (i as f32) - 1.9 })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|i| 0.11 * ((i * 7 % 23) as f32) - 1.2).collect();
        let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
        matvec(&x, &w, n, &mut fast);
        matvec_naive(&x, &w, n, &mut slow);
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(a.to_bits(), b.to_bits(), "matvec diverged from reference");
        }

        let wt: Vec<f32> = (0..n * k).map(|i| 0.09 * ((i * 5 % 19) as f32) - 0.8).collect();
        matvec_t(&x, &wt, n, &mut fast);
        matvec_t_naive(&x, &wt, n, &mut slow);
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(a.to_bits(), b.to_bits(), "matvec_t diverged from reference");
        }
    }

    #[test]
    fn matvec_t_is_transpose_of_matvec() {
        let x = [0.5, -1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3] as n=2, k=3 for matvec_t
        let mut yt = [0.0; 2];
        matvec_t(&x, &w, 2, &mut yt);
        // row0 · x = 1*0.5 + 2*-1 + 3*2 = 4.5 ; row1 · x = 4*0.5 + 5*-1 + 6*2 = 9
        assert_eq!(yt, [4.5, 9.0]);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let g = [1.0; 4];
        let b = [0.0; 4];
        let mut out = [0.0; 4];
        layer_norm(&x, &g, &b, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = [1000.0, 1001.0, 999.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn relu_and_tanh() {
        let mut x = [-1.0, 0.5];
        relu_inplace(&mut x);
        assert_eq!(x, [0.0, 0.5]);
        let mut y = [0.0f32, 100.0];
        tanh_inplace(&mut y);
        assert!((y[0]).abs() < 1e-7 && (y[1] - 1.0).abs() < 1e-5);
    }
}
