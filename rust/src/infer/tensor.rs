//! Minimal dense-math substrate for the native inference engine.
//!
//! Row-major `f32` throughout, shaped to the decoder's needs: vector ×
//! matrix products (the single-token hot path), their batched m-row
//! forms ([`matmul`] / [`matmul_t`] — the fused speculative-verify
//! pass), LayerNorm, ReLU, tanh, and a numerically-stable softmax.  No
//! external BLAS; instead a layered kernel stack where every tier is
//! bit-identical to the one below it:
//!
//! 1. **naive** ([`matvec_naive`], [`matvec_t_naive`], [`matmul_naive`],
//!    [`matmul_t_naive`]) — one matrix row per pass.  The semantic
//!    reference: per-element op order, the `x == 0.0` row skip, and
//!    non-finite / signed-zero behaviour are all *defined* by these.
//! 2. **blocked** ([`matvec_blocked`], [`matvec_t_blocked`],
//!    [`matmul_blocked`], [`matmul_t_blocked`]) — cache-tiled over four
//!    matrix rows per pass with the per-element op sequence kept
//!    **exactly** the naive forms' (row 0 first, same zero skips), so
//!    results are bit-identical in every case.  This is the default
//!    backend and the byte-parity reference for tier 3.
//! 3. **simd** (the [`simd`] module, behind the `simd` cargo feature) —
//!    explicit `std::arch` AVX2 kernels on x86_64 with a portable
//!    fixed-width-chunk fallback, selected by **runtime CPU-feature
//!    dispatch**.  Vectorization only ever runs *independent*
//!    accumulation chains in parallel lanes (across output columns for
//!    [`matvec`], across output rows for [`matvec_t`]) and never uses
//!    FMA, so no sum is reassociated and no rounding changes: results
//!    stay bit-identical to tiers 1–2, which keeps the decode parity
//!    suites exact with the feature on or off.
//!
//! 4. **int8** ([`matvec_q`] / [`matvec_t_q`] / [`matmul_q`] /
//!    [`matmul_t_q`], each with naive / blocked / AVX2 variants) — the
//!    quantized-weight hot path.  Weights are stored **out-major**
//!    (`[n, k]`: one `i8` row plus one `f32` scale per output) by
//!    [`quantize_row`]; activations are quantized on the fly by the
//!    same function.  Every variant accumulates in exact `i32` and
//!    converts to `f32` through one shared `(sum as f32) * (sx * sw)`
//!    expression, and integer sums are order-free, so all int8
//!    variants are bit-identical *by construction* — including the
//!    AVX2 `_mm256_maddubs_epi16` unsigned·signed form, which is exact
//!    because [`quantize_row`] never emits −128 (pair sums stay below
//!    `i16::MAX` and `|a|`/`sign` never overflow).
//!
//! 5. **int4** ([`matvec_q4`] / [`matvec_t_q4`] / [`matmul_q4`] /
//!    [`matmul_t_q4`], each with naive / blocked / AVX2 variants) —
//!    group-wise 4-bit weights: [`quantize_row_q4`] packs two values
//!    per byte (even element in the low nibble) with one `f32` scale
//!    per [`Q4_GROUP`] (= 32) elements.  Activations stay int8 (the
//!    same [`quantize_row`] as tier 4).  Each group's integer dot is
//!    an exact order-free i32 sum, but the per-group sums cross into
//!    f32 one at a time, so the **ascending-group f32 accumulation
//!    order** through the shared `scale_out` expression is part of the
//!    contract every variant reproduces — naive, blocked and AVX2 stay
//!    bit-identical by construction.  One 32-element group is exactly
//!    one 16-byte packed lane-load in the AVX2 kernel; the maddubs
//!    pair sums stay ≤ 2·127·7 = 1778, far from i16 saturation.
//!
//! The public [`matvec`] / [`matvec_t`] / [`matmul`] / [`matmul_t`]
//! entry points resolve to tier 3 when the `simd` feature is enabled
//! (falling back per the runtime dispatch) and tier 2 otherwise; the
//! `*_q` / `*_q4` entry points dispatch the same way within tiers 4–5.
//! `rust/tests/tensor_props.rs` fuzzes every tier against the naive
//! references, including NaN, ±0.0 and subnormal inputs for f32 and
//! extreme-scale / saturated / degenerate shapes for int8 and int4.

/// y = x @ W where `x: [k]`, `w: [k, n]` row-major → `y: [n]`.
///
/// Dispatch: the SIMD tier when the `simd` feature is on, the scalar
/// blocked tier otherwise — bit-identical either way.
pub fn matvec(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    #[cfg(feature = "simd")]
    {
        simd::matvec(x, w, n, y);
    }
    #[cfg(not(feature = "simd"))]
    {
        matvec_blocked(x, w, n, y);
    }
}

/// y = x @ Wᵀ where `x: [k]`, `w: [n, k]` row-major → `y: [n]`.
/// (Used for the tied-embedding logit projection `h @ Eᵀ` — at small D
/// the single most expensive op per generated token.)
///
/// Dispatch: the SIMD tier when the `simd` feature is on, the scalar
/// blocked tier otherwise — bit-identical either way.
pub fn matvec_t(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    #[cfg(feature = "simd")]
    {
        simd::matvec_t(x, w, n, y);
    }
    #[cfg(not(feature = "simd"))]
    {
        matvec_t_blocked(x, w, n, y);
    }
}

/// ys = Xs @ W where `xs: [m, k]` (m activation rows), `w: [k, n]`
/// row-major → `ys: [m, n]`.  Row r of `ys` is bit-identical to
/// `matvec(&xs[r*k..], w, n, ..)` — the batch is a pure re-grouping
/// that streams `w` through cache **once** for all m rows instead of
/// once per row (the fused speculative-verify win: m = draft block
/// + 1).
pub fn matmul(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    #[cfg(feature = "simd")]
    {
        simd::matmul(xs, m, w, n, ys);
    }
    #[cfg(not(feature = "simd"))]
    {
        matmul_blocked(xs, m, w, n, ys);
    }
}

/// ys = Xs @ Wᵀ where `xs: [m, k]`, `w: [n, k]` row-major →
/// `ys: [m, n]`.  Row r of `ys` is bit-identical to
/// `matvec_t(&xs[r*k..], w, n, ..)`; each 4- (blocked) or 8-row (simd)
/// block of `w` stays hot in cache across all m activation rows.
pub fn matmul_t(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    #[cfg(feature = "simd")]
    {
        simd::matmul_t(xs, m, w, n, ys);
    }
    #[cfg(not(feature = "simd"))]
    {
        matmul_t_blocked(xs, m, w, n, ys);
    }
}

/// Which kernel backend the public entry points resolve to on this
/// machine: `"scalar"` (no `simd` feature), `"avx2"`, or `"portable"`
/// (the chunked fallback).  Benches record it next to their timings.
pub fn kernel_backend() -> &'static str {
    #[cfg(feature = "simd")]
    {
        simd::backend()
    }
    #[cfg(not(feature = "simd"))]
    {
        "scalar"
    }
}

// ---------------------------------------------------------------------------
// Tier 2: scalar blocked kernels (the default backend and the simd
// tier's byte-parity reference)
// ---------------------------------------------------------------------------

/// Blocked-axpy [`matvec`]: when all four of a block's `x` taps are
/// nonzero (the common dense case — layernormed activations), four rows
/// of `w` accumulate into `y` per pass, so each `y[j]` is loaded/stored
/// once per four input elements.  Blocks with any zero tap (ReLU
/// outputs on the FFN path are ~half zeros) fall back to the naive
/// row-at-a-time form with its per-row zero skip — so the op sequence
/// per `y[j]` is **exactly** [`matvec_naive`]'s in every case,
/// including non-finite weights and the sign of zero.
pub fn matvec_blocked(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), k * n, "matvec shape mismatch");
    debug_assert_eq!(y.len(), n);
    y.fill(0.0);
    let y = &mut y[..n];
    let blocks = k / 4 * 4;
    let mut i = 0;
    while i < blocks {
        let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
        if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
            let r0 = &w[i * n..(i + 1) * n];
            let r1 = &w[(i + 1) * n..(i + 2) * n];
            let r2 = &w[(i + 2) * n..(i + 3) * n];
            let r3 = &w[(i + 3) * n..(i + 4) * n];
            for j in 0..n {
                // Left-to-right adds match the naive row-at-a-time order.
                y[j] = y[j] + x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
            }
        } else {
            for ii in i..i + 4 {
                let xi = x[ii];
                if xi == 0.0 {
                    continue;
                }
                let row = &w[ii * n..(ii + 1) * n];
                for (yj, &wij) in y.iter_mut().zip(row) {
                    *yj += xi * wij;
                }
            }
        }
        i += 4;
    }
    for i in blocks..k {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for (yj, &wij) in y.iter_mut().zip(row) {
            *yj += xi * wij;
        }
    }
}

/// Blocked-dot [`matvec_t`]: four output rows share one streaming pass
/// over `x`, with four independent accumulators (each summed in the
/// same order as [`matvec_t_naive`], so outputs are bit-identical).
pub fn matvec_t_blocked(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), n * k, "matvec_t shape mismatch");
    debug_assert_eq!(y.len(), n);
    let blocks = n / 4 * 4;
    let mut j = 0;
    while j < blocks {
        let r0 = &w[j * k..(j + 1) * k];
        let r1 = &w[(j + 1) * k..(j + 2) * k];
        let r2 = &w[(j + 2) * k..(j + 3) * k];
        let r3 = &w[(j + 3) * k..(j + 4) * k];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (i, &xi) in x.iter().enumerate() {
            a0 += xi * r0[i];
            a1 += xi * r1[i];
            a2 += xi * r2[i];
            a3 += xi * r3[i];
        }
        y[j] = a0;
        y[j + 1] = a1;
        y[j + 2] = a2;
        y[j + 3] = a3;
        j += 4;
    }
    for j in blocks..n {
        let row = &w[j * k..(j + 1) * k];
        let mut acc = 0.0f32;
        for (xi, wji) in x.iter().zip(row) {
            acc += xi * wji;
        }
        y[j] = acc;
    }
}

/// Blocked [`matmul`]: the i-block loop runs **outermost** and the
/// activation-row loop inside it, so each four-row slab of `w` is
/// loaded once for all m rows.  Per row the i-blocks arrive in the same
/// order (with the same all-nonzero-taps check and zero skips) as
/// [`matvec_blocked`], so every output row is bit-identical to the
/// single-row call.
pub fn matmul_blocked(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
    debug_assert!(m > 0);
    debug_assert_eq!(xs.len() % m, 0, "matmul activation shape mismatch");
    let k = xs.len() / m;
    debug_assert_eq!(w.len(), k * n, "matmul shape mismatch");
    debug_assert_eq!(ys.len(), m * n);
    ys.fill(0.0);
    let blocks = k / 4 * 4;
    let mut i = 0;
    while i < blocks {
        for r in 0..m {
            let x = &xs[r * k..(r + 1) * k];
            let y = &mut ys[r * n..(r + 1) * n];
            let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                let r0 = &w[i * n..(i + 1) * n];
                let r1 = &w[(i + 1) * n..(i + 2) * n];
                let r2 = &w[(i + 2) * n..(i + 3) * n];
                let r3 = &w[(i + 3) * n..(i + 4) * n];
                for j in 0..n {
                    y[j] = y[j] + x0 * r0[j] + x1 * r1[j] + x2 * r2[j] + x3 * r3[j];
                }
            } else {
                for ii in i..i + 4 {
                    let xi = x[ii];
                    if xi == 0.0 {
                        continue;
                    }
                    let row = &w[ii * n..(ii + 1) * n];
                    for (yj, &wij) in y.iter_mut().zip(row) {
                        *yj += xi * wij;
                    }
                }
            }
        }
        i += 4;
    }
    for i in blocks..k {
        let row = &w[i * n..(i + 1) * n];
        for r in 0..m {
            let xi = xs[r * k + i];
            if xi == 0.0 {
                continue;
            }
            let y = &mut ys[r * n..(r + 1) * n];
            for (yj, &wij) in y.iter_mut().zip(row) {
                *yj += xi * wij;
            }
        }
    }
}

/// Blocked [`matmul_t`]: the output-row (j) block loop runs outermost
/// and the activation-row loop inside it, so each four-row slab of `w`
/// stays hot across all m rows.  Per activation row the j-blocks and
/// their accumulation order match [`matvec_t_blocked`] exactly.
pub fn matmul_t_blocked(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
    debug_assert!(m > 0);
    debug_assert_eq!(xs.len() % m, 0, "matmul_t activation shape mismatch");
    let k = xs.len() / m;
    debug_assert_eq!(w.len(), n * k, "matmul_t shape mismatch");
    debug_assert_eq!(ys.len(), m * n);
    let blocks = n / 4 * 4;
    let mut j = 0;
    while j < blocks {
        let r0 = &w[j * k..(j + 1) * k];
        let r1 = &w[(j + 1) * k..(j + 2) * k];
        let r2 = &w[(j + 2) * k..(j + 3) * k];
        let r3 = &w[(j + 3) * k..(j + 4) * k];
        for r in 0..m {
            let x = &xs[r * k..(r + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (i, &xi) in x.iter().enumerate() {
                a0 += xi * r0[i];
                a1 += xi * r1[i];
                a2 += xi * r2[i];
                a3 += xi * r3[i];
            }
            let y = &mut ys[r * n..(r + 1) * n];
            y[j] = a0;
            y[j + 1] = a1;
            y[j + 2] = a2;
            y[j + 3] = a3;
        }
        j += 4;
    }
    for j in blocks..n {
        let row = &w[j * k..(j + 1) * k];
        for r in 0..m {
            let x = &xs[r * k..(r + 1) * k];
            let mut acc = 0.0f32;
            for (xi, wji) in x.iter().zip(row) {
                acc += xi * wji;
            }
            ys[r * n + j] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Tier 1: naive reference kernels
// ---------------------------------------------------------------------------

/// Reference (unblocked) [`matvec`]: one row of `w` per pass, skipping
/// rows whose `x` tap is zero.  Defines the op order every faster tier
/// must reproduce bit-for-bit.
pub fn matvec_naive(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), k * n, "matvec shape mismatch");
    debug_assert_eq!(y.len(), n);
    y.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for (yj, &wij) in y.iter_mut().zip(row) {
            *yj += xi * wij;
        }
    }
}

/// Reference (unblocked) [`matvec_t`]: one dot product per output row.
pub fn matvec_t_naive(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    let k = x.len();
    debug_assert_eq!(w.len(), n * k, "matvec_t shape mismatch");
    for j in 0..n {
        let row = &w[j * k..(j + 1) * k];
        let mut acc = 0.0f32;
        for (xi, wji) in x.iter().zip(row) {
            acc += xi * wji;
        }
        y[j] = acc;
    }
}

/// Reference [`matmul`]: m independent [`matvec_naive`] calls.
pub fn matmul_naive(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    let k = xs.len() / m;
    for r in 0..m {
        matvec_naive(&xs[r * k..(r + 1) * k], w, n, &mut ys[r * n..(r + 1) * n]);
    }
}

/// Reference [`matmul_t`]: m independent [`matvec_t_naive`] calls.
pub fn matmul_t_naive(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    let k = xs.len() / m;
    for r in 0..m {
        matvec_t_naive(&xs[r * k..(r + 1) * k], w, n, &mut ys[r * n..(r + 1) * n]);
    }
}

// ---------------------------------------------------------------------------
// Tier 4: int8 quantized kernels (out-major weights, per-row scales)
// ---------------------------------------------------------------------------

/// Quantize one f32 row to int8 with a symmetric per-row scale:
/// `q[i] = round(x[i] · 127 / max|x|)` and the returned scale is
/// `max|x| / 127` (so `x ≈ q · scale`).  Quantized values land in
/// `[-127, 127]` — **never −128**, which the AVX2 unsigned·signed
/// multiply-add trick requires for exactness.  An all-zero row (or one
/// whose max is non-finite) quantizes to zeros with scale 0; NaN
/// entries under a finite max quantize to 0 (`as` casts saturate and
/// map NaN to 0).  Pure scalar code shared by weight-load-time and
/// on-the-fly activation quantization, so quantized inputs are
/// identical no matter which backend runs the kernels.
pub fn quantize_row(x: &[f32], q: &mut [i8]) -> f32 {
    debug_assert_eq!(x.len(), q.len(), "quantize_row shape mismatch");
    let mut maxabs = 0.0f32;
    for &v in x {
        let a = v.abs();
        if a > maxabs {
            maxabs = a;
        }
    }
    if maxabs == 0.0 || !maxabs.is_finite() {
        q.fill(0);
        return 0.0;
    }
    let inv = 127.0 / maxabs;
    for (qi, &v) in q.iter_mut().zip(x) {
        *qi = (v * inv).round() as i8;
    }
    maxabs / 127.0
}

/// The one place an int8 integer sum turns back into f32.  Every tier
/// uses this exact expression — one rounding for `sx * sw`, one for
/// the final product — so tier outputs are bit-identical as long as
/// their integer sums agree (which exact i32 accumulation guarantees).
#[inline]
pub(crate) fn scale_out(sum: i32, sx: f32, sw: f32) -> f32 {
    (sum as f32) * (sx * sw)
}

/// Exact i32 dot product of two int8 rows, ascending-index order.
#[inline]
fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    let mut sum = 0i32;
    for (&ai, &bi) in a.iter().zip(b) {
        sum += ai as i32 * bi as i32;
    }
    sum
}

/// Quantized [`matvec`]: `y = x @ W` where the logical `w: [k, n]` was
/// quantized **transposed** into out-major rows (`wq: [n, k]` int8,
/// `scales: [n]`), and the activation arrives pre-quantized (`qx: [k]`
/// with scale `sx`, from [`quantize_row`]).  `y[j] = (Σᵢ qx[i]·wq[j,i])
/// · sx · scales[j]`.  Out-major storage makes this the same row-dot
/// core as [`matvec_t_q`]; the two names document the *logical*
/// orientation at the call site.
pub fn matvec_q(qx: &[i8], sx: f32, wq: &[i8], scales: &[f32], y: &mut [f32]) {
    #[cfg(feature = "simd")]
    {
        simd::matvec_q(qx, sx, wq, scales, y);
    }
    #[cfg(not(feature = "simd"))]
    {
        matvec_q_blocked(qx, sx, wq, scales, y);
    }
}

/// Quantized [`matvec_t`]: `y = x @ Wᵀ` with `w: [n, k]` already
/// out-major — identical storage and kernel as [`matvec_q`] (the
/// quantized representation is always out-major, so the transposed
/// entry point is the same dot-product core).
pub fn matvec_t_q(qx: &[i8], sx: f32, wq: &[i8], scales: &[f32], y: &mut [f32]) {
    matvec_q(qx, sx, wq, scales, y);
}

/// Quantized [`matmul`]: m pre-quantized activation rows (`qxs: [m, k]`
/// with per-row scales `sxs: [m]`) against one out-major quantized
/// matrix.  Row r of `ys` is bit-identical to
/// `matvec_q(&qxs[r*k..], sxs[r], ..)`; each weight row streams through
/// cache once for all m activation rows.
pub fn matmul_q(qxs: &[i8], m: usize, sxs: &[f32], wq: &[i8], scales: &[f32], ys: &mut [f32]) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    #[cfg(feature = "simd")]
    {
        simd::matmul_q(qxs, m, sxs, wq, scales, ys);
    }
    #[cfg(not(feature = "simd"))]
    {
        matmul_q_blocked(qxs, m, sxs, wq, scales, ys);
    }
}

/// Quantized [`matmul_t`] — same storage and kernel as [`matmul_q`]
/// (see [`matvec_t_q`]).
pub fn matmul_t_q(qxs: &[i8], m: usize, sxs: &[f32], wq: &[i8], scales: &[f32], ys: &mut [f32]) {
    matmul_q(qxs, m, sxs, wq, scales, ys);
}

/// Reference int8 kernel: one row-dot per output, ascending order.
/// Because every tier accumulates the same exact i32 sum, this defines
/// the (unique) answer rather than an op order the others must mimic.
pub fn matvec_q_naive(qx: &[i8], sx: f32, wq: &[i8], scales: &[f32], y: &mut [f32]) {
    let k = qx.len();
    let n = scales.len();
    debug_assert_eq!(wq.len(), n * k, "matvec_q shape mismatch");
    debug_assert_eq!(y.len(), n);
    for j in 0..n {
        let row = &wq[j * k..(j + 1) * k];
        y[j] = scale_out(dot_i8_scalar(qx, row), sx, scales[j]);
    }
}

/// Blocked int8 kernel: four output rows share one streaming pass over
/// the quantized activation, with four independent i32 accumulators.
pub fn matvec_q_blocked(qx: &[i8], sx: f32, wq: &[i8], scales: &[f32], y: &mut [f32]) {
    let k = qx.len();
    let n = scales.len();
    debug_assert_eq!(wq.len(), n * k, "matvec_q shape mismatch");
    debug_assert_eq!(y.len(), n);
    let blocks = n / 4 * 4;
    let mut j = 0;
    while j < blocks {
        let r0 = &wq[j * k..(j + 1) * k];
        let r1 = &wq[(j + 1) * k..(j + 2) * k];
        let r2 = &wq[(j + 2) * k..(j + 3) * k];
        let r3 = &wq[(j + 3) * k..(j + 4) * k];
        let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
        for (i, &xi) in qx.iter().enumerate() {
            let xi = xi as i32;
            a0 += xi * r0[i] as i32;
            a1 += xi * r1[i] as i32;
            a2 += xi * r2[i] as i32;
            a3 += xi * r3[i] as i32;
        }
        y[j] = scale_out(a0, sx, scales[j]);
        y[j + 1] = scale_out(a1, sx, scales[j + 1]);
        y[j + 2] = scale_out(a2, sx, scales[j + 2]);
        y[j + 3] = scale_out(a3, sx, scales[j + 3]);
        j += 4;
    }
    for j in blocks..n {
        let row = &wq[j * k..(j + 1) * k];
        y[j] = scale_out(dot_i8_scalar(qx, row), sx, scales[j]);
    }
}

/// Reference batched int8 kernel: m independent [`matvec_q_naive`]s.
pub fn matmul_q_naive(
    qxs: &[i8],
    m: usize,
    sxs: &[f32],
    wq: &[i8],
    scales: &[f32],
    ys: &mut [f32],
) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    debug_assert_eq!(sxs.len(), m);
    let k = qxs.len() / m;
    let n = scales.len();
    for r in 0..m {
        matvec_q_naive(&qxs[r * k..(r + 1) * k], sxs[r], wq, scales, &mut ys[r * n..(r + 1) * n]);
    }
}

/// Blocked batched int8 kernel: output-row blocks outermost so each
/// four-row weight slab stays hot across all m activation rows.
pub fn matmul_q_blocked(
    qxs: &[i8],
    m: usize,
    sxs: &[f32],
    wq: &[i8],
    scales: &[f32],
    ys: &mut [f32],
) {
    debug_assert!(m > 0);
    debug_assert_eq!(qxs.len() % m, 0, "matmul_q activation shape mismatch");
    debug_assert_eq!(sxs.len(), m);
    let k = qxs.len() / m;
    let n = scales.len();
    debug_assert_eq!(wq.len(), n * k, "matmul_q shape mismatch");
    debug_assert_eq!(ys.len(), m * n);
    let blocks = n / 4 * 4;
    let mut j = 0;
    while j < blocks {
        let r0 = &wq[j * k..(j + 1) * k];
        let r1 = &wq[(j + 1) * k..(j + 2) * k];
        let r2 = &wq[(j + 2) * k..(j + 3) * k];
        let r3 = &wq[(j + 3) * k..(j + 4) * k];
        for r in 0..m {
            let qx = &qxs[r * k..(r + 1) * k];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for (i, &xi) in qx.iter().enumerate() {
                let xi = xi as i32;
                a0 += xi * r0[i] as i32;
                a1 += xi * r1[i] as i32;
                a2 += xi * r2[i] as i32;
                a3 += xi * r3[i] as i32;
            }
            let sx = sxs[r];
            let y = &mut ys[r * n..(r + 1) * n];
            y[j] = scale_out(a0, sx, scales[j]);
            y[j + 1] = scale_out(a1, sx, scales[j + 1]);
            y[j + 2] = scale_out(a2, sx, scales[j + 2]);
            y[j + 3] = scale_out(a3, sx, scales[j + 3]);
        }
        j += 4;
    }
    for j in blocks..n {
        let row = &wq[j * k..(j + 1) * k];
        for r in 0..m {
            let qx = &qxs[r * k..(r + 1) * k];
            ys[r * n + j] = scale_out(dot_i8_scalar(qx, row), sxs[r], scales[j]);
        }
    }
}

// ---------------------------------------------------------------------------
// Tier 5: int4 group-wise quantized kernels (out-major nibble-packed
// weights, per-group scales)
// ---------------------------------------------------------------------------

/// Elements per int4 quantization group: one f32 scale per 32 weights.
/// 32 packs to 16 bytes — exactly one 128-bit lane-load (32 sign-
/// extended i8 lanes) per group in the AVX2 kernel.
pub const Q4_GROUP: usize = 32;

/// Packed bytes per int4 row of `k` elements (two nibbles per byte).
#[inline]
pub fn q4_row_bytes(k: usize) -> usize {
    k.div_ceil(2)
}

/// Scale groups per int4 row of `k` elements.
#[inline]
pub fn q4_row_groups(k: usize) -> usize {
    k.div_ceil(Q4_GROUP)
}

/// Sign-extended int4 element `i` of a packed row: even elements live
/// in the low nibble, odd elements in the high nibble of byte `i / 2`.
#[inline]
pub(crate) fn q4_get(row: &[u8], i: usize) -> i32 {
    let b = row[i / 2];
    if i % 2 == 0 {
        (((b << 4) as i8) >> 4) as i32
    } else {
        ((b as i8) >> 4) as i32
    }
}

/// Quantize one f32 row to packed int4 with symmetric per-group scales
/// ([`Q4_GROUP`] elements per group): within each group,
/// `q[i] = round(x[i] · 7 / max|group|)` packed two to a byte (even
/// element in the low nibble) and the group's scale is `max|group| / 7`
/// (so `x ≈ q · scale` groupwise).  Quantized values land in `[-7, 7]`
/// — **never −8**.  An all-zero group (or one whose max is non-finite)
/// quantizes to zero nibbles with scale 0; NaN entries under a finite
/// max quantize to 0 — the same degenerate contract as
/// [`quantize_row`], applied per group.
pub fn quantize_row_q4(x: &[f32], q: &mut [u8], scales: &mut [f32]) {
    debug_assert_eq!(q.len(), q4_row_bytes(x.len()), "quantize_row_q4 byte shape mismatch");
    debug_assert_eq!(scales.len(), q4_row_groups(x.len()), "quantize_row_q4 scale shape mismatch");
    q.fill(0);
    for (g, sg) in scales.iter_mut().enumerate() {
        let lo = g * Q4_GROUP;
        let group = &x[lo..(lo + Q4_GROUP).min(x.len())];
        let mut maxabs = 0.0f32;
        for &v in group {
            let a = v.abs();
            if a > maxabs {
                maxabs = a;
            }
        }
        if maxabs == 0.0 || !maxabs.is_finite() {
            *sg = 0.0;
            continue;
        }
        let inv = 7.0 / maxabs;
        for (j, &v) in group.iter().enumerate() {
            let i = lo + j;
            let nib = ((v * inv).round() as i8 as u8) & 0x0F;
            q[i / 2] |= if i % 2 == 0 { nib } else { nib << 4 };
        }
        *sg = maxabs / 7.0;
    }
}

/// One output element of the int4 kernel: an exact i32 dot per scale
/// group, folded into f32 in ascending-group order through
/// [`scale_out`].  This is the semantic core every tier shares.
#[inline]
fn q4_dot_scalar(qx: &[i8], row: &[u8], srow: &[f32], sx: f32) -> f32 {
    let k = qx.len();
    let mut acc = 0.0f32;
    for (g, &sw) in srow.iter().enumerate() {
        let lo = g * Q4_GROUP;
        let hi = (lo + Q4_GROUP).min(k);
        let mut sum = 0i32;
        for i in lo..hi {
            sum += qx[i] as i32 * q4_get(row, i);
        }
        acc += scale_out(sum, sx, sw);
    }
    acc
}

/// Int4 [`matvec`]: `y = x @ W` with the logical `w: [k, n]` quantized
/// **transposed** into out-major packed rows (`wq: [n, ⌈k/2⌉]` bytes,
/// `scales: [n, ⌈k/32⌉]`), the activation pre-quantized to int8
/// (`qx: [k]`, scale `sx`, from [`quantize_row`]).  As with the int8
/// tier, out-major storage makes this the same row-dot core as
/// [`matvec_t_q4`].
pub fn matvec_q4(qx: &[i8], sx: f32, wq: &[u8], scales: &[f32], y: &mut [f32]) {
    #[cfg(feature = "simd")]
    {
        simd::matvec_q4(qx, sx, wq, scales, y);
    }
    #[cfg(not(feature = "simd"))]
    {
        matvec_q4_blocked(qx, sx, wq, scales, y);
    }
}

/// Int4 [`matvec_t`] — identical storage and kernel as [`matvec_q4`]
/// (the quantized representation is always out-major).
pub fn matvec_t_q4(qx: &[i8], sx: f32, wq: &[u8], scales: &[f32], y: &mut [f32]) {
    matvec_q4(qx, sx, wq, scales, y);
}

/// Int4 [`matmul`]: m pre-quantized int8 activation rows against one
/// out-major packed int4 matrix.  Row r of `ys` is bit-identical to
/// `matvec_q4(&qxs[r*k..], sxs[r], ..)`.
pub fn matmul_q4(qxs: &[i8], m: usize, sxs: &[f32], wq: &[u8], scales: &[f32], ys: &mut [f32]) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    #[cfg(feature = "simd")]
    {
        simd::matmul_q4(qxs, m, sxs, wq, scales, ys);
    }
    #[cfg(not(feature = "simd"))]
    {
        matmul_q4_blocked(qxs, m, sxs, wq, scales, ys);
    }
}

/// Int4 [`matmul_t`] — same storage and kernel as [`matmul_q4`].
pub fn matmul_t_q4(qxs: &[i8], m: usize, sxs: &[f32], wq: &[u8], scales: &[f32], ys: &mut [f32]) {
    matmul_q4(qxs, m, sxs, wq, scales, ys);
}

/// Reference int4 kernel: one [`q4_dot_scalar`] per output row.  The
/// ascending-group f32 accumulation order it uses *defines* the tier's
/// answer; blocked and AVX2 variants reproduce it exactly.
pub fn matvec_q4_naive(qx: &[i8], sx: f32, wq: &[u8], scales: &[f32], y: &mut [f32]) {
    let k = qx.len();
    let kb = q4_row_bytes(k);
    let groups = q4_row_groups(k);
    let n = y.len();
    debug_assert_eq!(wq.len(), n * kb, "matvec_q4 byte shape mismatch");
    debug_assert_eq!(scales.len(), n * groups, "matvec_q4 scale shape mismatch");
    for j in 0..n {
        let row = &wq[j * kb..(j + 1) * kb];
        let srow = &scales[j * groups..(j + 1) * groups];
        y[j] = q4_dot_scalar(qx, row, srow, sx);
    }
}

/// Blocked int4 kernel: four output rows share one streaming pass over
/// the quantized activation; within each group the four i32 sums are
/// independent, and each output's f32 chain still folds its groups in
/// ascending order — bit-identical to [`matvec_q4_naive`].
pub fn matvec_q4_blocked(qx: &[i8], sx: f32, wq: &[u8], scales: &[f32], y: &mut [f32]) {
    let k = qx.len();
    let kb = q4_row_bytes(k);
    let groups = q4_row_groups(k);
    let n = y.len();
    debug_assert_eq!(wq.len(), n * kb, "matvec_q4 byte shape mismatch");
    debug_assert_eq!(scales.len(), n * groups, "matvec_q4 scale shape mismatch");
    let blocks = n / 4 * 4;
    let mut j = 0;
    while j < blocks {
        let r0 = &wq[j * kb..(j + 1) * kb];
        let r1 = &wq[(j + 1) * kb..(j + 2) * kb];
        let r2 = &wq[(j + 2) * kb..(j + 3) * kb];
        let r3 = &wq[(j + 3) * kb..(j + 4) * kb];
        let s0 = &scales[j * groups..(j + 1) * groups];
        let s1 = &scales[(j + 1) * groups..(j + 2) * groups];
        let s2 = &scales[(j + 2) * groups..(j + 3) * groups];
        let s3 = &scales[(j + 3) * groups..(j + 4) * groups];
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for g in 0..groups {
            let lo = g * Q4_GROUP;
            let hi = (lo + Q4_GROUP).min(k);
            let (mut t0, mut t1, mut t2, mut t3) = (0i32, 0i32, 0i32, 0i32);
            for i in lo..hi {
                let xi = qx[i] as i32;
                t0 += xi * q4_get(r0, i);
                t1 += xi * q4_get(r1, i);
                t2 += xi * q4_get(r2, i);
                t3 += xi * q4_get(r3, i);
            }
            a0 += scale_out(t0, sx, s0[g]);
            a1 += scale_out(t1, sx, s1[g]);
            a2 += scale_out(t2, sx, s2[g]);
            a3 += scale_out(t3, sx, s3[g]);
        }
        y[j] = a0;
        y[j + 1] = a1;
        y[j + 2] = a2;
        y[j + 3] = a3;
        j += 4;
    }
    for j in blocks..n {
        let row = &wq[j * kb..(j + 1) * kb];
        let srow = &scales[j * groups..(j + 1) * groups];
        y[j] = q4_dot_scalar(qx, row, srow, sx);
    }
}

/// Reference batched int4 kernel: m independent [`matvec_q4_naive`]s.
pub fn matmul_q4_naive(
    qxs: &[i8],
    m: usize,
    sxs: &[f32],
    wq: &[u8],
    scales: &[f32],
    ys: &mut [f32],
) {
    if m == 0 {
        debug_assert!(ys.is_empty());
        return;
    }
    debug_assert_eq!(sxs.len(), m);
    debug_assert_eq!(ys.len() % m, 0);
    let k = qxs.len() / m;
    let n = ys.len() / m;
    for r in 0..m {
        matvec_q4_naive(
            &qxs[r * k..(r + 1) * k],
            sxs[r],
            wq,
            scales,
            &mut ys[r * n..(r + 1) * n],
        );
    }
}

/// Blocked batched int4 kernel: output-row blocks outermost so each
/// four-row packed slab stays hot across all m activation rows.
pub fn matmul_q4_blocked(
    qxs: &[i8],
    m: usize,
    sxs: &[f32],
    wq: &[u8],
    scales: &[f32],
    ys: &mut [f32],
) {
    debug_assert!(m > 0);
    debug_assert_eq!(qxs.len() % m, 0, "matmul_q4 activation shape mismatch");
    debug_assert_eq!(sxs.len(), m);
    debug_assert_eq!(ys.len() % m, 0);
    let k = qxs.len() / m;
    let kb = q4_row_bytes(k);
    let groups = q4_row_groups(k);
    let n = ys.len() / m;
    debug_assert_eq!(wq.len(), n * kb, "matmul_q4 byte shape mismatch");
    debug_assert_eq!(scales.len(), n * groups, "matmul_q4 scale shape mismatch");
    let blocks = n / 4 * 4;
    let mut j = 0;
    while j < blocks {
        let r0 = &wq[j * kb..(j + 1) * kb];
        let r1 = &wq[(j + 1) * kb..(j + 2) * kb];
        let r2 = &wq[(j + 2) * kb..(j + 3) * kb];
        let r3 = &wq[(j + 3) * kb..(j + 4) * kb];
        let s0 = &scales[j * groups..(j + 1) * groups];
        let s1 = &scales[(j + 1) * groups..(j + 2) * groups];
        let s2 = &scales[(j + 2) * groups..(j + 3) * groups];
        let s3 = &scales[(j + 3) * groups..(j + 4) * groups];
        for r in 0..m {
            let qx = &qxs[r * k..(r + 1) * k];
            let sx = sxs[r];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for g in 0..groups {
                let lo = g * Q4_GROUP;
                let hi = (lo + Q4_GROUP).min(k);
                let (mut t0, mut t1, mut t2, mut t3) = (0i32, 0i32, 0i32, 0i32);
                for i in lo..hi {
                    let xi = qx[i] as i32;
                    t0 += xi * q4_get(r0, i);
                    t1 += xi * q4_get(r1, i);
                    t2 += xi * q4_get(r2, i);
                    t3 += xi * q4_get(r3, i);
                }
                a0 += scale_out(t0, sx, s0[g]);
                a1 += scale_out(t1, sx, s1[g]);
                a2 += scale_out(t2, sx, s2[g]);
                a3 += scale_out(t3, sx, s3[g]);
            }
            let y = &mut ys[r * n..(r + 1) * n];
            y[j] = a0;
            y[j + 1] = a1;
            y[j + 2] = a2;
            y[j + 3] = a3;
        }
        j += 4;
    }
    for j in blocks..n {
        let row = &wq[j * kb..(j + 1) * kb];
        let srow = &scales[j * groups..(j + 1) * groups];
        for r in 0..m {
            let qx = &qxs[r * k..(r + 1) * k];
            ys[r * n + j] = q4_dot_scalar(qx, row, srow, sxs[r]);
        }
    }
}

// ---------------------------------------------------------------------------
// Tier 3: explicit-SIMD kernels (feature `simd`)
// ---------------------------------------------------------------------------

/// Explicit-SIMD kernel tier: AVX2 `std::arch` intrinsics on x86_64,
/// a portable fixed-width-chunk form elsewhere (or when the CPU lacks
/// AVX2), chosen by **runtime feature detection** on every entry (the
/// `is_x86_feature_detected!` result is cached by std).
///
/// **Bit-exactness strategy.**  The only parallelism used is across
/// *independent* accumulation chains — output columns for `matvec` /
/// `matmul` (each `y[j]` is its own chain), output rows for `matvec_t`
/// / `matmul_t` (eight dot products side by side, each lane summing in
/// ascending-i order).  No sum is ever split across lanes, and FMA is
/// never used (a fused multiply-add rounds once where `mul` + `add`
/// round twice, which would diverge from the scalar reference).  The
/// zero-tap row skip is preserved verbatim, so non-finite weights and
/// signed zeros behave exactly as in tier 1.
#[cfg(feature = "simd")]
pub mod simd {
    /// The backend runtime dispatch resolves to here: `"avx2"` or
    /// `"portable"`.
    pub fn backend() -> &'static str {
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
        "portable"
    }

    pub fn matvec(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
        debug_assert_eq!(w.len(), x.len() * n, "matvec shape mismatch");
        debug_assert_eq!(y.len(), n);
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matvec(x, w, n, y) };
            return;
        }
        portable::matvec(x, w, n, y);
    }

    pub fn matvec_t(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
        debug_assert_eq!(w.len(), n * x.len(), "matvec_t shape mismatch");
        debug_assert_eq!(y.len(), n);
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matmul_t(x, 1, w, n, y) };
            return;
        }
        portable::matvec_t(x, w, n, y);
    }

    pub fn matmul(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
        debug_assert!(m > 0);
        debug_assert_eq!(xs.len() % m, 0, "matmul activation shape mismatch");
        debug_assert_eq!(w.len(), (xs.len() / m) * n, "matmul shape mismatch");
        debug_assert_eq!(ys.len(), m * n);
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matmul(xs, m, w, n, ys) };
            return;
        }
        portable::matmul(xs, m, w, n, ys);
    }

    pub fn matmul_t(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
        debug_assert!(m > 0);
        debug_assert_eq!(xs.len() % m, 0, "matmul_t activation shape mismatch");
        debug_assert_eq!(w.len(), n * (xs.len() / m), "matmul_t shape mismatch");
        debug_assert_eq!(ys.len(), m * n);
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matmul_t(xs, m, w, n, ys) };
            return;
        }
        portable::matmul_t(xs, m, w, n, ys);
    }

    /// Int8 tier-4 dispatch.  The portable fallback is the blocked
    /// scalar kernel itself: integer accumulation is order-free, so
    /// there is no separate chunked form to keep bit-parity with — the
    /// blocked kernel *is* already the autovectorizer-friendly shape.
    pub fn matvec_q(qx: &[i8], sx: f32, wq: &[i8], scales: &[f32], y: &mut [f32]) {
        debug_assert_eq!(wq.len(), scales.len() * qx.len(), "matvec_q shape mismatch");
        debug_assert_eq!(y.len(), scales.len());
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matvec_q(qx, sx, wq, scales, y) };
            return;
        }
        super::matvec_q_blocked(qx, sx, wq, scales, y);
    }

    /// Batched int8 tier-4 dispatch (see [`matvec_q`] on the fallback).
    pub fn matmul_q(qxs: &[i8], m: usize, sxs: &[f32], wq: &[i8], scales: &[f32], ys: &mut [f32]) {
        debug_assert!(m > 0);
        debug_assert_eq!(qxs.len() % m, 0, "matmul_q activation shape mismatch");
        debug_assert_eq!(sxs.len(), m);
        debug_assert_eq!(wq.len(), scales.len() * (qxs.len() / m), "matmul_q shape mismatch");
        debug_assert_eq!(ys.len(), m * scales.len());
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matmul_q(qxs, m, sxs, wq, scales, ys) };
            return;
        }
        super::matmul_q_blocked(qxs, m, sxs, wq, scales, ys);
    }

    /// Int4 tier-5 dispatch.  As with [`matvec_q`], the portable
    /// fallback is the blocked scalar kernel itself: group sums are
    /// exact i32 and the f32 group fold is ascending-order in every
    /// variant, so there is nothing to chunk differently.
    pub fn matvec_q4(qx: &[i8], sx: f32, wq: &[u8], scales: &[f32], y: &mut [f32]) {
        debug_assert_eq!(
            wq.len(),
            y.len() * super::q4_row_bytes(qx.len()),
            "matvec_q4 byte shape mismatch"
        );
        debug_assert_eq!(
            scales.len(),
            y.len() * super::q4_row_groups(qx.len()),
            "matvec_q4 scale shape mismatch"
        );
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matvec_q4(qx, sx, wq, scales, y) };
            return;
        }
        super::matvec_q4_blocked(qx, sx, wq, scales, y);
    }

    /// Batched int4 tier-5 dispatch (see [`matvec_q4`] on the fallback).
    pub fn matmul_q4(qxs: &[i8], m: usize, sxs: &[f32], wq: &[u8], scales: &[f32], ys: &mut [f32]) {
        debug_assert!(m > 0);
        debug_assert_eq!(qxs.len() % m, 0, "matmul_q4 activation shape mismatch");
        debug_assert_eq!(sxs.len(), m);
        debug_assert_eq!(ys.len() % m, 0);
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 availability was just checked.
            unsafe { x86::matmul_q4(qxs, m, sxs, wq, scales, ys) };
            return;
        }
        super::matmul_q4_blocked(qxs, m, sxs, wq, scales, ys);
    }

    /// Portable chunked fallback: the same loop structure as the AVX2
    /// kernels, written over fixed-width `[f32; 8]` lane arrays so any
    /// backend's autovectorizer can lift them — and so the accumulation
    /// order is the scalar reference's whether or not it does.
    mod portable {
        use super::super::{matmul_blocked, matvec_blocked};

        /// The axpy inner loops of the blocked form are already
        /// element-independent (each `y[j]` its own chain), so tier 2
        /// *is* the portable chunked form for `matvec`.
        pub fn matvec(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
            matvec_blocked(x, w, n, y);
        }

        pub fn matmul(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
            matmul_blocked(xs, m, w, n, ys);
        }

        /// Eight output rows per pass, one lane-array slot per row;
        /// each slot accumulates its dot in ascending-i order (the
        /// naive order), so lanes never share a sum.
        pub fn matvec_t(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
            let k = x.len();
            let blocks = n / 8 * 8;
            let mut j = 0;
            while j < blocks {
                let mut acc = [0.0f32; 8];
                for (i, &xi) in x.iter().enumerate() {
                    for (l, a) in acc.iter_mut().enumerate() {
                        *a += xi * w[(j + l) * k + i];
                    }
                }
                y[j..j + 8].copy_from_slice(&acc);
                j += 8;
            }
            for j in blocks..n {
                let row = &w[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (xi, wji) in x.iter().zip(row) {
                    acc += xi * wji;
                }
                y[j] = acc;
            }
        }

        /// [`matvec_t`] with the j-block loop outermost and the
        /// activation-row loop inside, so each eight-row slab of `w`
        /// stays hot across all m rows.
        pub fn matmul_t(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
            let k = xs.len() / m;
            let blocks = n / 8 * 8;
            let mut j = 0;
            while j < blocks {
                for r in 0..m {
                    let x = &xs[r * k..(r + 1) * k];
                    let mut acc = [0.0f32; 8];
                    for (i, &xi) in x.iter().enumerate() {
                        for (l, a) in acc.iter_mut().enumerate() {
                            *a += xi * w[(j + l) * k + i];
                        }
                    }
                    ys[r * n + j..r * n + j + 8].copy_from_slice(&acc);
                }
                j += 8;
            }
            for j in blocks..n {
                let row = &w[j * k..(j + 1) * k];
                for r in 0..m {
                    let x = &xs[r * k..(r + 1) * k];
                    let mut acc = 0.0f32;
                    for (xi, wji) in x.iter().zip(row) {
                        acc += xi * wji;
                    }
                    ys[r * n + j] = acc;
                }
            }
        }
    }

    /// AVX2 kernels.  Every function carries
    /// `#[target_feature(enable = "avx2")]` and is only reached through
    /// the runtime-dispatch gates above.
    #[cfg(target_arch = "x86_64")]
    mod x86 {
        use std::arch::x86_64::*;

        /// y += a · row, skipping a == 0 exactly like the naive form
        /// (computing `0.0 * NaN` would differ).  mul + add, never FMA.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support; `row.len() == y.len()`.
        #[target_feature(enable = "avx2")]
        unsafe fn axpy(a: f32, row: &[f32], y: &mut [f32]) {
            if a == 0.0 {
                return;
            }
            let n = y.len();
            let av = _mm256_set1_ps(a);
            let lanes = n / 8 * 8;
            let mut j = 0;
            while j < lanes {
                let acc = _mm256_add_ps(
                    _mm256_loadu_ps(y.as_ptr().add(j)),
                    _mm256_mul_ps(av, _mm256_loadu_ps(row.as_ptr().add(j))),
                );
                _mm256_storeu_ps(y.as_mut_ptr().add(j), acc);
                j += 8;
            }
            for j in lanes..n {
                y[j] += a * row[j];
            }
        }

        /// One four-tap block of the blocked-axpy matvec: all-nonzero
        /// blocks vectorize across output columns (each lane is one
        /// `y[j]` chain, updated in the reference's left-to-right
        /// order); any zero tap falls back to per-row [`axpy`] with its
        /// skip.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support; `x.len() >= i + 4`
        /// and `w` must hold rows `i..i+4` of length `y.len()`.
        #[target_feature(enable = "avx2")]
        unsafe fn axpy4(x: &[f32], i: usize, w: &[f32], n: usize, y: &mut [f32]) {
            let (x0, x1, x2, x3) = (x[i], x[i + 1], x[i + 2], x[i + 3]);
            if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                let r0 = w.as_ptr().add(i * n);
                let r1 = w.as_ptr().add((i + 1) * n);
                let r2 = w.as_ptr().add((i + 2) * n);
                let r3 = w.as_ptr().add((i + 3) * n);
                let (v0, v1, v2, v3) = (
                    _mm256_set1_ps(x0),
                    _mm256_set1_ps(x1),
                    _mm256_set1_ps(x2),
                    _mm256_set1_ps(x3),
                );
                let lanes = n / 8 * 8;
                let mut j = 0;
                while j < lanes {
                    let mut acc = _mm256_loadu_ps(y.as_ptr().add(j));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(v0, _mm256_loadu_ps(r0.add(j))));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(v1, _mm256_loadu_ps(r1.add(j))));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(v2, _mm256_loadu_ps(r2.add(j))));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(v3, _mm256_loadu_ps(r3.add(j))));
                    _mm256_storeu_ps(y.as_mut_ptr().add(j), acc);
                    j += 8;
                }
                for j in lanes..n {
                    y[j] = y[j]
                        + x0 * *r0.add(j)
                        + x1 * *r1.add(j)
                        + x2 * *r2.add(j)
                        + x3 * *r3.add(j);
                }
            } else {
                for ii in i..i + 4 {
                    axpy(x[ii], &w[ii * n..(ii + 1) * n], y);
                }
            }
        }

        /// # Safety
        /// Caller must have verified AVX2 support and the
        /// `matvec` shape contract.
        #[target_feature(enable = "avx2")]
        pub unsafe fn matvec(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
            y.fill(0.0);
            let k = x.len();
            let blocks = k / 4 * 4;
            let mut i = 0;
            while i < blocks {
                axpy4(x, i, w, n, y);
                i += 4;
            }
            for i in blocks..k {
                axpy(x[i], &w[i * n..(i + 1) * n], y);
            }
        }

        /// # Safety
        /// Caller must have verified AVX2 support and the
        /// `matmul` shape contract (`m > 0`).
        #[target_feature(enable = "avx2")]
        pub unsafe fn matmul(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
            ys.fill(0.0);
            let k = xs.len() / m;
            let blocks = k / 4 * 4;
            // i-blocks outermost: one pass over each four-row slab of
            // `w` serves all m activation rows (per row, the block
            // order matches the single-row kernel, so rows stay
            // bit-identical to it).
            let mut i = 0;
            while i < blocks {
                for r in 0..m {
                    axpy4(&xs[r * k..(r + 1) * k], i, w, n, &mut ys[r * n..(r + 1) * n]);
                }
                i += 4;
            }
            for i in blocks..k {
                let row = &w[i * n..(i + 1) * n];
                for r in 0..m {
                    axpy(xs[r * k + i], row, &mut ys[r * n..(r + 1) * n]);
                }
            }
        }

        /// Eight dot products at once: rows `j..j+8` of `w` against
        /// `x`, each lane accumulating in ascending-i order (so every
        /// lane reproduces the naive dot bit-for-bit).  Full 8×8 tiles
        /// are loaded row-wise and transposed in registers; the i
        /// remainder gathers one strided lane-load per row.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support; `w` must hold rows
        /// `j..j+8` of length `k == x.len()`.
        #[target_feature(enable = "avx2")]
        unsafe fn dot8(x: &[f32], w: &[f32], k: usize, j: usize) -> __m256 {
            let base = w.as_ptr().add(j * k);
            let mut acc = _mm256_setzero_ps();
            let blocks = k / 8 * 8;
            let mut i = 0;
            while i < blocks {
                let r0 = _mm256_loadu_ps(base.add(i));
                let r1 = _mm256_loadu_ps(base.add(k + i));
                let r2 = _mm256_loadu_ps(base.add(2 * k + i));
                let r3 = _mm256_loadu_ps(base.add(3 * k + i));
                let r4 = _mm256_loadu_ps(base.add(4 * k + i));
                let r5 = _mm256_loadu_ps(base.add(5 * k + i));
                let r6 = _mm256_loadu_ps(base.add(6 * k + i));
                let r7 = _mm256_loadu_ps(base.add(7 * k + i));
                // 8×8 in-register transpose: c_m lane l = w[(j+l)*k + i+m].
                let t0 = _mm256_unpacklo_ps(r0, r1);
                let t1 = _mm256_unpackhi_ps(r0, r1);
                let t2 = _mm256_unpacklo_ps(r2, r3);
                let t3 = _mm256_unpackhi_ps(r2, r3);
                let t4 = _mm256_unpacklo_ps(r4, r5);
                let t5 = _mm256_unpackhi_ps(r4, r5);
                let t6 = _mm256_unpacklo_ps(r6, r7);
                let t7 = _mm256_unpackhi_ps(r6, r7);
                let s0 = _mm256_shuffle_ps::<0x44>(t0, t2);
                let s1 = _mm256_shuffle_ps::<0xEE>(t0, t2);
                let s2 = _mm256_shuffle_ps::<0x44>(t1, t3);
                let s3 = _mm256_shuffle_ps::<0xEE>(t1, t3);
                let s4 = _mm256_shuffle_ps::<0x44>(t4, t6);
                let s5 = _mm256_shuffle_ps::<0xEE>(t4, t6);
                let s6 = _mm256_shuffle_ps::<0x44>(t5, t7);
                let s7 = _mm256_shuffle_ps::<0xEE>(t5, t7);
                let c0 = _mm256_permute2f128_ps::<0x20>(s0, s4);
                let c1 = _mm256_permute2f128_ps::<0x20>(s1, s5);
                let c2 = _mm256_permute2f128_ps::<0x20>(s2, s6);
                let c3 = _mm256_permute2f128_ps::<0x20>(s3, s7);
                let c4 = _mm256_permute2f128_ps::<0x31>(s0, s4);
                let c5 = _mm256_permute2f128_ps::<0x31>(s1, s5);
                let c6 = _mm256_permute2f128_ps::<0x31>(s2, s6);
                let c7 = _mm256_permute2f128_ps::<0x31>(s3, s7);
                // Ascending-i accumulation, one mul + one add per step.
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i]), c0));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i + 1]), c1));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i + 2]), c2));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i + 3]), c3));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i + 4]), c4));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i + 5]), c5));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i + 6]), c6));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i + 7]), c7));
                i += 8;
            }
            for i in blocks..k {
                let wv = _mm256_set_ps(
                    *base.add(7 * k + i),
                    *base.add(6 * k + i),
                    *base.add(5 * k + i),
                    *base.add(4 * k + i),
                    *base.add(3 * k + i),
                    *base.add(2 * k + i),
                    *base.add(k + i),
                    *base.add(i),
                );
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(x[i]), wv));
            }
            acc
        }

        /// Transposed product, batched (m = 1 is `matvec_t`): j-blocks
        /// of eight outermost so each eight-row slab of `w` is streamed
        /// once for all m activation rows.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support and the
        /// `matmul_t` shape contract (`m > 0`).
        #[target_feature(enable = "avx2")]
        pub unsafe fn matmul_t(xs: &[f32], m: usize, w: &[f32], n: usize, ys: &mut [f32]) {
            let k = xs.len() / m;
            let blocks = n / 8 * 8;
            let mut j = 0;
            while j < blocks {
                for r in 0..m {
                    let acc = dot8(&xs[r * k..(r + 1) * k], w, k, j);
                    _mm256_storeu_ps(ys.as_mut_ptr().add(r * n + j), acc);
                }
                j += 8;
            }
            for j in blocks..n {
                let row = &w[j * k..(j + 1) * k];
                for r in 0..m {
                    let x = &xs[r * k..(r + 1) * k];
                    let mut acc = 0.0f32;
                    for (xi, wji) in x.iter().zip(row) {
                        acc += xi * wji;
                    }
                    ys[r * n + j] = acc;
                }
            }
        }

        /// Exact int8 dot product, 32 bytes per step, via the
        /// unsigned·signed multiply-add idiom: `|a| · sign(b, a)` feeds
        /// `_mm256_maddubs_epi16` (u8 × i8 → pairwise i16 sums), then
        /// `_mm256_madd_epi16` against ones widens to i32.  Exact
        /// because quantized values never reach −128 (`quantize_row`
        /// clamps to ±127): `|a| ≤ 127` fits u8 without the `abs(−128)`
        /// wrap, `sign` never overflows, and each i16 pair sum is at
        /// most `2·127·127 = 32258 < i16::MAX` — no saturation, and
        /// i32 accumulation is order-free, so the result equals the
        /// scalar reference bit-for-bit.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support; `a.len() == b.len()`.
        #[target_feature(enable = "avx2")]
        unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
            let k = a.len();
            let ones = _mm256_set1_epi16(1);
            let mut acc = _mm256_setzero_si256();
            let blocks = k / 32 * 32;
            let mut i = 0;
            while i < blocks {
                let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
                let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
                let abs_a = _mm256_sign_epi8(va, va);
                let sb = _mm256_sign_epi8(vb, va);
                let p16 = _mm256_maddubs_epi16(abs_a, sb);
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(p16, ones));
                i += 32;
            }
            // Horizontal sum of the eight i32 lanes (exact: integers).
            let s = _mm_add_epi32(_mm256_castsi256_si128(acc), _mm256_extracti128_si256::<1>(acc));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4E>(s));
            let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s));
            let mut sum = _mm_cvtsi128_si32(s);
            for i in blocks..k {
                sum += a[i] as i32 * b[i] as i32;
            }
            sum
        }

        /// # Safety
        /// Caller must have verified AVX2 support and the `matvec_q`
        /// shape contract (out-major `wq: [n, k]`, values in ±127).
        #[target_feature(enable = "avx2")]
        pub unsafe fn matvec_q(qx: &[i8], sx: f32, wq: &[i8], scales: &[f32], y: &mut [f32]) {
            let k = qx.len();
            for (j, yj) in y.iter_mut().enumerate() {
                let sum = dot_i8(qx, &wq[j * k..(j + 1) * k]);
                *yj = super::super::scale_out(sum, sx, scales[j]);
            }
        }

        /// Batched [`matvec_q`]: weight rows outermost so each int8 row
        /// (and its scale) streams through cache once for all m
        /// activation rows.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support and the `matmul_q`
        /// shape contract (`m > 0`, values in ±127).
        #[target_feature(enable = "avx2")]
        pub unsafe fn matmul_q(
            qxs: &[i8],
            m: usize,
            sxs: &[f32],
            wq: &[i8],
            scales: &[f32],
            ys: &mut [f32],
        ) {
            let k = qxs.len() / m;
            let n = scales.len();
            for (j, &sw) in scales.iter().enumerate() {
                let row = &wq[j * k..(j + 1) * k];
                for r in 0..m {
                    let sum = dot_i8(&qxs[r * k..(r + 1) * k], row);
                    ys[r * n + j] = super::super::scale_out(sum, sxs[r], sw);
                }
            }
        }

        /// Exact int4 group-dot row core: each full 32-element group is
        /// one 16-byte packed load, nibble-split (`& 0x0F` / logical
        /// shift then mask), interleaved back to element order with
        /// `unpacklo/hi_epi8` (even elements come from low nibbles),
        /// sign-extended from 4-bit two's complement via `(n ^ 8) − 8`,
        /// then fed through the same unsigned·signed maddubs idiom as
        /// [`dot_i8`].  Pair sums stay ≤ 2·127·7 = 1778 (exact), each
        /// group's i32 sum is horizontally reduced (exact), and group
        /// sums fold into f32 in ascending order through `scale_out` —
        /// bit-identical to the scalar reference.  A partial tail group
        /// (k % 32 ≠ 0) runs the scalar core.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support; `row` must hold
        /// `⌈k/2⌉` packed bytes and `srow` one scale per group.
        #[target_feature(enable = "avx2")]
        unsafe fn q4_dot(qx: &[i8], row: &[u8], srow: &[f32], sx: f32) -> f32 {
            let k = qx.len();
            let ones = _mm256_set1_epi16(1);
            let nib_mask = _mm_set1_epi8(0x0F);
            let sign_bit = _mm256_set1_epi8(8);
            let full = k / super::super::Q4_GROUP;
            let mut acc = 0.0f32;
            for g in 0..full {
                let packed = _mm_loadu_si128(row.as_ptr().add(g * 16) as *const __m128i);
                let lo = _mm_and_si128(packed, nib_mask);
                let hi = _mm_and_si128(_mm_srli_epi16::<4>(packed), nib_mask);
                // Interleave to element order: byte b holds elements
                // (2b, 2b+1) as (low, high) nibble.
                let b0 = _mm_unpacklo_epi8(lo, hi);
                let b1 = _mm_unpackhi_epi8(lo, hi);
                let w = _mm256_set_m128i(b1, b0);
                let w = _mm256_sub_epi8(_mm256_xor_si256(w, sign_bit), sign_bit);
                let vx = _mm256_loadu_si256(qx.as_ptr().add(g * 32) as *const __m256i);
                let abs_x = _mm256_sign_epi8(vx, vx);
                let sw = _mm256_sign_epi8(w, vx);
                let p16 = _mm256_maddubs_epi16(abs_x, sw);
                let s32 = _mm256_madd_epi16(p16, ones);
                // Horizontal sum of the eight i32 lanes (exact).
                let s = _mm_add_epi32(
                    _mm256_castsi256_si128(s32),
                    _mm256_extracti128_si256::<1>(s32),
                );
                let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0x4E>(s));
                let s = _mm_add_epi32(s, _mm_shuffle_epi32::<0xB1>(s));
                let sum = _mm_cvtsi128_si32(s);
                acc += super::super::scale_out(sum, sx, srow[g]);
            }
            let lo_i = full * super::super::Q4_GROUP;
            if lo_i < k {
                let mut sum = 0i32;
                for i in lo_i..k {
                    sum += qx[i] as i32 * super::super::q4_get(row, i);
                }
                acc += super::super::scale_out(sum, sx, srow[full]);
            }
            acc
        }

        /// # Safety
        /// Caller must have verified AVX2 support and the `matvec_q4`
        /// shape contract (out-major packed `wq: [n, ⌈k/2⌉]`,
        /// `scales: [n, ⌈k/32⌉]`, activation values in ±127).
        #[target_feature(enable = "avx2")]
        pub unsafe fn matvec_q4(qx: &[i8], sx: f32, wq: &[u8], scales: &[f32], y: &mut [f32]) {
            let kb = super::super::q4_row_bytes(qx.len());
            let groups = super::super::q4_row_groups(qx.len());
            for (j, yj) in y.iter_mut().enumerate() {
                let row = &wq[j * kb..(j + 1) * kb];
                let srow = &scales[j * groups..(j + 1) * groups];
                *yj = q4_dot(qx, row, srow, sx);
            }
        }

        /// Batched [`matvec_q4`]: weight rows outermost so each packed
        /// row (and its scale group) streams through cache once for
        /// all m activation rows.
        ///
        /// # Safety
        /// Caller must have verified AVX2 support and the `matmul_q4`
        /// shape contract (`m > 0`).
        #[target_feature(enable = "avx2")]
        pub unsafe fn matmul_q4(
            qxs: &[i8],
            m: usize,
            sxs: &[f32],
            wq: &[u8],
            scales: &[f32],
            ys: &mut [f32],
        ) {
            let k = qxs.len() / m;
            let kb = super::super::q4_row_bytes(k);
            let groups = super::super::q4_row_groups(k);
            let n = ys.len() / m;
            for j in 0..n {
                let row = &wq[j * kb..(j + 1) * kb];
                let srow = &scales[j * groups..(j + 1) * groups];
                for r in 0..m {
                    let sum = q4_dot(&qxs[r * k..(r + 1) * k], row, srow, sxs[r]);
                    ys[r * n + j] = sum;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Elementwise ops
// ---------------------------------------------------------------------------

/// In-place y += x.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += b;
    }
}

/// LayerNorm with learned gain/bias (eps matches the L2 model's 1e-5).
pub fn layer_norm(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}

pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

pub fn tanh_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// Numerically-stable in-place softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    for v in x.iter_mut() {
        *v /= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_small() {
        // x: [2], W: [2, 3] = [[1,2,3],[4,5,6]] → y = [1*1+2*4, 1*2+2*5, 1*3+2*6]
        let x = [1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut y = [0.0; 3];
        matvec(&x, &w, 3, &mut y);
        assert_eq!(y, [9.0, 12.0, 15.0]);
    }

    /// Deterministic awkward test shapes: remainders in every blocking
    /// width (4 and 8), plus sprinkled zeros for the sparsity skip.
    fn fixture(k: usize, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..k)
            .map(|i| if i % 5 == 2 { 0.0 } else { 0.37 * (i as f32) - 1.9 })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|i| 0.11 * ((i * 7 % 23) as f32) - 1.2).collect();
        let wt: Vec<f32> = (0..n * k).map(|i| 0.09 * ((i * 5 % 19) as f32) - 0.8).collect();
        (x, w, wt)
    }

    fn assert_bits_eq(fast: &[f32], slow: &[f32], what: &str) {
        for (i, (a, b)) in fast.iter().zip(slow).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{what} diverged from reference at {i}");
        }
    }

    #[test]
    fn dispatched_and_blocked_match_naive_bit_for_bit() {
        for (k, n) in [(13, 11), (16, 24), (7, 3), (29, 17), (8, 8)] {
            let (x, w, wt) = fixture(k, n);
            let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
            matvec_naive(&x, &w, n, &mut slow);
            matvec(&x, &w, n, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec");
            matvec_blocked(&x, &w, n, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_blocked");

            matvec_t_naive(&x, &wt, n, &mut slow);
            matvec_t(&x, &wt, n, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_t");
            matvec_t_blocked(&x, &wt, n, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_t_blocked");
        }
    }

    #[test]
    fn batched_rows_match_single_row_calls_bit_for_bit() {
        for (m, k, n) in [(1, 13, 11), (5, 16, 24), (9, 7, 3), (3, 8, 8)] {
            let xs: Vec<f32> = (0..m * k)
                .map(|i| if i % 7 == 3 { 0.0 } else { 0.21 * (i as f32) - 1.4 })
                .collect();
            let (_, w, wt) = fixture(k, n);
            let mut batch = vec![0.0f32; m * n];
            let mut rows = vec![0.0f32; m * n];

            matmul(&xs, m, &w, n, &mut batch);
            matmul_naive(&xs, m, &w, n, &mut rows);
            assert_bits_eq(&batch, &rows, "matmul");
            matmul_blocked(&xs, m, &w, n, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_blocked");

            matmul_t(&xs, m, &wt, n, &mut batch);
            matmul_t_naive(&xs, m, &wt, n, &mut rows);
            assert_bits_eq(&batch, &rows, "matmul_t");
            matmul_t_blocked(&xs, m, &wt, n, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_t_blocked");
        }
    }

    #[test]
    fn empty_batch_and_empty_shapes_are_noops() {
        matmul(&[], 0, &[], 5, &mut []);
        matmul_t(&[], 0, &[], 5, &mut []);
        let mut y = [0.0f32; 0];
        matvec(&[], &[], 0, &mut y);
        matvec_t(&[], &[], 0, &mut y);
        // k = 0 with outputs: matmul zeroes, matmul_t writes zero dots.
        let mut ys = [7.0f32; 6];
        matmul(&[], 2, &[], 3, &mut ys);
        assert_eq!(ys, [0.0; 6]);
        let mut ys = [7.0f32; 6];
        matmul_t(&[], 2, &[], 3, &mut ys);
        assert_eq!(ys, [0.0; 6]);
    }

    #[test]
    fn non_finite_and_signed_zero_semantics_match_naive() {
        // A zero tap against a NaN weight row must be *skipped* (0 * NaN
        // is NaN — the skip is semantic, not just a fast path), and
        // negative zero must count as zero.
        let k = 9;
        let n = 10;
        let mut x: Vec<f32> = (0..k).map(|i| 0.3 * i as f32 - 1.0).collect();
        x[2] = 0.0;
        x[3] = -0.0;
        x[7] = f32::NAN;
        let mut w = vec![0.5f32; k * n];
        for j in 0..n {
            w[2 * n + j] = f32::NAN;
            w[3 * n + j] = f32::INFINITY;
        }
        let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
        matvec_naive(&x, &w, n, &mut slow);
        matvec(&x, &w, n, &mut fast);
        assert_bits_eq(&fast, &slow, "matvec with NaN/±0.0");
        assert!(slow.iter().all(|v| v.is_nan()), "NaN tap must propagate");

        let mut wt = vec![0.25f32; n * k];
        wt[5] = f32::NEG_INFINITY;
        matvec_t_naive(&x, &wt, n, &mut slow);
        matvec_t(&x, &wt, n, &mut fast);
        assert_bits_eq(&fast, &slow, "matvec_t with NaN/±0.0");
    }

    #[test]
    fn kernel_backend_is_stable() {
        let b = kernel_backend();
        assert!(["scalar", "avx2", "portable"].contains(&b), "unknown backend {b}");
        assert_eq!(b, kernel_backend());
    }

    #[test]
    fn matvec_t_is_transpose_of_matvec() {
        let x = [0.5, -1.0, 2.0];
        let w = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2, 3] as n=2, k=3 for matvec_t
        let mut yt = [0.0; 2];
        matvec_t(&x, &w, 2, &mut yt);
        // row0 · x = 1*0.5 + 2*-1 + 3*2 = 4.5 ; row1 · x = 4*0.5 + 5*-1 + 6*2 = 9
        assert_eq!(yt, [4.5, 9.0]);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let g = [1.0; 4];
        let b = [0.0; 4];
        let mut out = [0.0; 4];
        layer_norm(&x, &g, &b, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = [1000.0, 1001.0, 999.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn relu_and_tanh() {
        let mut x = [-1.0, 0.5];
        relu_inplace(&mut x);
        assert_eq!(x, [0.0, 0.5]);
        let mut y = [0.0f32, 100.0];
        tanh_inplace(&mut y);
        assert!((y[0]).abs() < 1e-7 && (y[1] - 1.0).abs() < 1e-5);
    }

    /// Deterministic quantized fixture: f32 rows pushed through
    /// [`quantize_row`] exactly as the engine does it.
    fn qfixture(k: usize, n: usize) -> (Vec<i8>, f32, Vec<i8>, Vec<f32>) {
        let x: Vec<f32> = (0..k).map(|i| 0.37 * (i as f32) - 1.9).collect();
        let mut qx = vec![0i8; k];
        let sx = quantize_row(&x, &mut qx);
        let mut wq = vec![0i8; n * k];
        let mut scales = vec![0.0f32; n];
        for j in 0..n {
            let row: Vec<f32> =
                (0..k).map(|i| 0.11 * (((j * k + i) * 7 % 23) as f32) - 1.2).collect();
            scales[j] = quantize_row(&row, &mut wq[j * k..(j + 1) * k]);
        }
        (qx, sx, wq, scales)
    }

    #[test]
    fn quantize_row_bounds_and_roundtrip() {
        let x: Vec<f32> = (0..33).map(|i| 0.4 * (i as f32) - 6.0).collect();
        let mut q = vec![0i8; 33];
        let s = quantize_row(&x, &mut q);
        assert!(s > 0.0);
        assert_eq!(q.iter().map(|v| v.abs()).max().unwrap(), 127, "max row value maps to ±127");
        for (&xi, &qi) in x.iter().zip(&q) {
            assert!(qi != i8::MIN, "−128 must never be emitted");
            assert!(
                (xi - qi as f32 * s).abs() <= 0.5 * s + 1e-6,
                "round-trip error above half a step: {xi} vs {} (scale {s})",
                qi as f32 * s
            );
        }
        // Degenerate rows: all-zero and non-finite-max both quantize to
        // zeros with scale 0.
        let mut q = vec![7i8; 4];
        assert_eq!(quantize_row(&[0.0, -0.0, 0.0, 0.0], &mut q), 0.0);
        assert_eq!(q, vec![0i8; 4]);
        let mut q = vec![7i8; 2];
        assert_eq!(quantize_row(&[f32::INFINITY, 1.0], &mut q), 0.0);
        assert_eq!(q, vec![0i8; 2]);
        // NaN never wins the max scan (`NaN > maxabs` is false), so a
        // NaN entry under a finite max quantizes to 0 with the scale
        // set by the finite values.
        let mut q = vec![7i8; 2];
        assert_eq!(quantize_row(&[f32::NAN, 1.0], &mut q), 1.0 / 127.0);
        assert_eq!(q, vec![0i8, 127]);
    }

    #[test]
    fn int8_tiers_match_naive_bit_for_bit() {
        for (k, n) in [(13, 11), (16, 24), (7, 3), (33, 8), (64, 5), (1, 1)] {
            let (qx, sx, wq, scales) = qfixture(k, n);
            let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
            matvec_q_naive(&qx, sx, &wq, &scales, &mut slow);
            matvec_q_blocked(&qx, sx, &wq, &scales, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_q_blocked");
            fast.fill(7.0);
            matvec_q(&qx, sx, &wq, &scales, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_q dispatched");
            fast.fill(7.0);
            matvec_t_q(&qx, sx, &wq, &scales, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_t_q alias");
        }
    }

    #[test]
    fn int8_batched_rows_match_single_row_calls() {
        for (m, k, n) in [(1, 13, 11), (5, 16, 24), (9, 7, 3), (3, 64, 8)] {
            let (_, _, wq, scales) = qfixture(k, n);
            let mut qxs = vec![0i8; m * k];
            let mut sxs = vec![0.0f32; m];
            for r in 0..m {
                let x: Vec<f32> = (0..k).map(|i| 0.21 * ((r * k + i) as f32) - 1.4).collect();
                sxs[r] = quantize_row(&x, &mut qxs[r * k..(r + 1) * k]);
            }
            let mut rows = vec![0.0f32; m * n];
            for r in 0..m {
                matvec_q_naive(
                    &qxs[r * k..(r + 1) * k],
                    sxs[r],
                    &wq,
                    &scales,
                    &mut rows[r * n..(r + 1) * n],
                );
            }
            let mut batch = vec![7.0f32; m * n];
            matmul_q_naive(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_q_naive");
            batch.fill(7.0);
            matmul_q_blocked(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_q_blocked");
            batch.fill(7.0);
            matmul_q(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_q dispatched");
            batch.fill(7.0);
            matmul_t_q(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_t_q alias");
        }
        // Empty batch is a no-op for the dispatched forms.
        matmul_q(&[], 0, &[], &[], &[0.5], &mut []);
        matmul_t_q(&[], 0, &[], &[], &[0.5], &mut []);
    }

    #[test]
    fn int8_saturated_values_stay_exact_across_tiers() {
        // Hand-built ±127 saturation (the maddubs pair-sum worst case)
        // with extreme scales: every tier must agree bit-for-bit.
        let k = 35; // 32-lane AVX2 block + remainder
        let n = 9;
        let qx: Vec<i8> = (0..k).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
        let wq: Vec<i8> = (0..n * k).map(|i| if i % 3 == 0 { -127 } else { 127 }).collect();
        for sx in [1.0e-30f32, 1.0, 3.4e30] {
            for sw in [1.0e-30f32, 0.7, 3.4e30] {
                let scales = vec![sw; n];
                let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
                matvec_q_naive(&qx, sx, &wq, &scales, &mut slow);
                matvec_q_blocked(&qx, sx, &wq, &scales, &mut fast);
                assert_bits_eq(&fast, &slow, "saturated blocked");
                fast.fill(7.0);
                matvec_q(&qx, sx, &wq, &scales, &mut fast);
                assert_bits_eq(&fast, &slow, "saturated dispatched");
            }
        }
    }

    /// Deterministic int4 fixture: f32 rows pushed through
    /// [`quantize_row_q4`] exactly as weight load does it, activation
    /// through [`quantize_row`] exactly as the engine does it.
    fn q4fixture(k: usize, n: usize) -> (Vec<i8>, f32, Vec<u8>, Vec<f32>) {
        let x: Vec<f32> = (0..k).map(|i| 0.37 * (i as f32) - 1.9).collect();
        let mut qx = vec![0i8; k];
        let sx = quantize_row(&x, &mut qx);
        let kb = q4_row_bytes(k);
        let groups = q4_row_groups(k);
        let mut wq = vec![0u8; n * kb];
        let mut scales = vec![0.0f32; n * groups];
        for j in 0..n {
            let row: Vec<f32> =
                (0..k).map(|i| 0.11 * (((j * k + i) * 7 % 23) as f32) - 1.2).collect();
            quantize_row_q4(
                &row,
                &mut wq[j * kb..(j + 1) * kb],
                &mut scales[j * groups..(j + 1) * groups],
            );
        }
        (qx, sx, wq, scales)
    }

    #[test]
    fn quantize_row_q4_bounds_and_roundtrip() {
        // 65 elements: two full groups plus a one-element tail group.
        let x: Vec<f32> = (0..65).map(|i| 0.4 * (i as f32) - 6.0).collect();
        let mut q = vec![0u8; q4_row_bytes(65)];
        let mut s = vec![0.0f32; q4_row_groups(65)];
        quantize_row_q4(&x, &mut q, &mut s);
        assert_eq!(s.len(), 3);
        for (g, &sg) in s.iter().enumerate() {
            assert!(sg > 0.0, "group {g} scale");
        }
        let mut max_nib = 0i32;
        for (i, &xi) in x.iter().enumerate() {
            let v = super::q4_get(&q, i);
            assert!((-7..=7).contains(&v), "−8 must never be emitted (got {v})");
            max_nib = max_nib.max(v.abs());
            let back = v as f32 * s[i / Q4_GROUP];
            assert!(
                (xi - back).abs() <= 0.5 * s[i / Q4_GROUP] + 1e-6,
                "round-trip error above half a step: {xi} vs {back}"
            );
        }
        assert_eq!(max_nib, 7, "each group's max maps to ±7");
        // Degenerate groups: all-zero and non-finite-max both quantize
        // to zero nibbles with scale 0; NaN never wins the max scan, so
        // a NaN under a finite max quantizes to 0 with the finite
        // values' scale — all per group, matching [`quantize_row`]'s
        // per-row contract.
        let mut q = vec![0xFFu8; q4_row_bytes(4)];
        let mut s = vec![7.0f32; 1];
        quantize_row_q4(&[0.0, -0.0, 0.0, 0.0], &mut q, &mut s);
        assert_eq!((q, s), (vec![0u8; 2], vec![0.0f32]));
        let mut q = vec![0xFFu8; 1];
        let mut s = vec![7.0f32; 1];
        quantize_row_q4(&[f32::INFINITY, 1.0], &mut q, &mut s);
        assert_eq!((q, s), (vec![0u8; 1], vec![0.0f32]));
        let mut q = vec![0u8; 1];
        let mut s = vec![0.0f32; 1];
        quantize_row_q4(&[f32::NAN, 3.0], &mut q, &mut s);
        assert_eq!(super::q4_get(&q, 0), 0, "NaN under a finite max quantizes to 0");
        assert_eq!(super::q4_get(&q, 1), 7);
        assert_eq!(s, vec![3.0 / 7.0]);
    }

    #[test]
    fn int4_tiers_match_naive_bit_for_bit() {
        // Shapes straddle group boundaries: k % 32 ∈ {0, 1, 31, ±1 of
        // a boundary} plus odd k (half-filled final byte).
        for (k, n) in [(13, 11), (31, 8), (32, 8), (33, 8), (64, 5), (65, 3), (96, 4), (1, 1)] {
            let (qx, sx, wq, scales) = q4fixture(k, n);
            let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
            matvec_q4_naive(&qx, sx, &wq, &scales, &mut slow);
            matvec_q4_blocked(&qx, sx, &wq, &scales, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_q4_blocked");
            fast.fill(7.0);
            matvec_q4(&qx, sx, &wq, &scales, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_q4 dispatched");
            fast.fill(7.0);
            matvec_t_q4(&qx, sx, &wq, &scales, &mut fast);
            assert_bits_eq(&fast, &slow, "matvec_t_q4 alias");
        }
    }

    #[test]
    fn int4_batched_rows_match_single_row_calls() {
        for (m, k, n) in [(1, 13, 11), (5, 33, 24), (9, 7, 3), (3, 64, 8), (2, 96, 5)] {
            let kb = q4_row_bytes(k);
            let groups = q4_row_groups(k);
            let (_, _, wq, scales) = q4fixture(k, n);
            let mut qxs = vec![0i8; m * k];
            let mut sxs = vec![0.0f32; m];
            for r in 0..m {
                let x: Vec<f32> = (0..k).map(|i| 0.21 * ((r * k + i) as f32) - 1.4).collect();
                sxs[r] = quantize_row(&x, &mut qxs[r * k..(r + 1) * k]);
            }
            let mut rows = vec![0.0f32; m * n];
            for r in 0..m {
                matvec_q4_naive(
                    &qxs[r * k..(r + 1) * k],
                    sxs[r],
                    &wq,
                    &scales,
                    &mut rows[r * n..(r + 1) * n],
                );
            }
            assert_eq!(wq.len(), n * kb);
            assert_eq!(scales.len(), n * groups);
            let mut batch = vec![7.0f32; m * n];
            matmul_q4_naive(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_q4_naive");
            batch.fill(7.0);
            matmul_q4_blocked(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_q4_blocked");
            batch.fill(7.0);
            matmul_q4(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_q4 dispatched");
            batch.fill(7.0);
            matmul_t_q4(&qxs, m, &sxs, &wq, &scales, &mut batch);
            assert_bits_eq(&batch, &rows, "matmul_t_q4 alias");
        }
        // Empty batch is a no-op for the dispatched forms.
        matmul_q4(&[], 0, &[], &[], &[0.5], &mut []);
        matmul_t_q4(&[], 0, &[], &[], &[0.5], &mut []);
    }

    #[test]
    fn int4_saturated_groups_stay_exact_across_tiers() {
        // Hand-built ±7 nibbles against ±127 activations (the maddubs
        // pair-sum worst case for this tier) with extreme scales:
        // every tier must agree bit-for-bit, including the mixed-sign
        // group-fold in f32.
        let k = 35; // one full 32-element group + a 3-element tail
        let n = 9;
        let qx: Vec<i8> = (0..k).map(|i| if i % 2 == 0 { 127 } else { -127 }).collect();
        let kb = q4_row_bytes(k);
        let groups = q4_row_groups(k);
        let mut wq = vec![0u8; n * kb];
        for (i, b) in wq.iter_mut().enumerate() {
            // low nibble 7, high nibble −7 (0b1001), alternating.
            *b = if i % 3 == 0 { 0x97 } else { 0x79 };
        }
        for sx in [1.0e-30f32, 1.0, 3.4e30] {
            for sw in [1.0e-30f32, 0.7, 3.4e30] {
                let scales = vec![sw; n * groups];
                let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
                matvec_q4_naive(&qx, sx, &wq, &scales, &mut slow);
                matvec_q4_blocked(&qx, sx, &wq, &scales, &mut fast);
                assert_bits_eq(&fast, &slow, "int4 saturated blocked");
                fast.fill(7.0);
                matvec_q4(&qx, sx, &wq, &scales, &mut fast);
                assert_bits_eq(&fast, &slow, "int4 saturated dispatched");
            }
        }
    }

    #[test]
    fn int4_zero_scale_groups_contribute_nothing() {
        // A group with scale 0 (degenerate at quantization time) must
        // contribute exactly +0.0 in every tier, even when its nibbles
        // are nonzero garbage.
        let k = 64;
        let n = 4;
        let qx = vec![64i8; k];
        let wq = vec![0x57u8; n * q4_row_bytes(k)];
        let groups = q4_row_groups(k);
        let mut scales = vec![0.5f32; n * groups];
        for j in 0..n {
            scales[j * groups] = 0.0; // first group of every row dead
        }
        let (mut fast, mut slow) = (vec![0.0f32; n], vec![0.0f32; n]);
        matvec_q4_naive(&qx, 0.25, &wq, &scales, &mut slow);
        matvec_q4(&qx, 0.25, &wq, &scales, &mut fast);
        assert_bits_eq(&fast, &slow, "zero-scale group");
        let all_dead = vec![0.0f32; n * groups];
        matvec_q4_naive(&qx, 0.25, &wq, &all_dead, &mut slow);
        assert!(slow.iter().all(|v| v.to_bits() == 0), "all-dead rows give +0.0");
    }
}
